"""Grid push-relabel kernel vs the numpy oracle.

The single most important correctness signal of the build path: the Pallas
kernel (interpret=True) must be *bit-exact* against the loop-and-snapshot
oracle in kernels/ref.py, wave for wave, on both reachable and adversarial
states.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.grid_wave import make_grid_kernel, wave
from tests.conftest import random_grid_instance, random_midstate_grid


def run_ref_waves(h, e, cap, cs, csrc, k):
    """k waves of the oracle with early exit, mirroring the kernel loop."""
    tot = dict(sf=0, bf=0, pu=0, rl=0, waves=0)
    for _ in range(k):
        if not (np.asarray(e) > 0).any():
            break
        h, e, cap, cs, csrc, sf, bf, pu, rl = ref.grid_wave_ref(h, e, cap, cs, csrc)
        tot["sf"] += sf
        tot["bf"] += bf
        tot["pu"] += pu
        tot["rl"] += rl
        tot["waves"] += 1
    return h, e, cap, cs, csrc, tot


def assert_state_equal(kernel_out, ref_out, what=""):
    names = ["h", "e", "cap", "cap_sink", "cap_src"]
    for name, a, b in zip(names, kernel_out, ref_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=f"{what}:{name}")


class TestSingleWave:
    @pytest.mark.parametrize("seed", range(8))
    @pytest.mark.parametrize("shape", [(3, 3), (4, 6), (8, 8)])
    def test_wave_matches_ref_on_fresh_instance(self, seed, shape):
        rng = np.random.default_rng(seed)
        h, e, cap, cs, csrc, _ = random_grid_instance(rng, *shape)
        got = wave(jnp.array(h), jnp.array(e), jnp.array(cap), jnp.array(cs), jnp.array(csrc), shape[0] * shape[1] + 2)
        want = ref.grid_wave_ref(h, e, cap, cs, csrc)
        assert_state_equal(got[:5], want[:5], f"seed={seed}")
        assert (int(got[5]), int(got[6]), int(got[7]), int(got[8])) == want[5:]

    @pytest.mark.parametrize("seed", range(8))
    def test_wave_matches_ref_on_adversarial_midstate(self, seed):
        rng = np.random.default_rng(1000 + seed)
        state = random_midstate_grid(rng, 5, 7)
        got = wave(*(jnp.array(a) for a in state), 5 * 7 + 2)
        want = ref.grid_wave_ref(*state)
        assert_state_equal(got[:5], want[:5], f"adv seed={seed}")

    def test_wave_no_active_nodes_is_identity(self):
        h = np.zeros((4, 4), np.int32)
        e = np.zeros((4, 4), np.int32)
        cap = np.ones((4, 4, 4), np.int32)
        cs = np.ones((4, 4), np.int32)
        csrc = np.zeros((4, 4), np.int32)
        got = wave(jnp.array(h), jnp.array(e), jnp.array(cap), jnp.array(cs), jnp.array(csrc), 18)
        assert_state_equal(got[:5], (h, e, cap, cs, csrc))
        assert int(got[7]) == 0 and int(got[8]) == 0

    def test_wave_single_active_pushes_to_sink(self):
        # One active node with a sink arc: must push min(e, cap) to the sink.
        h = np.array([[1]], np.int32)
        e = np.array([[5]], np.int32)
        cap = np.zeros((4, 1, 1), np.int32)
        cs = np.array([[3]], np.int32)
        csrc = np.array([[5]], np.int32)
        out = wave(jnp.array(h), jnp.array(e), jnp.array(cap), jnp.array(cs), jnp.array(csrc), 3)
        assert int(out[5]) == 3  # sink_flow
        assert np.asarray(out[1])[0, 0] == 2  # leftover excess
        assert np.asarray(out[3])[0, 0] == 0  # sink arc saturated

    def test_wave_relabel_when_no_lower_neighbour(self):
        # Active node whose only residual neighbour is higher -> relabel.
        h = np.array([[2, 5]], np.int32)
        e = np.array([[4, 0]], np.int32)
        cap = np.zeros((4, 1, 2), np.int32)
        cap[3, 0, 0] = 9  # east arc to the higher neighbour
        cs = np.zeros((1, 2), np.int32)
        csrc = np.zeros((1, 2), np.int32)
        out = wave(jnp.array(h), jnp.array(e), jnp.array(cap), jnp.array(cs), jnp.array(csrc), 4)
        assert np.asarray(out[0])[0, 0] == 6  # h = h(nb) + 1
        assert int(out[8]) == 1


class TestKernelMultiWave:
    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("k_inner", [1, 3, 16])
    def test_kernel_equals_k_ref_waves(self, seed, k_inner):
        rng = np.random.default_rng(seed)
        H, W = 6, 6
        h, e, cap, cs, csrc, _ = random_grid_instance(rng, H, W)
        kern = make_grid_kernel(H, W, k_inner=k_inner)
        got = kern(jnp.array(h), jnp.array(e), jnp.array(cap), jnp.array(cs), jnp.array(csrc))
        want = run_ref_waves(h, e, cap, cs, csrc, k_inner)
        assert_state_equal(got[:5], want[:5], f"k={k_inner}")
        stats = np.asarray(got[5])
        tot = want[5]
        assert stats[0] == tot["sf"] and stats[1] == tot["bf"]
        assert stats[3] == tot["pu"] and stats[4] == tot["rl"]
        assert stats[5] == tot["waves"]

    def test_kernel_early_exit_when_quiescent(self):
        # Already-quiescent instance: zero waves run.
        H = W = 4
        kern = make_grid_kernel(H, W, k_inner=8)
        z = jnp.zeros((H, W), jnp.int32)
        got = kern(z, z, jnp.zeros((4, H, W), jnp.int32), z, z)
        assert int(np.asarray(got[5])[5]) == 0  # waves


class TestEndToEnd:
    @pytest.mark.parametrize("seed", range(6))
    def test_wave_solve_equals_ford_fulkerson(self, seed):
        rng = np.random.default_rng(seed)
        H, W = (4, 4) if seed % 2 else (5, 3)
        h, e, cap, cs, csrc, src_exc = random_grid_instance(rng, H, W)
        sink_total, src_total, *_ = ref.grid_solve_ref(h, e, cap, cs, csrc)
        n, edges, s, t = ref.grid_to_edges(cap, cs, src_exc)
        assert sink_total == ref.ford_fulkerson(n, edges, s, t)
        # Conservation: everything injected either reached t or returned to s.
        assert sink_total + src_total == int(src_exc.sum())

    def test_kernel_solve_equals_ford_fulkerson(self):
        rng = np.random.default_rng(7)
        H = W = 5
        h, e, cap, cs, csrc, src_exc = random_grid_instance(rng, H, W)
        kern = make_grid_kernel(H, W, k_inner=16)
        state = [jnp.array(a) for a in (h, e, cap, cs, csrc)]
        sink_total = 0
        for _ in range(2000):
            *state, stats = kern(*state)
            stats = np.asarray(stats)
            sink_total += int(stats[0])
            if stats[2] == 0:
                break
        else:
            pytest.fail("kernel did not converge")
        n, edges, s, t = ref.grid_to_edges(cap, cs, src_exc)
        assert sink_total == ref.ford_fulkerson(n, edges, s, t)


class TestWaveInvariants:
    """Hypothesis: invariants hold on arbitrary random mid-states."""

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        height=st.integers(2, 6),
        width=st.integers(2, 6),
    )
    def test_wave_preserves_mass_and_caps(self, seed, height, width):
        rng = np.random.default_rng(seed)
        h, e, cap, cs, csrc = random_midstate_grid(rng, height, width)
        out = wave(
            jnp.array(h), jnp.array(e), jnp.array(cap), jnp.array(cs), jnp.array(csrc),
            height * width + 2,
        )
        h2, e2, cap2, cs2, csrc2 = (np.asarray(a) for a in out[:5])
        sf, bf = int(out[5]), int(out[6])
        # Mass conservation: excess + outflows is invariant.
        assert e2.sum() + sf + bf == e.sum()
        # Capacities stay non-negative and pairwise sums are preserved.
        assert (cap2 >= 0).all() and (cs2 >= 0).all() and (csrc2 >= 0).all()
        pair_ns = cap[0, 1:, :] + cap[1, :-1, :]
        pair_ns2 = cap2[0, 1:, :] + cap2[1, :-1, :]
        np.testing.assert_array_equal(pair_ns, pair_ns2)
        pair_we = cap[2, :, 1:] + cap[3, :, :-1]
        pair_we2 = cap2[2, :, 1:] + cap2[3, :, :-1]
        np.testing.assert_array_equal(pair_we, pair_we2)
        # Heights never decrease and only change for active nodes.
        assert (h2 >= h).all()
        assert (h2[e <= 0] == h[e <= 0]).all()

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_kernel_matches_ref_on_hypothesis_states(self, seed):
        rng = np.random.default_rng(seed)
        height = int(rng.integers(2, 7))
        width = int(rng.integers(2, 7))
        state = random_midstate_grid(rng, height, width)
        kern = make_grid_kernel(height, width, k_inner=3)
        got = kern(*(jnp.array(a) for a in state))
        want = run_ref_waves(*state, 3)
        assert_state_equal(got[:5], want[:5], f"hyp seed={seed}")
