"""Executable model of the tiled parallel wave protocol.

Mirrors rust/src/gridflow/wave.rs (sequential oracle) and the 4-phase
tile protocol of rust/src/gridflow/par_wave.rs: parallel decisions,
parallel apply with owned interiors, sequential border reconciliation,
then compaction.  The protocol was designed against this model (1 680
differential cases during development); the committed test keeps a
trimmed matrix as a regression pin for anyone editing either engine or
porting the protocol into the Pallas kernels.

Pure stdlib: no numpy/jax required.
"""
import random
import copy

DIRS = [(-1, 0), (1, 0), (0, -1), (0, 1)]
OPP = [1, 0, 3, 2]
INF = 1 << 30


class St:
    def __init__(self, hh, ww):
        self.hh, self.ww = hh, ww
        n = hh * ww
        self.h = [0] * n
        self.e = [0] * n
        self.cap = [0] * (4 * n)
        self.cap_sink = [0] * n
        self.cap_src = [0] * n

    def cells(self):
        return self.hh * self.ww

    def key(self):
        return (tuple(self.h), tuple(self.e), tuple(self.cap),
                tuple(self.cap_sink), tuple(self.cap_src))


def decide(st, c):
    """Decision for active cell c against snapshot heights. Returns
    None | ('push', arc, delta) | ('relabel', new_h)."""
    hh, ww = st.hh, st.ww
    cells = hh * ww
    v_total = cells + 2
    i, j = divmod(c, ww)
    best_h = INF
    best_a = -1
    for a, (di, dj) in enumerate(DIRS):
        ni, nj = i + di, j + dj
        if ni < 0 or nj < 0 or ni >= hh or nj >= ww:
            continue
        if st.cap[a * cells + c] > 0:
            hn = st.h[ni * ww + nj]
            if hn < best_h:
                best_h = hn
                best_a = a
    if st.cap_sink[c] > 0 and 0 < best_h:
        best_h = 0
        best_a = 4
    if st.cap_src[c] > 0 and v_total < best_h:
        best_h = v_total
        best_a = 5
    if best_a == -1:
        return None
    if st.h[c] > best_h:
        if best_a == 4:
            cap = st.cap_sink[c]
        elif best_a == 5:
            cap = st.cap_src[c]
        else:
            cap = st.cap[best_a * cells + c]
        return ('push', best_a, min(st.e[c], cap))
    return ('relabel', best_h + 1)


# ---------------------------------------------------------------- sequential
class SeqScratch:
    def __init__(self):
        self.decisions = []
        self.active = []
        self.on_list = []
        self.built_for = None

    def rebuild(self, st):
        cells = st.cells()
        self.on_list = [False] * cells
        self.active = []
        for c in range(cells):
            if st.e[c] > 0:
                self.active.append(c)
                self.on_list[c] = True
        self.decisions = [None] * cells
        self.built_for = (st.hh, st.ww)


def seq_wave(st, scratch):
    hh, ww = st.hh, st.ww
    cells = hh * ww
    if scratch.built_for != (hh, ww):
        scratch.rebuild(st)
    for c in scratch.active:
        if st.e[c] <= 0:
            continue
        scratch.decisions[c] = decide(st, c)
    stats = dict(sink_flow=0, src_flow=0, pushes=0, relabels=0)
    n0 = len(scratch.active)
    for idx in range(n0):
        c = scratch.active[idx]
        d = scratch.decisions[c]
        scratch.decisions[c] = None
        if d is None:
            continue
        if d[0] == 'relabel':
            st.h[c] = d[1]
            stats['relabels'] += 1
            continue
        _, arc, delta = d
        stats['pushes'] += 1
        st.e[c] -= delta
        if arc == 4:
            st.cap_sink[c] -= delta
            stats['sink_flow'] += delta
        elif arc == 5:
            st.cap_src[c] -= delta
            stats['src_flow'] += delta
        else:
            i, j = divmod(c, ww)
            di, dj = DIRS[arc]
            nc = (i + di) * ww + (j + dj)
            st.cap[arc * cells + c] -= delta
            st.cap[OPP[arc] * cells + nc] += delta
            st.e[nc] += delta
            if not scratch.on_list[nc]:
                scratch.on_list[nc] = True
                scratch.active.append(nc)
    w = 0
    for r in range(len(scratch.active)):
        c = scratch.active[r]
        if st.e[c] > 0:
            scratch.active[w] = c
            w += 1
        else:
            scratch.on_list[c] = False
    del scratch.active[w:]
    return stats


# ------------------------------------------------------------------ parallel
class ParScratch:
    def __init__(self, tile_rows):
        self.tile_rows = tile_rows
        self.tiles = []      # list of dicts: active, border
        self.decisions = []
        self.on_list = []
        self.built_for = None

    def n_tiles(self, hh):
        return (hh + self.tile_rows - 1) // self.tile_rows

    def rebuild(self, st):
        hh, ww = st.hh, st.ww
        cells = hh * ww
        self.on_list = [False] * cells
        self.decisions = [None] * cells
        self.tiles = []
        for t in range(self.n_tiles(hh)):
            r0 = t * self.tile_rows
            r1 = min(r0 + self.tile_rows, hh)
            tile = dict(base=r0 * ww, end=r1 * ww, active=[], border=[])
            for c in range(tile['base'], tile['end']):
                if st.e[c] > 0:
                    tile['active'].append(c)
                    self.on_list[c] = True
            self.tiles.append(tile)
        self.built_for = (hh, ww)


def par_wave(st, scratch, threads):
    hh, ww = st.hh, st.ww
    cells = hh * ww
    if scratch.built_for != (hh, ww):
        scratch.rebuild(st)
    tiles = scratch.tiles
    nt = len(tiles)
    # Phase 1: decisions, per tile (read-only state; disjoint decision
    # ranges). Worker w handles tiles w, w+threads, ... -- order
    # irrelevant, simulate in that order anyway.
    for w in range(threads):
        for t in range(w, nt, threads):
            for c in tiles[t]['active']:
                if st.e[c] <= 0:
                    continue
                scratch.decisions[c] = decide(st, c)
    # Phase 2: apply with owned interiors; cross-tile receive deferred.
    stats_tiles = []
    for t in range(nt):
        tiles[t]['border'] = []
    for w in range(threads):
        for t in range(w, nt, threads):
            tile = tiles[t]
            stats = dict(sink_flow=0, src_flow=0, pushes=0, relabels=0)
            n0 = len(tile['active'])
            for idx in range(n0):
                c = tile['active'][idx]
                d = scratch.decisions[c]
                scratch.decisions[c] = None
                if d is None:
                    continue
                if d[0] == 'relabel':
                    st.h[c] = d[1]
                    stats['relabels'] += 1
                    continue
                _, arc, delta = d
                stats['pushes'] += 1
                st.e[c] -= delta
                if arc == 4:
                    st.cap_sink[c] -= delta
                    stats['sink_flow'] += delta
                elif arc == 5:
                    st.cap_src[c] -= delta
                    stats['src_flow'] += delta
                else:
                    i, j = divmod(c, ww)
                    di, dj = DIRS[arc]
                    nc = (i + di) * ww + (j + dj)
                    st.cap[arc * cells + c] -= delta
                    if tile['base'] <= nc < tile['end']:
                        st.cap[OPP[arc] * cells + nc] += delta
                        st.e[nc] += delta
                        if not scratch.on_list[nc]:
                            scratch.on_list[nc] = True
                            tile['active'].append(nc)
                    else:
                        tile['border'].append((nc, OPP[arc], delta))
            stats_tiles.append(stats)
    # Phase 3: sequential border reconciliation.
    for t in range(nt):
        for (nc, arc, delta) in tiles[t]['border']:
            st.cap[arc * cells + nc] += delta
            st.e[nc] += delta
            if not scratch.on_list[nc]:
                scratch.on_list[nc] = True
                tt = (nc // ww) // scratch.tile_rows
                tiles[tt]['active'].append(nc)
    # Phase 4: compaction, after all excess updates have landed (keeps
    # the active set exactly equal to the sequential engine's).
    for t in range(nt):
        tile = tiles[t]
        kept = []
        for c in tile['active']:
            if st.e[c] > 0:
                kept.append(c)
            else:
                scratch.on_list[c] = False
        tile['active'] = kept
    total = dict(sink_flow=0, src_flow=0, pushes=0, relabels=0)
    for s in stats_tiles:
        for k in total:
            total[k] += s[k]
    return total


def par_active_count(scratch):
    return sum(len(t['active']) for t in scratch.tiles)


# ----------------------------------------------------------------- driving
def random_state(rng, hh, ww, max_cap):
    """Adversarial random state: arbitrary heights, negative excess,
    partial caps — a superset of anything a real solve produces."""
    st = St(hh, ww)
    cells = hh * ww
    for c in range(cells):
        st.h[c] = rng.randrange(0, cells + 4)
        st.e[c] = rng.randrange(-2, max_cap) if rng.random() < 0.5 else 0
        if rng.random() < 0.3:
            st.cap_sink[c] = rng.randrange(0, max_cap)
        if rng.random() < 0.3:
            st.cap_src[c] = rng.randrange(0, max_cap)
    for a in range(4):
        for c in range(cells):
            i, j = divmod(c, ww)
            di, dj = DIRS[a]
            if 0 <= i + di < hh and 0 <= j + dj < ww and rng.random() < 0.7:
                st.cap[a * cells + c] = rng.randrange(0, max_cap)
    return st


def host_mutate(rng, st):
    """Random host-style mutation: tweak e / h / caps arbitrarily."""
    cells = st.cells()
    for _ in range(cells // 4):
        c = rng.randrange(cells)
        kind = rng.randrange(3)
        if kind == 0:
            st.e[c] += rng.randrange(-2, 5)
        elif kind == 1:
            st.h[c] = rng.randrange(0, 2 * (cells + 2))
        else:
            st.cap[rng.randrange(4) * cells + c] = rng.randrange(0, 6)


def run_case(seed, hh, ww, tile_rows, threads, waves, supersteps):
    rng = random.Random(seed)
    st_seq = random_state(rng, hh, ww, 9)
    st_par = copy.deepcopy(st_seq)
    seq = SeqScratch()
    par = ParScratch(tile_rows)
    for ss in range(supersteps):
        seq.rebuild(st_seq)
        par.rebuild(st_par)
        for wv in range(waves):
            if len(seq.active) == 0:
                assert par_active_count(par) == 0, (seed, ss, wv)
                break
            a = seq_wave(st_seq, seq)
            b = par_wave(st_par, par, threads)
            assert a == b, (seed, ss, wv, a, b)
            assert st_seq.key() == st_par.key(), (seed, ss, wv, "state diverged")
            par_active = sorted(c for t in par.tiles for c in t['active'])
            assert sorted(seq.active) == par_active, (seed, ss, wv, "active set diverged")
            assert seq.on_list == par.on_list, (seed, ss, wv, "on_list diverged")
        # Host round stand-in: identical arbitrary mutation on both.
        host_mutate(rng, st_seq)
        st_par.h = list(st_seq.h)
        st_par.e = list(st_seq.e)
        st_par.cap = list(st_seq.cap)
        st_par.cap_sink = list(st_seq.cap_sink)
        st_par.cap_src = list(st_seq.cap_src)


def test_tiled_protocol_bit_exact():
    cases = 0
    for seed in range(4):
        for (hh, ww) in [(1, 7), (4, 4), (7, 5), (8, 8)]:
            for tile_rows in [1, 2, 3, 100]:
                for threads in [1, 2, 3]:
                    run_case(seed, hh, ww, tile_rows, threads,
                             waves=30, supersteps=2)
                    cases += 1
    assert cases == 192


def test_degenerate_shapes():
    for (hh, ww) in [(1, 1), (5, 1), (2, 9)]:
        for tile_rows in [1, 4]:
            run_case(3, hh, ww, tile_rows, threads=4, waves=25, supersteps=2)
