"""CSA refine kernel vs the numpy oracle + optimality ground truth."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.csa_wave import (
    backward_half_wave,
    forward_half_wave,
    make_csa_kernel,
    wave,
)
from tests.conftest import random_csa_refine_start


def run_ref_waves(cost, f, px, py, ex, ey, eps, k):
    tot = dict(pu=0, rl=0, waves=0)
    for _ in range(k):
        if not ((np.asarray(ex) > 0).any() or (np.asarray(ey) > 0).any()):
            break
        f, px, py, ex, ey, pu, rl = ref.csa_wave_ref(cost, f, px, py, ex, ey, eps)
        tot["pu"] += pu
        tot["rl"] += rl
        tot["waves"] += 1
    return f, px, py, ex, ey, tot


def random_midstate(rng, n, max_weight=100):
    """Arbitrary consistent mid-refine state: f has row sums in {0,1}."""
    w = rng.integers(0, max_weight + 1, size=(n, n)).astype(np.int64)
    cost = (-w * (n + 1)).astype(np.int32)
    eps = max(1, int(np.abs(cost).max()) // int(rng.integers(1, 12)))
    f = np.zeros((n, n), np.int32)
    for x in range(n):
        if rng.random() < 0.6:
            f[x, rng.integers(0, n)] = 1
    ex = (1 - f.sum(axis=1)).astype(np.int32)
    ey = (f.sum(axis=0) - 1).astype(np.int32)
    px = rng.integers(-5000, 100, size=n).astype(np.int32)
    py = rng.integers(-5000, 100, size=n).astype(np.int32)
    return cost, f, px, py, ex, ey, eps


class TestHalfWaves:
    @pytest.mark.parametrize("seed", range(10))
    def test_forward_half_wave_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 9))
        cost, f, px, py, ex, ey, eps = random_midstate(rng, n)
        got = forward_half_wave(
            jnp.array(cost), jnp.array(f), jnp.array(px), jnp.array(py),
            jnp.array(ex), jnp.array(ey), jnp.int32(eps),
        )
        want = ref.csa_forward_ref(cost, f, px, py, ex, ey, eps)
        np.testing.assert_array_equal(np.asarray(got[0]), want[0], "f")
        np.testing.assert_array_equal(np.asarray(got[1]), want[1], "px")
        np.testing.assert_array_equal(np.asarray(got[2]), want[2], "ex")
        np.testing.assert_array_equal(np.asarray(got[3]), want[3], "ey")
        assert (int(got[4]), int(got[5])) == want[4:]

    @pytest.mark.parametrize("seed", range(10))
    def test_backward_half_wave_matches_ref(self, seed):
        rng = np.random.default_rng(100 + seed)
        n = int(rng.integers(2, 9))
        cost, f, px, py, ex, ey, eps = random_midstate(rng, n)
        # Make some Y nodes active so the backward wave has work.
        got = backward_half_wave(
            jnp.array(cost), jnp.array(f), jnp.array(px), jnp.array(py),
            jnp.array(ex), jnp.array(ey), jnp.int32(eps),
        )
        want = ref.csa_backward_ref(cost, f, px, py, ex, ey, eps)
        np.testing.assert_array_equal(np.asarray(got[0]), want[0], "f")
        np.testing.assert_array_equal(np.asarray(got[1]), want[1], "py")
        np.testing.assert_array_equal(np.asarray(got[2]), want[2], "ex")
        np.testing.assert_array_equal(np.asarray(got[3]), want[3], "ey")


class TestKernelMultiWave:
    @pytest.mark.parametrize("seed", range(5))
    @pytest.mark.parametrize("k_inner", [1, 4, 16])
    def test_kernel_equals_k_ref_waves(self, seed, k_inner):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 9))
        _, cost, f, px, py, ex, ey, eps = random_csa_refine_start(rng, n)
        kern = make_csa_kernel(n, k_inner=k_inner)
        got = kern(
            jnp.array(cost), jnp.array(f), jnp.array(px), jnp.array(py),
            jnp.array(ex), jnp.array(ey), jnp.array([eps], dtype=jnp.int32),
        )
        fw, pxw, pyw, exw, eyw, tot = run_ref_waves(cost, f, px, py, ex, ey, eps, k_inner)
        np.testing.assert_array_equal(np.asarray(got[0]), fw)
        np.testing.assert_array_equal(np.asarray(got[1]), pxw)
        np.testing.assert_array_equal(np.asarray(got[2]), pyw)
        np.testing.assert_array_equal(np.asarray(got[3]), exw)
        np.testing.assert_array_equal(np.asarray(got[4]), eyw)
        stats = np.asarray(got[5])
        assert stats[2] == tot["pu"] and stats[3] == tot["rl"] and stats[4] == tot["waves"]

    def test_kernel_early_exit_when_quiescent(self):
        n = 4
        kern = make_csa_kernel(n, k_inner=8)
        cost = jnp.zeros((n, n), jnp.int32)
        f = jnp.eye(n, dtype=jnp.int32)
        z = jnp.zeros((n,), jnp.int32)
        got = kern(cost, f, z, z, z, z, jnp.array([1], jnp.int32))
        assert int(np.asarray(got[5])[4]) == 0  # waves


class TestRefineSolve:
    @pytest.mark.parametrize("seed", range(8))
    def test_full_scaling_solve_is_optimal(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 8))
        w = rng.integers(0, 101, size=(n, n))
        assign, total = ref.csa_solve_ref(w)
        _, best = ref.brute_force_assignment(w)
        assert total == best
        assert sorted(assign) == list(range(n))

    def test_kernel_refine_to_quiescence_yields_perfect_matching(self, ):
        rng = np.random.default_rng(3)
        n = 6
        _, cost, f, px, py, ex, ey, eps = random_csa_refine_start(rng, n)
        kern = make_csa_kernel(n, k_inner=16)
        state = [jnp.array(cost), jnp.array(f), jnp.array(px), jnp.array(py),
                 jnp.array(ex), jnp.array(ey)]
        for _ in range(500):
            out = kern(state[0], state[1], state[2], state[3], state[4], state[5],
                       jnp.array([eps], dtype=jnp.int32))
            state = [state[0]] + list(out[:5])
            stats = np.asarray(out[5])
            if stats[0] + stats[1] == 0:
                break
        else:
            pytest.fail("refine did not converge")
        fm = np.asarray(state[1])
        assert (fm.sum(axis=0) == 1).all() and (fm.sum(axis=1) == 1).all()


class TestWaveInvariants:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 10))
    def test_wave_preserves_matching_invariants(self, seed, n):
        rng = np.random.default_rng(seed)
        cost, f, px, py, ex, ey, eps = random_midstate(rng, n)
        out = wave(
            jnp.array(cost), jnp.array(f), jnp.array(px), jnp.array(py),
            jnp.array(ex), jnp.array(ey), jnp.int32(eps),
        )
        f2, px2, py2, ex2, ey2 = (np.asarray(a) for a in out[:5])
        # f stays 0/1 with row sums <= 1; excess bookkeeping consistent.
        assert ((f2 == 0) | (f2 == 1)).all()
        np.testing.assert_array_equal(ex2, 1 - f2.sum(axis=1))
        np.testing.assert_array_equal(ey2, f2.sum(axis=0) - 1)
        # Total excess is conserved by pushes (pushes just move units).
        assert ex2.sum() + ey2.sum() == np.asarray(ex).sum() + np.asarray(ey).sum()
        # Prices never increase (paper Lemma 5.2).
        assert (px2 <= px).all() and (py2 <= py).all()

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_kernel_matches_ref_on_hypothesis_states(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 11))
        cost, f, px, py, ex, ey, eps = random_midstate(rng, n)
        kern = make_csa_kernel(n, k_inner=3)
        got = kern(
            jnp.array(cost), jnp.array(f), jnp.array(px), jnp.array(py),
            jnp.array(ex), jnp.array(ey), jnp.array([eps], dtype=jnp.int32),
        )
        want = run_ref_waves(cost, f, px, py, ex, ey, eps, 3)
        np.testing.assert_array_equal(np.asarray(got[0]), want[0])
        np.testing.assert_array_equal(np.asarray(got[1]), want[1])
        np.testing.assert_array_equal(np.asarray(got[2]), want[2])
        np.testing.assert_array_equal(np.asarray(got[3]), want[3])
        np.testing.assert_array_equal(np.asarray(got[4]), want[4])
