"""Shared fixtures and instance generators for the kernel test-suite."""

from __future__ import annotations

import numpy as np
import pytest


def random_grid_instance(rng, height, width, max_cap=15, frac_source=0.3, frac_sink=0.3):
    """A random grid max-flow instance in device layout.

    Returns (h, e, cap, cap_sink, cap_src, source_excess) where
    source_excess = u(s, x) is the preloaded excess (Hong's Init).
    """
    cap = rng.integers(0, max_cap + 1, size=(4, height, width)).astype(np.int32)
    # Arcs leaving the grid do not exist.
    cap[0, 0, :] = 0
    cap[1, -1, :] = 0
    cap[2, :, 0] = 0
    cap[3, :, -1] = 0
    cap_sink = np.where(
        rng.random((height, width)) < frac_sink,
        rng.integers(1, max_cap + 1, size=(height, width)),
        0,
    ).astype(np.int32)
    source_excess = np.where(
        rng.random((height, width)) < frac_source,
        rng.integers(1, max_cap + 1, size=(height, width)),
        0,
    ).astype(np.int32)
    # Avoid degenerate overlap making flow trivial: fine either way.
    h = np.zeros((height, width), np.int32)
    e = source_excess.copy()
    cap_src = source_excess.copy()  # u_f(x, s) = u(s, x) after saturation
    return h, e, cap, cap_sink, cap_src, source_excess


def random_midstate_grid(rng, height, width, max_cap=15):
    """An arbitrary (not necessarily reachable) mid-execution grid state —
    used to check wave parity pointwise on a much larger state space."""
    h = rng.integers(0, 2 * height * width + 4, size=(height, width)).astype(np.int32)
    e = rng.integers(0, 20, size=(height, width)).astype(np.int32)
    cap = rng.integers(0, max_cap + 1, size=(4, height, width)).astype(np.int32)
    cap[0, 0, :] = 0
    cap[1, -1, :] = 0
    cap[2, :, 0] = 0
    cap[3, :, -1] = 0
    cap_sink = rng.integers(0, max_cap + 1, size=(height, width)).astype(np.int32)
    cap_src = rng.integers(0, max_cap + 1, size=(height, width)).astype(np.int32)
    return h, e, cap, cap_sink, cap_src


def random_csa_refine_start(rng, n, max_weight=100):
    """A fresh refine state for a random weight matrix, paper scaling."""
    w = rng.integers(0, max_weight + 1, size=(n, n)).astype(np.int64)
    cost = (-w * (n + 1)).astype(np.int32)
    eps = max(1, int(np.abs(cost).max()))
    f = np.zeros((n, n), np.int32)
    ex = np.ones(n, np.int32)
    ey = -np.ones(n, np.int32)
    py = np.zeros(n, np.int32)
    px = np.array(
        [-(min(int(cost[x, y]) for y in range(n))) - eps for x in range(n)],
        np.int32,
    )
    return w, cost, f, px, py, ex, ey, eps


@pytest.fixture
def rng():
    return np.random.default_rng(12345)
