"""L2 super-steps + AOT lowering: dynamic `outer`, shapes, HLO-text output."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot
from compile.kernels import ref
from compile.model import (
    csa_example_args,
    grid_example_args,
    make_csa_superstep,
    make_grid_superstep,
)
from tests.conftest import random_csa_refine_start, random_grid_instance


class TestGridSuperstep:
    def test_outer_zero_is_identity(self):
        rng = np.random.default_rng(0)
        h, e, cap, cs, csrc, _ = random_grid_instance(rng, 4, 4)
        step = make_grid_superstep(4, 4, k_inner=4)
        out = step(jnp.array(h), jnp.array(e), jnp.array(cap), jnp.array(cs),
                   jnp.array(csrc), jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(out[0]), h)
        np.testing.assert_array_equal(np.asarray(out[1]), e)
        assert int(np.asarray(out[5])[5]) == 0

    @pytest.mark.parametrize("outer,k_inner", [(1, 4), (3, 2), (2, 8)])
    def test_outer_times_inner_equals_ref_waves(self, outer, k_inner):
        rng = np.random.default_rng(42)
        h, e, cap, cs, csrc, _ = random_grid_instance(rng, 5, 5)
        step = make_grid_superstep(5, 5, k_inner=k_inner)
        out = step(jnp.array(h), jnp.array(e), jnp.array(cap), jnp.array(cs),
                   jnp.array(csrc), jnp.int32(outer))
        hr, er, cr, csr, csrcr = h, e, cap, cs, csrc
        for _ in range(outer * k_inner):
            if not (er > 0).any():
                break
            hr, er, cr, csr, csrcr, *_ = ref.grid_wave_ref(hr, er, cr, csr, csrcr)
        np.testing.assert_array_equal(np.asarray(out[0]), hr)
        np.testing.assert_array_equal(np.asarray(out[1]), er)
        np.testing.assert_array_equal(np.asarray(out[2]), cr)

    def test_superstep_drives_to_quiescence_and_matches_maxflow(self):
        rng = np.random.default_rng(11)
        h, e, cap, cs, csrc, src_exc = random_grid_instance(rng, 6, 6)
        step = jax.jit(make_grid_superstep(6, 6, k_inner=16))
        state = [jnp.array(a) for a in (h, e, cap, cs, csrc)]
        sink = 0
        for _ in range(200):
            *state, stats = step(*state, jnp.int32(64))
            stats = np.asarray(stats)
            sink += int(stats[0])
            if stats[2] == 0:
                break
        else:
            pytest.fail("did not converge")
        n, edges, s, t = ref.grid_to_edges(cap, cs, src_exc)
        assert sink == ref.ford_fulkerson(n, edges, s, t)


class TestCsaSuperstep:
    def test_outer_zero_is_identity(self):
        rng = np.random.default_rng(1)
        _, cost, f, px, py, ex, ey, eps = random_csa_refine_start(rng, 5)
        step = make_csa_superstep(5, k_inner=4)
        out = step(jnp.array(cost), jnp.array(f), jnp.array(px), jnp.array(py),
                   jnp.array(ex), jnp.array(ey), jnp.array([eps], jnp.int32),
                   jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(out[0]), f)
        assert int(np.asarray(out[5])[4]) == 0

    def test_superstep_refine_reaches_perfect_matching(self):
        rng = np.random.default_rng(2)
        n = 7
        _, cost, f, px, py, ex, ey, eps = random_csa_refine_start(rng, n)
        step = jax.jit(make_csa_superstep(n, k_inner=16))
        state = [jnp.array(f), jnp.array(px), jnp.array(py), jnp.array(ex), jnp.array(ey)]
        costj = jnp.array(cost)
        for _ in range(200):
            out = step(costj, *state, jnp.array([eps], jnp.int32), jnp.int32(64))
            state = list(out[:5])
            stats = np.asarray(out[5])
            if stats[0] + stats[1] == 0:
                break
        else:
            pytest.fail("did not converge")
        fm = np.asarray(state[0])
        assert (fm.sum(axis=0) == 1).all() and (fm.sum(axis=1) == 1).all()


class TestAot:
    def test_grid_hlo_text_lowers(self):
        text = aot.lower_grid(8, 8)
        assert text.startswith("HloModule")
        assert "while" in text  # the dynamic outer loop survived lowering

    def test_csa_hlo_text_lowers(self):
        text = aot.lower_csa(8)
        assert text.startswith("HloModule")
        assert "while" in text

    def test_example_args_shapes(self):
        args = grid_example_args(8, 8)
        assert args[2].shape == (4, 8, 8)
        args = csa_example_args(16)
        assert args[0].shape == (16, 16)
        assert args[6].shape == (1,)
