"""L2: the JAX compute graph around the L1 Pallas kernels.

One *super-step* = a dynamic number (`outer`, a runtime scalar) of kernel
invocations, each of which runs up to ``K_INNER`` VMEM-resident waves.  The
paper's ``CYCLE`` parameter maps to ``K_INNER * outer``: the Rust coordinator
chooses `outer` per host round, so a single AOT artifact per *shape* serves
every CYCLE setting.

The activity counter is computed inside the kernel and threaded through the
loop so the super-step exits early once the instance is quiescent — the
device-side analogue of the paper's "all excesses stay the same" stopping
rule, without extra host round-trips.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.csa_wave import K_INNER_DEFAULT as CSA_K_INNER
from compile.kernels.csa_wave import make_csa_kernel
from compile.kernels.grid_wave import K_INNER_DEFAULT as GRID_K_INNER
from compile.kernels.grid_wave import make_grid_kernel

# Stats vector layout shared with the Rust runtime (keep in sync with
# rust/src/runtime/device.rs).
GRID_STATS = ("sink_flow", "src_flow", "active", "pushes", "relabels", "waves")
CSA_STATS = ("active_x", "active_y", "pushes", "relabels", "waves", "zero")


def make_grid_superstep(height: int, width: int, k_inner: int = GRID_K_INNER):
    """Returns f(h, e, cap, cap_sink, cap_src, outer) -> (state..., stats)."""
    kern = make_grid_kernel(height, width, k_inner)

    def superstep(h, e, cap, cap_sink, cap_src, outer):
        zero = jnp.int32(0)

        def cond(carry):
            i, _h, _e, _cap, _cs, _csrc, _sf, _bf, _pu, _rl, _wv, act = carry
            return (i < outer) & (act > 0)

        def body(carry):
            i, h, e, cap, cs, csrc, sf, bf, pu, rl, wv, _act = carry
            h, e, cap, cs, csrc, stats = kern(h, e, cap, cs, csrc)
            return (
                i + 1,
                h,
                e,
                cap,
                cs,
                csrc,
                sf + stats[0],
                bf + stats[1],
                pu + stats[3],
                rl + stats[4],
                wv + stats[5],
                stats[2],
            )

        init_act = jnp.sum((e > 0).astype(jnp.int32), dtype=jnp.int32)
        carry = (
            zero, h, e, cap, cap_sink, cap_src,
            zero, zero, zero, zero, zero, init_act,
        )
        (_, h, e, cap, cap_sink, cap_src, sf, bf, pu, rl, wv, act) = jax.lax.while_loop(
            cond, body, carry
        )
        stats = jnp.stack([sf, bf, act, pu, rl, wv])
        return h, e, cap, cap_sink, cap_src, stats

    return superstep


def make_csa_superstep(n: int, k_inner: int = CSA_K_INNER):
    """Returns f(cost, f, px, py, ex, ey, eps, outer) -> (state..., stats)."""
    kern = make_csa_kernel(n, k_inner)

    def superstep(cost, f, px, py, ex, ey, eps, outer):
        zero = jnp.int32(0)

        def activity(ex, ey):
            return jnp.sum((ex > 0).astype(jnp.int32)) + jnp.sum(
                (ey > 0).astype(jnp.int32)
            )

        def cond(carry):
            i, _f, _px, _py, _ex, _ey, _pu, _rl, _wv, act = carry
            return (i < outer) & (act > 0)

        def body(carry):
            i, f, px, py, ex, ey, pu, rl, wv, _act = carry
            f, px, py, ex, ey, stats = kern(cost, f, px, py, ex, ey, eps)
            return (
                i + 1,
                f,
                px,
                py,
                ex,
                ey,
                pu + stats[2],
                rl + stats[3],
                wv + stats[4],
                stats[0] + stats[1],
            )

        init_act = activity(ex, ey).astype(jnp.int32)
        carry = (zero, f, px, py, ex, ey, zero, zero, zero, init_act)
        (_, f, px, py, ex, ey, pu, rl, wv, _act) = jax.lax.while_loop(cond, body, carry)
        ax = jnp.sum((ex > 0).astype(jnp.int32), dtype=jnp.int32)
        ay = jnp.sum((ey > 0).astype(jnp.int32), dtype=jnp.int32)
        stats = jnp.stack([ax, ay, pu, rl, wv, jnp.int32(0)])
        return f, px, py, ex, ey, stats

    return superstep


def grid_example_args(height: int, width: int):
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((height, width), i32),      # h
        jax.ShapeDtypeStruct((height, width), i32),      # e
        jax.ShapeDtypeStruct((4, height, width), i32),   # cap
        jax.ShapeDtypeStruct((height, width), i32),      # cap_sink
        jax.ShapeDtypeStruct((height, width), i32),      # cap_src
        jax.ShapeDtypeStruct((), i32),                   # outer
    )


def csa_example_args(n: int):
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((n, n), i32),  # cost
        jax.ShapeDtypeStruct((n, n), i32),  # f
        jax.ShapeDtypeStruct((n,), i32),    # px
        jax.ShapeDtypeStruct((n,), i32),    # py
        jax.ShapeDtypeStruct((n,), i32),    # ex
        jax.ShapeDtypeStruct((n,), i32),    # ey
        jax.ShapeDtypeStruct((1,), i32),    # eps
        jax.ShapeDtypeStruct((), i32),      # outer
    )
