"""Pure-numpy correctness oracles for the Pallas kernels.

Deliberately written with explicit Python loops and snapshot-then-apply
semantics so they are an *independent* specification of one synchronous
wave, not a refactoring of the jnp code.  pytest/hypothesis compares the
Pallas kernels against these, element-for-element.

Also provides tiny ground-truth solvers:
  * ``ford_fulkerson`` — BFS augmenting-path max-flow on an adjacency dict,
  * ``brute_force_assignment`` — permutation scan for n <= 8.
"""

from __future__ import annotations

import itertools
from collections import deque

import numpy as np

INF = np.int32(1 << 30)

# ---------------------------------------------------------------------------
# Grid push-relabel wave oracle
# ---------------------------------------------------------------------------

# Arc order must match grid_wave.py: N, S, W, E, sink, source.
_DIRS = [(-1, 0), (1, 0), (0, -1), (0, 1)]
_OPP = [1, 0, 3, 2]


def grid_wave_ref(h, e, cap, cap_sink, cap_src):
    """One synchronous wave; returns the new state plus per-wave counters.

    All decisions are taken from a snapshot of the inputs, then applied —
    matching the data-parallel semantics of the kernel.
    """
    h = np.asarray(h, dtype=np.int64)
    e = np.asarray(e, dtype=np.int64)
    cap = np.asarray(cap, dtype=np.int64).copy()
    cap_sink = np.asarray(cap_sink, dtype=np.int64).copy()
    cap_src = np.asarray(cap_src, dtype=np.int64).copy()
    H, Wd = h.shape
    v_total = H * Wd + 2

    h_new = h.copy()
    e_new = e.copy()
    sink_flow = 0
    src_flow = 0
    pushes = 0
    relabels = 0

    # Decision phase (snapshot).
    decisions = []  # (i, j, arc, delta) or (i, j, -1, new_height)
    for i in range(H):
        for j in range(Wd):
            if e[i, j] <= 0:
                continue
            # Find the lowest residual neighbour; tie-break by arc order,
            # matching jnp.argmin's first-minimum rule.
            best_h, best_a = int(INF), -1
            for a, (di, dj) in enumerate(_DIRS):
                ni, nj = i + di, j + dj
                if 0 <= ni < H and 0 <= nj < Wd and cap[a, i, j] > 0:
                    if h[ni, nj] < best_h:
                        best_h, best_a = int(h[ni, nj]), a
            if cap_sink[i, j] > 0 and 0 < best_h:
                best_h, best_a = 0, 4
            if cap_src[i, j] > 0 and v_total < best_h:
                best_h, best_a = v_total, 5
            if best_a == -1:
                continue  # isolated active node: nothing to do
            if h[i, j] > best_h:
                if best_a < 4:
                    c = cap[best_a, i, j]
                elif best_a == 4:
                    c = cap_sink[i, j]
                else:
                    c = cap_src[i, j]
                decisions.append((i, j, best_a, min(int(e[i, j]), int(c))))
            else:
                decisions.append((i, j, -1, best_h + 1))

    # Apply phase.
    for i, j, a, val in decisions:
        if a == -1:
            h_new[i, j] = val
            relabels += 1
            continue
        pushes += 1
        delta = val
        e_new[i, j] -= delta
        if a == 4:
            cap_sink[i, j] -= delta
            sink_flow += delta
        elif a == 5:
            cap_src[i, j] -= delta
            src_flow += delta
        else:
            di, dj = _DIRS[a]
            ni, nj = i + di, j + dj
            cap[a, i, j] -= delta
            cap[_OPP[a], ni, nj] += delta
            e_new[ni, nj] += delta

    return (
        h_new.astype(np.int32),
        e_new.astype(np.int32),
        cap.astype(np.int32),
        cap_sink.astype(np.int32),
        cap_src.astype(np.int32),
        sink_flow,
        src_flow,
        pushes,
        relabels,
    )


def grid_solve_ref(h, e, cap, cap_sink, cap_src, max_waves=200000):
    """Run waves to quiescence; returns total flow delivered to the sink."""
    total_sink = 0
    total_src = 0
    for _ in range(max_waves):
        if not (np.asarray(e) > 0).any():
            break
        h, e, cap, cap_sink, cap_src, sf, bf, _, _ = grid_wave_ref(
            h, e, cap, cap_sink, cap_src
        )
        total_sink += sf
        total_src += bf
    else:
        raise RuntimeError("grid_solve_ref did not converge")
    return total_sink, total_src, h, e, cap, cap_sink, cap_src


# ---------------------------------------------------------------------------
# CSA refine wave oracle
# ---------------------------------------------------------------------------


def csa_forward_ref(cost, f, px, py, ex, ey, eps):
    cost = np.asarray(cost, dtype=np.int64)
    f = np.asarray(f, dtype=np.int64).copy()
    px = np.asarray(px, dtype=np.int64).copy()
    py = np.asarray(py, dtype=np.int64)
    ex = np.asarray(ex, dtype=np.int64).copy()
    ey = np.asarray(ey, dtype=np.int64).copy()
    n = cost.shape[0]
    pushes = relabels = 0

    decisions = []
    for x in range(n):
        if ex[x] <= 0:
            continue
        best_c, best_y = int(INF), -1
        for y in range(n):
            if f[x, y] == 0:
                c = int(cost[x, y] - py[y])
                if c < best_c:
                    best_c, best_y = c, y
        if best_y == -1:
            continue
        if best_c < -px[x]:
            decisions.append((x, best_y, None))
        else:
            decisions.append((x, -1, -(best_c + int(eps))))

    for x, y, newp in decisions:
        if y == -1:
            px[x] = newp
            relabels += 1
        else:
            f[x, y] += 1
            ex[x] -= 1
            ey[y] += 1
            pushes += 1
    return f, px, ex, ey, pushes, relabels


def csa_backward_ref(cost, f, px, py, ex, ey, eps):
    cost = np.asarray(cost, dtype=np.int64)
    f = np.asarray(f, dtype=np.int64).copy()
    px = np.asarray(px, dtype=np.int64)
    py = np.asarray(py, dtype=np.int64).copy()
    ex = np.asarray(ex, dtype=np.int64).copy()
    ey = np.asarray(ey, dtype=np.int64).copy()
    n = cost.shape[0]
    pushes = relabels = 0

    decisions = []
    for y in range(n):
        if ey[y] <= 0:
            continue
        best_c, best_x = int(INF), -1
        for x in range(n):
            if f[x, y] == 1:
                c = int(-cost[x, y] - px[x])
                if c < best_c:
                    best_c, best_x = c, x
        if best_x == -1:
            continue
        if best_c < -py[y]:
            decisions.append((y, best_x, None))
        else:
            decisions.append((y, -1, -(best_c + int(eps))))

    for y, x, newp in decisions:
        if x == -1:
            py[y] = newp
            relabels += 1
        else:
            f[x, y] -= 1
            ey[y] -= 1
            ex[x] += 1
            pushes += 1
    return f, py, ex, ey, pushes, relabels


def csa_wave_ref(cost, f, px, py, ex, ey, eps):
    f, px, ex, ey, p1, r1 = csa_forward_ref(cost, f, px, py, ex, ey, eps)
    f, py, ex, ey, p2, r2 = csa_backward_ref(cost, f, px, py, ex, ey, eps)
    return f, px, py, ex, ey, p1 + p2, r1 + r2


def csa_refine_ref(cost, px, py, eps, max_waves=100000):
    """Full refine at one eps from the de-saturated state (f = 0)."""
    n = cost.shape[0]
    f = np.zeros((n, n), dtype=np.int64)
    ex = np.ones(n, dtype=np.int64)
    ey = -np.ones(n, dtype=np.int64)
    px = np.asarray(px, dtype=np.int64).copy()
    py = np.asarray(py, dtype=np.int64).copy()
    # Price initialisation, Algorithm 5.2 lines 5-6.
    for x in range(n):
        px[x] = -min(int(cost[x, y] - py[y]) for y in range(n)) - int(eps)
    for _ in range(max_waves):
        if not ((ex > 0).any() or (ey > 0).any()):
            break
        f, px, py, ex, ey, _, _ = csa_wave_ref(cost, f, px, py, ex, ey, eps)
    else:
        raise RuntimeError("csa_refine_ref did not converge")
    return f, px, py


def csa_solve_ref(weights, alpha=10):
    """Full cost-scaling solve (max-weight assignment) — ground truth driver.

    weights: int array [n, n].  Returns (assignment list, total weight).
    """
    w = np.asarray(weights, dtype=np.int64)
    n = w.shape[0]
    # Max-weight -> min-cost, scaled by (n + 1) for exact integer scaling.
    cost = -w * (n + 1)
    px = np.zeros(n, dtype=np.int64)
    py = np.zeros(n, dtype=np.int64)
    eps = max(1, int(np.abs(cost).max()))
    while True:
        f, px, py = csa_refine_ref(cost, px, py, eps)
        if eps == 1:
            break
        eps = max(1, (eps + alpha - 1) // alpha)
    assign = [int(np.argmax(f[x])) for x in range(n)]
    total = int(sum(w[x, assign[x]] for x in range(n)))
    return assign, total


# ---------------------------------------------------------------------------
# Ground-truth solvers
# ---------------------------------------------------------------------------


def ford_fulkerson(n_nodes, edges, s, t):
    """Max-flow via BFS augmenting paths.  edges: list of (u, v, cap)."""
    capm = {}
    adj = [[] for _ in range(n_nodes)]
    for u, v, c in edges:
        if (u, v) not in capm:
            capm[(u, v)] = 0
            capm[(v, u)] = capm.get((v, u), 0)
            adj[u].append(v)
            adj[v].append(u)
        capm[(u, v)] += c
    flow = 0
    while True:
        parent = {s: s}
        q = deque([s])
        while q and t not in parent:
            u = q.popleft()
            for v in adj[u]:
                if v not in parent and capm.get((u, v), 0) > 0:
                    parent[v] = u
                    q.append(v)
        if t not in parent:
            return flow
        # Find the bottleneck along the path.
        bott = int(INF)
        v = t
        while v != s:
            u = parent[v]
            bott = min(bott, capm[(u, v)])
            v = u
        v = t
        while v != s:
            u = parent[v]
            capm[(u, v)] -= bott
            capm[(v, u)] = capm.get((v, u), 0) + bott
            v = u
        flow += int(bott)


def grid_to_edges(cap, cap_sink, source_excess):
    """Convert an *initial* grid instance to an edge list for ford_fulkerson.

    The device state encodes the source arcs implicitly: ``source_excess``
    holds u(s, x) (preloaded excess).  Node ids: cell (i, j) -> i*W + j,
    source = H*W, sink = H*W + 1.
    """
    cap = np.asarray(cap)
    H, Wd = cap.shape[1:]
    s, t = H * Wd, H * Wd + 1
    edges = []
    for i in range(H):
        for j in range(Wd):
            u = i * Wd + j
            for a, (di, dj) in enumerate(_DIRS):
                ni, nj = i + di, j + dj
                if 0 <= ni < H and 0 <= nj < Wd and cap[a, i, j] > 0:
                    edges.append((u, ni * Wd + nj, int(cap[a, i, j])))
            if cap_sink[i, j] > 0:
                edges.append((u, t, int(cap_sink[i, j])))
            if source_excess[i, j] > 0:
                edges.append((s, u, int(source_excess[i, j])))
    return H * Wd + 2, edges, s, t


def brute_force_assignment(weights):
    """Exact max-weight assignment by permutation scan (n <= 8)."""
    w = np.asarray(weights)
    n = w.shape[0]
    assert n <= 8, "brute force limited to n <= 8"
    best, best_perm = None, None
    for perm in itertools.permutations(range(n)):
        tot = int(sum(w[i, perm[i]] for i in range(n)))
        if best is None or tot > best:
            best, best_perm = tot, list(perm)
    return best_perm, best
