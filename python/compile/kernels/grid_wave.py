"""L1 Pallas kernel: synchronous push-relabel wave on a 4-connected grid.

This is the TPU re-derivation of the paper's CUDA lock-free push-relabel
kernel (Algorithm 4.5 / 4.8).  CUDA expresses one thread per node with
global-memory atomics; Pallas/TPU has no global-memory RMW atomics, so the
same per-node step is expressed as a *dense synchronous wave*:

  * every node reads a snapshot of the heights (the analogue of Vineet &
    Narayanan staging heights in shared memory),
  * picks its lowest residual neighbour (Hong's selection rule, lines 4-9
    of Algorithm 4.5),
  * either pushes ``min(e, u_f)`` to that single neighbour or relabels to
    ``h_min + 1``,
  * incoming flow is reconstructed with shifted reductions instead of
    ``atomicAdd`` — a push x->y and a push y->x cannot coexist in one wave
    because they require ``h(x) > h(y)`` and ``h(y) > h(x)`` simultaneously,
    so the wave is conflict-free by construction.

State layout (all ``int32``):

  h        : [H, W]      node heights
  e        : [H, W]      node excess
  cap      : [4, H, W]   residual capacity to N/S/W/E neighbour
  cap_sink : [H, W]      residual capacity of the (x, t) arc
  cap_src  : [H, W]      residual capacity of the (x, s) arc (returns flow)

The source and sink are *implicit*: an arc to the sink behaves like a
neighbour of height 0, an arc to the source like a neighbour of height
``V = H*W + 2``.  ``K_INNER`` waves run inside one kernel invocation so the
state stays resident in VMEM between waves — the TPU analogue of the paper's
``CYCLE`` iterations between host round-trips.

Outputs: updated state plus ``stats : int32[6]`` =
  [sink_flow, src_flow, active_nodes, pushes, relabels, waves_run].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Arc indices.
N, S, W, E = 0, 1, 2, 3
ARC_SINK, ARC_SRC = 4, 5
INF = np.int32(1 << 30)

# Number of waves executed per kernel invocation (VMEM-resident).
K_INNER_DEFAULT = 16


def _shift_from_south(x):
    """r[i, j] = x[i+1, j]; bottom row becomes `fill` (here 0)."""
    return jnp.concatenate([x[1:, :], jnp.zeros_like(x[:1, :])], axis=0)


def _shift_from_north(x):
    """r[i, j] = x[i-1, j]; top row becomes 0."""
    return jnp.concatenate([jnp.zeros_like(x[:1, :]), x[:-1, :]], axis=0)


def _shift_from_east(x):
    """r[i, j] = x[i, j+1]; last column becomes 0."""
    return jnp.concatenate([x[:, 1:], jnp.zeros_like(x[:, :1])], axis=1)


def _shift_from_west(x):
    """r[i, j] = x[i, j-1]; first column becomes 0."""
    return jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)


def _neighbour_heights(h):
    """Heights of the N/S/W/E neighbours, INF outside the grid.

    nbh[a][i, j] is the height of the node the arc `a` of (i, j) points to.
    """
    inf_row = jnp.full_like(h[:1, :], INF)
    inf_col = jnp.full_like(h[:, :1], INF)
    hn = jnp.concatenate([inf_row, h[:-1, :]], axis=0)   # north nb = h[i-1,j]
    hs = jnp.concatenate([h[1:, :], inf_row], axis=0)    # south nb = h[i+1,j]
    hw = jnp.concatenate([inf_col, h[:, :-1]], axis=1)   # west  nb = h[i,j-1]
    he = jnp.concatenate([h[:, 1:], inf_col], axis=1)    # east  nb = h[i,j+1]
    return hn, hs, hw, he


def wave(h, e, cap, cap_sink, cap_src, v_total):
    """One synchronous push-relabel wave.  Pure function of the state.

    Returns (h, e, cap, cap_sink, cap_src, sink_flow, src_flow,
             pushes, relabels) where the flows/counters are this wave's
    contribution only.
    """
    hn, hs, hw, he = _neighbour_heights(h)
    v = np.int32(v_total)

    # Candidate neighbour heights per arc, INF when the arc is saturated.
    nbh = jnp.stack(
        [
            jnp.where(cap[N] > 0, hn, INF),
            jnp.where(cap[S] > 0, hs, INF),
            jnp.where(cap[W] > 0, hw, INF),
            jnp.where(cap[E] > 0, he, INF),
            jnp.where(cap_sink > 0, jnp.zeros_like(h), INF),
            jnp.where(cap_src > 0, jnp.full_like(h, v), INF),
        ],
        axis=0,
    )  # [6, H, W]

    hmin = jnp.min(nbh, axis=0)
    amin = jnp.argmin(nbh, axis=0).astype(jnp.int32)

    active = e > 0
    can_push = active & (h > hmin)

    cap_all = jnp.concatenate(
        [cap, cap_sink[None], cap_src[None]], axis=0
    )  # [6, H, W]
    arc_cap = jnp.take_along_axis(cap_all, amin[None], axis=0)[0]
    delta = jnp.where(can_push, jnp.minimum(e, arc_cap), 0).astype(jnp.int32)

    # Per-arc outgoing flow (one-hot over the chosen arc).
    arc_iota = jax.lax.broadcasted_iota(jnp.int32, (6,) + h.shape, 0)
    out = jnp.where(
        (arc_iota == amin[None]) & can_push[None], delta[None], 0
    ).astype(jnp.int32)  # [6, H, W]

    # Incoming flow: the receiver of a push along arc `a` sees it arrive
    # from the opposite direction.
    recv_n = _shift_from_south(out[N])  # (i,j) receives the N-push of (i+1,j)
    recv_s = _shift_from_north(out[S])
    recv_w = _shift_from_east(out[W])
    recv_e = _shift_from_west(out[E])
    inflow = recv_n + recv_s + recv_w + recv_e

    e_new = e - delta + inflow

    # Residual capacity updates: forward arc shrinks at the pusher, the
    # reverse arc grows at the receiver (reverse of N at (i,j) is S at
    # (i-1,j), which is exactly where recv_n lands, etc.).
    cap_new = jnp.stack(
        [
            cap[N] - out[N] + recv_s,
            cap[S] - out[S] + recv_n,
            cap[W] - out[W] + recv_e,
            cap[E] - out[E] + recv_w,
        ],
        axis=0,
    )
    cap_sink_new = cap_sink - out[ARC_SINK]
    cap_src_new = cap_src - out[ARC_SRC]

    sink_flow = jnp.sum(out[ARC_SINK], dtype=jnp.int32)
    src_flow = jnp.sum(out[ARC_SRC], dtype=jnp.int32)

    # Relabel: active nodes that could not push rise to h_min + 1.
    do_relabel = active & jnp.logical_not(can_push) & (hmin < INF)
    h_new = jnp.where(do_relabel, hmin + 1, h)

    pushes = jnp.sum(can_push.astype(jnp.int32), dtype=jnp.int32)
    relabels = jnp.sum(do_relabel.astype(jnp.int32), dtype=jnp.int32)
    return (
        h_new,
        e_new,
        cap_new,
        cap_sink_new,
        cap_src_new,
        sink_flow,
        src_flow,
        pushes,
        relabels,
    )


def _kernel_body(
    h_ref,
    e_ref,
    cap_ref,
    cap_sink_ref,
    cap_src_ref,
    h_out,
    e_out,
    cap_out,
    cap_sink_out,
    cap_src_out,
    stats_out,
    *,
    v_total: int,
    k_inner: int,
):
    """Pallas kernel: run up to `k_inner` waves with the state in VMEM."""
    h = h_ref[...]
    e = e_ref[...]
    cap = cap_ref[...]
    cap_sink = cap_sink_ref[...]
    cap_src = cap_src_ref[...]

    zero = np.int32(0)

    def cond(carry):
        i, _h, _e, _cap, _cs, _csrc, _sf, _bf, _pu, _rl, act = carry
        return (i < k_inner) & (act > 0)

    def body(carry):
        i, h, e, cap, cs, csrc, sf, bf, pu, rl, _act = carry
        h, e, cap, cs, csrc, dsf, dbf, dpu, drl = wave(h, e, cap, cs, csrc, v_total)
        act = jnp.sum((e > 0).astype(jnp.int32), dtype=jnp.int32)
        return (i + 1, h, e, cap, cs, csrc, sf + dsf, bf + dbf, pu + dpu, rl + drl, act)

    init_act = jnp.sum((e > 0).astype(jnp.int32), dtype=jnp.int32)
    carry = (zero, h, e, cap, cap_sink, cap_src, zero, zero, zero, zero, init_act)
    (waves, h, e, cap, cap_sink, cap_src, sf, bf, pu, rl, act) = jax.lax.while_loop(
        cond, body, carry
    )

    h_out[...] = h
    e_out[...] = e
    cap_out[...] = cap
    cap_sink_out[...] = cap_sink
    cap_src_out[...] = cap_src
    stats_out[...] = jnp.stack([sf, bf, act, pu, rl, waves])


def make_grid_kernel(height: int, width: int, k_inner: int = K_INNER_DEFAULT):
    """Build the pallas_call for an HxW grid.  `interpret=True` so the kernel
    lowers to plain HLO runnable on the CPU PJRT client (a real-TPU build
    would emit a Mosaic custom-call instead)."""
    v_total = height * width + 2
    shape = (height, width)
    kernel = functools.partial(_kernel_body, v_total=v_total, k_inner=k_inner)
    out_shape = [
        jax.ShapeDtypeStruct(shape, jnp.int32),        # h
        jax.ShapeDtypeStruct(shape, jnp.int32),        # e
        jax.ShapeDtypeStruct((4,) + shape, jnp.int32),  # cap
        jax.ShapeDtypeStruct(shape, jnp.int32),        # cap_sink
        jax.ShapeDtypeStruct(shape, jnp.int32),        # cap_src
        jax.ShapeDtypeStruct((6,), jnp.int32),         # stats
    ]

    def run(h, e, cap, cap_sink, cap_src):
        return pl.pallas_call(
            kernel,
            out_shape=out_shape,
            interpret=True,
        )(h, e, cap, cap_sink, cap_src)

    return run
