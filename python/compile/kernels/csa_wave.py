"""L1 Pallas kernel: lock-free cost-scaling refine wave for the assignment
problem on a complete bipartite graph (paper Algorithm 5.4).

The paper runs one CUDA thread per node; each active node scans its residual
arcs for the minimum *partially reduced cost* ``c'_p(x,y) = c(x,y) - p(y)``
and either pushes one unit of flow along the argmin arc (if admissible,
``min_c'p < -p(x)``) or relabels ``p(x) <- -(min_c'p + eps)``.

TPU adaptation: dense synchronous waves over the ``n x n`` cost matrix.

  * forward half-wave: every active x in X (e(x) > 0) scans its row of
    residual arcs (f == 0), pushes to the argmin y or relabels;
  * backward half-wave: every active y in Y (e(y) > 0) scans its column of
    residual reverse arcs (f == 1) with ``c'_p(y,x) = -c(x,y) - p(x)``,
    pushes back or relabels.

Invariants (complete graph, unit capacities): e(x) in {0,1} and
row-sum(f[x,:]) = 1 - e(x); e(y) = col-sum(f[:,y]) - 1 >= -1.  Two X nodes
may push to the same y in one wave — those are *different* unit-capacity
arcs, exactly as in the lock-free execution; y then becomes active and
pushes the worse unit back.  A push x->y and y->x cannot collide on the same
arc because admissibility of (x,y) and (y,x) is mutually exclusive
(paper Lemma 5.5 case 8).

State (all ``int32``): cost[n,n] (scaled by n+1), f[n,n] in {0,1},
px[n], py[n], ex[n], ey[n], eps[1].

Stats output ``int32[6]``: [active_x, active_y, pushes, relabels, waves, 0].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

INF = np.int32(1 << 30)

K_INNER_DEFAULT = 16


def forward_half_wave(cost, f, px, py, ex, ey, eps):
    """Active X nodes push one unit to their min-reduced-cost Y or relabel."""
    n = cost.shape[0]
    cp = cost - py[None, :]                       # c'_p(x, y)
    cand = jnp.where(f == 0, cp, INF)             # residual (x,y) arcs
    minc = jnp.min(cand, axis=1)
    argy = jnp.argmin(cand, axis=1).astype(jnp.int32)

    active = ex > 0
    admissible = active & (minc < -px) & (minc < INF)
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    onehot = ((col_iota == argy[:, None]) & admissible[:, None]).astype(jnp.int32)

    f_new = f + onehot
    ex_new = ex - admissible.astype(jnp.int32)
    ey_new = ey + jnp.sum(onehot, axis=0)

    do_relabel = active & jnp.logical_not(admissible) & (minc < INF)
    px_new = jnp.where(do_relabel, -(minc + eps), px)

    pushes = jnp.sum(admissible.astype(jnp.int32), dtype=jnp.int32)
    relabels = jnp.sum(do_relabel.astype(jnp.int32), dtype=jnp.int32)
    return f_new, px_new, ex_new, ey_new, pushes, relabels


def backward_half_wave(cost, f, px, py, ex, ey, eps):
    """Active Y nodes push one unit back along their min reverse arc."""
    n = cost.shape[0]
    cpb = -cost - px[:, None]                     # c'_p(y, x), indexed [x, y]
    cand = jnp.where(f == 1, cpb, INF)            # residual (y,x) arcs
    minc = jnp.min(cand, axis=0)                  # per y
    argx = jnp.argmin(cand, axis=0).astype(jnp.int32)

    active = ey > 0
    admissible = active & (minc < -py) & (minc < INF)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    onehot = ((row_iota == argx[None, :]) & admissible[None, :]).astype(jnp.int32)

    f_new = f - onehot
    ey_new = ey - admissible.astype(jnp.int32)
    ex_new = ex + jnp.sum(onehot, axis=1)

    do_relabel = active & jnp.logical_not(admissible) & (minc < INF)
    py_new = jnp.where(do_relabel, -(minc + eps), py)

    pushes = jnp.sum(admissible.astype(jnp.int32), dtype=jnp.int32)
    relabels = jnp.sum(do_relabel.astype(jnp.int32), dtype=jnp.int32)
    return f_new, py_new, ex_new, ey_new, pushes, relabels


def wave(cost, f, px, py, ex, ey, eps):
    """One full wave = forward half-wave then backward half-wave."""
    f, px, ex, ey, pu1, rl1 = forward_half_wave(cost, f, px, py, ex, ey, eps)
    f, py, ex, ey, pu2, rl2 = backward_half_wave(cost, f, px, py, ex, ey, eps)
    return f, px, py, ex, ey, pu1 + pu2, rl1 + rl2


def _kernel_body(
    cost_ref,
    f_ref,
    px_ref,
    py_ref,
    ex_ref,
    ey_ref,
    eps_ref,
    f_out,
    px_out,
    py_out,
    ex_out,
    ey_out,
    stats_out,
    *,
    k_inner: int,
):
    cost = cost_ref[...]
    f = f_ref[...]
    px = px_ref[...]
    py = py_ref[...]
    ex = ex_ref[...]
    ey = ey_ref[...]
    eps = eps_ref[0]

    zero = np.int32(0)

    def activity(ex, ey):
        ax = jnp.sum((ex > 0).astype(jnp.int32), dtype=jnp.int32)
        ay = jnp.sum((ey > 0).astype(jnp.int32), dtype=jnp.int32)
        return ax, ay

    def cond(carry):
        i, _f, _px, _py, ex, ey, _pu, _rl = carry
        ax, ay = activity(ex, ey)
        return (i < k_inner) & (ax + ay > 0)

    def body(carry):
        i, f, px, py, ex, ey, pu, rl = carry
        f, px, py, ex, ey, dpu, drl = wave(cost, f, px, py, ex, ey, eps)
        return (i + 1, f, px, py, ex, ey, pu + dpu, rl + drl)

    carry = (zero, f, px, py, ex, ey, zero, zero)
    waves, f, px, py, ex, ey, pu, rl = jax.lax.while_loop(cond, body, carry)

    ax, ay = activity(ex, ey)
    f_out[...] = f
    px_out[...] = px
    py_out[...] = py
    ex_out[...] = ex
    ey_out[...] = ey
    stats_out[...] = jnp.stack([ax, ay, pu, rl, waves, jnp.zeros_like(waves)])


def make_csa_kernel(n: int, k_inner: int = K_INNER_DEFAULT):
    """Build the pallas_call for an n x n assignment instance."""
    kernel = functools.partial(_kernel_body, k_inner=k_inner)
    out_shape = [
        jax.ShapeDtypeStruct((n, n), jnp.int32),  # f
        jax.ShapeDtypeStruct((n,), jnp.int32),    # px
        jax.ShapeDtypeStruct((n,), jnp.int32),    # py
        jax.ShapeDtypeStruct((n,), jnp.int32),    # ex
        jax.ShapeDtypeStruct((n,), jnp.int32),    # ey
        jax.ShapeDtypeStruct((6,), jnp.int32),    # stats
    ]

    def run(cost, f, px, py, ex, ey, eps):
        return pl.pallas_call(
            kernel,
            out_shape=out_shape,
            interpret=True,
        )(cost, f, px, py, ex, ey, eps)

    return run
