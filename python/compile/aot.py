"""AOT: lower every (kernel, shape) variant to HLO *text* in artifacts/.

HLO text — not ``lowered.compile()`` or a serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
the Rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.

Run via ``make artifacts`` (which no-ops when inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts

Emits:
    grid_wave_{H}x{W}.hlo.txt     for (H, W) in GRID_VARIANTS
    csa_refine_{n}.hlo.txt        for n in CSA_VARIANTS
    manifest.txt                  one line per artifact: name kind dims k_inner
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import (
    csa_example_args,
    grid_example_args,
    make_csa_superstep,
    make_grid_superstep,
)
from compile.kernels.csa_wave import K_INNER_DEFAULT as CSA_K_INNER
from compile.kernels.grid_wave import K_INNER_DEFAULT as GRID_K_INNER

GRID_VARIANTS = [(8, 8), (16, 16), (32, 32), (64, 64)]
CSA_VARIANTS = [8, 16, 30, 32, 64]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_grid(height: int, width: int) -> str:
    fn = make_grid_superstep(height, width)
    lowered = jax.jit(fn).lower(*grid_example_args(height, width))
    return to_hlo_text(lowered)


def lower_csa(n: int) -> str:
    fn = make_csa_superstep(n)
    lowered = jax.jit(fn).lower(*csa_example_args(n))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: ignored single-file path")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact names to (re)build, e.g. csa_refine_8",
    )
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = []

    for h, w in GRID_VARIANTS:
        name = f"grid_wave_{h}x{w}"
        manifest.append(f"{name} grid {h} {w} {GRID_K_INNER}")
        if only is not None and name not in only:
            continue
        text = lower_grid(h, w)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    for n in CSA_VARIANTS:
        name = f"csa_refine_{n}"
        manifest.append(f"{name} csa {n} {n} {CSA_K_INNER}")
        if only is not None and name not in only:
            continue
        text = lower_csa(n)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {out_dir}/manifest.txt ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
