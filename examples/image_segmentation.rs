//! Image segmentation via graph cuts — the §1/§4 application: MAP
//! estimation of a binary MRF by min-cut on the Kolmogorov–Zabih network,
//! solved with the hybrid push-relabel pipeline.
//!
//! ```bash
//! cargo run --release --example image_segmentation -- [HxW] [lambda]
//! ```

use flowmatch::energy::segmentation::{ascii_render, segment_image, segment_image_baseline};
use flowmatch::gridflow::NativeGridExecutor;
use flowmatch::util::{Rng, Timer};
use flowmatch::workloads::grid_gen::synthetic_image;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let (h, w) = args
        .get(1)
        .and_then(|s| s.split_once('x'))
        .map(|(a, b)| (a.parse().unwrap_or(24), b.parse().unwrap_or(24)))
        .unwrap_or((24, 24));
    let lambda: i64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);

    let mut rng = Rng::seeded(7);
    let img = synthetic_image(&mut rng, h, w);

    println!("input image ({h}x{w}, '@'=bright):");
    for i in 0..h {
        let row: String = (0..w)
            .map(|j| match img[i * w + j] {
                0..=90 => ' ',
                91..=160 => '.',
                _ => '@',
            })
            .collect();
        println!("  {row}");
    }

    // The paper's pipeline: MRF -> KZ grid network -> hybrid push-relabel.
    let mut exec = NativeGridExecutor::default();
    let t = Timer::start();
    let seg = segment_image(&img, h, w, lambda, &mut exec)?;
    let hybrid_time = t.elapsed();

    // Sequential Dinic baseline for parity + speed comparison.
    let t = Timer::start();
    let baseline = segment_image_baseline(&img, h, w, lambda)?;
    let baseline_time = t.elapsed();

    assert_eq!(seg.energy, baseline.energy, "engines disagree on MAP energy");

    println!(
        "\nsegmentation ('#'=foreground): energy={} cut={} fg={} px",
        seg.energy, seg.flow, seg.foreground
    );
    print!("{}", ascii_render(&seg.labels, h, w));
    println!(
        "hybrid={:.2} ms  dinic-baseline={:.2} ms  (identical energies)",
        hybrid_time * 1e3,
        baseline_time * 1e3
    );
    Ok(())
}
