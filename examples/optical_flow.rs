//! Optical flow via the assignment problem — the paper's §1 motivating
//! application: feature matching between consecutive frames reduced to
//! max-weight bipartite matching, solved by cost scaling.
//!
//! ```bash
//! cargo run --release --example optical_flow
//! ```

use flowmatch::assignment::csa::SequentialCsa;
use flowmatch::assignment::csa_lockfree::LockFreeCsa;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::opticalflow::compute_flow;
use flowmatch::opticalflow::flow::translate_image;
use flowmatch::util::{Rng, Timer};
use flowmatch::workloads::grid_gen::synthetic_image;

fn main() -> anyhow::Result<()> {
    let (h, w) = (32usize, 32usize);
    let (dy, dx) = (2i64, 1i64);
    let mut rng = Rng::seeded(11);

    // Two synthetic frames: the second is the first translated by (dy,dx)
    // — the ground truth every recovered vector is scored against.
    let frame_a = synthetic_image(&mut rng, h, w);
    let frame_b = translate_image(&frame_a, h, w, dy, dx);

    for (name, solver) in [
        ("csa-seq", &SequentialCsa::default() as &dyn AssignmentSolver),
        ("csa-lockfree", &LockFreeCsa::default()),
    ] {
        let t = Timer::start();
        let field = compute_flow(&frame_a, &frame_b, h, w, 14, solver)?;
        let elapsed = t.elapsed();
        let epe = field.mean_endpoint_error(dy as f64, dx as f64);
        println!(
            "{name:<14} matches={:<3} weight={:<6} mean-EPE={epe:.3} px  time={:.2} ms",
            field.vectors.len(),
            field.matching_weight,
            elapsed * 1e3,
        );
        for v in field.vectors.iter().take(6) {
            println!(
                "  ({:>2},{:>2}) -> ({:>2},{:>2})   flow=({:+},{:+})",
                v.from.0,
                v.from.1,
                v.to.0,
                v.to.1,
                v.to.0 as i64 - v.from.0 as i64,
                v.to.1 as i64 - v.from.1 as i64,
            );
        }
        anyhow::ensure!(epe < 2.5, "{name}: endpoint error too large ({epe})");
    }
    println!("optical flow recovered the ground-truth translation ({dy},{dx})");
    Ok(())
}
