//! Quickstart: the two systems of the paper in ~60 lines.
//!
//! ```bash
//! make artifacts            # once: AOT-compile the kernels
//! cargo run --release --example quickstart
//! ```

use flowmatch::assignment::{self, AssignmentSolver};
use flowmatch::coordinator;
use flowmatch::graph::AssignmentInstance;
use flowmatch::runtime::ArtifactRegistry;
use flowmatch::util::Rng;
use flowmatch::workloads;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::seeded(42);

    // ------------------------------------------------------------------
    // 1. Max flow on a grid graph (§4): the hybrid scheme — device waves
    //    (PJRT artifact if built, native twin otherwise) + host global
    //    relabeling.
    // ------------------------------------------------------------------
    let net = workloads::random_grid(&mut rng, 16, 16, 16, 0.25, 0.25);
    let registry = ArtifactRegistry::discover().ok();
    let (report, backend) = coordinator::solve_grid(&net, 256, registry.as_ref())?;
    println!(
        "max flow on 16x16 grid [{backend:?}]: value = {} ({} waves, {} host rounds)",
        report.flow, report.waves, report.host_rounds
    );

    // Cross-check against a classical sequential engine.
    use flowmatch::maxflow::MaxFlowSolver;
    let mut csr = net.to_flow_network();
    let seq = flowmatch::maxflow::dinic::Dinic.solve(&mut csr)?;
    assert_eq!(report.flow, seq.value);
    println!("  cross-check vs Dinic: OK ({})", seq.value);

    // ------------------------------------------------------------------
    // 2. Assignment on a complete bipartite graph (§5): cost scaling with
    //    the lock-free refine.
    // ------------------------------------------------------------------
    let inst: AssignmentInstance = workloads::uniform_costs(&mut rng, 12, 100);
    let result = assignment::csa_lockfree::LockFreeCsa::default().solve(&inst)?;
    let exact = assignment::hungarian::Hungarian.solve(&inst)?;
    println!(
        "assignment n=12: lock-free CSA weight = {} (Hungarian: {})",
        result.weight, exact.weight
    );
    assert_eq!(result.weight, exact.weight);

    // The same instance through the PJRT device path, when available.
    if let Some(reg) = &registry {
        let mut driver = coordinator::PjrtAssignmentDriver::for_size(reg, inst.n)?;
        let (dev_result, tel) = driver.solve(&inst)?;
        println!(
            "  PJRT path: weight = {} in {} device rounds (padded to n={})",
            dev_result.weight, tel.device_rounds, tel.padded_n
        );
        assert_eq!(dev_result.weight, exact.weight);
    } else {
        println!("  (run `make artifacts` to exercise the PJRT path)");
    }

    println!("quickstart OK");
    Ok(())
}
