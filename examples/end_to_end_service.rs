//! END-TO-END DRIVER (the repo's full-stack validation run): load the AOT
//! artifacts, start the batched assignment service on its device thread,
//! replay a real-time request trace (20 fps of n=30, C<=100 matching
//! problems — exactly the paper's §6 operating point), and report
//! latency/throughput against the paper's 1/20 s real-time bar.
//!
//! Every layer composes here: L1 Pallas waves (AOT-lowered) -> L2
//! super-step loop -> PJRT runtime -> cost-scaling driver with host
//! price updates -> batched service -> trace replay. Results are recorded
//! in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_service
//! ```

use flowmatch::assignment::hungarian::Hungarian;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::coordinator::{AssignmentService, ServiceConfig};
use flowmatch::runtime::{transfer, ArtifactRegistry};
use flowmatch::util::stats::fmt_duration;
use flowmatch::util::{Rng, Timer};
use flowmatch::workloads::{RequestTrace, TraceConfig};

fn main() -> anyhow::Result<()> {
    let requests = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60usize);

    let have_artifacts = ArtifactRegistry::discover().map(|r| !r.is_empty()).unwrap_or(false);
    if !have_artifacts {
        println!("NOTE: no artifacts found; service will run on the native twin.");
        println!("      Run `make artifacts` for the PJRT path.\n");
    }

    // The §6 workload: n = 30, costs <= 100, arriving at 20 fps.
    let cfg = TraceConfig {
        requests,
        n: 30,
        max_weight: 100,
        arrival_gap: 0.05,
        geometric_frac: 0.5,
    };
    let mut rng = Rng::seeded(2026);
    let trace = RequestTrace::generate(&mut rng, &cfg);

    let service = AssignmentService::start(ServiceConfig {
        max_batch: 8,
        use_pjrt: have_artifacts,
        max_n: 30,
    });

    transfer::GLOBAL.reset();
    println!(
        "replaying {} requests (n={}, C<={}, {:.0} fps)...",
        trace.len(),
        cfg.n,
        cfg.max_weight,
        1.0 / cfg.arrival_gap
    );

    let start = Timer::start();
    let mut receivers = Vec::new();
    for req in &trace.requests {
        let now = start.elapsed();
        if req.arrival > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(req.arrival - now));
        }
        receivers.push((req.id, service.submit(req.instance.clone())));
    }

    // Collect replies and verify EVERY answer against the exact baseline.
    let mut optimal = 0usize;
    for (id, rx) in receivers {
        let reply = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("service dropped reply {id}"))?
            .map_err(|e| anyhow::anyhow!(e))?;
        let exact = Hungarian.solve(&trace.requests[id].instance)?;
        anyhow::ensure!(
            reply.weight == exact.weight,
            "request {id}: weight {} != optimum {}",
            reply.weight,
            exact.weight
        );
        optimal += 1;
    }
    let wall = start.elapsed();
    let report = service.shutdown()?;
    let tx = transfer::GLOBAL.snapshot();

    println!("\n=== end-to-end report ===");
    println!("backend            : {}", report.backend);
    println!("requests served    : {} ({} verified optimal)", report.served, optimal);
    println!("wall clock         : {}", fmt_duration(wall));
    println!("throughput         : {:.1} req/s", report.throughput_rps);
    println!("latency p50        : {}", fmt_duration(report.p50_latency));
    println!("latency p99        : {}", fmt_duration(report.p99_latency));
    println!("latency mean       : {}", fmt_duration(report.mean_latency));
    println!(
        "host<->device      : {} H2D calls / {} KiB, {} D2H calls / {} KiB",
        tx.h2d_calls,
        tx.h2d_bytes / 1024,
        tx.d2h_calls,
        tx.d2h_bytes / 1024
    );
    let bar = 0.05;
    println!(
        "paper §6 bar (1/20 s per solve): p50 {} ({} vs {})",
        if report.p50_latency <= bar { "MET" } else { "MISSED" },
        fmt_duration(report.p50_latency),
        fmt_duration(bar)
    );
    anyhow::ensure!(optimal == trace.len(), "not all answers optimal");
    Ok(())
}
