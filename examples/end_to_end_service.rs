//! END-TO-END DRIVER (the repo's full-stack validation run): start the
//! sharded solver pool, replay a mixed real-time trace — 20 fps of
//! n=30, C<=100 matching problems (exactly the paper's §6 operating
//! point) interleaved with grid max-flow solves, including periodic
//! oversized grids — and verify EVERY reply against the sequential
//! oracles (Hungarian for matchings, the native wave engine for
//! grids) while reporting latency against the paper's 1/20 s bar.
//!
//! Every layer composes here: L1 Pallas waves (AOT-lowered, when
//! artifacts exist) -> L2 super-step loop -> PJRT runtime -> backend
//! router -> size-class sharded queues -> persistent solver workers
//! (grid waves on the shared worker pool) -> trace replay.  Results
//! are recorded in EXPERIMENTS.md §E9.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_service
//! ```

use flowmatch::assignment::hungarian::Hungarian;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::coordinator::{solve_grid_with, GridEngine};
use flowmatch::runtime::{transfer, ArtifactRegistry};
use flowmatch::service::{replay, PoolConfig, ProblemInstance, SizeClass, SolverPool};
use flowmatch::util::stats::fmt_duration;
use flowmatch::util::Rng;
use flowmatch::workloads::{MixedTrace, MixedTraceConfig, TraceConfig};

fn main() -> anyhow::Result<()> {
    let requests = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60usize);

    let have_artifacts = ArtifactRegistry::discover()
        .map(|r| !r.is_empty())
        .unwrap_or(false);
    if !have_artifacts {
        println!("NOTE: no artifacts found; assignment requests run on native engines.");
        println!("      Run `make artifacts` for the PJRT path.\n");
    }

    // The §6 workload (n = 30, costs <= 100, 20 fps — the Small
    // shard) plus a grid stream that exercises the other two shards:
    // 48² solves (Medium) with every 4th at 96² (Large).
    let cfg = MixedTraceConfig {
        assign: TraceConfig {
            requests,
            n: 30,
            max_weight: 100,
            arrival_gap: 0.05,
            geometric_frac: 0.5,
        },
        grid_requests: requests / 6,
        grid_size: 48,
        grid_max_cap: 16,
        grid_arrival_gap: 0.3,
        large_every: 4,
        large_size: 96,
        deadline: 0.0,
    };
    let mut rng = Rng::seeded(2026);
    let trace = MixedTrace::generate(&mut rng, &cfg);

    let mut pool_cfg = PoolConfig::default();
    pool_cfg.router.use_pjrt = have_artifacts;
    pool_cfg.router.pjrt_max_n = 30;
    let cycle = pool_cfg.router.cycle_waves;
    let pool = SolverPool::start(pool_cfg);

    transfer::GLOBAL.reset();
    println!(
        "replaying {} requests ({} matchings n={} at {:.0} fps, {} grids {}²/{}²) on {} workers...",
        trace.len(),
        trace.assignment_count(),
        cfg.assign.n,
        1.0 / cfg.assign.arrival_gap,
        trace.grid_count(),
        cfg.grid_size,
        cfg.large_size,
        pool.workers(),
    );

    let out = replay(&pool, &trace, true);
    let report = pool.shutdown();
    let tx = transfer::GLOBAL.snapshot();

    // Verify EVERY answer against the sequential single-solver oracle.
    let mut optimal = 0usize;
    for (id, reply) in &out.replies {
        let reply = reply
            .as_ref()
            .map_err(|e| anyhow::anyhow!("request {id}: {e}"))?;
        match &trace.requests[*id].instance {
            ProblemInstance::Assignment(inst) => {
                let exact = Hungarian.solve(inst)?;
                anyhow::ensure!(
                    reply.outcome.weight() == Some(exact.weight),
                    "request {id}: weight {:?} != optimum {}",
                    reply.outcome.weight(),
                    exact.weight
                );
            }
            ProblemInstance::Grid(net) => {
                let (want, _) = solve_grid_with(net, cycle, None, GridEngine::Native)?;
                anyhow::ensure!(
                    reply.outcome.flow() == Some(want.flow),
                    "request {id}: flow {:?} != oracle {}",
                    reply.outcome.flow(),
                    want.flow
                );
            }
        }
        optimal += 1;
    }

    println!("\n=== end-to-end report ===");
    println!("requests served    : {} ({} verified against oracles)", out.ok, optimal);
    println!("rejected / failed  : {} / {}", out.rejected, out.failed);
    println!("wall clock         : {}", fmt_duration(out.wall_seconds));
    println!("throughput         : {:.1} req/s", out.throughput_rps);
    if let Some(s) = &out.assign {
        println!(
            "matching latency   : p50={} p95={} p99={}",
            fmt_duration(s.p50),
            fmt_duration(s.p95),
            fmt_duration(s.p99)
        );
    }
    if let Some(s) = &out.grid {
        println!(
            "grid latency       : p50={} p95={} p99={}",
            fmt_duration(s.p50),
            fmt_duration(s.p95),
            fmt_duration(s.p99)
        );
    }
    for class in SizeClass::ALL {
        if let Some(s) = &report.class_latency[class.index()] {
            println!(
                "{:<7} shard       : p50={} p99={} ({} reqs)",
                class.name(),
                fmt_duration(s.p50),
                fmt_duration(s.p99),
                s.count
            );
        }
    }
    let backends: Vec<String> = report
        .backends
        .iter()
        .map(|(b, c)| format!("{b}={c}"))
        .collect();
    println!("backends           : [{}]", backends.join(", "));
    println!(
        "host<->device      : {} H2D calls / {} KiB, {} D2H calls / {} KiB",
        tx.h2d_calls,
        tx.h2d_bytes / 1024,
        tx.d2h_calls,
        tx.d2h_bytes / 1024
    );
    let bar = 0.05;
    let p50 = out.assign.as_ref().map_or(0.0, |s| s.p50);
    println!(
        "paper §6 bar (1/20 s per matching): p50 {} ({} vs {})",
        if p50 <= bar { "MET" } else { "MISSED" },
        fmt_duration(p50),
        fmt_duration(bar)
    );
    anyhow::ensure!(optimal == trace.len(), "not all answers verified");
    Ok(())
}
