//! Integration tests for the fault-tolerant serving layer: a seeded
//! chaos trace through the pool (deterministic panics + injected
//! errors) with every surviving reply checked bit-exact against the
//! sequential oracles, breaker trip + route-around under a permanently
//! broken backend, oracle detection of corrupted results, and
//! deadline shedding under an induced stall.

use std::collections::BTreeSet;
use std::time::{Duration, Instant};

use flowmatch::assignment::hungarian::Hungarian;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::coordinator::{solve_grid_with, GridEngine};
use flowmatch::service::{
    replay, FaultPlan, PoolConfig, ProblemInstance, RejectReason, ReplyError, RouterConfig,
    ShardConfig, SolverPool,
};
use flowmatch::util::Rng;
use flowmatch::workloads::{random_grid, MixedTrace, MixedTraceConfig, TraceConfig};

const CYCLE: usize = 128;

fn pool_config(workers: usize) -> PoolConfig {
    PoolConfig {
        workers,
        shard: ShardConfig {
            // n=10 assignment (100 units) is Small, 24² grids (576)
            // are Medium, 48² grids (2304) are Large.
            small_max_units: 256,
            medium_max_units: 1024,
            queue_depth: 64,
            max_units: 1 << 16,
        },
        router: RouterConfig {
            use_pjrt: false, // keep the oracle artifact-free
            cycle_waves: CYCLE,
            par_threads: 2,
            tile_rows: 4,
            retry_backoff_ms: 0, // keep the suite fast; determinism is unit-tested
            ..Default::default()
        },
        session_budget_mb: 64,
    }
}

fn mixed_trace(seed: u64, assign_requests: usize, grid_requests: usize) -> MixedTrace {
    let mut rng = Rng::seeded(seed);
    MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests: assign_requests,
                n: 10,
                max_weight: 60,
                arrival_gap: 0.0,
                ..Default::default()
            },
            grid_requests,
            grid_size: 24,
            grid_max_cap: 12,
            grid_arrival_gap: 0.0,
            large_every: 3,
            large_size: 48,
            ..Default::default()
        },
    )
}

/// The ISSUE acceptance scenario: a fixed chaos seed injects panics
/// and errors into the `native-par` backend mid-trace.  Every request
/// must get exactly one reply, none may be lost, at least one retry
/// must fire, and every success must still match the sequential
/// oracles exactly — faults cost latency, never answers.
#[test]
fn chaos_trace_loses_nothing_and_stays_oracle_exact() {
    let mut cfg = pool_config(3);
    cfg.router.fault = Some(FaultPlan::chaos(7));
    let trace = mixed_trace(701, 12, 6);
    let pool = SolverPool::start(cfg);
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    // Exactly one reply per request, in trace order; nothing dropped.
    assert_eq!(out.sent, trace.len());
    assert_eq!(out.replies.len(), trace.len());
    let ids: BTreeSet<usize> = out.replies.iter().map(|(id, _)| *id).collect();
    assert_eq!(ids.len(), trace.len(), "duplicate or missing reply ids");
    assert_eq!(out.lost, 0, "a chaos run must never lose a reply");

    // chaos(7) panics native-par every 3rd solve; the retry path must
    // have fired and absorbed every fault (fallback engines are clean).
    assert!(report.retries >= 1, "fault plan failed to inject");
    assert_eq!(out.ok, trace.len(), "rejected={} failed={}", out.rejected, out.failed);
    assert_eq!(out.retries, report.retries);

    // Successes are bit-exact against the sequential single-solver
    // oracles — including replies that went through a retry.
    for (id, reply) in &out.replies {
        let reply = reply.as_ref().unwrap_or_else(|e| panic!("request {id}: {e}"));
        match &trace.requests[*id].instance {
            ProblemInstance::Assignment(inst) => {
                let exact = Hungarian.solve(inst).unwrap();
                assert_eq!(
                    reply.outcome.weight(),
                    Some(exact.weight),
                    "request {id}: backend {} suboptimal after {} retries",
                    reply.backend,
                    reply.retries
                );
            }
            ProblemInstance::Grid(net) => {
                let (want, _) = solve_grid_with(net, CYCLE, None, GridEngine::Native).unwrap();
                assert_eq!(
                    reply.outcome.flow(),
                    Some(want.flow),
                    "request {id}: backend {} wrong flow after {} retries",
                    reply.backend,
                    reply.retries
                );
            }
        }
    }
}

/// A backend that panics on *every* solve trips its breaker after
/// `breaker_threshold` consecutive failures; from then on the router
/// skips it up front and traffic converges on the fallback — every
/// request still succeeds, and the report shows the breaker open.
#[test]
fn always_panicking_backend_trips_breaker_and_traffic_converges() {
    let mut cfg = pool_config(1); // single worker: deterministic order
    cfg.router.fault = Some(FaultPlan::new("native-par").with_panic_every(1));
    cfg.router.max_retries = 1;
    cfg.router.breaker_threshold = 2;
    cfg.router.breaker_cooldown = 100; // stays open for the whole run
    let trace = mixed_trace(702, 0, 6);
    let grids = trace.len();
    let pool = SolverPool::start(cfg);
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    assert_eq!(out.ok, grids, "rejected={} failed={}", out.rejected, out.failed);
    assert_eq!(out.lost, 0);
    // The first two requests each burn one retry tripping the breaker;
    // after that native-par is skipped pre-dispatch, not attempted.
    assert_eq!(report.retries, 2);
    assert!(report.breaker_skips >= 1, "open breaker was never routed around");
    // Every reply came from a fallback engine, never the broken one.
    for (id, reply) in &out.replies {
        let reply = reply.as_ref().unwrap_or_else(|e| panic!("request {id}: {e}"));
        assert_ne!(reply.backend, "native-par", "request {id} served by the broken engine");
        if let ProblemInstance::Grid(net) = &trace.requests[*id].instance {
            let (want, _) = solve_grid_with(net, CYCLE, None, GridEngine::Native).unwrap();
            assert_eq!(reply.outcome.flow(), Some(want.flow), "request {id}");
        }
    }
    // The report carries the breaker state for observability.
    assert!(report.breakers_open() >= 1, "{:?}", report.breakers);
    let b = report
        .breakers
        .iter()
        .find(|b| b.backend == "native-par" && b.is_open())
        .expect("native-par breaker open in the report");
    assert!(b.opened_total >= 1);
}

/// Result corruption (wrong-cost faults) is visible to the oracles:
/// the service returns the corrupted answer (it cannot know), and the
/// differential check catches it — the reason chaos mode never sets
/// `wrong_every`, and the knob exists for harness self-tests like this.
#[test]
fn corrupted_results_are_caught_by_the_oracle() {
    let mut cfg = pool_config(1);
    cfg.router.fault = Some(FaultPlan::new("hungarian").with_wrong_every(1));
    cfg.router.max_retries = 0;
    let trace = mixed_trace(703, 5, 0); // Small matchings route to hungarian
    let pool = SolverPool::start(cfg);
    let out = replay(&pool, &trace, false);
    drop(pool.shutdown());

    assert_eq!(out.ok, trace.len());
    for (id, reply) in &out.replies {
        let reply = reply.as_ref().unwrap();
        let ProblemInstance::Assignment(inst) = &trace.requests[*id].instance else {
            unreachable!("assignment-only trace");
        };
        let exact = Hungarian.solve(inst).unwrap();
        // Every solve was corrupted by +1: the differential oracle
        // detects all of them.
        assert_eq!(
            reply.outcome.weight(),
            Some(exact.weight + 1),
            "request {id}: corruption not applied — oracle detection untestable"
        );
    }
}

/// Deadlines shed stale work: with one worker stalled by an injected
/// delay longer than every deadline, the queued requests are shed
/// pre-dispatch (`deadline` reject reason) and the stalled solve is
/// cancelled at its next poll point — no worker time is burned on
/// answers the client has given up on, and nothing is lost.
#[test]
fn deadline_sheds_queued_requests_under_stall() {
    let mut cfg = pool_config(1);
    // Every native solve stalls 80ms; deadlines are 25ms.
    cfg.router.fault = Some(FaultPlan::new("native").with_delay_every(1, 80));
    cfg.router.max_retries = 1;
    let mut rng = Rng::seeded(704);
    let trace = MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests: 0,
                ..Default::default()
            },
            grid_requests: 4,
            grid_size: 12, // 144 units: Small lane -> the native backend
            grid_max_cap: 8,
            grid_arrival_gap: 0.0,
            large_every: 0,
            deadline: 0.025,
            ..Default::default()
        },
    );
    let pool = SolverPool::start(cfg);
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    assert_eq!(out.ok + out.rejected + out.failed, out.sent);
    assert_eq!(out.lost, 0);
    // The requests queued behind the stalled solve passed their
    // deadline waiting and were shed before dispatch.
    assert!(
        out.deadline_misses >= 2,
        "expected pre-dispatch sheds, got {:?}",
        out.reject_reasons
    );
    assert!(out
        .reject_reasons
        .iter()
        .any(|(label, n)| *label == "deadline" && *n >= 2));
    // The server saw at least as many misses (sheds + mid-flight
    // cancellations of the stalled solve).
    assert!(report.deadline_misses >= out.deadline_misses);
}

/// A solve cancelled mid-flight by its deadline is a *client* problem,
/// not a backend fault: it must not charge the backend a breaker
/// strike, must not burn a retry attempt on a fallback engine, and is
/// accounted server-side as a deadline miss.  With `breaker_threshold
/// = 1` a single wrongly-charged strike would open the breaker, so the
/// closed-breaker assertion below is sharp.
#[test]
fn midflight_cancel_charges_no_strike_and_burns_no_retry() {
    let mut cfg = pool_config(1);
    // The solve itself stalls past the deadline (80ms vs 25ms), so the
    // request is dispatched live and cancelled at the next poll point.
    cfg.router.fault = Some(FaultPlan::new("native").with_delay_every(1, 80));
    cfg.router.max_retries = 2;
    cfg.router.breaker_threshold = 1;
    let mut rng = Rng::seeded(705);
    let trace = MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests: 0,
                ..Default::default()
            },
            grid_requests: 1, // a single request: nothing queues behind it
            grid_size: 12,    // 144 units: Small lane -> the native backend
            grid_max_cap: 8,
            grid_arrival_gap: 0.0,
            large_every: 0,
            deadline: 0.025,
            ..Default::default()
        },
    );
    let pool = SolverPool::start(cfg);
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    assert_eq!(out.sent, 1);
    assert_eq!(out.lost, 0);
    // The reply is a cancellation-shaped failure that burned no retry
    // (a fallback attempt could not beat the already-expired deadline).
    match &out.replies[0].1 {
        Err(ReplyError::Failed { retries, .. }) => assert_eq!(*retries, 0),
        // Tight schedules may shed at dispatch instead; both shapes
        // count as a server-side deadline miss and charge no strike.
        Err(ReplyError::Rejected(RejectReason::DeadlineExceeded)) => {}
        other => panic!("expected a cancelled solve, got {other:?}"),
    }
    assert_eq!(report.retries, 0, "cancellation burned a retry");
    assert!(report.deadline_misses >= 1, "miss not accounted server-side");
    // No breaker strike: with threshold 1 any strike would show here.
    assert_eq!(report.breakers_open(), 0, "{:?}", report.breakers);
    assert!(
        report.breakers.iter().all(|b| b.opened_total == 0),
        "cancellation charged a breaker strike: {:?}",
        report.breakers
    );
}

/// Regression for the shard-clog bug: a bounded shard packed with jobs
/// whose deadlines have already passed must not reject fresh work.  The
/// push sweeps the expired jobs out (each replied `DeadlineExceeded`
/// and counted as a miss) and admits the new request, which is then
/// actually served.
#[test]
fn expired_queue_backlog_does_not_block_admission() {
    let mut cfg = pool_config(1);
    cfg.shard.queue_depth = 2;
    // The single worker stalls 150ms on every native solve, keeping it
    // busy while the queue behind it fills and expires.
    cfg.router.fault = Some(FaultPlan::new("native").with_delay_every(1, 150));
    let mut rng = Rng::seeded(706);
    let net = random_grid(&mut rng, 12, 12, 8, 0.25, 0.25);
    let pool = SolverPool::start(cfg);
    // Occupy the worker with a no-deadline solve.
    let busy = pool
        .try_submit_with_deadline(ProblemInstance::Grid(net.clone()), None)
        .expect("first request admitted");
    std::thread::sleep(Duration::from_millis(30)); // worker picks it up
    // Fill the Small shard to its depth with jobs that expire at once.
    let stale: Vec<_> = (0..2)
        .map(|_| {
            pool.try_submit_with_deadline(
                ProblemInstance::Grid(net.clone()),
                Some(Duration::from_millis(1)),
            )
            .expect("admitted up to queue depth")
        })
        .collect();
    std::thread::sleep(Duration::from_millis(10)); // let them expire
    // The regression: before the sweep this was `QueueFull` — dead jobs
    // holding capacity against live traffic.
    let fresh = pool
        .try_submit_with_deadline(ProblemInstance::Grid(net.clone()), None)
        .expect("expired jobs may not hold shard capacity");
    // The swept jobs were answered, not dropped.
    for rx in stale {
        match rx.recv().expect("swept job still gets a reply") {
            Err(ReplyError::Rejected(RejectReason::DeadlineExceeded)) => {}
            other => panic!("expected a deadline shed, got {other:?}"),
        }
    }
    let reply = fresh.recv().expect("fresh reply");
    assert!(reply.is_ok(), "fresh request not served: {reply:?}");
    assert!(busy.recv().expect("busy reply").is_ok());
    let report = pool.shutdown();
    assert!(report.deadline_misses >= 2, "sweep misses not counted");
    assert_eq!(report.served, 2);
}

/// Regression for the backoff-ignores-deadline bug: with a first
/// backend that fails instantly and a retry backoff far longer than the
/// request's deadline, the reply must arrive about when the deadline
/// passes — the backoff sleep is clamped to the remaining budget and
/// the post-sleep cancellation check returns without burning the retry.
#[test]
fn retry_backoff_respects_the_deadline() {
    let mut cfg = pool_config(1);
    cfg.router.fault = Some(FaultPlan::new("native").with_panic_every(1));
    cfg.router.max_retries = 2;
    cfg.router.retry_backoff_ms = 10_000; // would dwarf the 30ms deadline
    let mut rng = Rng::seeded(707);
    let net = random_grid(&mut rng, 12, 12, 8, 0.25, 0.25);
    let pool = SolverPool::start(cfg);
    let t = Instant::now();
    let rx = pool
        .try_submit_with_deadline(ProblemInstance::Grid(net), Some(Duration::from_millis(30)))
        .expect("admitted");
    let reply = rx.recv().expect("reply channel dropped");
    let elapsed = t.elapsed();
    let report = pool.shutdown();
    match reply {
        Err(ReplyError::Failed { retries, .. }) => {
            assert_eq!(retries, 0, "cancelled request burned a retry")
        }
        other => panic!("expected a cancelled failure, got {other:?}"),
    }
    // Far under the 10s backoff; generous slack for a loaded CI box.
    assert!(elapsed < Duration::from_secs(2), "backoff ignored the deadline: {elapsed:?}");
    assert_eq!(report.retries, 0);
    assert!(report.deadline_misses >= 1, "miss not accounted server-side");
}
