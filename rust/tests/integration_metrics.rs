//! Differential tests for the telemetry spine: the registry twins the
//! service maintains must reproduce the client- and server-side
//! reports *exactly* — same update sites, same counts — and the phase
//! breakdowns riding the replies must be coherent with the measured
//! latencies.

use flowmatch::obs;
use flowmatch::service::{
    replay, replay_sessions, PoolConfig, ProblemInstance, RouterConfig, ShardConfig, SolverPool,
};
use flowmatch::util::Rng;
use flowmatch::workloads::{
    DeltaTrace, DeltaTraceConfig, MixedTrace, MixedTraceConfig, TraceConfig,
};

fn test_pool_config(workers: usize) -> PoolConfig {
    PoolConfig {
        workers,
        shard: ShardConfig {
            small_max_units: 256,
            medium_max_units: 1024,
            queue_depth: 64,
            max_units: 1 << 16,
        },
        router: RouterConfig {
            use_pjrt: false,
            cycle_waves: 128,
            par_threads: 2,
            tile_rows: 4,
            ..Default::default()
        },
        session_budget_mb: 64,
    }
}

fn mixed_trace(seed: u64) -> MixedTrace {
    let mut rng = Rng::seeded(seed);
    MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests: 10,
                n: 10,
                max_weight: 60,
                arrival_gap: 0.0,
                ..Default::default()
            },
            grid_requests: 5,
            grid_size: 24,
            grid_max_cap: 12,
            grid_arrival_gap: 0.0,
            large_every: 0,
            ..Default::default()
        },
    )
}

/// Read this pool's `flowmatch_pool_<field>_total{pool="..."}` twin.
fn pool_counter(label: &str, field: &str) -> u64 {
    obs::global()
        .counter_value(&format!("flowmatch_pool_{field}_total{{pool=\"{label}\"}}"))
        .unwrap_or(0)
}

/// The headline differential: every `PoolReport` counter has a registry
/// twin incremented at the identical call site, so after shutdown the
/// two views must be equal — not approximately, exactly.
#[test]
fn pool_report_counters_match_registry_twins_exactly() {
    let trace = mixed_trace(601);
    let pool = SolverPool::start(test_pool_config(3));
    let label = pool.metrics_label().to_string();
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    assert_eq!(pool_counter(&label, "served") as usize, report.served);
    assert_eq!(pool_counter(&label, "rejected") as usize, report.rejected);
    assert_eq!(pool_counter(&label, "failed") as usize, report.failed);
    assert_eq!(pool_counter(&label, "retries"), report.retries);
    assert_eq!(pool_counter(&label, "breaker_skips"), report.breaker_skips);
    assert_eq!(
        pool_counter(&label, "deadline_misses") as usize,
        report.deadline_misses
    );
    assert_eq!(
        pool_counter(&label, "warm_served") as usize,
        report.warm_served
    );
    assert_eq!(
        pool_counter(&label, "sessions_evicted") as usize,
        report.sessions_evicted
    );

    // Reply conservation, read back from the metrics alone: every
    // request sent ended as exactly one of served / rejected / failed.
    assert_eq!(out.sent, out.ok + out.rejected + out.failed);
    assert_eq!(
        (pool_counter(&label, "served")
            + pool_counter(&label, "rejected")
            + pool_counter(&label, "failed")) as usize,
        out.sent
    );

    // Per-backend served twins agree with the report's breakdown.
    for (backend, n) in &report.backends {
        let twin = obs::global()
            .counter_value(&format!(
                "flowmatch_pool_backend_served_total{{pool=\"{label}\",backend=\"{backend}\"}}"
            ))
            .unwrap_or(0);
        assert_eq!(twin as usize, *n, "backend {backend}");
    }

    // The latency histogram saw exactly the served requests.
    let text = obs::global().render_text();
    let count_line = format!("flowmatch_pool_latency_seconds_count{{pool=\"{label}\"}}");
    let counted: u64 = text
        .lines()
        .find_map(|l| l.strip_prefix(&count_line))
        .expect("latency histogram in exposition")
        .trim()
        .parse()
        .unwrap();
    assert_eq!(counted as usize, report.served);
}

/// Every served reply carries a phase breakdown whose queue wait is
/// bounded by the measured latency, grid replies carry nonzero engine
/// op counters, and the engine phase counters land in the registry.
#[test]
fn replies_carry_coherent_phase_breakdowns() {
    use flowmatch::obs::Phase;

    let reg = obs::global();
    let wave_key = "flowmatch_phase_micros_total{family=\"grid\",phase=\"wave_compute\"}";
    let queue_key = "flowmatch_phase_micros_total{family=\"service\",phase=\"queue_wait\"}";
    let wave_before = reg.counter_value(wave_key).unwrap_or(0);

    let trace = mixed_trace(602);
    let pool = SolverPool::start(test_pool_config(2));
    let out = replay(&pool, &trace, false);
    drop(pool.shutdown());

    assert_eq!(out.ok, out.sent, "trace must be fully served");
    let mut grid_replies = 0;
    for (id, reply) in &out.replies {
        let reply = reply.as_ref().unwrap();
        let phases = reply
            .phases
            .as_ref()
            .unwrap_or_else(|| panic!("request {id}: served reply without phases"));
        // Queue wait is measured before the solve starts; it can never
        // exceed the submit-to-reply latency (allow scheduler noise).
        assert!(
            phases.get(Phase::QueueWait) <= reply.latency + 0.005,
            "request {id}: queue_wait {} > latency {}",
            phases.get(Phase::QueueWait),
            reply.latency
        );
        if matches!(trace.requests[*id].instance, ProblemInstance::Grid(_)) {
            grid_replies += 1;
            assert!(phases.waves > 0, "request {id}: grid solve with 0 waves");
            assert!(phases.pushes > 0, "request {id}: grid solve with 0 pushes");
            assert!(
                phases.total_seconds() > 0.0,
                "request {id}: grid solve with an all-zero phase profile"
            );
            // The breakdown is a decomposition of the solve, not an
            // unrelated set of stopwatches: it cannot exceed the
            // end-to-end latency by more than timer noise.
            assert!(
                phases.total_seconds() <= reply.latency + 0.010,
                "request {id}: phases sum {} vs latency {}",
                phases.total_seconds(),
                reply.latency
            );
        }
    }
    assert!(grid_replies > 0, "trace generated no grid requests");
    // Aggregated client view sums the per-reply breakdowns.
    assert!(out.phases.waves > 0 && out.phases.pushes > 0);
    // And the solve-boundary flush advanced the registry's grid wave
    // phase counter (delta-based: the registry is process-global).
    assert!(
        reg.counter_value(wave_key).unwrap_or(0) > wave_before,
        "grid wave_compute phase counter did not advance"
    );
    assert!(
        reg.counter_value(queue_key).unwrap_or(0) > 0,
        "service queue_wait phase counter never recorded"
    );
}

/// The heuristic counters reach the registry end to end: a CSR solve
/// with gap relabeling advances the engine-labelled gap twins by
/// exactly the stats it returned, and a tuned grid solve advances the
/// family-labelled rebalance twin by exactly the phases it reported.
#[test]
fn gap_and_rebalance_counters_land_in_registry() {
    use flowmatch::graph::csr::NetworkBuilder;
    use flowmatch::gridflow::{HostRounds, HybridGridSolver, NativeGridExecutor};
    use flowmatch::maxflow::{fifo::FifoPushRelabel, MaxFlowSolver};
    use flowmatch::parallel::{CommitMode, ParTuning, StripeBalance};

    let reg = obs::global();

    // CSR side: the manufactured bottleneck (s→a→b→t with the sink arc
    // the bottleneck) fires exactly the gap events its stats report,
    // and solve_traced flushes them under the engine's name.
    let gap_key = "flowmatch_engine_gap_relabels_total{engine=\"fifo+gap\"}";
    let nodes_key = "flowmatch_engine_gap_nodes_total{engine=\"fifo+gap\"}";
    let before_gap = reg.counter_value(gap_key).unwrap_or(0);
    let before_nodes = reg.counter_value(nodes_key).unwrap_or(0);
    let mut b = NetworkBuilder::new(4, 0, 3);
    b.add_edge(0, 1, 5, 0);
    b.add_edge(1, 2, 5, 0);
    b.add_edge(2, 3, 2, 0);
    let mut g = b.build().unwrap();
    let stats = FifoPushRelabel::generic().with_gap().solve_traced(&mut g).unwrap();
    assert_eq!(stats.value, 2);
    assert!(stats.gap_relabels > 0, "bottleneck must fire a gap event");
    assert_eq!(
        reg.counter_value(gap_key).unwrap_or(0) - before_gap,
        stats.gap_relabels
    );
    assert_eq!(
        reg.counter_value(nodes_key).unwrap_or(0) - before_nodes,
        stats.gap_nodes
    );

    // Grid side: a weighted/merged striped solve reports its re-cuts in
    // the reply phases, and the solve-boundary flush twins them under
    // family="grid" (no other tuned solve runs in this binary, so the
    // delta is exact whatever the count is).
    let reb_key = "flowmatch_engine_rebalances_total{family=\"grid\"}";
    let before_reb = reg.counter_value(reb_key).unwrap_or(0);
    let mut rng = Rng::seeded(604);
    let net = flowmatch::workloads::random_grid(&mut rng, 12, 6, 9, 0.3, 0.3);
    let mut exec = NativeGridExecutor::default();
    let report = HybridGridSolver::with_cycle(16)
        .with_host_rounds(HostRounds::Striped)
        .with_tuning(ParTuning {
            balance: StripeBalance::Weighted,
            commit: CommitMode::Merged,
        })
        .solve(&net, &mut exec)
        .unwrap();
    assert_eq!(
        reg.counter_value(reb_key).unwrap_or(0) - before_reb,
        report.phases.rebalances
    );
}

/// Micro-batching counters have registry twins too: run a pool with
/// batching engaged (deep closed-loop grid burst, generous linger) and
/// pin every batch field of the `PoolReport` against its
/// `flowmatch_pool_*` twin — exactly, not approximately.  A second pool
/// at the default `batch_max = 1` must leave all four at zero.
#[test]
fn batch_counters_match_registry_twins_exactly() {
    let mut rng = Rng::seeded(605);
    let trace = MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests: 4,
                n: 10,
                max_weight: 60,
                arrival_gap: 0.0,
                ..Default::default()
            },
            grid_requests: 10,
            grid_size: 24,
            grid_max_cap: 12,
            grid_arrival_gap: 0.0,
            large_every: 0,
            ..Default::default()
        },
    );

    let mut cfg = test_pool_config(2);
    cfg.router.batch_max = 8;
    cfg.router.batch_linger_us = 20_000;
    let pool = SolverPool::start(cfg);
    let label = pool.metrics_label().to_string();
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    assert_eq!(out.lost, 0);
    assert!(report.batches >= 1, "burst must cut at least one batch");
    assert_eq!(pool_counter(&label, "batches") as usize, report.batches);
    assert_eq!(
        pool_counter(&label, "batched_jobs") as usize,
        report.batched_jobs
    );
    assert_eq!(
        pool_counter(&label, "padding_waste_cells"),
        report.padding_waste_cells
    );
    assert_eq!(
        pool_counter(&label, "linger_sheds") as usize,
        report.linger_sheds
    );
    // Uniform 24x24 batches pad nothing: waste counts the envelope
    // minus the logical cells, and here every slot *is* the envelope.
    assert_eq!(report.padding_waste_cells, 0);

    let plain = SolverPool::start(test_pool_config(2));
    let plain_label = plain.metrics_label().to_string();
    drop(replay(&plain, &trace, false));
    let plain_report = plain.shutdown();
    assert_eq!(plain_report.batches, 0, "default batch_max must not batch");
    assert_eq!(pool_counter(&plain_label, "batches"), 0);
    assert_eq!(pool_counter(&plain_label, "batched_jobs"), 0);
}

/// Warm-session replay: warm replies carry a breakdown too, and the
/// pool's warm-served twin matches the client's count of warm hits.
#[test]
fn session_replay_metrics_match() {
    let dcfg = DeltaTraceConfig {
        sessions: 2,
        updates_per_session: 4,
        edits_per_update: 3,
        grid_size: 16,
        ..Default::default()
    };
    let mut rng = Rng::seeded(603);
    let trace = DeltaTrace::generate(&mut rng, &dcfg);
    let pool = SolverPool::start(test_pool_config(2));
    let label = pool.metrics_label().to_string();
    let out = replay_sessions(&pool, &trace);
    let report = pool.shutdown();

    assert_eq!(out.lost, 0);
    assert_eq!(report.warm_served, out.warm_hits);
    assert_eq!(pool_counter(&label, "warm_served") as usize, out.warm_hits);
    for (id, reply) in &out.replies {
        if let Ok(reply) = reply {
            assert!(
                reply.phases.is_some(),
                "request {id}: session reply without phases"
            );
        }
    }
}
