//! Integration: the application pipelines (segmentation, optical flow)
//! and the batched service, end to end.

use flowmatch::assignment::csa::SequentialCsa;
use flowmatch::assignment::hungarian::Hungarian;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::coordinator::{AssignmentService, ServiceConfig};
use flowmatch::energy::segmentation::{segment_image, segment_image_baseline};
use flowmatch::energy::{build_kz_network, BinaryMrf, PairwiseTerm};
use flowmatch::gridflow::NativeGridExecutor;
use flowmatch::opticalflow::compute_flow;
use flowmatch::opticalflow::flow::translate_image;
use flowmatch::util::Rng;
use flowmatch::workloads::grid_gen::synthetic_image;
use flowmatch::workloads::{RequestTrace, TraceConfig};

#[test]
fn segmentation_pipeline_hybrid_vs_baseline_on_many_images() {
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::seeded(seed);
        let (h, w) = (16, 16);
        let img = synthetic_image(&mut rng, h, w);
        let mut exec = NativeGridExecutor::default();
        let a = segment_image(&img, h, w, 12, &mut exec).unwrap();
        let b = segment_image_baseline(&img, h, w, 12).unwrap();
        assert_eq!(a.energy, b.energy, "seed={seed}");
        assert_eq!(a.labels, b.labels, "seed={seed}: different MAP labellings");
    }
}

#[test]
fn kz_energy_certificate_on_random_regular_mrfs() {
    let mut rng = Rng::seeded(4);
    for _ in 0..6 {
        let (h, w) = (2 + rng.index(2), 2 + rng.index(3));
        let mut mrf = BinaryMrf::new(h, w);
        for p in 0..h * w {
            mrf.unary[p] = (rng.range_i64(0, 25), rng.range_i64(0, 25));
        }
        for i in 0..h {
            for j in 0..w {
                let p = mrf.cell(i, j);
                if i + 1 < h && rng.chance(0.8) {
                    mrf.pair_s[p] = Some(PairwiseTerm::potts(rng.range_i64(0, 9)));
                }
                if j + 1 < w && rng.chance(0.8) {
                    mrf.pair_e[p] = Some(PairwiseTerm::potts(rng.range_i64(0, 9)));
                }
            }
        }
        let kz = build_kz_network(&mrf).unwrap();
        use flowmatch::maxflow::MaxFlowSolver;
        let mut g = kz.network.to_flow_network();
        let stats = flowmatch::maxflow::highest::HighestLabel::default()
            .solve(&mut g)
            .unwrap();
        let (_, want) = mrf.brute_force_min();
        assert_eq!(stats.value + kz.constant, want);
    }
}

#[test]
fn optical_flow_recovers_translations() {
    let mut rng = Rng::seeded(5);
    let (h, w) = (24, 24);
    let img = synthetic_image(&mut rng, h, w);
    for (dy, dx) in [(1i64, 0i64), (0, 2), (2, 2)] {
        let moved = translate_image(&img, h, w, dy, dx);
        let field = compute_flow(&img, &moved, h, w, 10, &SequentialCsa::default()).unwrap();
        let epe = field.mean_endpoint_error(dy as f64, dx as f64);
        assert!(epe < 3.0, "({dy},{dx}): endpoint error {epe}");
    }
}

#[test]
fn service_replays_trace_with_all_optimal_answers() {
    let cfg = TraceConfig {
        requests: 12,
        n: 10,
        max_weight: 100,
        arrival_gap: 0.0,
        geometric_frac: 0.5,
    };
    let mut rng = Rng::seeded(6);
    let trace = RequestTrace::generate(&mut rng, &cfg);
    let service = AssignmentService::start(ServiceConfig {
        max_batch: 4,
        use_pjrt: false, // native twin: keeps this test artifact-free
        max_n: 16,
    });
    let receivers: Vec<_> = trace
        .requests
        .iter()
        .map(|r| (r.id, service.submit(r.instance.clone())))
        .collect();
    for (id, rx) in receivers {
        let reply = rx.recv().unwrap().unwrap();
        let want = Hungarian.solve(&trace.requests[id].instance).unwrap();
        assert_eq!(reply.weight, want.weight, "request {id}");
    }
    let report = service.shutdown().unwrap();
    assert_eq!(report.served, 12);
    assert!(report.p50_latency > 0.0);
}

#[test]
fn service_pjrt_backend_when_artifacts_present() {
    if flowmatch::runtime::ArtifactRegistry::discover().is_err() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let service = AssignmentService::start(ServiceConfig {
        max_batch: 4,
        use_pjrt: true,
        max_n: 16,
    });
    let mut rng = Rng::seeded(7);
    let inst = flowmatch::workloads::uniform_costs(&mut rng, 12, 100);
    let want = Hungarian.solve(&inst).unwrap();
    let reply = service.submit(inst).recv().unwrap().unwrap();
    assert_eq!(reply.weight, want.weight);
    assert_eq!(reply.backend, "pjrt");
    let report = service.shutdown().unwrap();
    assert_eq!(report.backend, "pjrt");
}
