//! Property tests for the max-flow stack (in-tree `prop` harness):
//! engine-vs-engine parity with certificates on random instances, wave
//! invariants, and heuristic safety.

use flowmatch::graph::csr::NetworkBuilder;
use flowmatch::graph::validate::assert_max_flow;
use flowmatch::gridflow::{self, native_wave, HybridGridSolver, NativeGridExecutor};
use flowmatch::maxflow::{self, MaxFlowSolver};
use flowmatch::prop::{forall, Config};
use flowmatch::util::Rng;
use flowmatch::workloads::random_grid;
use flowmatch::{prop_assert, prop_assert_eq};

/// Random sparse digraph with s = 0, t = n-1.
fn random_network(rng: &mut Rng) -> flowmatch::graph::FlowNetwork {
    let n = 4 + rng.index(12);
    let mut b = NetworkBuilder::new(n, 0, n - 1);
    let m = n + rng.index(3 * n);
    for _ in 0..m {
        let u = rng.index(n);
        let mut v = rng.index(n);
        if u == v {
            v = (v + 1) % n;
        }
        b.add_edge(u, v, rng.range_i64(0, 20), 0);
    }
    // Guarantee some source/sink incidence.
    let v1 = 1 + rng.index(n - 2);
    let c1 = rng.range_i64(1, 20);
    b.add_edge(0, v1, c1, 0);
    let v2 = 1 + rng.index(n - 2);
    let c2 = rng.range_i64(1, 20);
    b.add_edge(v2, n - 1, c2, 0);
    b.build().unwrap()
}

#[test]
fn prop_engines_agree_with_certificates() {
    forall(
        Config::cases(60).seed(0xF10).named("engines agree"),
        |rng| {
            let base = random_network(rng);
            let mut value = None;
            for engine in maxflow::all_engines() {
                let mut g = base.clone();
                let stats = engine
                    .solve(&mut g)
                    .map_err(|e| format!("{}: {e}", engine.name()))?;
                assert_max_flow(&g, stats.value).map_err(|e| format!("{}: {e}", engine.name()))?;
                match value {
                    None => value = Some(stats.value),
                    Some(v) => prop_assert_eq!(stats.value, v, engine.name()),
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_grid_hybrid_matches_dinic() {
    forall(
        Config::cases(30).seed(0xF11).named("grid hybrid parity"),
        |rng| {
            let h = 3 + rng.index(8);
            let w = 3 + rng.index(8);
            let cap = 1 + rng.range_i64(0, 30);
            let net = random_grid(rng, h, w, cap, 0.35, 0.35);
            let cycle = 1 + rng.index(200);
            let mut exec = NativeGridExecutor::default();
            let report = HybridGridSolver::with_cycle(cycle)
                .solve(&net, &mut exec)
                .map_err(|e| e.to_string())?;
            let mut g = net.to_flow_network();
            let want = maxflow::dinic::Dinic.solve(&mut g).map_err(|e| e.to_string())?;
            prop_assert_eq!(report.flow, want.value, format!("cycle={cycle} {h}x{w}"));
            Ok(())
        },
    );
}

#[test]
fn prop_wave_invariants() {
    forall(Config::cases(60).seed(0xF12).named("wave invariants"), |rng| {
        let h = 2 + rng.index(7);
        let w = 2 + rng.index(7);
        let cap = 1 + rng.range_i64(0, 15);
        let net = random_grid(rng, h, w, cap, 0.4, 0.4);
        let (mut st, total) = gridflow::init_state(&net);
        let mut sink = 0i64;
        let mut src = 0i64;
        let waves = 1 + rng.index(50);
        let mut h_prev = st.h.clone();
        for _ in 0..waves {
            let wstat = native_wave(&mut st);
            sink += wstat.sink_flow;
            src += wstat.src_flow;
            // Mass conservation.
            let excess_sum: i64 = st.e.iter().map(|&e| e as i64).sum();
            prop_assert_eq!(excess_sum + sink + src, total, "mass");
            // Heights monotone.
            prop_assert!(
                st.h.iter().zip(&h_prev).all(|(a, b)| a >= b),
                "height decreased"
            );
            // Caps non-negative.
            prop_assert!(st.cap.iter().all(|&c| c >= 0), "negative residual");
            prop_assert!(st.cap_sink.iter().all(|&c| c >= 0), "negative sink cap");
            prop_assert!(st.cap_src.iter().all(|&c| c >= 0), "negative src cap");
            h_prev = st.h.clone();
        }
        Ok(())
    });
}

#[test]
fn prop_lockfree_any_thread_count() {
    forall(
        Config::cases(25).seed(0xF13).named("lockfree threads"),
        |rng| {
            let base = random_network(rng);
            let mut g0 = base.clone();
            let want = maxflow::dinic::Dinic.solve(&mut g0).map_err(|e| e.to_string())?;
            let threads = 1 + rng.index(4);
            let mut g = base.clone();
            let stats = maxflow::lockfree::LockFree::with_threads(threads)
                .solve(&mut g)
                .map_err(|e| e.to_string())?;
            prop_assert_eq!(stats.value, want.value, format!("threads={threads}"));
            assert_max_flow(&g, stats.value).map_err(|e| e.to_string())?;
            Ok(())
        },
    );
}

#[test]
fn prop_striped_relabel_matches_sequential_heights() {
    use flowmatch::maxflow::global_relabel::{
        global_relabel, global_relabel_striped, RelabelScratch,
    };
    use flowmatch::parallel::Lanes;
    use flowmatch::service::WorkerPool;

    let pool = WorkerPool::new(3);
    forall(
        Config::cases(40).seed(0xF15).named("striped relabel parity"),
        |rng| {
            let base = random_network(rng);
            let mut g = base.clone();
            // Mid-solve residual state: a few augmentations in.
            let _ = maxflow::edmonds_karp::EdmondsKarp.solve(&mut g);
            let mut h_seq = vec![0i64; g.node_count()];
            let want = global_relabel(&g, &mut h_seq);
            let mut scratch = RelabelScratch::default();
            for lanes in [Lanes::Seq, Lanes::Scoped { threads: 3 }, Lanes::Pool(&pool)] {
                let mut h_par = vec![0i64; g.node_count()];
                let got = global_relabel_striped(&g, &mut h_par, &mut scratch, &lanes);
                prop_assert_eq!(&h_par, &h_seq, format!("lanes width {}", lanes.width()));
                prop_assert_eq!(got.reached, want.reached, "reached");
                prop_assert_eq!(got.gap_lifted, want.gap_lifted, "gap_lifted");
            }
            Ok(())
        },
    );
}

/// Engines with a lent relabel pool must reproduce the pool-less run
/// *exactly* (values and operation counters) — the striped relabel is a
/// drop-in — on an instance large enough to cross the striped-path
/// size threshold.
#[test]
fn pooled_engines_bit_exact_on_large_instance() {
    use flowmatch::maxflow::global_relabel::STRIPED_RELABEL_MIN_NODES;
    use flowmatch::service::WorkerPool;
    use std::sync::Arc;

    let n = STRIPED_RELABEL_MIN_NODES + 64;
    let mut rng = Rng::seeded(0xF16);
    let mut b = NetworkBuilder::new(n, 0, n - 1);
    for i in 0..n - 1 {
        b.add_edge(i, i + 1, rng.range_i64(1, 12), 0);
    }
    for _ in 0..3 * n {
        let u = rng.index(n);
        let mut v = rng.index(n);
        if u == v {
            v = (v + 1) % n;
        }
        b.add_edge(u, v, rng.range_i64(0, 9), 0);
    }
    let base = b.build().unwrap();

    let pool = Arc::new(WorkerPool::new(4));
    let seq_engines = maxflow::all_engines();
    let pooled_engines = maxflow::all_engines_with(Some(Arc::clone(&pool)));
    for (seq, pooled) in seq_engines.iter().zip(&pooled_engines) {
        let mut g1 = base.clone();
        let want = seq.solve(&mut g1).unwrap();
        let mut g2 = base.clone();
        let got = pooled.solve(&mut g2).unwrap();
        assert_eq!(got.value, want.value, "{} value", seq.name());
        // The deterministic engines must match work counters too — this
        // covers the gap/scaling variants, whose striped gap lifts must
        // be drop-ins just like the striped relabel; the lock-free
        // engines' counters are scheduling-dependent either way, so
        // only their values are pinned.
        if !seq.name().starts_with("lockfree") {
            assert_eq!(got, want, "{} stats", seq.name());
        }
        assert_max_flow(&g2, got.value).unwrap();
    }

    // The ARG ablation with a pooled striped BFS stays correct too.
    let mut g = base.clone();
    let stats = maxflow::lockfree::LockFree::with_arg(3)
        .with_relabel_pool(pool)
        .solve(&mut g)
        .unwrap();
    let mut g0 = base.clone();
    let want = maxflow::dinic::Dinic.solve(&mut g0).unwrap();
    assert_eq!(stats.value, want.value, "arg+pool value");
    assert_max_flow(&g, stats.value).unwrap();
}

/// §E15 differential suite: every gap × scaling combination, on both
/// the sequential and the striped (pooled, gate forced to 0) relabel
/// paths, must agree with the Dinic oracle on RMF instances — the
/// layered family the heuristics target.  The striped runs must also
/// be *bit-exact* with their sequential twins (same counters), since
/// the striped relabel and gap lift are drop-ins.
#[test]
fn prop_rmf_gap_scaling_differential() {
    use flowmatch::maxflow::ScalingMode;
    use flowmatch::service::WorkerPool;
    use flowmatch::workloads::rmf_network;
    use std::sync::Arc;

    let pool = Arc::new(WorkerPool::new(3));
    forall(
        Config::cases(6).seed(0xE15).named("rmf gap/scaling differential"),
        |rng| {
            let a = 2 + rng.index(2);
            let frames = 2 + rng.index(3);
            let base = rmf_network(rng, a, frames, 6);
            let mut g0 = base.clone();
            let want = maxflow::dinic::Dinic
                .solve(&mut g0)
                .map_err(|e| e.to_string())?
                .value;
            for gap in [false, true] {
                for scaling in [ScalingMode::Off, ScalingMode::Delta] {
                    let mut engines: Vec<Box<dyn MaxFlowSolver>> = Vec::new();
                    let mut fifo = maxflow::fifo::FifoPushRelabel::default().with_scaling(scaling);
                    let mut hybrid = maxflow::hybrid::Hybrid::with_cycle(64).with_scaling(scaling);
                    if gap {
                        fifo = fifo.with_gap();
                        hybrid = hybrid.with_gap();
                    }
                    let mut highest = maxflow::highest::HighestLabel::default().with_scaling(scaling);
                    highest.gap = gap;
                    engines.push(Box::new(fifo.clone()));
                    engines.push(Box::new(hybrid.clone()));
                    engines.push(Box::new(highest.clone()));
                    // Striped twins: lend the pool and force the gate to
                    // 0 so even these small instances take the striped
                    // relabel + gap-lift paths.
                    engines.push(Box::new(
                        fifo.with_striped_min_nodes(0)
                            .with_relabel_pool(Arc::clone(&pool)),
                    ));
                    engines.push(Box::new(
                        hybrid
                            .with_striped_min_nodes(0)
                            .with_relabel_pool(Arc::clone(&pool)),
                    ));
                    engines.push(Box::new(
                        highest
                            .with_striped_min_nodes(0)
                            .with_relabel_pool(Arc::clone(&pool)),
                    ));
                    let mut seq_stats = Vec::new();
                    for (i, engine) in engines.iter().enumerate() {
                        let mut g = base.clone();
                        let stats = engine
                            .solve(&mut g)
                            .map_err(|e| format!("{}: {e}", engine.name()))?;
                        prop_assert_eq!(
                            stats.value,
                            want,
                            format!("{} gap={gap} scaling={}", engine.name(), scaling.name())
                        );
                        assert_max_flow(&g, stats.value)
                            .map_err(|e| format!("{}: {e}", engine.name()))?;
                        if i < 3 {
                            seq_stats.push(stats);
                        } else {
                            prop_assert_eq!(
                                &stats,
                                &seq_stats[i - 3],
                                format!("{} striped twin not bit-exact", engine.name())
                            );
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_global_relabel_heights_are_valid_distances() {
    forall(
        Config::cases(40).seed(0xF14).named("global relabel validity"),
        |rng| {
            let base = random_network(rng);
            let mut g = base.clone();
            // Push some arbitrary flow via a few augmentations.
            let _ = maxflow::edmonds_karp::EdmondsKarp.solve(&mut g);
            let mut h = vec![0i64; g.node_count()];
            maxflow::global_relabel::global_relabel(&g, &mut h);
            // Validity: every residual arc satisfies h(u) <= h(v) + 1...
            for u in 0..g.node_count() {
                for &e in g.out_edges(u) {
                    if g.residual(e) > 0 && u != g.source() {
                        let v = g.edge_head(e);
                        // ...unless u was gap-lifted to n (excluded from
                        // useful work by construction).
                        if h[u] < g.node_count() as i64 {
                            prop_assert!(
                                h[u] <= h[v] + 1,
                                "invalid labelling: h({u})={} h({v})={}",
                                h[u],
                                h[v]
                            );
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
