//! Integration: every max-flow engine against every workload family, with
//! min-cut certificates, plus hybrid/PJRT parity on grids.

use flowmatch::graph::validate::assert_max_flow;
use flowmatch::graph::{dimacs, GridNetwork};
use flowmatch::gridflow::{HybridGridSolver, NativeGridExecutor};
use flowmatch::maxflow::{self, MaxFlowSolver};
use flowmatch::runtime::{ArtifactRegistry, GridDevice};
use flowmatch::util::Rng;
use flowmatch::workloads::{random_grid, rmf_network};

fn grid_cases() -> Vec<(String, GridNetwork)> {
    let mut out = Vec::new();
    for (seed, h, w, cap) in [
        (1u64, 8usize, 8usize, 10i64),
        (2, 16, 16, 25),
        (3, 8, 16, 5),
        (4, 12, 12, 100),
    ] {
        let mut rng = Rng::seeded(seed);
        out.push((
            format!("grid{h}x{w}s{seed}"),
            random_grid(&mut rng, h, w, cap, 0.3, 0.3),
        ));
    }
    out
}

#[test]
fn all_engines_agree_with_certificates_on_grids() {
    for (name, net) in grid_cases() {
        let mut reference = None;
        for engine in maxflow::all_engines() {
            let mut g = net.to_flow_network();
            let stats = engine.solve(&mut g).unwrap();
            assert_max_flow(&g, stats.value)
                .unwrap_or_else(|e| panic!("{name}/{}: {e}", engine.name()));
            match reference {
                None => reference = Some(stats.value),
                Some(v) => assert_eq!(stats.value, v, "{name}/{}", engine.name()),
            }
        }
    }
}

#[test]
fn all_engines_agree_on_rmf_networks() {
    for (seed, a, frames) in [(1u64, 3usize, 4usize), (2, 4, 3)] {
        let mut rng = Rng::seeded(seed);
        let base = rmf_network(&mut rng, a, frames, 12);
        let mut reference = None;
        for engine in maxflow::all_engines() {
            let mut g = base.clone();
            let stats = engine.solve(&mut g).unwrap();
            assert_max_flow(&g, stats.value)
                .unwrap_or_else(|e| panic!("rmf/{}: {e}", engine.name()));
            match reference {
                None => reference = Some(stats.value),
                Some(v) => assert_eq!(stats.value, v, "rmf/{}", engine.name()),
            }
        }
    }
}

#[test]
fn hybrid_grid_solver_matches_csr_engines() {
    for (name, net) in grid_cases() {
        let mut exec = NativeGridExecutor::default();
        let report = HybridGridSolver::with_cycle(128)
            .solve(&net, &mut exec)
            .unwrap();
        let mut g = net.to_flow_network();
        let want = maxflow::dinic::Dinic.solve(&mut g).unwrap();
        assert_eq!(report.flow, want.value, "{name}");
    }
}

#[test]
fn pjrt_hybrid_matches_native_on_grids() {
    let Ok(reg) = ArtifactRegistry::discover() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for seed in [1u64, 9] {
        let mut rng = Rng::seeded(seed);
        let net = random_grid(&mut rng, 16, 16, 20, 0.3, 0.3);
        let Ok(mut dev) = GridDevice::for_shape(&reg, 16, 16) else {
            eprintln!("skipping: no 16x16 artifact");
            return;
        };
        let solver = HybridGridSolver::with_cycle(256);
        let pjrt = solver.solve(&net, &mut dev).unwrap();
        let mut exec = NativeGridExecutor::default();
        let native = solver.solve(&net, &mut exec).unwrap();
        assert_eq!(pjrt.flow, native.flow, "seed={seed}");
        assert_eq!(pjrt.waves, native.waves, "seed={seed}: wave counts differ");
        assert_eq!(pjrt.host_rounds, native.host_rounds, "seed={seed}");
    }
}

#[test]
fn cycle_sweep_is_invariant_in_value() {
    let mut rng = Rng::seeded(5);
    let net = random_grid(&mut rng, 12, 12, 15, 0.3, 0.3);
    let mut g = net.to_flow_network();
    let want = maxflow::dinic::Dinic.solve(&mut g).unwrap().value;
    for cycle in [1usize, 16, 64, 512, 4096] {
        let mut exec = NativeGridExecutor::default();
        let report = HybridGridSolver::with_cycle(cycle)
            .solve(&net, &mut exec)
            .unwrap();
        assert_eq!(report.flow, want, "cycle={cycle}");
    }
}

#[test]
fn lockfree_thread_sweep_parity() {
    let mut rng = Rng::seeded(6);
    let base = rmf_network(&mut rng, 3, 3, 9);
    let mut g = base.clone();
    let want = maxflow::dinic::Dinic.solve(&mut g).unwrap().value;
    for threads in [1, 2, 3, 4, 8] {
        let mut g = base.clone();
        let stats = maxflow::lockfree::LockFree::with_threads(threads)
            .solve(&mut g)
            .unwrap();
        assert_eq!(stats.value, want, "threads={threads}");
        assert_max_flow(&g, stats.value).unwrap();
    }
}

#[test]
fn dimacs_roundtrip_preserves_maxflow() {
    let mut rng = Rng::seeded(7);
    let net = random_grid(&mut rng, 6, 6, 8, 0.4, 0.4);
    let g0 = net.to_flow_network();
    let text = dimacs::write_max_flow(&g0);
    let mut g1 = dimacs::MaxFlowFile::parse(&text).unwrap().to_network().unwrap();
    let mut g2 = net.to_flow_network();
    let a = maxflow::dinic::Dinic.solve(&mut g1).unwrap();
    let b = maxflow::dinic::Dinic.solve(&mut g2).unwrap();
    assert_eq!(a.value, b.value);
}

#[test]
fn heuristics_ablation_never_changes_value_and_reduces_work() {
    let mut rng = Rng::seeded(8);
    let net = random_grid(&mut rng, 16, 16, 30, 0.25, 0.25);
    let mut g1 = net.to_flow_network();
    let with = maxflow::fifo::FifoPushRelabel::default().solve(&mut g1).unwrap();
    let mut g2 = net.to_flow_network();
    let without = maxflow::fifo::FifoPushRelabel::generic().solve(&mut g2).unwrap();
    assert_eq!(with.value, without.value);
    // The claim under test is C2: heuristics reduce total work on
    // realistic grids (allow equality for degenerate cases).
    assert!(
        with.work() <= without.work(),
        "global relabeling increased work: {} vs {}",
        with.work(),
        without.work()
    );
}
