//! Integration tests for the adaptive execution layer: static mode
//! must reproduce the PR 3 routing tables verbatim, adaptive mode must
//! re-route across engines (cold start + probing) and spill saturated
//! Large grid work — and in every mode, every reply must stay exact
//! against the sequential oracles.
//!
//! The EWMA winner-flip itself is unit-tested deterministically in
//! `service::adaptive` (injected latencies, no wall clock); here we
//! drive the full pool.

use std::collections::BTreeSet;

use flowmatch::assignment::hungarian::Hungarian;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::coordinator::{solve_grid_with, GridEngine};
use flowmatch::service::{
    replay, Family, PoolConfig, ProblemInstance, RouterConfig, RoutingMode, ShardConfig,
    SizeClass, SolverPool,
};
use flowmatch::util::Rng;
use flowmatch::workloads::{MixedTrace, MixedTraceConfig, TraceConfig};

const CYCLE: usize = 128;

fn pool_config(workers: usize, routing: RoutingMode) -> PoolConfig {
    PoolConfig {
        workers,
        shard: ShardConfig {
            // n=10 assignment (100 units) is Small, 24² grids (576)
            // are Medium, 48² grids (2304) are Large.
            small_max_units: 256,
            medium_max_units: 1024,
            queue_depth: 64,
            max_units: 1 << 16,
        },
        router: RouterConfig {
            use_pjrt: false, // keep the oracle artifact-free
            cycle_waves: CYCLE,
            par_threads: 2,
            tile_rows: 4,
            routing,
            probe_every: 2,
            ..Default::default()
        },
        session_budget_mb: 64,
    }
}

fn mixed_trace(seed: u64, assign_requests: usize, grid_requests: usize) -> MixedTrace {
    let mut rng = Rng::seeded(seed);
    MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests: assign_requests,
                n: 10,
                max_weight: 60,
                arrival_gap: 0.0,
                ..Default::default()
            },
            grid_requests,
            grid_size: 24,
            grid_max_cap: 12,
            grid_arrival_gap: 0.0,
            large_every: 3,
            large_size: 48,
            ..Default::default()
        },
    )
}

/// Check every reply against the sequential single-solver oracles and
/// return the set of backends seen per (family, class).
fn verify_against_oracles(
    trace: &MixedTrace,
    replies: &[(usize, Result<flowmatch::service::SolveReply, flowmatch::service::ReplayError>)],
) -> BTreeSet<(Family, SizeClass, &'static str)> {
    let mut seen = BTreeSet::new();
    for (id, reply) in replies {
        let reply = reply.as_ref().unwrap_or_else(|e| panic!("request {id}: {e}"));
        match &trace.requests[*id].instance {
            ProblemInstance::Assignment(inst) => {
                let exact = Hungarian.solve(inst).unwrap();
                assert_eq!(
                    reply.outcome.weight(),
                    Some(exact.weight),
                    "request {id}: backend {} suboptimal",
                    reply.backend
                );
                seen.insert((Family::Assignment, reply.class, reply.backend));
            }
            ProblemInstance::Grid(net) => {
                let (want, _) = solve_grid_with(net, CYCLE, None, GridEngine::Native).unwrap();
                assert_eq!(
                    reply.outcome.flow(),
                    Some(want.flow),
                    "request {id}: backend {} wrong flow",
                    reply.backend
                );
                seen.insert((Family::Grid, reply.class, reply.backend));
            }
        }
    }
    seen
}

/// Static mode is the default and reproduces the PR 3 per-class
/// tables verbatim: every reply's backend is exactly the configured
/// table entry for its (family, class).
#[test]
fn static_mode_reproduces_table_routing_verbatim() {
    let cfg = pool_config(3, RoutingMode::Static);
    let assign_table = cfg.router.assign;
    let grid_table = cfg.router.grid;
    let trace = mixed_trace(601, 10, 6);
    let pool = SolverPool::start(cfg);
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    assert_eq!(out.ok, trace.len());
    for (id, reply) in &out.replies {
        let reply = reply.as_ref().unwrap();
        let expected = match &trace.requests[*id].instance {
            ProblemInstance::Assignment(_) => assign_table[reply.class.index()].name(),
            ProblemInstance::Grid(_) => grid_table[reply.class.index()].name(),
        };
        assert_eq!(
            reply.backend, expected,
            "request {id}: static routing diverged from the table"
        );
    }
    verify_against_oracles(&trace, &out.replies);
    // No spill in static mode, ever.
    assert_eq!(report.spilled, 0);
    // Telemetry still accumulates (per-backend observability).
    assert!(!report.routes.is_empty());
    assert!(report.routes.iter().all(|r| r.count > 0));
}

/// Adaptive mode demonstrably re-routes: cold start measures every
/// registered engine of each (family, class) that sees enough
/// requests, probing keeps revisiting them — and every answer still
/// matches the sequential oracles exactly.
#[test]
fn adaptive_mode_reroutes_and_stays_oracle_exact() {
    let trace = mixed_trace(602, 16, 6);
    let pool = SolverPool::start(pool_config(2, RoutingMode::Adaptive));
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    assert_eq!(out.ok, trace.len(), "rejected={} failed={}", out.rejected, out.failed);
    let seen = verify_against_oracles(&trace, &out.replies);

    // 16 Small matchings against 4 registered native assignment
    // engines: cold start alone must have spread them across all 4.
    let small_assign: BTreeSet<&str> = seen
        .iter()
        .filter(|(f, c, _)| *f == Family::Assignment && *c == SizeClass::Small)
        .map(|(_, _, b)| *b)
        .collect();
    assert_eq!(
        small_assign.into_iter().collect::<Vec<_>>(),
        ["csa-lockfree", "csa-seq", "csa-wave", "hungarian"],
        "adaptive routing did not measure every assignment engine"
    );

    // The report carries the measurement state: every routed pair has
    // a count and a finite EWMA.
    assert!(!report.routes.is_empty());
    for r in &report.routes {
        assert!(r.count > 0, "{}/{} {}", r.family.name(), r.class.name(), r.backend);
        let ewma = r.ewma_seconds.expect("routed backend has an EWMA");
        assert!(ewma.is_finite() && ewma >= 0.0);
    }
}

/// Saturation spill at the pool level: with the spill threshold at 0
/// (spill whenever the check runs), every Large grid is re-routed to
/// the self-threaded `fifo-lockfree` engine; Small/Medium traffic and
/// all results are untouched.
#[test]
fn adaptive_spill_routes_large_grids_to_lockfree() {
    let mut cfg = pool_config(2, RoutingMode::Adaptive);
    cfg.router.spill_depth = 0;
    let trace = mixed_trace(603, 8, 6); // every 3rd grid is 48² = Large
    let large_grids = trace
        .requests
        .iter()
        .filter(|r| matches!(&r.instance, ProblemInstance::Grid(_)) && r.instance.work_units() > 1024)
        .count();
    assert!(large_grids >= 2, "trace must contain Large grids");

    let pool = SolverPool::start(cfg);
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    assert_eq!(out.ok, trace.len());
    let seen = verify_against_oracles(&trace, &out.replies);
    for (id, reply) in &out.replies {
        let reply = reply.as_ref().unwrap();
        if reply.class == SizeClass::Large {
            assert_eq!(
                reply.backend, "fifo-lockfree",
                "request {id}: Large grid must spill under saturation"
            );
        }
    }
    // Spill only *forces* Large grids there; Medium grids may still
    // visit fifo-lockfree through ordinary cold-start probing, and
    // assignment traffic never can (wrong family).
    assert!(report.served_by("fifo-lockfree") >= large_grids);
    assert_eq!(report.spilled, large_grids);
    assert!(seen
        .iter()
        .all(|(f, _, b)| *f == Family::Grid || *b != "fifo-lockfree"));
}
