//! Property tests for the assignment stack: engine parity, ε-optimality
//! preservation, price monotonicity, heuristic safety.

use flowmatch::assignment::scaling::{epsilon_schedule, CsaState};
use flowmatch::assignment::wave::native_wave;
use flowmatch::assignment::{self, AssignmentSolver};
use flowmatch::graph::AssignmentInstance;
use flowmatch::prop::{forall, Config};
use flowmatch::util::Rng;
use flowmatch::{prop_assert, prop_assert_eq};

fn random_instance(rng: &mut Rng) -> AssignmentInstance {
    let n = 1 + rng.index(14);
    let c = 1 + rng.range_i64(0, 120);
    let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, c)).collect();
    AssignmentInstance::new(n, w)
}

#[test]
fn prop_all_engines_match_hungarian() {
    forall(Config::cases(40).seed(0xA10).named("engine parity"), |rng| {
        let inst = random_instance(rng);
        let want = assignment::hungarian::Hungarian
            .solve(&inst)
            .map_err(|e| e.to_string())?;
        for engine in assignment::all_engines() {
            let got = engine.solve(&inst).map_err(|e| format!("{}: {e}", engine.name()))?;
            prop_assert!(
                AssignmentInstance::is_permutation(&got.assignment),
                "{}: not a permutation",
                engine.name()
            );
            prop_assert_eq!(got.weight, want.weight, engine.name());
        }
        Ok(())
    });
}

#[test]
fn prop_wave_preserves_eps_optimality_and_monotone_prices() {
    forall(Config::cases(40).seed(0xA11).named("eps-optimality"), |rng| {
        let inst = random_instance(rng);
        if inst.n < 2 {
            return Ok(());
        }
        let (mut st, eps0) = CsaState::new(&inst);
        let eps = 1 + rng.range_i64(0, eps0);
        st.reset_refine(eps);
        st.check_eps_optimal(eps).map_err(|e| e.to_string())?;
        let mut guard = 0;
        while st.active_count() > 0 {
            let px_before = st.px.clone();
            let py_before = st.py.clone();
            native_wave(&mut st, eps);
            st.check_eps_optimal(eps)
                .map_err(|e| format!("after wave {guard}: {e}"))?;
            prop_assert!(
                st.px.iter().zip(&px_before).all(|(a, b)| a <= b),
                "px increased"
            );
            prop_assert!(
                st.py.iter().zip(&py_before).all(|(a, b)| a <= b),
                "py increased"
            );
            // Structural invariants (paper: e(x) ∈ {0,1}).
            prop_assert!(st.ex.iter().all(|&e| (0..=1).contains(&e)), "ex out of range");
            guard += 1;
            prop_assert!(guard < 500_000, "did not converge");
        }
        prop_assert!(st.is_flow(), "quiescent but not a flow");
        Ok(())
    });
}

#[test]
fn prop_price_update_safe_at_any_point() {
    forall(Config::cases(30).seed(0xA12).named("price update safety"), |rng| {
        let inst = random_instance(rng);
        if inst.n < 2 {
            return Ok(());
        }
        let (mut st, eps0) = CsaState::new(&inst);
        st.reset_refine(eps0);
        // Run a random number of waves, then the heuristic, then finish.
        for _ in 0..rng.index(10) {
            if st.active_count() == 0 {
                break;
            }
            native_wave(&mut st, eps0);
        }
        assignment::price_update::price_update(&mut st, eps0);
        st.check_eps_optimal(eps0)
            .map_err(|e| format!("after price update: {e}"))?;
        let mut guard = 0;
        while st.active_count() > 0 {
            native_wave(&mut st, eps0);
            guard += 1;
            prop_assert!(guard < 500_000, "did not converge after update");
        }
        Ok(())
    });
}

#[test]
fn prop_epsilon_schedule_properties() {
    forall(Config::cases(60).seed(0xA13).named("eps schedule"), |rng| {
        let eps0 = 1 + rng.range_i64(0, 1_000_000);
        let alpha = 2 + rng.range_i64(0, 30);
        let sched = epsilon_schedule(eps0, alpha);
        prop_assert_eq!(sched[0], eps0, "starts at eps0");
        prop_assert_eq!(*sched.last().unwrap(), 1, "ends at 1");
        prop_assert!(
            sched.windows(2).all(|w| w[1] < w[0] || w[0] == 1),
            "not strictly decreasing"
        );
        // Length bounded by log_alpha(eps0) + 2.
        let bound = ((eps0 as f64).log(alpha as f64).ceil() as usize) + 2;
        prop_assert!(sched.len() <= bound, "schedule too long: {} > {bound}", sched.len());
        Ok(())
    });
}

#[test]
fn prop_padding_preserves_optimum() {
    forall(Config::cases(30).seed(0xA14).named("padding"), |rng| {
        let inst = random_instance(rng);
        let m = inst.n + rng.index(10);
        let padded = inst.pad(m);
        let a = assignment::hungarian::Hungarian
            .solve(&inst)
            .map_err(|e| e.to_string())?;
        let b = assignment::hungarian::Hungarian
            .solve(&padded)
            .map_err(|e| e.to_string())?;
        prop_assert_eq!(a.weight, b.weight, "padded optimum differs");
        // unpad produces a valid, equally-good assignment.
        let unpadded = inst.unpad_assignment(&b.assignment);
        prop_assert!(
            AssignmentInstance::is_permutation(&unpadded),
            "unpad broke the permutation"
        );
        prop_assert_eq!(inst.assignment_weight(&unpadded), a.weight, "unpad weight");
        Ok(())
    });
}

#[test]
fn prop_auction_and_csa_agree_without_reference() {
    // Cross-engine agreement on larger instances where Hungarian also
    // runs but we additionally check the two scaling families agree on
    // op-count sanity: work is positive and bounded by the theory-level
    // envelope O(n^2 m log(nC)) with a generous constant.
    forall(Config::cases(15).seed(0xA15).named("work bounds"), |rng| {
        let n = 4 + rng.index(12);
        let c = 100;
        let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, c)).collect();
        let inst = AssignmentInstance::new(n, w);
        let got = assignment::csa::SequentialCsa::default()
            .solve(&inst)
            .map_err(|e| e.to_string())?;
        let nn = n as u64;
        let m = nn * nn;
        let logterm = (64 - ((nn * (c as u64 + 1)).leading_zeros() as u64)).max(1);
        let bound = 64 * nn * nn * m * logterm;
        prop_assert!(got.stats.pushes > 0, "no pushes recorded");
        prop_assert!(
            got.stats.pushes + got.stats.relabels <= bound,
            "work {} exceeds envelope {bound}",
            got.stats.pushes + got.stats.relabels
        );
        Ok(())
    });
}
