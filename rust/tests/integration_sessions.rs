//! Integration tests for warm-start sessions on dynamic graphs: a
//! delta trace (session opens + capacity-edit updates) replayed through
//! the pool's session API, with every reply — warm or cold — checked
//! against a cold solve of the fully-materialised edited instance.  The
//! oracle runs for every grid engine and both host-round policies, plus
//! the LRU-eviction degraded mode (cold fallback stays correct).

use flowmatch::coordinator::{solve_grid_with, GridEngine};
use flowmatch::service::{
    replay_sessions, GridBackend, HostRounds, PoolConfig, RouterConfig, SessionReplayOutcome,
    ShardConfig, SolverPool,
};
use flowmatch::util::Rng;
use flowmatch::workloads::{DeltaTrace, DeltaTraceConfig};

const CYCLE: usize = 128;

fn pool_config(workers: usize) -> PoolConfig {
    PoolConfig {
        workers,
        shard: ShardConfig {
            small_max_units: 256,
            medium_max_units: 1024,
            queue_depth: 64,
            max_units: 1 << 16,
        },
        router: RouterConfig {
            use_pjrt: false, // keep the oracle artifact-free
            cycle_waves: CYCLE,
            par_threads: 2,
            tile_rows: 4,
            retry_backoff_ms: 0,
            ..Default::default()
        },
        session_budget_mb: 64,
    }
}

fn delta_trace(seed: u64, sessions: usize, updates: usize, size: usize) -> DeltaTrace {
    let mut rng = Rng::seeded(seed);
    DeltaTrace::generate(
        &mut rng,
        &DeltaTraceConfig {
            sessions,
            updates_per_session: updates,
            edits_per_update: 3,
            grid_size: size,
            grid_max_cap: 12,
            arrival_gap: 0.0,
            deadline: 0.0,
        },
    )
}

/// The differential oracle: every successful reply's flow equals a cold
/// sequential solve of the trace's materialised edited instance at that
/// request.  Max-flow *value* is unique, so this holds for warm repairs
/// and cold fallbacks alike, on every engine.
fn assert_oracle(trace: &DeltaTrace, out: &SessionReplayOutcome, label: &str) {
    assert_eq!(out.lost, 0, "{label}: lost replies");
    for (id, reply) in &out.replies {
        let reply = reply
            .as_ref()
            .unwrap_or_else(|e| panic!("{label}: request {id}: {e}"));
        let (want, _) =
            solve_grid_with(&trace.edited[*id], CYCLE, None, GridEngine::Native).unwrap();
        assert_eq!(
            reply.outcome.flow(),
            Some(want.flow),
            "{label}: request {id} (warm={}, backend {}) diverged from the cold oracle",
            reply.warm,
            reply.backend
        );
    }
}

/// The ISSUE acceptance matrix: delta-solve ≡ cold-solve on the edited
/// graph for every engine × both host-round policies.  With a generous
/// budget nothing evicts, so every update is served warm.
#[test]
fn warm_updates_match_cold_oracle_for_every_engine_and_host_rounds() {
    for backend in [
        GridBackend::Native,
        GridBackend::NativePar,
        GridBackend::FifoLockfree,
    ] {
        for rounds in [HostRounds::Seq, HostRounds::Striped] {
            let label = format!("{}/{rounds:?}", backend.name());
            let mut cfg = pool_config(2);
            cfg.router.grid = [backend; 3];
            cfg.router.host_rounds = rounds;
            let trace = delta_trace(801, 3, 4, 16);
            let pool = SolverPool::start(cfg);
            let out = replay_sessions(&pool, &trace);
            let report = pool.shutdown();

            assert_eq!(out.sent, trace.len(), "{label}");
            assert_eq!(out.failed, 0, "{label}: failed replies");
            assert_oracle(&trace, &out, &label);
            // Nothing evicts under a 64MB budget: the whole update
            // stream is served warm, from sticky-routed residual caches.
            assert_eq!(out.opens, 3, "{label}: every open succeeds");
            assert_eq!(out.cold_fallbacks, 0, "{label}");
            assert_eq!(out.warm_hits, trace.update_count(), "{label}");
            assert_eq!(report.warm_served, out.warm_hits, "{label}");
            assert_eq!(report.sessions_evicted, 0, "{label}");
            assert!((out.warm_rate() - 1.0).abs() < 1e-12, "{label}");
        }
    }
}

/// Interleaved sessions under a zero-byte budget: every open evicts the
/// previous session, every update comes back `SessionEvicted`, and the
/// client's cold fallback (re-solving the materialised edited instance)
/// keeps every answer oracle-correct — the degraded mode loses warmth,
/// never correctness.
#[test]
fn evicted_sessions_fall_back_cold_and_stay_oracle_correct() {
    let mut cfg = pool_config(1); // one worker: both sessions share one LRU
    cfg.session_budget_mb = 0; // the store retains only the latest session
    let trace = delta_trace(802, 2, 4, 12);
    let pool = SolverPool::start(cfg);
    let out = replay_sessions(&pool, &trace);
    let report = pool.shutdown();

    assert_eq!(out.sent, trace.len());
    assert_eq!(out.failed, 0, "cold fallback must absorb every eviction");
    assert_oracle(&trace, &out, "evicting");
    // Two sessions round-robin against a one-session store: the replay
    // must have hit the eviction path and recovered.
    assert!(out.cold_fallbacks > 0, "budget never evicted");
    assert!(report.sessions_evicted > 0, "evictions not reported");
    // Every update got exactly one answer, warm or fallback-cold.
    assert_eq!(out.warm_hits + out.cold_fallbacks, trace.update_count());
    assert!(out.warm_rate() < 1.0);
}

/// Sticky routing across a multi-worker pool: with several workers and
/// several sessions, updates still reach the worker holding their
/// residual cache (a miss would surface as `SessionEvicted` and a cold
/// fallback).  Warmth is total under a generous budget.
#[test]
fn sticky_routing_keeps_updates_warm_across_workers() {
    let cfg = pool_config(3);
    let trace = delta_trace(803, 5, 3, 16);
    let pool = SolverPool::start(cfg);
    let out = replay_sessions(&pool, &trace);
    let report = pool.shutdown();

    assert_eq!(out.failed, 0);
    assert_oracle(&trace, &out, "sticky");
    assert_eq!(out.cold_fallbacks, 0, "sticky delivery missed its worker");
    assert_eq!(out.warm_hits, trace.update_count());
    assert_eq!(report.warm_served, out.warm_hits);
}
