//! Differential: the tiled parallel wave engine against the sequential
//! native twin — the sequential engine is the oracle, and the contract
//! is *bit-exactness*: identical per-wave `WaveStats`, identical state
//! trajectory, identical surviving active set, across thread counts and
//! tile sizes, on seeded random grids.

use std::sync::Arc;

use flowmatch::gridflow::wave::{active_cells, native_wave_with, WaveScratch};
use flowmatch::gridflow::{
    host, init_state, par_wave_pooled, par_wave_with, HostRounds, HybridGridSolver,
    NativeGridExecutor, NativeParGridExecutor, ParWaveScratch,
};
use flowmatch::maxflow::{self, MaxFlowSolver};
use flowmatch::parallel::Lanes;
use flowmatch::runtime::device::GridWireState;
use flowmatch::service::WorkerPool;
use flowmatch::util::Rng;
use flowmatch::workloads::random_grid;

fn assert_states_eq(a: &GridWireState, b: &GridWireState, ctx: &str) {
    assert_eq!(a.h, b.h, "{ctx}: heights");
    assert_eq!(a.e, b.e, "{ctx}: excess");
    assert_eq!(a.cap, b.cap, "{ctx}: caps");
    assert_eq!(a.cap_sink, b.cap_sink, "{ctx}: sink caps");
    assert_eq!(a.cap_src, b.cap_src, "{ctx}: src caps");
}

/// The 8+ seeded grids the acceptance criteria call for: mixed shapes,
/// capacities, and terminal densities.
fn grid_cases() -> Vec<(u64, usize, usize, i64)> {
    vec![
        (1, 8, 8, 10),
        (2, 16, 16, 25),
        (3, 5, 32, 5),
        (4, 12, 12, 100),
        (5, 9, 13, 7),
        (6, 21, 7, 16),
        (7, 1, 24, 9),
        (8, 24, 1, 9),
        (9, 17, 17, 40),
    ]
}

#[test]
fn wave_by_wave_bit_exact_across_threads_and_tiles() {
    for (seed, h, w, cap) in grid_cases() {
        let mut rng = Rng::seeded(seed);
        let net = random_grid(&mut rng, h, w, cap, 0.3, 0.3);
        let (st0, _) = init_state(&net);
        for threads in [1usize, 2, 4] {
            for tile_rows in [1usize, 2, 3, 5, 8] {
                let mut seq = st0.clone();
                let mut par = st0.clone();
                // Start from exact heights so relabels, interior pushes
                // and source returns all occur.
                host::global_relabel(&mut seq);
                host::global_relabel(&mut par);
                let mut ss = WaveScratch::default();
                let mut ps = ParWaveScratch::new(tile_rows);
                let ctx = format!("seed={seed} {h}x{w} t={threads} tr={tile_rows}");
                for wave in 0..600 {
                    if active_cells(&seq) == 0 {
                        break;
                    }
                    let a = native_wave_with(&mut seq, &mut ss);
                    let b = par_wave_with(&mut par, &mut ps, threads).unwrap();
                    assert_eq!(a, b, "{ctx}: stats at wave {wave}");
                    assert_states_eq(&seq, &par, &format!("{ctx} wave {wave}"));
                    assert_eq!(
                        ss.active_count(),
                        ps.active_count(),
                        "{ctx}: active count at wave {wave}"
                    );
                }
            }
        }
    }
}

#[test]
fn full_solver_reports_identical() {
    for (seed, h, w, cap) in grid_cases() {
        let mut rng = Rng::seeded(seed);
        let net = random_grid(&mut rng, h, w, cap, 0.3, 0.3);
        let solver = HybridGridSolver::with_cycle(64);
        let mut seq_exec = NativeGridExecutor::default();
        let want = solver.solve(&net, &mut seq_exec).unwrap();
        let mut g = net.to_flow_network();
        let dinic = maxflow::dinic::Dinic.solve(&mut g).unwrap();
        assert_eq!(want.flow, dinic.value, "seed={seed}: sequential vs dinic");
        for (threads, tile_rows) in [(1usize, 1usize), (2, 4), (4, 3), (4, 16)] {
            let mut exec = NativeParGridExecutor::new(threads, tile_rows);
            let got = solver.solve(&net, &mut exec).unwrap();
            let ctx = format!("seed={seed} t={threads} tr={tile_rows}");
            assert_eq!(got.flow, want.flow, "{ctx}: flow");
            assert_eq!(got.waves, want.waves, "{ctx}: waves");
            assert_eq!(got.pushes, want.pushes, "{ctx}: pushes");
            assert_eq!(got.relabels, want.relabels, "{ctx}: relabels");
            assert_eq!(got.host_rounds, want.host_rounds, "{ctx}: host rounds");
            assert_eq!(got.gap_cells, want.gap_cells, "{ctx}: gap cells");
            assert_eq!(got.cancelled_arcs, want.cancelled_arcs, "{ctx}: cancels");
        }
    }
}

/// The parity-coloured border reconciliation against the sequential
/// oracle on tall skinny grids with `tile_rows = 1` — every N/S push is
/// a cross-tile op, so the reconcile pass carries the whole trajectory.
/// Pinned wave-by-wave (state + stats + active sets), pooled and
/// unpooled, which is exactly the contract the retired serial apply
/// loop satisfied.
#[test]
fn parity_border_reconcile_bit_exact_on_tall_grids() {
    let pool = Arc::new(WorkerPool::new(3));
    for (seed, h, w) in [(41u64, 24usize, 2usize), (42, 31, 1), (43, 17, 3)] {
        let mut rng = Rng::seeded(seed);
        let net = random_grid(&mut rng, h, w, 9, 0.35, 0.35);
        let (st0, _) = init_state(&net);
        for pooled in [false, true] {
            let mut seq = st0.clone();
            let mut par = st0.clone();
            host::global_relabel(&mut seq);
            host::global_relabel(&mut par);
            let mut ss = WaveScratch::default();
            let mut ps = ParWaveScratch::new(1);
            let ctx = format!("seed={seed} {h}x{w} pooled={pooled}");
            for wave in 0..800 {
                if active_cells(&seq) == 0 {
                    break;
                }
                let a = native_wave_with(&mut seq, &mut ss);
                let b = if pooled {
                    par_wave_pooled(&mut par, &mut ps, &pool)
                } else {
                    par_wave_with(&mut par, &mut ps, 4)
                }
                .unwrap();
                assert_eq!(a, b, "{ctx}: stats at wave {wave}");
                assert_states_eq(&seq, &par, &format!("{ctx} wave {wave}"));
                assert_eq!(ss.active_count(), ps.active_count(), "{ctx} wave {wave}");
            }
            assert_eq!(active_cells(&par), 0, "{ctx}: drained");
        }
    }
}

/// Striped host rounds through the full solver: every report counter
/// must equal the sequential-host-round run — with no pool (sequential
/// lanes), with the executor's own pool, and mixed across engines.
#[test]
fn striped_host_rounds_full_solver_bit_exact() {
    let pool = Arc::new(WorkerPool::new(3));
    for (seed, h, w, cap) in grid_cases() {
        let mut rng = Rng::seeded(seed);
        let net = random_grid(&mut rng, h, w, cap, 0.3, 0.3);
        let solver_seq = HybridGridSolver::with_cycle(64);
        let solver_str = HybridGridSolver::with_cycle(64).with_host_rounds(HostRounds::Striped);
        let mut seq_exec = NativeGridExecutor::default();
        let want = solver_seq.solve(&net, &mut seq_exec).unwrap();

        // Striped on the sequential executor: Lanes::Seq fallback.
        let mut exec = NativeGridExecutor::default();
        let got = solver_str.solve(&net, &mut exec).unwrap();
        let ctx = format!("seed={seed} {h}x{w} native+striped");
        assert_eq!(got.flow, want.flow, "{ctx}");
        assert_eq!(got.waves, want.waves, "{ctx}");
        assert_eq!(got.gap_cells, want.gap_cells, "{ctx}");
        assert_eq!(got.cancelled_arcs, want.cancelled_arcs, "{ctx}");

        // Striped on the pooled tiled executor: host rounds actually
        // fan out on the pool.
        let mut exec = NativeParGridExecutor::new(2, 3).with_pool(Arc::clone(&pool));
        let got = solver_str.solve(&net, &mut exec).unwrap();
        let ctx = format!("seed={seed} {h}x{w} native-par-pooled+striped");
        assert_eq!(got.flow, want.flow, "{ctx}");
        assert_eq!(got.waves, want.waves, "{ctx}");
        assert_eq!(got.pushes, want.pushes, "{ctx}");
        assert_eq!(got.relabels, want.relabels, "{ctx}");
        assert_eq!(got.host_rounds, want.host_rounds, "{ctx}");
        assert_eq!(got.gap_cells, want.gap_cells, "{ctx}");
        assert_eq!(got.cancelled_arcs, want.cancelled_arcs, "{ctx}");
    }
}

/// The striped host passes against mid-solve states reached by real
/// waves (not just synthetic states): run waves, then compare one
/// striped round against one sequential round on clones.
#[test]
fn striped_host_round_matches_on_wave_reached_states() {
    let pool = Arc::new(WorkerPool::new(2));
    let lanes = Lanes::Pool(&pool);
    for (seed, h, w, cap) in grid_cases() {
        let mut rng = Rng::seeded(seed ^ 0xA5);
        let net = random_grid(&mut rng, h, w, cap, 0.3, 0.3);
        let (mut st, _) = init_state(&net);
        host::global_relabel(&mut st);
        let mut ws = WaveScratch::default();
        for burst in 0..4 {
            for _ in 0..12 {
                if active_cells(&st) == 0 {
                    break;
                }
                native_wave_with(&mut st, &mut ws);
            }
            let mut seq = st.clone();
            let mut par = st.clone();
            let mut ss = host::HostScratch::for_state(&seq);
            let mut ps = host::HostScratch::for_state(&par);
            let a = host::host_round_with(&mut seq, &mut ss);
            let b = host::host_round_par(&mut par, &mut ps, &lanes);
            let ctx = format!("seed={seed} {h}x{w} burst={burst}");
            assert_eq!(a, b, "{ctx}: stats");
            assert_states_eq(&seq, &par, &ctx);
            // Continue from the (identical) post-round state.
            st = seq;
            ws = WaveScratch::default();
        }
    }
}

#[test]
fn no_heuristics_trajectories_also_identical() {
    // Without host rounds the executors never get invalidated
    // mid-solve, exercising the persistent incremental active lists.
    let mut rng = Rng::seeded(11);
    let net = random_grid(&mut rng, 10, 10, 12, 0.3, 0.3);
    let solver = HybridGridSolver::no_heuristics(1_000_000);
    let mut seq_exec = NativeGridExecutor::default();
    let want = solver.solve(&net, &mut seq_exec).unwrap();
    for (threads, tile_rows) in [(2usize, 1usize), (4, 4)] {
        let mut exec = NativeParGridExecutor::new(threads, tile_rows);
        let got = solver.solve(&net, &mut exec).unwrap();
        assert_eq!(got.flow, want.flow);
        assert_eq!(got.waves, want.waves);
        assert_eq!(got.pushes, want.pushes);
    }
}

#[test]
fn executor_reuse_across_solves_is_safe() {
    // invalidate() must reset cached active sets when the same executor
    // instance solves a second (different) instance of the same shape.
    let mut rng = Rng::seeded(21);
    let net_a = random_grid(&mut rng, 8, 8, 10, 0.3, 0.3);
    let net_b = random_grid(&mut rng, 8, 8, 10, 0.3, 0.3);
    let solver = HybridGridSolver::with_cycle(64);

    let mut par = NativeParGridExecutor::new(2, 2);
    let mut seq = NativeGridExecutor::default();
    for net in [&net_a, &net_b, &net_a] {
        let a = solver.solve(net, &mut seq).unwrap();
        let b = solver.solve(net, &mut par).unwrap();
        assert_eq!(a.flow, b.flow);
        assert_eq!(a.waves, b.waves);
        let mut g = net.to_flow_network();
        let want = maxflow::dinic::Dinic.solve(&mut g).unwrap();
        assert_eq!(a.flow, want.value);
    }
}

#[test]
fn degenerate_shapes_and_thread_surplus() {
    // More threads than tiles, tile_rows larger than the grid, single
    // row/column grids: the engine must clamp and stay exact.
    for (h, w) in [(1usize, 1usize), (2, 2), (1, 16), (16, 1), (3, 5)] {
        let mut rng = Rng::seeded((h * 31 + w) as u64);
        let net = random_grid(&mut rng, h, w, 6, 0.5, 0.5);
        let solver = HybridGridSolver::with_cycle(32);
        let mut seq = NativeGridExecutor::default();
        let want = solver.solve(&net, &mut seq).unwrap();
        let mut par = NativeParGridExecutor::new(8, 64);
        let got = solver.solve(&net, &mut par).unwrap();
        assert_eq!(got.flow, want.flow, "{h}x{w}");
        assert_eq!(got.waves, want.waves, "{h}x{w}");
    }
}
