//! Integration tests for the sharded solver-pool service: a mixed
//! grid+assignment trace through the pool with every reply checked
//! against the sequential single-solver oracle, plus the
//! backpressure/admission-control behaviour.

use flowmatch::assignment::hungarian::Hungarian;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::coordinator::{solve_grid_with, GridEngine};
use flowmatch::graph::AssignmentInstance;
use flowmatch::service::{
    replay, GridBackend, PoolConfig, ProblemInstance, RejectReason, RouterConfig, ShardConfig,
    SizeClass, SolverPool,
};
use flowmatch::util::Rng;
use flowmatch::workloads::{MixedTrace, MixedTraceConfig, TraceConfig};

const CYCLE: usize = 128;

fn test_pool_config(workers: usize) -> PoolConfig {
    PoolConfig {
        workers,
        shard: ShardConfig {
            // Tuned so the test trace exercises all three classes:
            // n=10 assignment (100 units) is Small, 24² grids (576)
            // are Medium, 48² grids (2304) are Large.
            small_max_units: 256,
            medium_max_units: 1024,
            queue_depth: 64,
            max_units: 1 << 16,
        },
        router: RouterConfig {
            use_pjrt: false, // keep the oracle artifact-free
            cycle_waves: CYCLE,
            par_threads: 2,
            tile_rows: 4,
            ..Default::default()
        },
        session_budget_mb: 64,
    }
}

fn mixed_trace(seed: u64) -> MixedTrace {
    let mut rng = Rng::seeded(seed);
    MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests: 12,
                n: 10,
                max_weight: 60,
                arrival_gap: 0.0,
                ..Default::default()
            },
            grid_requests: 6,
            grid_size: 24,
            grid_max_cap: 12,
            grid_arrival_gap: 0.0,
            large_every: 3,
            large_size: 48,
            ..Default::default()
        },
    )
}

/// Every pooled reply matches the sequential single-solver path:
/// Hungarian for matchings (optimal weight + valid permutation), and
/// for grids the *full report* of the sequential native engine — the
/// native-par backend is bit-exact, so waves/pushes/relabels must
/// agree too, not just the flow value.
#[test]
fn mixed_trace_matches_sequential_oracles() {
    let trace = mixed_trace(501);
    let pool = SolverPool::start(test_pool_config(3));
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();

    assert_eq!(out.sent, trace.len());
    assert_eq!(out.ok, trace.len(), "rejected={} failed={}", out.rejected, out.failed);
    assert_eq!(report.served, trace.len());
    assert_eq!(report.assign_served, trace.assignment_count());
    assert_eq!(report.grid_served, trace.grid_count());

    for (id, reply) in &out.replies {
        let reply = reply.as_ref().unwrap_or_else(|e| panic!("request {id}: {e}"));
        match &trace.requests[*id].instance {
            ProblemInstance::Assignment(inst) => {
                let exact = Hungarian.solve(inst).unwrap();
                let got = reply.outcome.assignment().expect("assignment outcome");
                assert!(
                    AssignmentInstance::is_permutation(&got.assignment),
                    "request {id}: not a permutation"
                );
                assert_eq!(got.weight, exact.weight, "request {id}: suboptimal");
                assert_eq!(got.weight, inst.assignment_weight(&got.assignment));
            }
            ProblemInstance::Grid(net) => {
                let (want, _) = solve_grid_with(net, CYCLE, None, GridEngine::Native).unwrap();
                let got = reply.outcome.grid().expect("grid outcome");
                assert_eq!(got.flow, want.flow, "request {id}: wrong flow");
                if reply.backend == "native-par" {
                    // Bit-exactness of the pooled tiled engine.
                    assert_eq!(got.waves, want.waves, "request {id}");
                    assert_eq!(got.pushes, want.pushes, "request {id}");
                    assert_eq!(got.relabels, want.relabels, "request {id}");
                    assert_eq!(got.host_rounds, want.host_rounds, "request {id}");
                }
            }
        }
    }

    // The router sent each class where it was configured to go.
    assert!(report.served_by("hungarian") >= 1, "{:?}", report.backends);
    assert!(report.served_by("native-par") >= 1, "{:?}", report.backends);
}

/// Striped host rounds through the service: the native-par backend
/// wires its worker's wave pool into the between-wave cancel/relabel
/// (`[gridflow] host_rounds = striped`), and every grid reply must stay
/// *full-report* bit-exact with the sequential-everything oracle.
#[test]
fn striped_host_rounds_stay_oracle_exact() {
    use flowmatch::service::HostRounds;

    let mut cfg = test_pool_config(3);
    cfg.router.host_rounds = HostRounds::Striped;
    let trace = mixed_trace(502);
    let pool = SolverPool::start(cfg);
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();
    assert_eq!(out.ok, trace.len(), "rejected={} failed={}", out.rejected, out.failed);
    assert!(report.served_by("native-par") >= 1, "{:?}", report.backends);

    for (id, reply) in &out.replies {
        let reply = reply.as_ref().unwrap_or_else(|e| panic!("request {id}: {e}"));
        if let ProblemInstance::Grid(net) = &trace.requests[*id].instance {
            let (want, _) = solve_grid_with(net, CYCLE, None, GridEngine::Native).unwrap();
            let got = reply.outcome.grid().expect("grid outcome");
            assert_eq!(got.flow, want.flow, "request {id}: wrong flow");
            if reply.backend == "native-par" {
                assert_eq!(got.waves, want.waves, "request {id}");
                assert_eq!(got.pushes, want.pushes, "request {id}");
                assert_eq!(got.relabels, want.relabels, "request {id}");
                assert_eq!(got.host_rounds, want.host_rounds, "request {id}");
                assert_eq!(got.gap_cells, want.gap_cells, "request {id}");
                assert_eq!(got.cancelled_arcs, want.cancelled_arcs, "request {id}");
            }
        }
    }
}

/// The fifo-lockfree grid backend (Hong's CSR engine) agrees with the
/// sequential path on the flow value when routed to from the pool.
#[test]
fn lockfree_grid_backend_agrees_on_flow() {
    let mut cfg = test_pool_config(2);
    cfg.router.grid = [
        GridBackend::FifoLockfree,
        GridBackend::FifoLockfree,
        GridBackend::FifoLockfree,
    ];
    let trace = mixed_trace(502);
    let pool = SolverPool::start(cfg);
    let out = replay(&pool, &trace, false);
    let report = pool.shutdown();
    assert_eq!(out.ok, trace.len());
    assert_eq!(report.served_by("fifo-lockfree"), trace.grid_count());
    for (id, reply) in &out.replies {
        if let ProblemInstance::Grid(net) = &trace.requests[*id].instance {
            let (want, _) = solve_grid_with(net, CYCLE, None, GridEngine::Native).unwrap();
            let got = reply.as_ref().unwrap().outcome.flow().unwrap();
            assert_eq!(got, want.flow, "request {id}");
        }
    }
}

/// Backpressure: with no workers draining, the bounded shard fills to
/// its configured depth and the next submit is rejected with
/// `QueueFull`; an instance above the admission cap is rejected with
/// `TooLarge` regardless of queue state.
#[test]
fn backpressure_rejects_with_reason() {
    let mut cfg = test_pool_config(0); // admission-only: nothing drains
    cfg.shard.queue_depth = 2;
    let pool = SolverPool::start(cfg);
    let mut rng = Rng::seeded(9);

    let mut small =
        || ProblemInstance::Assignment(flowmatch::workloads::uniform_costs(&mut rng, 8, 20));
    assert!(pool.try_submit(small()).is_ok());
    assert!(pool.try_submit(small()).is_ok());
    match pool.try_submit(small()) {
        Err(RejectReason::QueueFull { class, depth }) => {
            assert_eq!(class, SizeClass::Small);
            assert_eq!(depth, 2);
        }
        other => panic!("expected QueueFull, got {other:?}"),
    }
    // Shards are independent: a Medium submit still goes through.
    let mut rng2 = Rng::seeded(10);
    let medium = ProblemInstance::Grid(flowmatch::workloads::random_grid(
        &mut rng2, 20, 20, 8, 0.25, 0.25,
    ));
    assert!(pool.try_submit(medium).is_ok());

    // Admission cap: 300² = 90000 > max_units (1 << 16).
    let big = ProblemInstance::Grid(flowmatch::graph::GridNetwork::zeros(300, 300));
    match pool.try_submit(big) {
        Err(RejectReason::TooLarge { units, max_units }) => {
            assert_eq!(units, 90_000);
            assert_eq!(max_units, 1 << 16);
        }
        other => panic!("expected TooLarge, got {other:?}"),
    }

    let report = pool.shutdown();
    assert_eq!(report.served, 0);
    assert_eq!(report.rejected, 2);
}

/// The legacy submit shape delivers the rejection through the channel.
#[test]
fn channel_submit_reports_rejection_string() {
    let cfg = test_pool_config(0);
    let pool = SolverPool::start(cfg);
    let rx = pool.submit(ProblemInstance::Grid(
        flowmatch::graph::GridNetwork::zeros(300, 300),
    ));
    let err = rx.recv().unwrap().unwrap_err();
    assert!(err.to_string().contains("too large"), "{err}");
}

/// Small requests do not queue behind a Large flood: with two workers,
/// worker 0 never scans the Large shard, so a burst of large grids
/// leaves the real-time lane free.
#[test]
fn small_requests_bypass_large_flood() {
    let mut cfg = test_pool_config(2);
    cfg.shard.queue_depth = 32;
    let pool = SolverPool::start(cfg);
    let mut rng = Rng::seeded(77);
    let mut receivers = Vec::new();
    // Flood the Large shard first...
    for _ in 0..4 {
        let net = flowmatch::workloads::random_grid(&mut rng, 48, 48, 10, 0.25, 0.25);
        receivers.push(pool.try_submit(ProblemInstance::Grid(net)).unwrap());
    }
    // ...then a small matching; it must complete even while the heavy
    // lane is saturated.
    let inst = flowmatch::workloads::uniform_costs(&mut rng, 10, 50);
    let want = Hungarian.solve(&inst).unwrap().weight;
    let rx = pool.try_submit(ProblemInstance::Assignment(inst)).unwrap();
    let reply = rx.recv().unwrap().unwrap();
    assert_eq!(reply.outcome.weight(), Some(want));
    assert_eq!(reply.class, SizeClass::Small);
    for rx in receivers {
        assert!(rx.recv().unwrap().is_ok());
    }
    let report = pool.shutdown();
    assert_eq!(report.served, 5);
}
