//! Integration: every assignment engine against Hungarian on every
//! workload family, the §5 reduction, alpha sweeps, and the PJRT driver.

use flowmatch::assignment::{self, AssignmentSolver};
use flowmatch::coordinator::PjrtAssignmentDriver;
use flowmatch::graph::{dimacs, AssignmentInstance};
use flowmatch::reductions;
use flowmatch::runtime::ArtifactRegistry;
use flowmatch::util::Rng;
use flowmatch::workloads::{geometric_costs, uniform_costs};

fn cases() -> Vec<(String, AssignmentInstance)> {
    let mut out = Vec::new();
    for (seed, n, c) in [(1u64, 5usize, 100i64), (2, 10, 100), (3, 16, 10), (4, 30, 100)] {
        let mut rng = Rng::seeded(seed);
        out.push((format!("uniform n={n} C={c}"), uniform_costs(&mut rng, n, c)));
    }
    for (seed, n) in [(5u64, 12usize), (6, 20)] {
        let mut rng = Rng::seeded(seed);
        out.push((format!("geometric n={n}"), geometric_costs(&mut rng, n, 3.0, 500)));
    }
    out
}

#[test]
fn all_engines_optimal_on_all_families() {
    for (name, inst) in cases() {
        let want = assignment::hungarian::Hungarian.solve(&inst).unwrap();
        for engine in assignment::all_engines() {
            let got = engine.solve(&inst).unwrap();
            assert!(
                AssignmentInstance::is_permutation(&got.assignment),
                "{name}/{}",
                engine.name()
            );
            assert_eq!(got.weight, want.weight, "{name}/{}", engine.name());
        }
    }
}

#[test]
fn reduction_to_mcmf_is_sound() {
    // Fig. 1 / E1: the explicit §5 reduction solved by SSP agrees with
    // Hungarian (and hence with every engine above).
    for (name, inst) in cases() {
        if inst.n > 16 {
            continue; // SSP on the dense reduction is O(n^3) anyway; keep fast
        }
        let (assign, weight) = reductions::solve_assignment_via_mcmf(&inst).unwrap();
        let want = assignment::hungarian::Hungarian.solve(&inst).unwrap();
        assert_eq!(weight, want.weight, "{name}");
        assert_eq!(weight, inst.assignment_weight(&assign), "{name}");
    }
}

#[test]
fn alpha_sweep_always_optimal() {
    let mut rng = Rng::seeded(7);
    let inst = uniform_costs(&mut rng, 14, 100);
    let want = assignment::hungarian::Hungarian.solve(&inst).unwrap().weight;
    for alpha in [2i64, 4, 8, 10, 16, 32, 64] {
        let got = assignment::csa::SequentialCsa::with_alpha(alpha)
            .solve(&inst)
            .unwrap();
        assert_eq!(got.weight, want, "alpha={alpha}");
    }
}

#[test]
fn lockfree_thread_sweep_optimal() {
    let mut rng = Rng::seeded(8);
    let inst = uniform_costs(&mut rng, 16, 100);
    let want = assignment::hungarian::Hungarian.solve(&inst).unwrap().weight;
    for threads in [1usize, 2, 3, 4, 8] {
        let got = assignment::csa_lockfree::LockFreeCsa::with_threads(threads)
            .solve(&inst)
            .unwrap();
        assert_eq!(got.weight, want, "threads={threads}");
    }
}

#[test]
fn pjrt_driver_optimal_with_and_without_padding() {
    let Ok(reg) = ArtifactRegistry::discover() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    // n=30 exact artifact; n=20 forces padding to 30; n=8 exact.
    for (seed, n) in [(9u64, 30usize), (10, 20), (11, 8)] {
        let mut rng = Rng::seeded(seed);
        let inst = uniform_costs(&mut rng, n, 100);
        let want = assignment::hungarian::Hungarian.solve(&inst).unwrap();
        let mut driver = PjrtAssignmentDriver::for_size(&reg, n).unwrap();
        let (got, tel) = driver.solve(&inst).unwrap();
        assert_eq!(got.weight, want.weight, "n={n}");
        assert!(
            AssignmentInstance::is_permutation(&got.assignment),
            "n={n}"
        );
        assert!(tel.device_rounds > 0);
        assert!(tel.padded_n >= n);
    }
}

#[test]
fn pjrt_driver_price_update_ablation() {
    let Ok(reg) = ArtifactRegistry::discover() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut rng = Rng::seeded(12);
    let inst = uniform_costs(&mut rng, 16, 100);
    let want = assignment::hungarian::Hungarian.solve(&inst).unwrap().weight;
    for price_updates in [true, false] {
        let mut driver = PjrtAssignmentDriver::for_size(&reg, 16).unwrap();
        driver.price_updates = price_updates;
        let (got, _) = driver.solve(&inst).unwrap();
        assert_eq!(got.weight, want, "price_updates={price_updates}");
    }
}

#[test]
fn asn_file_roundtrip_preserves_optimum() {
    let mut rng = Rng::seeded(13);
    let inst = uniform_costs(&mut rng, 9, 50);
    let text = dimacs::write_assignment(&inst);
    let parsed = dimacs::parse_assignment(&text).unwrap();
    let a = assignment::hungarian::Hungarian.solve(&inst).unwrap();
    let b = assignment::hungarian::Hungarian.solve(&parsed).unwrap();
    assert_eq!(a.weight, b.weight);
}

#[test]
fn matching_reduction_cardinality_parity() {
    // Fig. 1's other edge: cardinality matching via max-flow.
    let mut rng = Rng::seeded(14);
    for _ in 0..5 {
        let nx = 3 + rng.index(6);
        let ny = 3 + rng.index(6);
        let edges: Vec<Vec<usize>> = (0..nx)
            .map(|_| (0..ny).filter(|_| rng.chance(0.45)).collect())
            .collect();
        let (size, _) = reductions::max_cardinality_matching(
            nx,
            ny,
            &edges,
            &flowmatch::maxflow::dinic::Dinic,
        )
        .unwrap();
        assert_eq!(
            size,
            reductions::matching_to_flow::reference_matching(nx, ny, &edges)
        );
    }
}
