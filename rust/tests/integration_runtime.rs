//! Integration: PJRT runtime against real AOT artifacts, including the
//! cross-language parity check (PJRT kernel vs bit-exact native twin).
//!
//! Skipped with a note when `make artifacts` has not run.

use flowmatch::gridflow::{self, GridExecutor, NativeGridExecutor};
use flowmatch::runtime::device::{CsaWireState, GridWireState};
use flowmatch::runtime::{ArtifactRegistry, CsaDevice, GridDevice};
use flowmatch::util::Rng;
use flowmatch::workloads::grid_gen::random_grid;

fn registry() -> Option<ArtifactRegistry> {
    match ArtifactRegistry::discover() {
        Ok(reg) if !reg.is_empty() => Some(reg),
        _ => {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            None
        }
    }
}

/// Deterministic 8x8 grid instance in wire form.
fn demo_grid_state(seed: u64) -> (GridWireState, i64) {
    let mut rng = Rng::seeded(seed);
    let net = random_grid(&mut rng, 8, 8, 12, 0.3, 0.3);
    gridflow::init_state(&net)
}

#[test]
fn grid_device_runs_to_quiescence_and_conserves_mass() {
    let Some(reg) = registry() else { return };
    let dev = GridDevice::for_shape(&reg, 8, 8).expect("8x8 artifact");
    let (mut st, excess_total) = demo_grid_state(3);

    let mut sink_total = 0i64;
    let mut src_total = 0i64;
    for round in 0.. {
        assert!(round < 500, "did not converge");
        let stats = dev.step(&mut st, 64).expect("step");
        sink_total += stats.sink_flow;
        src_total += stats.src_flow;
        if stats.active == 0 {
            break;
        }
    }
    assert_eq!(sink_total + src_total, excess_total);
    assert!(st.cap.iter().all(|&c| c >= 0));
    assert!(st.cap_sink.iter().all(|&c| c >= 0));
    assert!(st.cap_src.iter().all(|&c| c >= 0));
}

#[test]
fn grid_device_outer_zero_is_identity() {
    let Some(reg) = registry() else { return };
    let dev = GridDevice::for_shape(&reg, 8, 8).expect("8x8 artifact");
    let (mut st, _) = demo_grid_state(4);
    let before = st.clone();
    let stats = dev.step(&mut st, 0).expect("step");
    assert_eq!(stats.waves, 0);
    assert_eq!(st.h, before.h);
    assert_eq!(st.e, before.e);
    assert_eq!(st.cap, before.cap);
}

/// THE cross-language pin: the PJRT artifact and the native Rust twin
/// must produce *identical* state trajectories, super-step for
/// super-step.
#[test]
fn pjrt_and_native_trajectories_are_bit_identical() {
    let Some(reg) = registry() else { return };
    let dev = GridDevice::for_shape(&reg, 8, 8).expect("8x8 artifact");
    let mut native = NativeGridExecutor::with_k_inner(dev.k_inner);

    let (mut st_dev, _) = demo_grid_state(5);
    let mut st_nat = st_dev.clone();

    for step in 0..20 {
        let a = dev.step(&mut st_dev, 2).expect("device step");
        let b = native.superstep(&mut st_nat, 2).expect("native step");
        assert_eq!(st_dev.h, st_nat.h, "heights diverged at step {step}");
        assert_eq!(st_dev.e, st_nat.e, "excess diverged at step {step}");
        assert_eq!(st_dev.cap, st_nat.cap, "caps diverged at step {step}");
        assert_eq!(st_dev.cap_sink, st_nat.cap_sink, "sink caps diverged");
        assert_eq!(st_dev.cap_src, st_nat.cap_src, "src caps diverged");
        assert_eq!(
            (a.sink_flow, a.src_flow, a.pushes, a.relabels, a.waves, a.active),
            (b.sink_flow, b.src_flow, b.pushes, b.relabels, b.waves, b.active),
            "stats diverged at step {step}"
        );
        if a.active == 0 {
            break;
        }
    }
}

#[test]
fn csa_device_refines_to_perfect_matching() {
    let Some(reg) = registry() else { return };
    let n = 8usize;
    let dev = CsaDevice::for_size(&reg, n).expect("csa artifact");
    assert_eq!(dev.n, n);

    let weights: Vec<i64> = (0..n * n).map(|k| ((k * 37 + 11) % 101) as i64).collect();
    let k = (n + 1) as i64;
    let cost: Vec<i32> = weights.iter().map(|&w| (-w * k) as i32).collect();
    let eps0 = weights.iter().max().unwrap() * k;

    let mut st = CsaWireState::fresh(cost.clone(), n);
    for x in 0..n {
        let row_min = (0..n).map(|y| st.cost[x * n + y]).min().unwrap();
        st.px[x] = -row_min - eps0 as i32;
    }

    for round in 0.. {
        assert!(round < 500, "refine did not converge");
        let stats = dev.step(&mut st, eps0 as i32, 64).expect("step");
        if stats.active() == 0 {
            break;
        }
    }
    for x in 0..n {
        let row: i32 = st.f[x * n..(x + 1) * n].iter().sum();
        assert_eq!(row, 1, "row {x}");
    }
    for y in 0..n {
        let col: i32 = (0..n).map(|x| st.f[x * n + y]).sum();
        assert_eq!(col, 1, "col {y}");
    }
    assert!(st.ex.iter().all(|&e| e == 0));
    assert!(st.ey.iter().all(|&e| e == 0));
}

#[test]
fn registry_discovers_expected_variants() {
    let Some(reg) = registry() else { return };
    assert!(reg.grid(8, 8).is_some());
    assert!(reg.grid(64, 64).is_some());
    assert!(reg.csa_at_least(8).is_some());
    // The padding rule returns the smallest artifact that fits.
    let spec = reg.csa_at_least(20).expect("n>=20 artifact");
    assert_eq!(spec.dim0, 30, "expected the n=30 artifact for n=20");
}
