//! Differential suite for batched device execution: the padded
//! multi-instance dispatch must be *bit-exact* with both the
//! per-instance device path and the native oracle — same flow, same
//! wave/push/relabel trajectory — across size classes, ragged batches,
//! and the degenerate batch of one.  On the service side, a pool with
//! micro-batching enabled must answer the identical flows as the
//! pre-batching pool, and `batch_max = 1` must keep batching fully
//! disengaged.

use std::collections::BTreeMap;

use flowmatch::coordinator::{solve_grid_batch, solve_grid_with, Backend, GridEngine};
use flowmatch::graph::GridNetwork;
use flowmatch::service::{
    replay, CancelToken, Cancelled, PoolConfig, ProblemInstance, RouterConfig, ShardConfig,
    SolveOutcome, SolverPool,
};
use flowmatch::util::Rng;
use flowmatch::workloads::{random_grid, MixedTrace, MixedTraceConfig, TraceConfig};

const CYCLE: usize = 96;

/// Solve each net solo with a forced engine and return the trajectory
/// counters that must survive batching untouched.
fn solo_trajectories(nets: &[GridNetwork], engine: GridEngine) -> Vec<(i64, u64, i64, i64, i64)> {
    nets.iter()
        .map(|net| {
            let (r, backend) = solve_grid_with(net, CYCLE, None, engine).unwrap();
            if engine == GridEngine::Pjrt {
                assert_eq!(backend, Backend::Pjrt, "forced device path must report Pjrt");
            }
            (r.flow, r.host_rounds, r.waves, r.pushes, r.relabels)
        })
        .collect()
}

fn assert_batch_matches(nets: &[GridNetwork], label: &str) {
    let refs: Vec<&GridNetwork> = nets.iter().collect();
    let cancels = vec![None; nets.len()];
    let batched = solve_grid_batch(&refs, CYCLE, None, &cancels).unwrap();
    let native = solo_trajectories(nets, GridEngine::Native);
    let device = solo_trajectories(nets, GridEngine::Pjrt);
    // The device path is bit-exact with native before batching even
    // enters the picture; assert it so a failure pinpoints the layer.
    assert_eq!(native, device, "{label}: per-instance device vs native");
    for (slot, report) in batched.into_iter().enumerate() {
        let r = report.unwrap_or_else(|e| panic!("{label}: slot {slot} failed: {e:#}"));
        assert_eq!(
            (r.flow, r.host_rounds, r.waves, r.pushes, r.relabels),
            native[slot],
            "{label}: slot {slot} diverged from the solo trajectory"
        );
    }
}

/// Uniform batch: every slot the same shape, no padding at all.
#[test]
fn uniform_batch_is_bit_exact_with_solo_solves() {
    let mut rng = Rng::seeded(901);
    let nets: Vec<GridNetwork> = (0..4)
        .map(|_| random_grid(&mut rng, 10, 10, 9, 0.3, 0.3))
        .collect();
    assert_batch_matches(&nets, "uniform 10x10 x4");
}

/// Ragged batch: four different shapes padded to the 9x10 envelope.
/// Padding planes carry zero capacity, so padded cells can never push;
/// each slot's trajectory must match its solo solve exactly.
#[test]
fn ragged_batch_is_bit_exact_with_solo_solves() {
    let mut rng = Rng::seeded(902);
    let shapes = [(6usize, 10usize), (8, 8), (5, 7), (9, 6)];
    let nets: Vec<GridNetwork> = shapes
        .iter()
        .map(|&(h, w)| random_grid(&mut rng, h, w, 12, 0.25, 0.25))
        .collect();
    assert_batch_matches(&nets, "ragged 9x10 envelope");
}

/// Larger size class: the batch path must not care how many host
/// rounds the instances need.
#[test]
fn medium_class_batch_is_bit_exact() {
    let mut rng = Rng::seeded(903);
    let nets: Vec<GridNetwork> = (0..3)
        .map(|_| random_grid(&mut rng, 16, 16, 20, 0.3, 0.3))
        .collect();
    assert_batch_matches(&nets, "16x16 x3");
}

/// The degenerate batch of one (what `--batch-max 1` would dispatch if
/// it dispatched at all) is the solo solve.
#[test]
fn batch_of_one_is_the_solo_solve() {
    let mut rng = Rng::seeded(904);
    let nets = vec![random_grid(&mut rng, 7, 11, 9, 0.3, 0.3)];
    assert_batch_matches(&nets, "batch of one");
}

/// A cancelled slot retires with a typed `Cancelled` error while its
/// batch-mates keep solving to the exact solo answers.
#[test]
fn cancelled_slot_does_not_disturb_batchmates() {
    let mut rng = Rng::seeded(905);
    let nets: Vec<GridNetwork> = (0..3)
        .map(|_| random_grid(&mut rng, 9, 9, 9, 0.3, 0.3))
        .collect();
    let refs: Vec<&GridNetwork> = nets.iter().collect();
    let dead = CancelToken::new();
    dead.cancel();
    let cancels = vec![None, Some(dead), None];
    let batched = solve_grid_batch(&refs, CYCLE, None, &cancels).unwrap();
    let native = solo_trajectories(&nets, GridEngine::Native);
    for (slot, report) in batched.into_iter().enumerate() {
        match report {
            Ok(r) => {
                assert_ne!(slot, 1, "cancelled slot must not produce a report");
                assert_eq!((r.flow, r.host_rounds, r.waves, r.pushes, r.relabels), native[slot]);
            }
            Err(e) => {
                assert_eq!(slot, 1, "live slot {slot} unexpectedly failed: {e:#}");
                assert!(Cancelled::caused(&e), "slot 1 must fail with Cancelled, got {e:#}");
            }
        }
    }
}

// ---------------------------------------------------------------- service

fn pool_config(batch_max: usize) -> PoolConfig {
    PoolConfig {
        workers: 2,
        shard: ShardConfig {
            small_max_units: 256,
            medium_max_units: 1024,
            queue_depth: 64,
            max_units: 1 << 16,
        },
        router: RouterConfig {
            use_pjrt: false,
            cycle_waves: 128,
            par_threads: 2,
            tile_rows: 4,
            batch_max,
            // Generous linger so the closed-loop burst reliably forms
            // multi-instance batches (the test asserts at least one).
            batch_linger_us: 20_000,
            ..Default::default()
        },
        session_budget_mb: 64,
    }
}

/// Back-to-back burst: matchings land Small, 24x24 grids land Medium,
/// so the Medium queues hold nothing but batchable grid jobs.
fn burst_trace(seed: u64) -> MixedTrace {
    let mut rng = Rng::seeded(seed);
    MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests: 6,
                n: 10,
                max_weight: 60,
                arrival_gap: 0.0,
                ..Default::default()
            },
            grid_requests: 12,
            grid_size: 24,
            grid_max_cap: 12,
            grid_arrival_gap: 0.0,
            large_every: 0,
            ..Default::default()
        },
    )
}

fn grid_flows(trace: &MixedTrace, out: &flowmatch::service::ReplayOutcome) -> BTreeMap<usize, i64> {
    let mut flows = BTreeMap::new();
    for (id, reply) in &out.replies {
        let reply = reply.as_ref().unwrap_or_else(|e| panic!("request {id}: {e}"));
        if matches!(trace.requests[*id].instance, ProblemInstance::Grid(_)) {
            let SolveOutcome::Grid(report) = &reply.outcome else {
                panic!("request {id}: grid request answered with a non-grid outcome");
            };
            flows.insert(*id, report.flow);
        }
    }
    flows
}

/// The headline service differential: a batching pool answers the
/// identical flows as the pre-batching pool, loses nothing, and
/// actually cuts at least one multi-instance batch; the `batch_max = 1`
/// pool never batches at all.
#[test]
fn batching_pool_answers_identical_flows_and_engages() {
    let trace = burst_trace(906);

    let plain = SolverPool::start(pool_config(1));
    let out_plain = replay(&plain, &trace, false);
    let report_plain = plain.shutdown();
    assert_eq!(out_plain.ok, out_plain.sent, "unbatched pool must serve the whole burst");
    assert_eq!(report_plain.batches, 0, "batch_max = 1 must keep batching disengaged");
    assert_eq!(report_plain.batched_jobs, 0);

    let batched = SolverPool::start(pool_config(8));
    let out_batched = replay(&batched, &trace, false);
    let report_batched = batched.shutdown();
    assert_eq!(out_batched.lost, 0, "a cut batch must answer every slot");
    assert_eq!(out_batched.ok, out_batched.sent, "batched pool must serve the whole burst");

    // Same trace, same answers: flows are engine-invariant.
    assert_eq!(grid_flows(&trace, &out_plain), grid_flows(&trace, &out_batched));

    // The burst is deep and the linger generous: batching must engage,
    // and every dispatch carries at least two jobs by construction.
    assert!(
        report_batched.batches >= 1,
        "no batch cut from a 12-grid closed-loop burst"
    );
    assert!(report_batched.batched_jobs >= 2 * report_batched.batches);
    let via_batch = out_batched
        .replies
        .iter()
        .filter(|(_, r)| r.as_ref().is_ok_and(|r| r.backend == "grid-batch"))
        .count();
    assert_eq!(
        via_batch, report_batched.batched_jobs,
        "client-side grid-batch replies must equal the pool's batched-job count"
    );
}
