//! Marshalling between Rust `i32` buffers and XLA literals.
//!
//! Every kernel input/output in this project is `int32` (DESIGN.md §7), so
//! the surface here is deliberately small and panic-free.

use anyhow::{Context, Result};

/// Build a rank-N i32 literal from a flat row-major buffer.
pub fn i32_tensor(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(
        n == data.len(),
        "literal shape {:?} wants {} elements, got {}",
        dims,
        n,
        data.len()
    );
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .context("reshaping literal")
}

/// Scalar i32 literal (rank 0).
pub fn i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a flat i32 vector and check the element count.
pub fn to_i32_vec(lit: &xla::Literal, expect: usize) -> Result<Vec<i32>> {
    let v = lit.to_vec::<i32>().context("literal -> Vec<i32>")?;
    anyhow::ensure!(
        v.len() == expect,
        "expected {} elements from device, got {}",
        expect,
        v.len()
    );
    Ok(v)
}
