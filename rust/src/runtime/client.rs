//! Per-thread PJRT CPU client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and therefore `!Send`: all
//! PJRT state lives in thread-locals, and the coordinator confines device
//! work to a single *device thread* (see `coordinator::server`) — mirroring
//! the single CUDA context of the paper's implementation.

use std::cell::OnceCell;

use anyhow::{Context, Result};

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// The calling thread's PJRT CPU client.  First call pays plugin start-up.
pub fn with_client<T>(f: impl FnOnce(&xla::PjRtClient) -> Result<T>) -> Result<T> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let _ = cell.set(client);
        }
        f(cell.get().expect("client initialised above"))
    })
}

/// Human-readable platform description (for `flowmatch info`).
pub fn platform_info() -> Result<String> {
    with_client(|c| {
        Ok(format!(
            "{} ({} devices)",
            c.platform_name(),
            c.device_count()
        ))
    })
}
