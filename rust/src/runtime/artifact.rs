//! Artifact discovery: map logical kernel names to `artifacts/*.hlo.txt`
//! files produced by `make artifacts` (python/compile/aot.py).
//!
//! The AOT step writes a `manifest.txt` with one line per artifact:
//!
//! ```text
//! grid_wave_32x32 grid 32 32 16
//! csa_refine_30   csa  30 30 16
//! ```
//!
//! (name, kind, dim0, dim1, k_inner).  The registry parses it and knows,
//! for a requested problem shape, which artifact to load.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Which L2 graph an artifact encodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Grid push-relabel super-step (`grid_wave_{H}x{W}`).
    Grid,
    /// CSA refine super-step (`csa_refine_{n}`).
    Csa,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "grid" => Ok(Self::Grid),
            "csa" => Ok(Self::Csa),
            other => bail!("unknown artifact kind {other:?} in manifest"),
        }
    }
}

/// One line of the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub dim0: usize,
    pub dim1: usize,
    pub k_inner: usize,
    pub path: PathBuf,
}

/// All artifacts found in one artifacts directory.
#[derive(Debug, Default)]
pub struct ArtifactRegistry {
    by_name: BTreeMap<String, ArtifactSpec>,
}

/// Locate the artifacts directory: `$FLOWMATCH_ARTIFACTS`, else `artifacts/`
/// relative to the current dir, else relative to the crate root (so tests
/// and benches work from any working directory).
pub fn default_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("FLOWMATCH_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.is_dir() {
            return Some(p);
        }
    }
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    candidates.into_iter().find(|p| p.is_dir())
}

impl ArtifactRegistry {
    /// Parse `manifest.txt` in `dir`.  Artifacts whose `.hlo.txt` file is
    /// missing (e.g. a partial `--only` build) are skipped.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut by_name = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                bail!("manifest line {} malformed: {line:?}", lineno + 1);
            }
            let path = dir.join(format!("{}.hlo.txt", parts[0]));
            if !path.is_file() {
                continue;
            }
            let spec = ArtifactSpec {
                name: parts[0].to_string(),
                kind: ArtifactKind::parse(parts[1])?,
                dim0: parts[2].parse().context("manifest dim0")?,
                dim1: parts[3].parse().context("manifest dim1")?,
                k_inner: parts[4].parse().context("manifest k_inner")?,
                path,
            };
            by_name.insert(spec.name.clone(), spec);
        }
        Ok(Self { by_name })
    }

    /// Load from the default location, if one exists.
    pub fn discover() -> Result<Self> {
        let dir = default_dir().context(
            "no artifacts directory found; run `make artifacts` or set FLOWMATCH_ARTIFACTS",
        )?;
        Self::load(&dir)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.by_name.get(name)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactSpec> {
        self.by_name.values()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Exact-shape grid artifact.
    pub fn grid(&self, height: usize, width: usize) -> Option<&ArtifactSpec> {
        self.by_name
            .values()
            .find(|s| s.kind == ArtifactKind::Grid && s.dim0 == height && s.dim1 == width)
    }

    /// Smallest CSA artifact with `dim0 >= n` (instances are padded up).
    pub fn csa_at_least(&self, n: usize) -> Option<&ArtifactSpec> {
        self.by_name
            .values()
            .filter(|s| s.kind == ArtifactKind::Csa && s.dim0 >= n)
            .min_by_key(|s| s.dim0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest() {
        let dir = std::env::temp_dir().join(format!("fm_artifacts_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "a_8 grid 8 8 16\nb_4 csa 4 4 8\n").unwrap();
        std::fs::write(dir.join("a_8.hlo.txt"), "HloModule x").unwrap();
        // b_4.hlo.txt intentionally missing -> skipped.
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert!(reg.get("a_8").is_some());
        assert!(reg.get("b_4").is_none());
        assert_eq!(reg.grid(8, 8).unwrap().k_inner, 16);
        assert!(reg.csa_at_least(2).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_manifest_rejected() {
        let dir = std::env::temp_dir().join(format!("fm_artifacts_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "only three fields\n").unwrap();
        assert!(ArtifactRegistry::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
