//! L3 runtime: load AOT-compiled HLO-text artifacts and execute them on the
//! PJRT CPU client from the coordinator's hot path.
//!
//! Python/JAX runs only at build time (`make artifacts`); at runtime this
//! module is the *only* bridge to the compiled compute graphs:
//!
//! ```text
//! PjRtClient::cpu()
//!   -> HloModuleProto::from_text_file("artifacts/<name>.hlo.txt")
//!   -> client.compile(..)            (cached per artifact)
//!   -> exe.execute(literals)         (one call per super-step)
//! ```
//!
//! Transfers between host and device are byte-accounted in [`transfer`] to
//! reproduce the paper's host<->device copy-minimization analysis (§4.6,
//! §5.5 of the paper).

pub mod artifact;
pub mod batch;
pub mod client;
pub mod device;
pub mod executor;
pub mod literal;
pub mod transfer;

pub use artifact::{ArtifactKind, ArtifactRegistry, ArtifactSpec};
pub use batch::{BatchDispatchStats, BatchedGridDriver, SimGridDevice};
pub use device::{CsaDevice, CsaStepStats, GridDevice, GridStepStats};
pub use executor::Executor;
pub use transfer::TransferLog;
