//! Batched device execution for the grid family: pack K same-size-class
//! grid instances into one padded `[K, PLANES, Hmax, Wmax]` literal, run
//! the wave phase for all of them as a single dispatch, and double-buffer
//! the host↔device staging so the upload of batch i+1 overlaps the
//! compute of batch i.
//!
//! Two execution substrates share the packed wire format:
//!
//! * A real PJRT artifact (when the toolchain/device is present) would
//!   consume the padded literal directly — the layout is chosen so the
//!   kernel indexes `[k, plane, i, j]` with no per-slot metadata.
//! * [`BatchedGridDriver`] itself carries a deterministic host-simulated
//!   device mode: compute runs on per-slot states *unpacked from the
//!   literal* (never on the caller's buffers), so every packing bug is
//!   observable as a wrong answer in the differential suites, exactly as
//!   it would be on hardware.
//!
//! The simulated compute is `gridflow::wave::native_wave_with` per slot —
//! the same single source of decision semantics the kernel is pinned to —
//! so batched trajectories are bit-exact with the sequential native
//! engine (slots never interact: pushes stay inside a slot's plane).
//!
//! Padding: a slot of logical dims `(h, w)` occupies the top-left corner
//! of its `(Hmax, Wmax)` plane; pad cells carry zero capacity and zero
//! excess, so they can never activate.  Compute still runs on the
//! *logical* dims (the relabel ceiling `V = cells + 2` is
//! dimension-derived, so a kernel must mask to logical dims too — the
//! host-simulated mode models that by reconstructing logical-dims states
//! from the literal).

use anyhow::{ensure, Result};

use super::device::{GridStepStats, GridWireState};
use super::transfer;
use crate::gridflow::wave::{native_wave_with, WaveScratch};

/// Planes per slot in the packed literal: h, e, cap[N,S,W,E], cap_sink,
/// cap_src — the whole wire state of one instance.
pub const PLANES: usize = 8;

/// Cumulative accounting for one driver's lifetime of batched dispatches.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchDispatchStats {
    /// Batched supersteps dispatched.
    pub dispatches: u64,
    /// Live instances summed over dispatches (= Σ K_live).
    pub instances: u64,
    /// Padded plane cells shipped (K · Hmax · Wmax per dispatch).
    pub padded_cells: u64,
    /// Logical cells of the live instances (≤ padded_cells).
    pub logical_cells: u64,
    /// Seconds spent packing/unpacking the staging literals (the
    /// host-side half of the transfer).
    pub transfer_seconds: f64,
    /// Seconds spent in the wave compute across all slots.
    pub compute_seconds: f64,
    /// Transfer seconds hidden behind compute by the double buffer
    /// (min(upload_i+1, compute_i) per adjacent dispatch pair).
    pub overlap_seconds: f64,
}

impl BatchDispatchStats {
    /// Padding waste: padded cells that carried no logical instance data,
    /// as a fraction of everything shipped (0 = perfectly packed).
    pub fn padding_waste(&self) -> f64 {
        if self.padded_cells == 0 {
            return 0.0;
        }
        1.0 - self.logical_cells as f64 / self.padded_cells as f64
    }

    /// Fraction of transfer time hidden behind compute (0 = fully
    /// serialized, → 1 = fully overlapped).
    pub fn overlap_ratio(&self) -> f64 {
        if self.transfer_seconds <= 0.0 {
            return 0.0;
        }
        (self.overlap_seconds / self.transfer_seconds).clamp(0.0, 1.0)
    }
}

/// The batched grid wave driver for one padded shape class.
///
/// Owns two staging literals (ping-pong): while dispatch i computes, the
/// pack of dispatch i+1 targets the other buffer, so the host-side
/// transfer work overlaps device compute — the overlap accounting below
/// models exactly that pipeline (credit = min(this pack, previous
/// compute)).
pub struct BatchedGridDriver {
    hmax: usize,
    wmax: usize,
    k_inner: usize,
    /// Ping-pong staging literals, each grown to `K · PLANES · Hmax ·
    /// Wmax` on demand.  `staging[upload]` receives the next pack.
    staging: [Vec<i32>; 2],
    upload: usize,
    /// Compute seconds of the previous dispatch — the budget the next
    /// pack can hide behind.
    prev_compute: f64,
    stats: BatchDispatchStats,
}

impl BatchedGridDriver {
    /// Driver for a padded shape class `(hmax, wmax)` with the standard
    /// wave budget per outer unit.
    pub fn for_class(hmax: usize, wmax: usize) -> Self {
        Self::with_k_inner(hmax, wmax, 16)
    }

    pub fn with_k_inner(hmax: usize, wmax: usize, k_inner: usize) -> Self {
        assert!(hmax > 0 && wmax > 0, "degenerate padded shape");
        Self {
            hmax,
            wmax,
            k_inner: k_inner.max(1),
            staging: [Vec::new(), Vec::new()],
            upload: 0,
            prev_compute: 0.0,
            stats: BatchDispatchStats::default(),
        }
    }

    pub fn k_inner(&self) -> usize {
        self.k_inner
    }

    pub fn padded_shape(&self) -> (usize, usize) {
        (self.hmax, self.wmax)
    }

    /// Whether a state of these dims fits this driver's padded planes.
    pub fn admits(&self, st: &GridWireState) -> bool {
        st.height <= self.hmax && st.width <= self.wmax
    }

    /// Cumulative dispatch accounting since construction.
    pub fn stats(&self) -> BatchDispatchStats {
        self.stats
    }

    fn slot_stride(&self) -> usize {
        PLANES * self.hmax * self.wmax
    }

    /// Copy one plane (logical dims `h×w`) into the padded plane at
    /// `base`, row by row.  Pad cells keep whatever `fill` left there
    /// (the pack zero-fills the buffer first).
    fn pack_plane(&self, buf: &mut [i32], base: usize, src: &[i32], h: usize, w: usize) {
        for r in 0..h {
            let dst = base + r * self.wmax;
            buf[dst..dst + w].copy_from_slice(&src[r * w..(r + 1) * w]);
        }
    }

    fn unpack_plane(&self, buf: &[i32], base: usize, dst: &mut [i32], h: usize, w: usize) {
        for r in 0..h {
            let src = base + r * self.wmax;
            dst[r * w..r * w + w].copy_from_slice(&buf[src..src + w]);
        }
    }

    /// Pack every live slot into the current upload buffer.  Dead slots
    /// (and pad cells) are zeroed: zero capacity + zero excess can never
    /// activate, so a kernel may run over the full padded plane safely.
    fn pack(&mut self, states: &[GridWireState], live: &[bool]) {
        let stride = self.slot_stride();
        let total = stride * states.len();
        let plane = self.hmax * self.wmax;
        let (hmax, wmax) = (self.hmax, self.wmax);
        let mut buf = std::mem::take(&mut self.staging[self.upload]);
        buf.clear();
        buf.resize(total, 0);
        for (k, st) in states.iter().enumerate() {
            if !live[k] {
                continue;
            }
            let (h, w) = (st.height, st.width);
            debug_assert!(h <= hmax && w <= wmax);
            let cells = st.cells();
            let base = k * stride;
            self.pack_plane(&mut buf, base, &st.h, h, w);
            self.pack_plane(&mut buf, base + plane, &st.e, h, w);
            for a in 0..4 {
                self.pack_plane(
                    &mut buf,
                    base + (2 + a) * plane,
                    &st.cap[a * cells..(a + 1) * cells],
                    h,
                    w,
                );
            }
            self.pack_plane(&mut buf, base + 6 * plane, &st.cap_sink, h, w);
            self.pack_plane(&mut buf, base + 7 * plane, &st.cap_src, h, w);
        }
        self.staging[self.upload] = buf;
    }

    /// Rebuild one slot's logical-dims state from a staging buffer.
    /// This is the read side of the wire format: compute consumes ONLY
    /// what round-tripped through the literal.
    fn unpack_slot(&self, buf: &[i32], k: usize, height: usize, width: usize) -> GridWireState {
        let stride = self.slot_stride();
        let plane = self.hmax * self.wmax;
        let base = k * stride;
        let mut st = GridWireState::zeros(height, width);
        let cells = st.cells();
        self.unpack_plane(buf, base, &mut st.h, height, width);
        self.unpack_plane(buf, base + plane, &mut st.e, height, width);
        for a in 0..4 {
            self.unpack_plane(
                buf,
                base + (2 + a) * plane,
                &mut st.cap[a * cells..(a + 1) * cells],
                height,
                width,
            );
        }
        self.unpack_plane(buf, base + 6 * plane, &mut st.cap_sink, height, width);
        self.unpack_plane(buf, base + 7 * plane, &mut st.cap_src, height, width);
        st
    }

    /// Run one batched superstep: every live slot advances by up to
    /// `outer · k_inner` waves (stopping early when its active set
    /// drains), exactly like one `GridExecutor::superstep` per slot.
    ///
    /// `states[k]` is read and (for live slots) overwritten with the
    /// post-superstep wire state; the returned vector carries one
    /// [`GridStepStats`] per slot (dead slots report all-zero stats).
    /// Slots never interact — the per-slot trajectory is bit-exact with
    /// a solo solve of the same instance.
    pub fn superstep_batch(
        &mut self,
        states: &mut [GridWireState],
        live: &[bool],
        outer: i32,
    ) -> Result<Vec<GridStepStats>> {
        ensure!(
            states.len() == live.len(),
            "superstep_batch: {} states vs {} live flags",
            states.len(),
            live.len()
        );
        ensure!(!states.is_empty(), "superstep_batch: empty batch");
        for (k, st) in states.iter().enumerate() {
            ensure!(
                self.admits(st),
                "slot {k}: {}x{} exceeds padded class {}x{}",
                st.height,
                st.width,
                self.hmax,
                self.wmax
            );
        }

        // Upload: pack live slots into the staging literal and account
        // the H2D bytes (payload + the `outer` scalar), mirroring
        // `GridDevice::step`.
        let t_pack = std::time::Instant::now();
        self.pack(states, live);
        let upload_bytes = self.staging[self.upload].len() * 4 + 4;
        transfer::GLOBAL.record_h2d(upload_bytes);
        let pack_secs = t_pack.elapsed().as_secs_f64();

        // Compute: per live slot, on states reconstructed FROM the
        // literal.  A packing bug (wrong stride, swapped plane, clipped
        // row) therefore changes answers instead of hiding behind a
        // host-side shortcut.
        let t_compute = std::time::Instant::now();
        let budget = outer as i64 * self.k_inner as i64;
        let mut out = vec![GridStepStats::default(); states.len()];
        let mut scratch = WaveScratch::default();
        let upload = self.upload;
        let mut logical = 0u64;
        let mut live_count = 0u64;
        for k in 0..states.len() {
            if !live[k] {
                continue;
            }
            live_count += 1;
            logical += states[k].cells() as u64;
            let mut st =
                self.unpack_slot(&self.staging[upload], k, states[k].height, states[k].width);
            scratch.rebuild(&st);
            let stats = &mut out[k];
            for _ in 0..budget {
                if scratch.active_count() == 0 {
                    break;
                }
                let w = native_wave_with(&mut st, &mut scratch);
                stats.sink_flow += w.sink_flow;
                stats.src_flow += w.src_flow;
                stats.pushes += w.pushes;
                stats.relabels += w.relabels;
                stats.waves += 1;
            }
            stats.active = scratch.active_count() as i64;
            states[k] = st;
        }
        let compute_secs = t_compute.elapsed().as_secs_f64();

        // Download: the result planes come back through the other
        // staging buffer (ping-pong), so the next dispatch's upload
        // never waits on this readback.  D2H mirrors `GridDevice::step`
        // (payload + 24 bytes of scalar stats, per live slot).
        let t_unpack = std::time::Instant::now();
        let download = 1 - self.upload;
        {
            let mut buf = std::mem::take(&mut self.staging[download]);
            buf.clear();
            buf.resize(self.slot_stride() * states.len(), 0);
            let (hmax, wmax) = (self.hmax, self.wmax);
            let plane = hmax * wmax;
            let stride = self.slot_stride();
            for (k, st) in states.iter().enumerate() {
                if !live[k] {
                    continue;
                }
                let base = k * stride;
                self.pack_plane(&mut buf, base, &st.h, st.height, st.width);
                self.pack_plane(&mut buf, base + plane, &st.e, st.height, st.width);
            }
            self.staging[download] = buf;
        }
        transfer::GLOBAL.record_d2h(self.staging[download].len() * 4 + 24 * live_count as usize);
        let unpack_secs = t_unpack.elapsed().as_secs_f64();

        // Double-buffer pipeline model: this dispatch's host-side pack
        // ran while the previous dispatch's compute was still in flight,
        // so up to min(pack, prev_compute) of it was free.
        let transfer_secs = pack_secs + unpack_secs;
        self.stats.overlap_seconds += pack_secs.min(self.prev_compute);
        self.prev_compute = compute_secs;
        self.upload = download;

        self.stats.dispatches += 1;
        self.stats.instances += live_count;
        self.stats.padded_cells += (states.len() * self.hmax * self.wmax) as u64;
        self.stats.logical_cells += logical;
        self.stats.transfer_seconds += transfer_secs;
        self.stats.compute_seconds += compute_secs;
        Ok(out)
    }
}

/// Deterministic host-simulated device for the per-instance path: a
/// batch-of-one view over [`BatchedGridDriver`], so `GridEngine::Pjrt`
/// stays testable (and bit-exact with the native engine) in containers
/// with no PJRT device.  The `GridExecutor` impl lives in
/// `gridflow::batch` next to the solver-side plumbing.
pub struct SimGridDevice {
    pub driver: BatchedGridDriver,
}

impl SimGridDevice {
    pub fn for_shape(height: usize, width: usize) -> Self {
        Self {
            driver: BatchedGridDriver::for_class(height, width),
        }
    }

    pub fn step(&mut self, state: &mut GridWireState, outer: i32) -> Result<GridStepStats> {
        let live = [true];
        let mut stats =
            self.driver
                .superstep_batch(std::slice::from_mut(state), &live, outer)?;
        Ok(stats.pop().expect("batch of one"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny deterministic instance: border sources, far-corner sinks.
    fn demo_state(h: usize, w: usize, seed: i32) -> GridWireState {
        let mut st = GridWireState::zeros(h, w);
        let cells = h * w;
        for c in 0..cells {
            for a in 0..4 {
                st.cap[a * cells + c] = ((c as i32 * 7 + a as i32 * 3 + seed) % 5) + 1;
            }
        }
        st.cap_src[0] = 6 + seed;
        st.e[0] = 6 + seed;
        st.cap_sink[cells - 1] = 5 + seed;
        st
    }

    fn solo_superstep(st: &mut GridWireState, outer: i32, k_inner: usize) -> GridStepStats {
        let mut stats = GridStepStats::default();
        let mut scratch = WaveScratch::default();
        scratch.rebuild(st);
        for _ in 0..(outer as i64 * k_inner as i64) {
            if scratch.active_count() == 0 {
                break;
            }
            let w = native_wave_with(st, &mut scratch);
            stats.sink_flow += w.sink_flow;
            stats.src_flow += w.src_flow;
            stats.pushes += w.pushes;
            stats.relabels += w.relabels;
            stats.waves += 1;
        }
        stats.active = scratch.active_count() as i64;
        stats
    }

    /// The tentpole invariant at the superstep level: a padded batched
    /// dispatch advances every slot exactly as a solo native superstep
    /// would — heights, excesses, and every counter.
    #[test]
    fn batched_superstep_matches_solo_per_slot() {
        let mut driver = BatchedGridDriver::for_class(5, 6);
        // Ragged dims inside one padded class.
        let mut batched = vec![
            demo_state(3, 4, 0),
            demo_state(5, 6, 1),
            demo_state(4, 3, 2),
        ];
        let mut solo = batched.clone();
        let live = [true, true, true];
        let stats = driver
            .superstep_batch(&mut batched, &live, 2)
            .expect("batched superstep");
        for (k, (b, s)) in batched.iter().zip(solo.iter_mut()).enumerate() {
            let want = solo_superstep(s, 2, driver.k_inner());
            assert_eq!(stats[k], want, "slot {k} stats");
            assert_eq!(b.h, s.h, "slot {k} heights");
            assert_eq!(b.e, s.e, "slot {k} excess");
        }
    }

    /// Dead slots are left untouched and report zero stats.
    #[test]
    fn dead_slots_are_skipped() {
        let mut driver = BatchedGridDriver::for_class(4, 4);
        let mut batched = vec![demo_state(4, 4, 0), demo_state(4, 4, 3)];
        let before = batched[1].clone();
        let stats = driver
            .superstep_batch(&mut batched, &[true, false], 1)
            .unwrap();
        assert_eq!(stats[1], GridStepStats::default());
        assert_eq!(batched[1].h, before.h);
        assert_eq!(batched[1].e, before.e);
        assert!(stats[0].waves > 0, "live slot advanced");
    }

    /// SimGridDevice (batch of one) is the same superstep again.
    #[test]
    fn sim_device_matches_solo() {
        let mut dev = SimGridDevice::for_shape(4, 5);
        let mut a = demo_state(4, 5, 0);
        let mut b = a.clone();
        let got = dev.step(&mut a, 3).unwrap();
        let want = solo_superstep(&mut b, 3, dev.driver.k_inner());
        assert_eq!(got, want);
        assert_eq!(a.h, b.h);
        assert_eq!(a.e, b.e);
    }

    /// Accounting: padded vs logical cells, dispatch counts, and the
    /// waste/overlap ratios stay in range.
    #[test]
    fn dispatch_stats_account_padding() {
        let mut driver = BatchedGridDriver::for_class(6, 6);
        let mut batched = vec![demo_state(3, 3, 0), demo_state(6, 6, 1)];
        driver
            .superstep_batch(&mut batched, &[true, true], 1)
            .unwrap();
        let s = driver.stats();
        assert_eq!(s.dispatches, 1);
        assert_eq!(s.instances, 2);
        assert_eq!(s.padded_cells, 72);
        assert_eq!(s.logical_cells, 9 + 36);
        let waste = s.padding_waste();
        assert!((waste - (1.0 - 45.0 / 72.0)).abs() < 1e-12, "{waste}");
        let overlap = s.overlap_ratio();
        assert!((0.0..=1.0).contains(&overlap), "{overlap}");
    }

    /// Oversized instances are refused, not silently clipped.
    #[test]
    fn oversized_slot_is_an_error() {
        let mut driver = BatchedGridDriver::for_class(3, 3);
        let mut batched = vec![demo_state(4, 3, 0)];
        assert!(driver.superstep_batch(&mut batched, &[true], 1).is_err());
    }
}
