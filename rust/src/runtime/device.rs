//! Typed device front-ends for the two kernels.
//!
//! A *device* owns one compiled artifact and moves typed host state across
//! the PJRT boundary, one super-step per call.  The wire layout matches
//! `python/compile/model.py` exactly (same tuple order, same `int32`
//! stats vectors).

use anyhow::Result;

use super::artifact::{ArtifactRegistry, ArtifactSpec};
use super::{executor, literal, transfer};

// ---------------------------------------------------------------------------
// Grid push-relabel device
// ---------------------------------------------------------------------------

/// Host copy of the grid kernel state (flat row-major `i32` arrays).
#[derive(Debug, Clone)]
pub struct GridWireState {
    pub height: usize,
    pub width: usize,
    /// Heights, `height * width`.
    pub h: Vec<i32>,
    /// Excess, `height * width`.
    pub e: Vec<i32>,
    /// Residual caps to N/S/W/E, `4 * height * width` (arc-major).
    pub cap: Vec<i32>,
    /// Residual cap of the (x, t) arc, `height * width`.
    pub cap_sink: Vec<i32>,
    /// Residual cap of the (x, s) arc, `height * width`.
    pub cap_src: Vec<i32>,
}

impl GridWireState {
    pub fn zeros(height: usize, width: usize) -> Self {
        let n = height * width;
        Self {
            height,
            width,
            h: vec![0; n],
            e: vec![0; n],
            cap: vec![0; 4 * n],
            cap_sink: vec![0; n],
            cap_src: vec![0; n],
        }
    }

    pub fn cells(&self) -> usize {
        self.height * self.width
    }

    /// Total bytes of one full host->device state upload.
    pub fn byte_size(&self) -> usize {
        (self.h.len() + self.e.len() + self.cap.len() + self.cap_sink.len() + self.cap_src.len())
            * std::mem::size_of::<i32>()
    }
}

/// Stats vector of one grid super-step (model.py GRID_STATS order).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GridStepStats {
    pub sink_flow: i64,
    pub src_flow: i64,
    pub active: i64,
    pub pushes: i64,
    pub relabels: i64,
    pub waves: i64,
}

/// PJRT-backed grid super-step executor.
pub struct GridDevice {
    exe: std::rc::Rc<executor::Executor>,
    pub height: usize,
    pub width: usize,
    pub k_inner: usize,
}

impl GridDevice {
    pub fn from_spec(spec: &ArtifactSpec) -> Result<Self> {
        let exe = executor::get_or_compile(&spec.name, &spec.path)?;
        Ok(Self {
            exe,
            height: spec.dim0,
            width: spec.dim1,
            k_inner: spec.k_inner,
        })
    }

    /// Look up the exact-shape artifact in `reg`.
    pub fn for_shape(reg: &ArtifactRegistry, height: usize, width: usize) -> Result<Self> {
        let spec = reg.grid(height, width).ok_or_else(|| {
            anyhow::anyhow!("no grid artifact for {height}x{width}; run `make artifacts`")
        })?;
        Self::from_spec(spec)
    }

    /// Run up to `outer * k_inner` waves on the device; updates `state`
    /// in place and returns the accumulated stats.
    pub fn step(&self, state: &mut GridWireState, outer: i32) -> Result<GridStepStats> {
        anyhow::ensure!(
            state.height == self.height && state.width == self.width,
            "state is {}x{}, artifact wants {}x{}",
            state.height,
            state.width,
            self.height,
            self.width
        );
        let (hh, ww) = (self.height, self.width);
        let n = hh * ww;
        let inputs = [
            literal::i32_tensor(&state.h, &[hh, ww])?,
            literal::i32_tensor(&state.e, &[hh, ww])?,
            literal::i32_tensor(&state.cap, &[4, hh, ww])?,
            literal::i32_tensor(&state.cap_sink, &[hh, ww])?,
            literal::i32_tensor(&state.cap_src, &[hh, ww])?,
            literal::i32_scalar(outer),
        ];
        transfer::GLOBAL.record_h2d(state.byte_size() + 4);

        let out = self.exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 6, "grid step returned {} outputs", out.len());

        state.h = literal::to_i32_vec(&out[0], n)?;
        state.e = literal::to_i32_vec(&out[1], n)?;
        state.cap = literal::to_i32_vec(&out[2], 4 * n)?;
        state.cap_sink = literal::to_i32_vec(&out[3], n)?;
        state.cap_src = literal::to_i32_vec(&out[4], n)?;
        let stats = literal::to_i32_vec(&out[5], 6)?;
        transfer::GLOBAL.record_d2h(state.byte_size() + 24);

        Ok(GridStepStats {
            sink_flow: stats[0] as i64,
            src_flow: stats[1] as i64,
            active: stats[2] as i64,
            pushes: stats[3] as i64,
            relabels: stats[4] as i64,
            waves: stats[5] as i64,
        })
    }
}

// ---------------------------------------------------------------------------
// CSA refine device
// ---------------------------------------------------------------------------

/// Host copy of the CSA kernel state.
#[derive(Debug, Clone)]
pub struct CsaWireState {
    pub n: usize,
    /// Scaled min-cost matrix, `n * n` row-major.
    pub cost: Vec<i32>,
    /// Unit flows (0/1), `n * n`.
    pub f: Vec<i32>,
    pub px: Vec<i32>,
    pub py: Vec<i32>,
    pub ex: Vec<i32>,
    pub ey: Vec<i32>,
}

impl CsaWireState {
    /// Fresh refine state for a scaled cost matrix: f = 0, e(x) = 1,
    /// e(y) = -1 (the paper's reduction replacing supplies, §5).
    pub fn fresh(cost: Vec<i32>, n: usize) -> Self {
        assert_eq!(cost.len(), n * n);
        Self {
            n,
            cost,
            f: vec![0; n * n],
            px: vec![0; n],
            py: vec![0; n],
            ex: vec![1; n],
            ey: vec![-1; n],
        }
    }

    pub fn byte_size(&self) -> usize {
        (self.cost.len() + self.f.len() + 4 * self.n) * std::mem::size_of::<i32>()
    }
}

/// Stats vector of one CSA super-step (model.py CSA_STATS order).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CsaStepStats {
    pub active_x: i64,
    pub active_y: i64,
    pub pushes: i64,
    pub relabels: i64,
    pub waves: i64,
}

impl CsaStepStats {
    pub fn active(&self) -> i64 {
        self.active_x + self.active_y
    }
}

/// PJRT-backed CSA refine super-step executor.
pub struct CsaDevice {
    exe: std::rc::Rc<executor::Executor>,
    pub n: usize,
    pub k_inner: usize,
}

impl CsaDevice {
    pub fn from_spec(spec: &ArtifactSpec) -> Result<Self> {
        let exe = executor::get_or_compile(&spec.name, &spec.path)?;
        Ok(Self {
            exe,
            n: spec.dim0,
            k_inner: spec.k_inner,
        })
    }

    /// Smallest artifact that fits an `n x n` instance (caller pads).
    pub fn for_size(reg: &ArtifactRegistry, n: usize) -> Result<Self> {
        let spec = reg.csa_at_least(n).ok_or_else(|| {
            anyhow::anyhow!("no CSA artifact for n >= {n}; run `make artifacts`")
        })?;
        Self::from_spec(spec)
    }

    /// Run up to `outer * k_inner` waves of refine at `eps`.
    pub fn step(&self, state: &mut CsaWireState, eps: i32, outer: i32) -> Result<CsaStepStats> {
        anyhow::ensure!(
            state.n == self.n,
            "state is n={}, artifact wants n={}",
            state.n,
            self.n
        );
        let n = self.n;
        let inputs = [
            literal::i32_tensor(&state.cost, &[n, n])?,
            literal::i32_tensor(&state.f, &[n, n])?,
            literal::i32_tensor(&state.px, &[n])?,
            literal::i32_tensor(&state.py, &[n])?,
            literal::i32_tensor(&state.ex, &[n])?,
            literal::i32_tensor(&state.ey, &[n])?,
            literal::i32_tensor(&[eps], &[1])?,
            literal::i32_scalar(outer),
        ];
        transfer::GLOBAL.record_h2d(state.byte_size() + 8);

        let out = self.exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 6, "csa step returned {} outputs", out.len());

        state.f = literal::to_i32_vec(&out[0], n * n)?;
        state.px = literal::to_i32_vec(&out[1], n)?;
        state.py = literal::to_i32_vec(&out[2], n)?;
        state.ex = literal::to_i32_vec(&out[3], n)?;
        state.ey = literal::to_i32_vec(&out[4], n)?;
        let stats = literal::to_i32_vec(&out[5], 6)?;
        transfer::GLOBAL.record_d2h((state.f.len() + 4 * n + 6) * 4);

        Ok(CsaStepStats {
            active_x: stats[0] as i64,
            active_y: stats[1] as i64,
            pushes: stats[2] as i64,
            relabels: stats[3] as i64,
            waves: stats[4] as i64,
        })
    }
}
