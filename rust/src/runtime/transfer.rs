//! Host<->device transfer accounting.
//!
//! The paper devotes §4.6 and §5.5 to minimizing `cudaMemcpy` traffic (only
//! heights back to the device after a global relabel; flows/excesses/prices
//! as separate arrays).  PJRT hides the copies inside `execute`, so the
//! coordinator logs the bytes it marshals each way; the CYCLE-sweep bench
//! (E4) reports these columns next to wall-clock time.

use std::sync::atomic::{AtomicU64, Ordering};

/// Cumulative transfer counters.  Cheap enough to keep global and atomic.
#[derive(Debug, Default)]
pub struct TransferLog {
    h2d_bytes: AtomicU64,
    d2h_bytes: AtomicU64,
    h2d_calls: AtomicU64,
    d2h_calls: AtomicU64,
}

impl TransferLog {
    pub const fn new() -> Self {
        Self {
            h2d_bytes: AtomicU64::new(0),
            d2h_bytes: AtomicU64::new(0),
            h2d_calls: AtomicU64::new(0),
            d2h_calls: AtomicU64::new(0),
        }
    }

    pub fn record_h2d(&self, bytes: usize) {
        self.h2d_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.h2d_calls.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_d2h(&self, bytes: usize) {
        self.d2h_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.d2h_calls.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            h2d_bytes: self.h2d_bytes.load(Ordering::Relaxed),
            d2h_bytes: self.d2h_bytes.load(Ordering::Relaxed),
            h2d_calls: self.h2d_calls.load(Ordering::Relaxed),
            d2h_calls: self.d2h_calls.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.h2d_bytes.store(0, Ordering::Relaxed);
        self.d2h_bytes.store(0, Ordering::Relaxed);
        self.h2d_calls.store(0, Ordering::Relaxed);
        self.d2h_calls.store(0, Ordering::Relaxed);
    }
}

/// Point-in-time copy of a [`TransferLog`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub h2d_calls: u64,
    pub d2h_calls: u64,
}

impl TransferSnapshot {
    /// Difference since `earlier` (for per-phase reporting).
    pub fn since(&self, earlier: &TransferSnapshot) -> TransferSnapshot {
        TransferSnapshot {
            h2d_bytes: self.h2d_bytes - earlier.h2d_bytes,
            d2h_bytes: self.d2h_bytes - earlier.d2h_bytes,
            h2d_calls: self.h2d_calls - earlier.h2d_calls,
            d2h_calls: self.d2h_calls - earlier.d2h_calls,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.h2d_bytes + self.d2h_bytes
    }
}

/// Global log used by the default devices.
pub static GLOBAL: TransferLog = TransferLog::new();

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_roundtrip() {
        let log = TransferLog::new();
        log.record_h2d(100);
        log.record_h2d(24);
        log.record_d2h(8);
        let s = log.snapshot();
        assert_eq!(s.h2d_bytes, 124);
        assert_eq!(s.h2d_calls, 2);
        assert_eq!(s.d2h_bytes, 8);
        assert_eq!(s.total_bytes(), 132);
        let s2 = log.snapshot().since(&s);
        assert_eq!(s2.total_bytes(), 0);
        log.reset();
        assert_eq!(log.snapshot().total_bytes(), 0);
    }
}
