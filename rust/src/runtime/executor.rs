//! Compiled-executable cache: HLO text -> PJRT executable, compiled once
//! per artifact per thread (the paper's analogue: one CUDA module load).
//!
//! PJRT handles are `!Send` (`Rc` internally), so the cache is
//! thread-local; the coordinator keeps all device work on one thread.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::client;

/// A compiled artifact plus its execution entry point.
pub struct Executor {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executor {
    /// Load HLO text from `path` and compile it on this thread's client.
    pub fn compile_file(name: &str, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client::with_client(|c| {
            c.compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling artifact {name}: {e}"))
        })?;
        Ok(Self {
            exe,
            name: name.to_string(),
        })
    }

    /// Execute with the given input literals; returns the flattened output
    /// tuple (AOT lowering uses `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {}: {e}", self.name))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing result tuple of {}: {e}", self.name))
    }
}

thread_local! {
    static CACHE: RefCell<HashMap<String, Rc<Executor>>> = RefCell::new(HashMap::new());
}

/// Get this thread's cached executor for `name`, compiling on first use.
pub fn get_or_compile(name: &str, path: &Path) -> Result<Rc<Executor>> {
    if let Some(exe) = CACHE.with(|c| c.borrow().get(name).cloned()) {
        return Ok(exe);
    }
    let exe = Rc::new(Executor::compile_file(name, path)?);
    CACHE.with(|c| c.borrow_mut().insert(name.to_string(), exe.clone()));
    Ok(exe)
}

/// Number of executables compiled on this thread (for diagnostics).
pub fn cached_count() -> usize {
    CACHE.with(|c| c.borrow().len())
}
