//! Max-flow engines (§4 of the paper).
//!
//! Sequential baselines (Edmonds–Karp, Dinic, FIFO and highest-label
//! push-relabel with the global/gap heuristics), Hong's lock-free
//! multi-threaded algorithm on atomics (Algorithm 4.5), and the hybrid
//! CYCLE-bounded scheme of Algorithm 4.6–4.8.  Every engine implements
//! [`MaxFlowSolver`] over the shared CSR [`FlowNetwork`] and reports the
//! operation counters the paper's complexity claims are stated in.

pub mod edmonds_karp;
pub mod dinic;
pub mod fifo;
pub mod global_relabel;
pub mod highest;
pub mod hybrid;
pub mod lockfree;
pub mod warm;

use anyhow::Result;

use crate::graph::FlowNetwork;

/// Operation counters: the paper analyses parallel complexity "in the
/// number of operations, not in the execution time" (§4.4), so every
/// engine reports them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FlowStats {
    /// Max-flow value.
    pub value: i64,
    /// Push operations (saturating + non-saturating).
    pub pushes: u64,
    /// Relabel operations.
    pub relabels: u64,
    /// Global-relabel heuristic runs.
    pub global_relabels: u64,
    /// Nodes lifted by gap relabeling.
    pub gap_nodes: u64,
    /// Gap-relabel events (one per bucket that emptied and triggered a
    /// batched lift; `gap_nodes` counts the lifted nodes).
    pub gap_relabels: u64,
    /// Host rounds (hybrid engines) or BFS phases (augmenting engines).
    pub rounds: u64,
}

impl FlowStats {
    pub fn work(&self) -> u64 {
        self.pushes + self.relabels
    }
}

/// Excess-scaling discipline for the sequential push-relabel engines.
///
/// `Delta` runs the discharge loop in Δ-phases: only nodes with excess
/// ≥ Δ are admitted to the active set, and Δ halves each time the set
/// drains.  Push amounts are untouched, so the computed flow (and the
/// final residual network) is identical to `Off` — only the discharge
/// order and the op counters move.  Phases are reported in
/// [`FlowStats::rounds`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScalingMode {
    /// Plain FIFO/highest-label admission (the default; bit-exact with
    /// the pre-scaling engines).
    #[default]
    Off,
    /// Δ-phase excess scaling.
    Delta,
}

impl ScalingMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "off" => Ok(ScalingMode::Off),
            "delta" => Ok(ScalingMode::Delta),
            other => anyhow::bail!("unknown scaling mode {other:?} (expected off|delta)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ScalingMode::Off => "off",
            ScalingMode::Delta => "delta",
        }
    }
}

/// A max-flow engine: mutates `g`'s residual capacities into a maximum
/// flow and returns the counters.  `g.reset()` restores the instance.
pub trait MaxFlowSolver {
    fn name(&self) -> &'static str;
    fn solve(&self, g: &mut FlowNetwork) -> Result<FlowStats>;

    /// [`MaxFlowSolver::solve`], plus a flush of the op counters into
    /// the global metrics registry under this engine's name
    /// (`flowmatch_engine_*_total{engine="fifo"}`, …).  One registry
    /// touch per solve; the solve itself is unchanged.  Serving layers
    /// call this so every engine they route to is visible in the
    /// exposition without per-engine wiring.
    fn solve_traced(&self, g: &mut FlowNetwork) -> Result<FlowStats> {
        let stats = self.solve(g)?;
        crate::obs::record_flow_stats(self.name(), &stats);
        Ok(stats)
    }
}

/// All registered engines (for benches and parity tests).
pub fn all_engines() -> Vec<Box<dyn MaxFlowSolver>> {
    all_engines_with(None)
}

/// All engines, with the push-relabel family borrowing `pool` for their
/// periodic global relabel (striped BFS on large instances; identical
/// results, see [`global_relabel::global_relabel_auto`]).  The list
/// includes the opt-in heuristic variants (gap relabeling, Δ-phase
/// excess scaling) so the differential oracles in `prop_maxflow` cover
/// them alongside the defaults; the order is fixed and shared between
/// the pooled and unpooled lists so they can be zipped pairwise.
pub fn all_engines_with(
    pool: Option<std::sync::Arc<crate::service::pool::WorkerPool>>,
) -> Vec<Box<dyn MaxFlowSolver>> {
    let mut fifo = fifo::FifoPushRelabel::default();
    let mut highest = highest::HighestLabel::default();
    let mut lockfree = lockfree::LockFree::default();
    let mut hybrid = hybrid::Hybrid::default();
    if let Some(pool) = pool {
        fifo = fifo.with_relabel_pool(std::sync::Arc::clone(&pool));
        highest = highest.with_relabel_pool(std::sync::Arc::clone(&pool));
        lockfree = lockfree.with_relabel_pool(std::sync::Arc::clone(&pool));
        hybrid = hybrid.with_relabel_pool(pool);
    }
    vec![
        Box::new(edmonds_karp::EdmondsKarp),
        Box::new(dinic::Dinic),
        Box::new(fifo.clone()),
        Box::new(fifo.clone().with_gap()),
        Box::new(fifo.clone().with_scaling(ScalingMode::Delta)),
        Box::new(fifo.with_gap().with_scaling(ScalingMode::Delta)),
        Box::new(highest.clone()),
        Box::new(highest.with_scaling(ScalingMode::Delta)),
        Box::new(lockfree.clone()),
        Box::new(lockfree.with_gap()),
        Box::new(hybrid.clone()),
        Box::new(hybrid.with_gap().with_scaling(ScalingMode::Delta)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::NetworkBuilder;

    /// CLRS figure instance; max flow 23.
    pub(crate) fn clrs() -> FlowNetwork {
        let mut b = NetworkBuilder::new(6, 0, 5);
        b.add_edge(0, 1, 16, 0);
        b.add_edge(0, 2, 13, 0);
        b.add_edge(1, 2, 10, 4);
        b.add_edge(1, 3, 12, 0);
        b.add_edge(2, 3, 0, 9);
        b.add_edge(2, 4, 14, 0);
        b.add_edge(3, 5, 20, 0);
        b.add_edge(4, 3, 7, 0);
        b.add_edge(4, 5, 4, 0);
        b.build().unwrap()
    }

    #[test]
    fn every_engine_solves_clrs() -> Result<()> {
        use anyhow::Context;
        for engine in all_engines() {
            let mut g = clrs();
            let stats = engine
                .solve(&mut g)
                .with_context(|| format!("{} solve", engine.name()))?;
            assert_eq!(stats.value, 23, "{} value", engine.name());
            crate::graph::validate::assert_max_flow(&g, 23)
                .with_context(|| format!("{} certificate", engine.name()))?;
        }
        Ok(())
    }

    #[test]
    fn disconnected_sink_gives_zero() {
        for engine in all_engines() {
            let mut b = NetworkBuilder::new(4, 0, 3);
            b.add_edge(0, 1, 5, 0);
            b.add_edge(1, 2, 5, 0); // no arc to 3
            let mut g = b.build().unwrap();
            let stats = engine.solve(&mut g).unwrap();
            assert_eq!(stats.value, 0, "{}", engine.name());
        }
    }

    #[test]
    fn parallel_paths_sum() {
        for engine in all_engines() {
            let mut b = NetworkBuilder::new(5, 0, 4);
            for mid in 1..4 {
                b.add_edge(0, mid, mid as i64, 0);
                b.add_edge(mid, 4, mid as i64, 0);
            }
            let mut g = b.build().unwrap();
            let stats = engine.solve(&mut g).unwrap();
            assert_eq!(stats.value, 6, "{}", engine.name());
        }
    }
}
