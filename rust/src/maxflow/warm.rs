//! Warm-start solving on the general CSR network: the [`FlowNetwork`]
//! *is* the residual state, so a session keeps the solved network plus
//! a per-node excess ledger, repairs both when edge capacities change,
//! and resumes the FIFO engine from the affected nodes
//! ([`FifoPushRelabel::resume`]) instead of re-solving cold.
//!
//! The repair is the CSR twin of `gridflow::warm` and is pleasantly
//! uniform because terminals are ordinary nodes here: an edge set to
//! `u'` keeps `f' = min(f, u')` of its flow and refunds the rest along
//! the reverse mate (`push(e ^ 1, f - f')` — always legal, the mate's
//! residual is `rcap + f`); nodes driven negative pull their own
//! outgoing flow back, cascading, until every interior excess is
//! non-negative again.  Each pullback strictly reduces total flow mass
//! and a deficit node always has positive outflow, so the cascade
//! terminates.  The resumed engine re-saturates source arcs and
//! rebuilds heights with an exact global relabel, and the max-flow
//! value is unique, so warm ≡ cold on the edited network — the
//! differential oracle `tests/integration_sessions.rs` pins.

use anyhow::{ensure, Result};

use crate::graph::csr::{EdgeId, FlowNetwork};

use super::fifo::FifoPushRelabel;
use super::FlowStats;

/// One capacity edit: set edge `edge`'s capacity to `cap` (absolute,
/// not additive).  `edge` addresses either orientation of a pair; its
/// mate's capacity is independent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CsrDelta {
    pub edge: EdgeId,
    pub cap: i64,
}

/// Snapshot of a completed CSR solve a session keeps between requests:
/// the solved (residual) network plus the excess ledger the repair and
/// resume share.
#[derive(Debug, Clone)]
pub struct CsrWarmState {
    g: FlowNetwork,
    excess: Vec<i64>,
}

impl CsrWarmState {
    /// Cold-solve `g` with `engine` and keep the final residual state.
    pub fn solve_cold(mut g: FlowNetwork, engine: &FifoPushRelabel) -> Result<(FlowStats, CsrWarmState)> {
        use super::MaxFlowSolver;
        let stats = engine.solve(&mut g)?;
        // A completed solve leaves zero excess everywhere that matters;
        // the terminals' entries are bookkeeping the resume never reads.
        let excess = vec![0i64; g.node_count()];
        Ok((stats, CsrWarmState { g, excess }))
    }

    /// The current residual network (for inspection and oracles).
    pub fn network(&self) -> &FlowNetwork {
        &self.g
    }

    /// Approximate resident size for the session store's LRU budget:
    /// per edge two i64 capacity lanes + id/head u32 lanes, per node
    /// the excess ledger and CSR offsets.
    pub fn approx_bytes(&self) -> usize {
        self.g.edge_pair_count() * 2 * 24 + self.g.node_count() * 16 + 256
    }

    /// Edit capacities and repair the preflow locally (no solving).
    pub fn apply_deltas(&mut self, deltas: &[CsrDelta]) -> Result<()> {
        let m2 = self.g.edge_pair_count() * 2;
        let mut work: Vec<usize> = Vec::new();
        for d in deltas {
            ensure!((d.edge as usize) < m2, "edge id {} out of range", d.edge);
            ensure!(d.cap >= 0, "negative capacity {}", d.cap);
            let e = d.edge;
            let tail = self.g.edge_head(e ^ 1);
            let head = self.g.edge_head(e);
            let f = self.g.flow(e);
            // Keep what fits under the new capacity, refund the rest to
            // the tail (debiting the head, possibly into deficit).
            let f_new = f.min(d.cap);
            let w = f - f_new;
            if w > 0 {
                self.g.push(e ^ 1, w);
                self.excess[tail] += w;
                self.excess[head] -= w;
                if self.excess[head] < 0 {
                    work.push(head);
                }
            }
            self.g.set_capacity(e, d.cap, d.cap - f_new);
        }
        self.resolve_deficits(work)
    }

    /// Pull flow back out of deficit nodes until every interior excess
    /// is non-negative again.
    fn resolve_deficits(&mut self, mut work: Vec<usize>) -> Result<()> {
        let (s, t) = (self.g.source(), self.g.sink());
        while let Some(u) = work.pop() {
            // Terminals absorb imbalance by definition; a cascade may
            // also have refilled u since it was queued.
            if u == s || u == t || self.excess[u] >= 0 {
                continue;
            }
            for idx in 0..self.g.out_edges(u).len() {
                if self.excess[u] >= 0 {
                    break;
                }
                let e = self.g.out_edges(u)[idx];
                let f = self.g.flow(e);
                if f <= 0 {
                    continue;
                }
                let w = f.min(-self.excess[u]);
                let v = self.g.edge_head(e);
                self.g.push(e ^ 1, w);
                self.excess[u] += w;
                self.excess[v] -= w;
                if v != s && v != t && self.excess[v] < 0 {
                    work.push(v);
                }
            }
            // Always resolvable: a deficit node has positive outflow.
            ensure!(
                self.excess[u] >= 0,
                "unresolvable deficit {} at node {u}",
                self.excess[u]
            );
        }
        Ok(())
    }

    /// Resume the engine on the repaired state.
    pub fn resume(&mut self, engine: &FifoPushRelabel) -> Result<FlowStats> {
        engine.resume(&mut self.g, &mut self.excess)
    }

    /// Edit + repair + resume in one call — the session update path.
    pub fn update(&mut self, deltas: &[CsrDelta], engine: &FifoPushRelabel) -> Result<FlowStats> {
        self.apply_deltas(deltas)?;
        self.resume(engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::NetworkBuilder;
    use crate::graph::grid::E;
    use crate::maxflow::{dinic::Dinic, MaxFlowSolver};
    use crate::util::Rng;
    use crate::workloads::random_grid;

    fn cold_value(g: &FlowNetwork) -> i64 {
        let mut fresh = g.clone();
        fresh.reset();
        Dinic.solve(&mut fresh).unwrap().value
    }

    #[test]
    fn diamond_edit_stream_matches_cold() {
        let mut b = NetworkBuilder::new(4, 0, 3);
        let e_top_in = b.add_edge(0, 1, 3, 0);
        let e_top_out = b.add_edge(1, 3, 3, 0);
        b.add_edge(0, 2, 2, 0);
        let e_bot_out = b.add_edge(2, 3, 2, 0);
        let g = b.build().unwrap();
        let engine = FifoPushRelabel::default();
        let (first, mut warm) = CsrWarmState::solve_cold(g, &engine).unwrap();
        assert_eq!(first.value, 5);
        // Cut the top path's exit under full flow: 3 units pulled back.
        let s = warm.update(&[CsrDelta { edge: e_top_out, cap: 1 }], &engine).unwrap();
        assert_eq!(s.value, 3);
        assert_eq!(cold_value(warm.network()), 3);
        // Re-widen it and also widen the bottom exit.
        let s = warm
            .update(
                &[CsrDelta { edge: e_top_out, cap: 4 }, CsrDelta { edge: e_bot_out, cap: 9 }],
                &engine,
            )
            .unwrap();
        assert_eq!(s.value, 5, "still limited by the 3+2 source edges");
        let s = warm.update(&[CsrDelta { edge: e_top_in, cap: 9 }], &engine).unwrap();
        assert_eq!(s.value, 6);
        assert_eq!(cold_value(warm.network()), 6);
    }

    #[test]
    fn random_grid_edit_stream_matches_cold() {
        for seed in [11u64, 12, 13] {
            let mut rng = Rng::seeded(seed);
            let net = random_grid(&mut rng, 6, 6, 9, 0.3, 0.3);
            let (g, idx) = net.to_flow_network_indexed();
            let engine = FifoPushRelabel::default();
            let (_, mut warm) = CsrWarmState::solve_cold(g, &engine).unwrap();
            for step in 0..4 {
                let mut deltas = Vec::new();
                while deltas.len() < 4 {
                    let i = (rng.next_u64() % 6) as usize;
                    let j = (rng.next_u64() % 6) as usize;
                    let cap = (rng.next_u64() % 10) as i64;
                    let e = match rng.next_u64() % 3 {
                        0 => idx.source(i, j),
                        1 => idx.sink(i, j),
                        _ => match idx.arc(E, i, j) {
                            Some(e) => e,
                            None => continue,
                        },
                    };
                    deltas.push(CsrDelta { edge: e, cap });
                }
                let s = warm.update(&deltas, &engine).unwrap();
                assert_eq!(s.value, cold_value(warm.network()), "seed {seed} step {step}");
            }
        }
    }

    #[test]
    fn zero_cap_pairs_are_editable_via_index() {
        // to_flow_network_indexed emits zero-capacity pairs, so an edit
        // stream can grow arcs that started absent.
        let mut net = crate::graph::GridNetwork::zeros(1, 2);
        net.cap_source[0] = 5;
        net.cap_sink[1] = 5;
        // No interior arc at all: flow 0.
        let (g, idx) = net.to_flow_network_indexed();
        let engine = FifoPushRelabel::default();
        let (first, mut warm) = CsrWarmState::solve_cold(g, &engine).unwrap();
        assert_eq!(first.value, 0);
        let e = idx.arc(E, 0, 0).unwrap();
        let s = warm.update(&[CsrDelta { edge: e, cap: 4 }], &engine).unwrap();
        assert_eq!(s.value, 4);
    }

    #[test]
    fn bad_delta_rejected() {
        let mut b = NetworkBuilder::new(3, 0, 2);
        let e = b.add_edge(0, 1, 1, 0);
        b.add_edge(1, 2, 1, 0);
        let engine = FifoPushRelabel::default();
        let (_, mut warm) = CsrWarmState::solve_cold(b.build().unwrap(), &engine).unwrap();
        assert!(warm.apply_deltas(&[CsrDelta { edge: 99, cap: 1 }]).is_err());
        assert!(warm.apply_deltas(&[CsrDelta { edge: e, cap: -1 }]).is_err());
    }
}
