//! Highest-label push-relabel with bucketed active sets and *exact* gap
//! relabeling via label counts — the strongest sequential push-relabel
//! variant in the comparison ([Cherkassky & Goldberg 1995], the paper's
//! reference [3]).

use std::sync::Arc;

use anyhow::Result;

use crate::graph::csr::FlowNetwork;
use crate::service::pool::WorkerPool;
use crate::util::CancelToken;

use super::global_relabel::{global_relabel_auto, RelabelScratch};
use super::{FlowStats, MaxFlowSolver};

/// Highest-label engine with gap relabeling; global relabel every
/// `global_freq * n` relabels (None disables, for the E3 ablation).
#[derive(Debug, Clone)]
pub struct HighestLabel {
    pub global_relabel_freq: Option<f64>,
    /// Enable the label-count gap heuristic.
    pub gap: bool,
    /// Worker pool for the striped global relabel on large instances.
    pub relabel_pool: Option<Arc<WorkerPool>>,
    /// Cooperative cancellation, polled at the global-relabel entry
    /// points.
    pub cancel: Option<CancelToken>,
}

impl Default for HighestLabel {
    fn default() -> Self {
        Self {
            global_relabel_freq: Some(1.0),
            gap: true,
            relabel_pool: None,
            cancel: None,
        }
    }
}

impl HighestLabel {
    pub fn no_gap() -> Self {
        Self {
            gap: false,
            ..Self::default()
        }
    }

    pub fn with_relabel_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.relabel_pool = Some(pool);
        self
    }

    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }
}

struct Buckets {
    /// active[h] = stack of active nodes at height h.
    active: Vec<Vec<u32>>,
    highest: usize,
}

impl Buckets {
    fn new(levels: usize) -> Self {
        Self {
            active: vec![Vec::new(); levels],
            highest: 0,
        }
    }

    fn push(&mut self, v: u32, h: usize) {
        self.active[h].push(v);
        self.highest = self.highest.max(h);
    }

    fn pop_highest(&mut self) -> Option<(u32, usize)> {
        loop {
            if let Some(v) = self.active[self.highest].pop() {
                return Some((v, self.highest));
            }
            if self.highest == 0 {
                return None;
            }
            self.highest -= 1;
        }
    }

    fn clear(&mut self) {
        for b in &mut self.active {
            b.clear();
        }
        self.highest = 0;
    }
}

impl MaxFlowSolver for HighestLabel {
    fn name(&self) -> &'static str {
        if self.gap {
            "highest+gap"
        } else {
            "highest-nogap"
        }
    }

    fn solve(&self, g: &mut FlowNetwork) -> Result<FlowStats> {
        let mut stats = FlowStats::default();
        let n = g.node_count();
        let (s, t) = (g.source(), g.sink());
        let levels = 2 * n + 1;

        let mut h = vec![0i64; n];
        let mut excess = vec![0i64; n];
        let mut cur = vec![0usize; n];
        // label_count[d] = number of nodes at height d (for gap detection).
        let mut label_count = vec![0usize; levels];

        h[s] = n as i64;
        for idx in 0..g.out_edges(s).len() {
            let e = g.out_edges(s)[idx];
            let c = g.residual(e);
            if c > 0 {
                let v = g.edge_head(e);
                g.push(e, c);
                excess[v] += c;
                excess[s] -= c;
                stats.pushes += 1;
            }
        }
        let mut rscratch = RelabelScratch::default();
        if let Some(c) = &self.cancel {
            c.check()?;
        }
        if self.global_relabel_freq.is_some() {
            let out = global_relabel_auto(g, &mut h, self.relabel_pool.as_deref(), &mut rscratch);
            stats.global_relabels += 1;
            stats.gap_nodes += out.gap_lifted as u64;
        }

        let mut buckets = Buckets::new(levels);
        let rebuild =
            |buckets: &mut Buckets, label_count: &mut Vec<usize>, h: &[i64], excess: &[i64]| {
                buckets.clear();
                label_count.iter_mut().for_each(|c| *c = 0);
                for v in 0..n {
                    let hv = (h[v] as usize).min(levels - 1);
                    label_count[hv] += 1;
                    if v != s && v != t && excess[v] > 0 && hv < levels {
                        buckets.push(v as u32, hv);
                    }
                }
            };
        rebuild(&mut buckets, &mut label_count, &h, &excess);

        let mut relabels_since_global = 0u64;
        let budget = self
            .global_relabel_freq
            .map(|f| (f * n as f64).max(1.0) as u64);

        while let Some((u32v, hv)) = buckets.pop_highest() {
            let u = u32v as usize;
            if excess[u] <= 0 || h[u] as usize != hv {
                continue; // stale entry
            }
            // Discharge u.
            while excess[u] > 0 {
                let out_len = g.out_edges(u).len();
                if cur[u] == out_len {
                    // Relabel.
                    let old_h = h[u] as usize;
                    let mut min_h = i64::MAX;
                    for &e in g.out_edges(u) {
                        if g.residual(e) > 0 {
                            min_h = min_h.min(h[g.edge_head(e)]);
                        }
                    }
                    if min_h == i64::MAX {
                        break;
                    }
                    let new_h = (min_h + 1).min((levels - 1) as i64);
                    stats.relabels += 1;
                    relabels_since_global += 1;
                    label_count[old_h] -= 1;
                    h[u] = new_h;
                    label_count[new_h as usize] += 1;
                    cur[u] = 0;

                    // Gap heuristic: if old level emptied below n, every node
                    // above it (and below n) can never reach t again.
                    if self.gap && label_count[old_h] == 0 && old_h < n {
                        for v in 0..n {
                            let hv = h[v] as usize;
                            if v != s && hv > old_h && hv < n {
                                label_count[hv] -= 1;
                                h[v] = (n + 1) as i64;
                                label_count[n + 1] += 1;
                                stats.gap_nodes += 1;
                            }
                        }
                    }
                    if let Some(b) = budget {
                        if relabels_since_global >= b {
                            if let Some(c) = &self.cancel {
                                c.check()?;
                            }
                            let out = global_relabel_auto(
                                g,
                                &mut h,
                                self.relabel_pool.as_deref(),
                                &mut rscratch,
                            );
                            stats.global_relabels += 1;
                            stats.gap_nodes += out.gap_lifted as u64;
                            relabels_since_global = 0;
                            rebuild(&mut buckets, &mut label_count, &h, &excess);
                        }
                    }
                    if h[u] as usize >= levels - 1 {
                        break;
                    }
                    continue;
                }
                let e = g.out_edges(u)[cur[u]];
                let v = g.edge_head(e);
                if g.residual(e) > 0 && h[u] == h[v] + 1 {
                    let delta = excess[u].min(g.residual(e));
                    let was_inactive = excess[v] == 0;
                    g.push(e, delta);
                    excess[u] -= delta;
                    excess[v] += delta;
                    stats.pushes += 1;
                    if v != s && v != t && was_inactive {
                        buckets.push(v as u32, h[v] as usize);
                    }
                } else {
                    cur[u] += 1;
                }
            }
            if excess[u] > 0 && (h[u] as usize) < levels - 1 {
                buckets.push(u as u32, h[u] as usize);
            }
        }

        stats.value = excess[t];
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::assert_max_flow;

    #[test]
    fn solves_clrs_variants() {
        for engine in [HighestLabel::default(), HighestLabel::no_gap()] {
            let mut g = crate::maxflow::tests::clrs();
            let stats = engine.solve(&mut g).unwrap();
            assert_eq!(stats.value, 23, "{}", engine.name());
            assert_max_flow(&g, 23).unwrap();
        }
    }

    #[test]
    fn gap_heuristic_fires_on_trap() {
        // Network with a large trap region that becomes disconnected from t.
        let mut b = crate::graph::csr::NetworkBuilder::new(12, 0, 11);
        b.add_edge(0, 1, 10, 0);
        b.add_edge(1, 11, 2, 0);
        // Trap: chain 1 -> 2 -> ... -> 10 with no exit to t.
        for i in 1..10 {
            b.add_edge(i, i + 1, 8, 0);
        }
        let mut g = b.build().unwrap();
        let stats = HighestLabel::default().solve(&mut g).unwrap();
        assert_eq!(stats.value, 2);
        assert_max_flow(&g, 2).unwrap();
    }
}
