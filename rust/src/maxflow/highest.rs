//! Highest-label push-relabel with bucketed active sets and *exact* gap
//! relabeling via label counts — the strongest sequential push-relabel
//! variant in the comparison ([Cherkassky & Goldberg 1995], the paper's
//! reference [3]).

use std::sync::Arc;

use anyhow::Result;

use crate::graph::csr::FlowNetwork;
use crate::service::pool::WorkerPool;
use crate::util::CancelToken;

use super::global_relabel::{global_relabel_auto_with, RelabelScratch, STRIPED_RELABEL_MIN_NODES};
use super::{FlowStats, MaxFlowSolver, ScalingMode};

/// Highest-label engine with gap relabeling; global relabel every
/// `global_freq * n` relabels (None disables, for the E3 ablation).
#[derive(Debug, Clone)]
pub struct HighestLabel {
    pub global_relabel_freq: Option<f64>,
    /// Enable the label-count gap heuristic.
    pub gap: bool,
    /// Δ-phase excess scaling (see [`ScalingMode`]); `Off` by default.
    pub scaling: ScalingMode,
    /// Node-count gate for the striped global-relabel path; mirrors
    /// `[maxflow] striped_relabel_min_nodes` in the service config.
    pub striped_relabel_min_nodes: usize,
    /// Worker pool for the striped global relabel on large instances.
    pub relabel_pool: Option<Arc<WorkerPool>>,
    /// Cooperative cancellation, polled at the global-relabel entry
    /// points.
    pub cancel: Option<CancelToken>,
}

impl Default for HighestLabel {
    fn default() -> Self {
        Self {
            global_relabel_freq: Some(1.0),
            gap: true,
            scaling: ScalingMode::Off,
            striped_relabel_min_nodes: STRIPED_RELABEL_MIN_NODES,
            relabel_pool: None,
            cancel: None,
        }
    }
}

impl HighestLabel {
    pub fn no_gap() -> Self {
        Self {
            gap: false,
            ..Self::default()
        }
    }

    pub fn with_scaling(mut self, mode: ScalingMode) -> Self {
        self.scaling = mode;
        self
    }

    pub fn with_striped_min_nodes(mut self, min_nodes: usize) -> Self {
        self.striped_relabel_min_nodes = min_nodes;
        self
    }

    pub fn with_relabel_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.relabel_pool = Some(pool);
        self
    }

    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }
}

struct Buckets {
    /// active[h] = stack of active nodes at height h.
    active: Vec<Vec<u32>>,
    highest: usize,
}

impl Buckets {
    fn new(levels: usize) -> Self {
        Self {
            active: vec![Vec::new(); levels],
            highest: 0,
        }
    }

    fn push(&mut self, v: u32, h: usize) {
        self.active[h].push(v);
        self.highest = self.highest.max(h);
    }

    fn pop_highest(&mut self) -> Option<(u32, usize)> {
        loop {
            if let Some(v) = self.active[self.highest].pop() {
                return Some((v, self.highest));
            }
            if self.highest == 0 {
                return None;
            }
            self.highest -= 1;
        }
    }

    fn clear(&mut self) {
        for b in &mut self.active {
            b.clear();
        }
        self.highest = 0;
    }
}

impl MaxFlowSolver for HighestLabel {
    fn name(&self) -> &'static str {
        match (self.gap, self.scaling == ScalingMode::Delta) {
            (true, false) => "highest+gap",
            (false, false) => "highest-nogap",
            (true, true) => "highest+gap+scale",
            (false, true) => "highest+scale",
        }
    }

    fn solve(&self, g: &mut FlowNetwork) -> Result<FlowStats> {
        let mut stats = FlowStats::default();
        let n = g.node_count();
        let (s, t) = (g.source(), g.sink());
        let levels = 2 * n + 1;

        let mut h = vec![0i64; n];
        let mut excess = vec![0i64; n];
        let mut cur = vec![0usize; n];
        // label_count[d] = number of nodes at height d (for gap detection).
        let mut label_count = vec![0usize; levels];

        h[s] = n as i64;
        for idx in 0..g.out_edges(s).len() {
            let e = g.out_edges(s)[idx];
            let c = g.residual(e);
            if c > 0 {
                let v = g.edge_head(e);
                g.push(e, c);
                excess[v] += c;
                excess[s] -= c;
                stats.pushes += 1;
            }
        }
        let mut rscratch = RelabelScratch::default();
        if let Some(c) = &self.cancel {
            c.check()?;
        }
        if self.global_relabel_freq.is_some() {
            let out = global_relabel_auto_with(
                g,
                &mut h,
                self.relabel_pool.as_deref(),
                &mut rscratch,
                self.striped_relabel_min_nodes,
                None,
            );
            stats.global_relabels += 1;
            stats.gap_nodes += out.gap_lifted as u64;
        }

        let mut buckets = Buckets::new(levels);
        let rebuild =
            |buckets: &mut Buckets, label_count: &mut Vec<usize>, h: &[i64], excess: &[i64]| {
                buckets.clear();
                label_count.iter_mut().for_each(|c| *c = 0);
                for v in 0..n {
                    let hv = (h[v] as usize).min(levels - 1);
                    label_count[hv] += 1;
                    if v != s && v != t && excess[v] > 0 && hv < levels {
                        buckets.push(v as u32, hv);
                    }
                }
            };
        rebuild(&mut buckets, &mut label_count, &h, &excess);

        let mut relabels_since_global = 0u64;
        let budget = self
            .global_relabel_freq
            .map(|f| (f * n as f64).max(1.0) as u64);

        // Δ-phase excess scaling: with Δ = 1 (the default) the
        // admission test `excess ≥ 1` is exactly the pre-scaling "has
        // excess" condition, so the default engine is bit-identical.
        let mut delta = 1i64;
        if self.scaling == ScalingMode::Delta {
            let max_e = (0..n)
                .filter(|&v| v != s && v != t)
                .map(|v| excess[v])
                .max()
                .unwrap_or(0);
            while delta <= max_e / 2 {
                delta *= 2;
            }
            if delta > 1 {
                // The initial rebuild admitted every active node; defer
                // the ones below the opening Δ to later phases.
                buckets.clear();
                for v in 0..n {
                    if v != s && v != t && excess[v] >= delta && (h[v] as usize) < levels - 1 {
                        buckets.push(v as u32, (h[v] as usize).min(levels - 1));
                    }
                }
            }
        }

        loop {
            while let Some((u32v, hv)) = buckets.pop_highest() {
                let u = u32v as usize;
                if excess[u] <= 0 || h[u] as usize != hv {
                    continue; // stale entry
                }
                // Discharge u.
                while excess[u] > 0 {
                    let out_len = g.out_edges(u).len();
                    if cur[u] == out_len {
                        // Relabel.
                        let old_h = h[u] as usize;
                        let mut min_h = i64::MAX;
                        for &e in g.out_edges(u) {
                            if g.residual(e) > 0 {
                                min_h = min_h.min(h[g.edge_head(e)]);
                            }
                        }
                        if min_h == i64::MAX {
                            break;
                        }
                        let new_h = (min_h + 1).min((levels - 1) as i64);
                        stats.relabels += 1;
                        relabels_since_global += 1;
                        label_count[old_h] -= 1;
                        h[u] = new_h;
                        label_count[new_h as usize] += 1;
                        cur[u] = 0;

                        // Gap heuristic: if old level emptied below n, every node
                        // above it (and below n) can never reach t again.
                        if self.gap && label_count[old_h] == 0 && old_h > 0 && old_h < n {
                            let mut lifted = 0u64;
                            for v in 0..n {
                                let hv = h[v] as usize;
                                if v != s && hv > old_h && hv < n {
                                    label_count[hv] -= 1;
                                    h[v] = (n + 1) as i64;
                                    label_count[n + 1] += 1;
                                    lifted += 1;
                                }
                            }
                            if lifted > 0 {
                                stats.gap_relabels += 1;
                                stats.gap_nodes += lifted;
                            }
                        }
                        if let Some(b) = budget {
                            if relabels_since_global >= b {
                                if let Some(c) = &self.cancel {
                                    c.check()?;
                                }
                                let out = global_relabel_auto_with(
                                    g,
                                    &mut h,
                                    self.relabel_pool.as_deref(),
                                    &mut rscratch,
                                    self.striped_relabel_min_nodes,
                                    None,
                                );
                                stats.global_relabels += 1;
                                stats.gap_nodes += out.gap_lifted as u64;
                                relabels_since_global = 0;
                                rebuild(&mut buckets, &mut label_count, &h, &excess);
                            }
                        }
                        if h[u] as usize >= levels - 1 {
                            break;
                        }
                        continue;
                    }
                    let e = g.out_edges(u)[cur[u]];
                    let v = g.edge_head(e);
                    if g.residual(e) > 0 && h[u] == h[v] + 1 {
                        let push_amt = excess[u].min(g.residual(e));
                        let was_inactive = excess[v] == 0;
                        g.push(e, push_amt);
                        excess[u] -= push_amt;
                        excess[v] += push_amt;
                        stats.pushes += 1;
                        if v != s && v != t && was_inactive && excess[v] >= delta {
                            buckets.push(v as u32, h[v] as usize);
                        }
                    } else {
                        cur[u] += 1;
                    }
                }
                if excess[u] >= delta && (h[u] as usize) < levels - 1 {
                    buckets.push(u as u32, h[u] as usize);
                }
            }
            if self.scaling != ScalingMode::Delta || delta <= 1 {
                break;
            }
            delta /= 2;
            stats.rounds += 1;
            for v in 0..n {
                if v != s && v != t && excess[v] >= delta && (h[v] as usize) < levels - 1 {
                    buckets.push(v as u32, h[v] as usize);
                }
            }
        }

        stats.value = excess[t];
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::assert_max_flow;

    #[test]
    fn solves_clrs_variants() {
        for engine in [
            HighestLabel::default(),
            HighestLabel::no_gap(),
            HighestLabel::default().with_scaling(ScalingMode::Delta),
            HighestLabel::no_gap().with_scaling(ScalingMode::Delta),
        ] {
            let mut g = crate::maxflow::tests::clrs();
            let stats = engine.solve(&mut g).unwrap();
            assert_eq!(stats.value, 23, "{}", engine.name());
            assert_max_flow(&g, 23).unwrap();
        }
    }

    #[test]
    fn gap_events_are_counted() {
        // s → a → b → t with the sink arc as bottleneck: returning the
        // 3 stranded units empties bucket 1 while a and b sit above it,
        // so exactly one gap event lifts both.  Global relabel is
        // disabled so the incremental machinery is the only lift.
        let mut b = crate::graph::csr::NetworkBuilder::new(4, 0, 3);
        b.add_edge(0, 1, 5, 0);
        b.add_edge(1, 2, 5, 0);
        b.add_edge(2, 3, 2, 0);
        let mut g = b.build().unwrap();
        let engine = HighestLabel {
            global_relabel_freq: None,
            ..HighestLabel::default()
        };
        let stats = engine.solve(&mut g).unwrap();
        assert_eq!(stats.value, 2);
        assert_max_flow(&g, 2).unwrap();
        assert!(stats.gap_relabels > 0, "stats: {stats:?}");
        assert!(stats.gap_nodes >= 2 * stats.gap_relabels);
    }

    #[test]
    fn gap_heuristic_fires_on_trap() {
        // Network with a large trap region that becomes disconnected from t.
        let mut b = crate::graph::csr::NetworkBuilder::new(12, 0, 11);
        b.add_edge(0, 1, 10, 0);
        b.add_edge(1, 11, 2, 0);
        // Trap: chain 1 -> 2 -> ... -> 10 with no exit to t.
        for i in 1..10 {
            b.add_edge(i, i + 1, 8, 0);
        }
        let mut g = b.build().unwrap();
        let stats = HighestLabel::default().solve(&mut g).unwrap();
        assert_eq!(stats.value, 2);
        assert_max_flow(&g, 2).unwrap();
    }
}
