//! Edmonds–Karp: BFS augmenting paths, `O(V E^2)` — the §4.1 baseline.

use std::collections::VecDeque;

use anyhow::Result;

use crate::graph::csr::{EdgeId, FlowNetwork};

use super::{FlowStats, MaxFlowSolver};

pub struct EdmondsKarp;

impl MaxFlowSolver for EdmondsKarp {
    fn name(&self) -> &'static str {
        "edmonds-karp"
    }

    fn solve(&self, g: &mut FlowNetwork) -> Result<FlowStats> {
        let mut stats = FlowStats::default();
        let n = g.node_count();
        let (s, t) = (g.source(), g.sink());
        let mut parent: Vec<Option<EdgeId>> = vec![None; n];

        loop {
            // BFS for the shortest augmenting path.
            parent.iter_mut().for_each(|p| *p = None);
            let mut q = VecDeque::new();
            q.push_back(s);
            let mut found = false;
            'bfs: while let Some(u) = q.pop_front() {
                for &e in g.out_edges(u) {
                    let v = g.edge_head(e);
                    if v != s && parent[v].is_none() && g.residual(e) > 0 {
                        parent[v] = Some(e);
                        if v == t {
                            found = true;
                            break 'bfs;
                        }
                        q.push_back(v);
                    }
                }
            }
            stats.rounds += 1;
            if !found {
                break;
            }
            // Bottleneck and augment.
            let mut bottleneck = i64::MAX;
            let mut v = t;
            while v != s {
                let e = parent[v].expect("path");
                bottleneck = bottleneck.min(g.residual(e));
                v = g.edge_head(e ^ 1);
            }
            let mut v = t;
            while v != s {
                let e = parent[v].expect("path");
                g.push(e, bottleneck);
                stats.pushes += 1;
                v = g.edge_head(e ^ 1);
            }
            stats.value += bottleneck;
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::NetworkBuilder;

    #[test]
    fn single_edge() {
        let mut b = NetworkBuilder::new(2, 0, 1);
        b.add_edge(0, 1, 7, 0);
        let mut g = b.build().unwrap();
        assert_eq!(EdmondsKarp.solve(&mut g).unwrap().value, 7);
    }

    #[test]
    fn uses_reverse_edges_for_rerouting() {
        // Classic instance where a naive path choice must be undone.
        let mut b = NetworkBuilder::new(4, 0, 3);
        b.add_edge(0, 1, 1, 0);
        b.add_edge(0, 2, 1, 0);
        b.add_edge(1, 2, 1, 0);
        b.add_edge(1, 3, 1, 0);
        b.add_edge(2, 3, 1, 0);
        let mut g = b.build().unwrap();
        assert_eq!(EdmondsKarp.solve(&mut g).unwrap().value, 2);
        crate::graph::validate::assert_max_flow(&g, 2).unwrap();
    }
}
