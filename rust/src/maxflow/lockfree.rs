//! Hong's lock-free multi-threaded push-relabel (Algorithm 4.5), as
//! faithfully as the host allows: real OS threads, shared excess/capacity
//! arrays of `AtomicI64`, no locks, no barriers.
//!
//! Key properties the paper relies on (and that we preserve):
//!
//! * `e(x)` is only ever *decreased* by the thread owning `x` and only
//!   ever *increased* by neighbours, so `delta = min(e', c_f(x, y))` read
//!   from a stale `e'` never overshoots;
//! * `c_f(x, y)` is only decreased by `x`'s owner (pushes out of `x`), so
//!   the residual check cannot be invalidated concurrently;
//! * `h(x)` is written only by `x`'s owner (the relabel needs no RMW);
//! * every push/relabel is equivalent to some sequential trace
//!   (Hong 2008, mirrored by the paper's Lemma 5.3 for prices).
//!
//! Termination detection is the hybrid scheme's rule (Algorithm 4.6):
//! `e(s) + e(t) == ExcessTotal`, with `e(s)` counting flow returned to the
//! source.
//!
//! ## Memory orderings
//!
//! The engine originally ran every atomic op at `SeqCst`.  The invariants
//! above justify a much cheaper set, used throughout:
//!
//! * **Owner-read / foreign-increment values** (`e(x)` read by `x`'s
//!   owner, `c_f` of out-arcs of `x`): `Relaxed`.  The owner is the only
//!   decrementer, so a stale read only *under*-estimates and
//!   `delta = min(e', c')` can never overshoot — the same argument that
//!   makes the plain (unfenced) CUDA kernel of the paper sound.
//! * **Heights**: `Relaxed`, and every write is a *monotone raise*
//!   (`fetch_max` in the relabel, a raising CAS loop in ARG) — with
//!   ARG enabled the BFS thread writes heights too, so owner-only
//!   plain stores would be a lost-update race.  Heights are read
//!   heuristically by neighbours; a stale height costs extra work
//!   (a re-examined push or a redundant relabel attempt), never an
//!   unaccounted unit of flow.  Even under `SeqCst` the neighbour scan
//!   reads each location at a different instant, so cross-location
//!   staleness was always part of the algorithm's contract.
//! * **The push handshake**: the receive-side `e(y).fetch_add` is
//!   `Release` and the owner's `e(x)` entry load is `Acquire`, so a
//!   thread that *sees* new excess also sees the reverse-arc capacity
//!   that arrived with it (message passing) and can always route it
//!   back.  Mass conservation itself needs no ordering — it follows
//!   from RMW atomicity.
//! * **Termination**: `e(s)`/`e(t)` are monotone non-decreasing, so the
//!   `Acquire` loads in `terminated()` pairing with the `Release` adds
//!   make `e(s) + e(t) >= ExcessTotal` a stable, sufficient condition.
//!   The `done` flag is a standard `Release`-store/`Acquire`-load latch,
//!   and the final capacity read-back happens after `thread::scope`
//!   joins (a full synchronisation point), so it can be `Relaxed`.

use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::graph::csr::FlowNetwork;
use crate::parallel::{deal, Lanes, Stripes, StripedFrontier};
use crate::service::pool::WorkerPool;
use crate::util::{CancelToken, Cancelled};

use super::{FlowStats, MaxFlowSolver};

/// Lock-free engine; `threads = 0` means one worker per available core.
#[derive(Debug, Clone)]
pub struct LockFree {
    pub threads: usize,
    /// Run the Asynchronous Global Relabeling heuristic (§4.5, Hong & He
    /// 2011): a distinguished thread periodically recomputes BFS heights
    /// *concurrently* with the push/relabel workers.  Heights are only
    /// ever raised (monotone guard), which keeps Hong's invariants.  The
    /// paper tried ARG and found it slower than the host-round scheme on
    /// CUDA because of the global-memory queue; here it is an ablation
    /// option (off by default, like the paper's final implementation).
    pub arg: bool,
    /// Gap detection via atomic height-bucket occupancy counters: every
    /// height transition (worker `fetch_max` relabels and BFS-thread CAS
    /// raises) moves a node between `bucket[old]` and `bucket[new]`
    /// atomically, and a distinguished thread polls for an empty bucket
    /// with occupants above it.  Unlike the sequential engines, an
    /// instantaneous "bucket d is empty" observation is not stable here
    /// (a node below can climb into `d` while the sweep runs), so the
    /// counters act as a cheap *trigger* only: the lift itself is a
    /// snapshot-BFS raise pass — the same raising-only machinery as ARG,
    /// which is safe regardless of how stale the trigger was.  Stranded
    /// nodes (height below `n` at raise time, unreachable from `t` in
    /// the snapshot) are lifted to `n` in one stripe-parallel sweep.
    pub gap: bool,
    /// Worker pool the ARG thread's BFS borrows on large instances; the
    /// BFS runs on the striped frontier substrate either way (`None` =
    /// sequential lanes).
    pub relabel_pool: Option<Arc<WorkerPool>>,
    /// Cooperative cancellation, polled by every worker once per sweep:
    /// a cancelled solve joins its threads and returns the typed
    /// [`Cancelled`] error instead of a flow.
    pub cancel: Option<CancelToken>,
}

impl Default for LockFree {
    fn default() -> Self {
        Self {
            threads: 2,
            arg: false,
            gap: false,
            relabel_pool: None,
            cancel: None,
        }
    }
}

impl LockFree {
    pub fn with_threads(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
            ..Self::default()
        }
    }

    pub fn with_arg(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
            arg: true,
            ..Self::default()
        }
    }

    pub fn with_gap(mut self) -> Self {
        self.gap = true;
        self
    }

    pub fn with_relabel_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.relabel_pool = Some(pool);
        self
    }

    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }
}

/// Reusable ARG-pass buffers, owned by the distinguished BFS thread.
#[derive(Default)]
struct ArgScratch {
    dist: Vec<i32>,
    /// Residual-capacity snapshot, refilled in place each pass (the
    /// ARG thread loops continuously — a fresh |2E| Vec per pass would
    /// be pure allocator churn).
    snap: Vec<i64>,
    frontier: StripedFrontier,
}

struct Shared<'a> {
    g: &'a FlowNetwork,
    cap: Vec<AtomicI64>,
    excess: Vec<AtomicI64>,
    height: Vec<AtomicI64>,
    /// Height-bucket occupancy for heights `0..n` (empty unless the gap
    /// trigger is enabled).  Every height transition moves a node
    /// between buckets with two relaxed RMWs (add-then-sub, so a racy
    /// reader sees a transient double count, never a transient hole).
    bucket: Vec<AtomicI64>,
    done: AtomicBool,
    pushes: AtomicI64,
    relabels: AtomicI64,
    gap_events: AtomicI64,
    gap_lift_nodes: AtomicI64,
    excess_total: i64,
}

impl<'a> Shared<'a> {
    /// One Hong step for node `x`: find the lowest residual neighbour,
    /// push if strictly lower, otherwise relabel.  Returns true if an
    /// operation was applied.
    fn step(&self, x: usize, n: usize) -> bool {
        // Acquire pairs with the Release half of a neighbour's push: if
        // we see the excess, we also see the reverse residual capacity
        // that came with it.
        let e_x = self.excess[x].load(Ordering::Acquire);
        if e_x <= 0 {
            return false;
        }
        // Lines 4-9: lowest residual neighbour.  Relaxed: out-arc caps
        // are only decreased by this thread (stale reads under-estimate)
        // and heights are heuristic (see module docs).
        let mut best_h = i64::MAX;
        let mut best_e = None;
        for &eid in self.g.out_edges(x) {
            if self.cap[eid as usize].load(Ordering::Relaxed) > 0 {
                let hy = self.height[self.g.edge_head(eid)].load(Ordering::Relaxed);
                if hy < best_h {
                    best_h = hy;
                    best_e = Some(eid);
                }
            }
        }
        let Some(eid) = best_e else {
            return false; // no residual arc (cannot happen for active nodes)
        };
        // Own height: written only by this thread.
        let h_x = self.height[x].load(Ordering::Relaxed);
        if h_x > best_h {
            // PUSH (lines 11-15).  cap[eid] is only decreased by this
            // thread, so the min is safe even under concurrency.
            let c = self.cap[eid as usize].load(Ordering::Relaxed);
            let delta = e_x.min(c);
            if delta <= 0 {
                return false;
            }
            let y = self.g.edge_head(eid);
            // Send side: owner-exclusive decrements, no ordering needed.
            self.cap[eid as usize].fetch_sub(delta, Ordering::Relaxed);
            self.cap[(eid ^ 1) as usize].fetch_add(delta, Ordering::Relaxed);
            self.excess[x].fetch_sub(delta, Ordering::Relaxed);
            // Receive side: Release publishes the reverse capacity above
            // to whoever Acquire-loads the new excess.
            self.excess[y].fetch_add(delta, Ordering::Release);
            self.pushes.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            // RELABEL (line 17): a monotone raise.  Without ARG this
            // thread is the only writer of h(x) (fetch_max == store,
            // since relabel implies h(x) <= best_h); with ARG the BFS
            // thread may concurrently CAS-raise h(x), and fetch_max
            // keeps the heights-never-decrease invariant both rely on.
            // Heights stay < 2n in any sequential trace; the 4n guard
            // is a pure safety net against pathological interleavings.
            if best_h >= 4 * n as i64 {
                return false;
            }
            let prev = self.height[x].fetch_max(best_h + 1, Ordering::Relaxed);
            if prev < best_h + 1 {
                self.bucket_move(prev, best_h + 1);
            }
            self.relabels.fetch_add(1, Ordering::Relaxed);
            true
        }
    }

    /// Account a height transition in the occupancy buckets.  Only the
    /// thread that actually performed the raise (`fetch_max` returning a
    /// smaller previous value, or a successful CAS) calls this, so each
    /// transition is counted exactly once.  Increment before decrement:
    /// a concurrent reader then sees at worst a transient double-count,
    /// never a spurious empty bucket.
    #[inline]
    fn bucket_move(&self, old: i64, new: i64) {
        if self.bucket.is_empty() {
            return;
        }
        let n = self.bucket.len() as i64;
        if (0..n).contains(&new) {
            self.bucket[new as usize].fetch_add(1, Ordering::Relaxed);
        }
        if (0..n).contains(&old) {
            self.bucket[old as usize].fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Racy scan for a gap candidate: the lowest empty bucket `d ≥ 1`
    /// with some occupied bucket above it (still below `n`).  Purely a
    /// trigger — both false positives (transient states) and misses
    /// (caught on the next poll) are harmless.
    fn find_gap(&self) -> Option<usize> {
        let mut gap = None;
        for d in 1..self.bucket.len() {
            let c = self.bucket[d].load(Ordering::Relaxed);
            match gap {
                None if c == 0 => gap = Some(d),
                Some(_) if c > 0 => return gap,
                _ => {}
            }
        }
        None
    }

    fn terminated(&self) -> bool {
        // Acquire pairs with the Release adds; both terminal excesses
        // are monotone non-decreasing, so the test is stable.
        let (s, t) = (self.g.source(), self.g.sink());
        self.excess[s].load(Ordering::Acquire) + self.excess[t].load(Ordering::Acquire)
            >= self.excess_total
    }

    /// One ARG pass (§4.5) with the classic queue BFS — the fast shape
    /// on small graphs and the fallback when no pool is lent.  Returns
    /// the number of stranded nodes lifted out of the tracked height
    /// range (raised from `< n` to `n`).
    fn arg_pass_seq(&self, n: usize) -> u64 {
        use std::collections::VecDeque;
        let (s, t) = (self.g.source(), self.g.sink());
        // The snapshot is heuristic (any plausible residual graph will
        // do — heights are only ever raised), so Relaxed loads suffice.
        let snap: Vec<i64> = self.cap.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let mut dist = vec![-1i64; n];
        dist[t] = 0;
        let mut q = VecDeque::new();
        q.push_back(t);
        while let Some(u) = q.pop_front() {
            for &e in self.g.out_edges(u) {
                let v = self.g.edge_head(e);
                if dist[v] < 0 && snap[(e ^ 1) as usize] > 0 && v != s {
                    dist[v] = dist[u] + 1;
                    q.push_back(v);
                }
            }
        }
        let mut lifted = 0u64;
        for v in 0..n {
            if v == s || v == t {
                continue;
            }
            let target = if dist[v] >= 0 { dist[v] } else { n as i64 };
            if let Some(prev) = self.raise_height(v, target) {
                if prev < n as i64 && target >= n as i64 {
                    lifted += 1;
                }
            }
        }
        lifted
    }

    /// Monotone raise via CAS loop; no payload travels with the height,
    /// so Relaxed orderings are enough.  Returns `Some(previous)` when
    /// this call performed the raise.
    fn raise_height(&self, v: usize, target: i64) -> Option<i64> {
        loop {
            let cur = self.height[v].load(Ordering::Relaxed);
            if cur >= target {
                return None;
            }
            if self.height[v]
                .compare_exchange(cur, target, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                self.bucket_move(cur, target);
                return Some(cur);
            }
        }
    }

    /// One ARG pass (§4.5): BFS over a *snapshot* of the residual
    /// capacities, then raise (never lower) heights to the exact
    /// distances.  Raising-only keeps every worker-side invariant: a
    /// stale-low height only costs extra work, a lowered height could
    /// break termination.
    ///
    /// The BFS runs on the striped frontier substrate (level-synchronous
    /// — identical distances to [`Self::arg_pass_seq`]), and the raise
    /// sweep fans out over the same stripes; the CAS raises are
    /// per-node atomics, so stripe order is irrelevant.  Only used on
    /// large instances with a lent pool — below that the queue BFS wins
    /// (same rationale as `global_relabel_auto`).
    fn arg_pass_striped(&self, n: usize, scratch: &mut ArgScratch, lanes: &Lanes<'_>) -> u64 {
        let (s, t) = (self.g.source(), self.g.sink());
        let stripes = Stripes::new(n, lanes.width() * 2);
        let ArgScratch {
            dist,
            snap,
            frontier,
        } = scratch;
        snap.clear();
        snap.extend(self.cap.iter().map(|c| c.load(Ordering::Relaxed)));
        let snap: &[i64] = snap;
        dist.clear();
        dist.resize(n, -1);
        frontier.reset(stripes);
        dist[t] = 0;
        frontier.seed(t);
        let g = self.g;
        let neigh = |u: usize, emit: &mut dyn FnMut(usize)| {
            for &e in g.out_edges(u) {
                let v = g.edge_head(e);
                if v != s && snap[(e ^ 1) as usize] > 0 {
                    emit(v);
                }
            }
        };
        frontier.run(dist, 0, None, &neigh, lanes);

        let sl = stripes.stripe_len();
        let mut tasks = Vec::with_capacity(stripes.n_stripes());
        for (o, chunk) in dist.chunks(sl).enumerate() {
            tasks.push((o * sl, chunk));
        }
        let lifted_total = AtomicI64::new(0);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for group in deal(tasks, lanes.width()) {
            let lifted_total = &lifted_total;
            jobs.push(Box::new(move || {
                let mut lifted = 0i64;
                for (base, chunk) in group {
                    for (lc, &d) in chunk.iter().enumerate() {
                        let v = base + lc;
                        if v == s || v == t {
                            continue;
                        }
                        let target = if d >= 0 { d as i64 } else { n as i64 };
                        if let Some(prev) = self.raise_height(v, target) {
                            if prev < n as i64 && target >= n as i64 {
                                lifted += 1;
                            }
                        }
                    }
                }
                if lifted > 0 {
                    lifted_total.fetch_add(lifted, Ordering::Relaxed);
                }
            }));
        }
        lanes.run(jobs);
        lifted_total.load(Ordering::Relaxed) as u64
    }
}

impl MaxFlowSolver for LockFree {
    fn name(&self) -> &'static str {
        if self.gap {
            "lockfree-hong+gap"
        } else {
            "lockfree-hong"
        }
    }

    fn solve(&self, g: &mut FlowNetwork) -> Result<FlowStats> {
        let n = g.node_count();
        let (s, t) = (g.source(), g.sink());

        // Init (Algorithm 4.5 Init): saturate source arcs; e(s) counts
        // *returned* flow so it starts at 0.
        let mut cap0: Vec<i64> = g.capacities().to_vec();
        let mut excess0 = vec![0i64; n];
        let mut excess_total = 0i64;
        for &eid in g.out_edges(s) {
            let c = cap0[eid as usize];
            if c > 0 {
                cap0[eid as usize] = 0;
                cap0[(eid ^ 1) as usize] += c;
                excess0[g.edge_head(eid)] += c;
                excess_total += c;
            }
        }
        let mut height0 = vec![0i64; n];
        height0[s] = n as i64;

        // Occupancy buckets only exist when the gap trigger is on; the
        // initial state has every node except the source at height 0.
        let bucket0 = if self.gap {
            let b: Vec<AtomicI64> = (0..n).map(|_| AtomicI64::new(0)).collect();
            b[0].store(n as i64 - 1, Ordering::Relaxed);
            b
        } else {
            Vec::new()
        };

        let shared = Shared {
            g,
            cap: cap0.into_iter().map(AtomicI64::new).collect(),
            excess: excess0.into_iter().map(AtomicI64::new).collect(),
            height: height0.into_iter().map(AtomicI64::new).collect(),
            bucket: bucket0,
            done: AtomicBool::new(false),
            pushes: AtomicI64::new(0),
            relabels: AtomicI64::new(0),
            gap_events: AtomicI64::new(0),
            gap_lift_nodes: AtomicI64::new(0),
            excess_total,
        };

        let workers = self.threads.max(1);
        let cancel = self.cancel.as_ref();
        let was_cancelled = AtomicBool::new(false);
        std::thread::scope(|scope| {
            if self.arg || self.gap {
                // The distinguished relabel thread: with ARG it runs BFS
                // passes back-to-back (§4.5); with the gap trigger it
                // polls the occupancy buckets and runs a pass only when
                // a candidate gap shows up.  Both lift via the same
                // raising-only snapshot pass — striped on the lent pool
                // for large instances, the classic queue BFS otherwise
                // (the striped pass's per-level batches only pay off
                // with real lanes and enough nodes).
                let shared = &shared;
                let relabel_pool = self.relabel_pool.clone();
                let (arg, gap) = (self.arg, self.gap);
                scope.spawn(move || {
                    let striped = relabel_pool.is_some()
                        && n >= super::global_relabel::STRIPED_RELABEL_MIN_NODES;
                    let mut scratch = ArgScratch::default();
                    let lanes = match &relabel_pool {
                        Some(p) if striped => Lanes::Pool(p.as_ref()),
                        _ => Lanes::Seq,
                    };
                    // Passes accumulate their time locally and flush
                    // once — a registry touch per pass would contend.
                    let mut arg_secs = 0.0;
                    while !shared.done.load(Ordering::Acquire) {
                        let gap_hit = gap && shared.find_gap().is_some();
                        if arg || gap_hit {
                            let t = crate::util::Timer::start();
                            let lifted = if striped {
                                shared.arg_pass_striped(n, &mut scratch, &lanes)
                            } else {
                                shared.arg_pass_seq(n)
                            };
                            arg_secs += t.elapsed();
                            if gap_hit {
                                shared.gap_events.fetch_add(1, Ordering::Relaxed);
                                shared
                                    .gap_lift_nodes
                                    .fetch_add(lifted as i64, Ordering::Relaxed);
                            }
                        }
                        std::thread::yield_now();
                    }
                    crate::obs::record_phase_secs(
                        "csr",
                        crate::obs::Phase::GlobalRelabel,
                        arg_secs,
                    );
                });
            }
            for w in 0..workers {
                let shared = &shared;
                let was_cancelled = &was_cancelled;
                scope.spawn(move || {
                    // Round-robin over this worker's node stripe.
                    let mine: Vec<usize> = (0..n)
                        .filter(|&v| v != s && v != t && v % workers == w)
                        .collect();
                    let mut idle_sweeps = 0u32;
                    loop {
                        if shared.done.load(Ordering::Acquire) {
                            break;
                        }
                        // Once per sweep: cheap relative to the node
                        // scan, prompt enough for deadline enforcement.
                        if cancel.is_some_and(|c| c.is_cancelled()) {
                            was_cancelled.store(true, Ordering::Release);
                            shared.done.store(true, Ordering::Release);
                            break;
                        }
                        let mut did_work = false;
                        for &v in &mine {
                            // Drain v greedily (the paper's while e(x) > 0),
                            // but bound the burst so termination checks run.
                            let mut burst = 0;
                            while shared.step(v, n) {
                                did_work = true;
                                burst += 1;
                                if burst >= 64 {
                                    break;
                                }
                            }
                        }
                        if shared.terminated() {
                            shared.done.store(true, Ordering::Release);
                            break;
                        }
                        if did_work {
                            idle_sweeps = 0;
                        } else {
                            idle_sweeps += 1;
                            if idle_sweeps > 2 {
                                std::thread::yield_now();
                            }
                        }
                    }
                });
            }
        });

        // A cancelled solve stops with flow still in transit: report the
        // typed error and leave the caller's network untouched.
        if was_cancelled.load(Ordering::Acquire) {
            return Err(anyhow::Error::new(Cancelled));
        }

        // Write the state back into the network.  `thread::scope` has
        // joined every worker, which synchronises-with all their writes,
        // so Relaxed loads read the final values.
        let cap: Vec<i64> = shared
            .cap
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let value = shared.excess[t].load(Ordering::Relaxed);
        let stats = FlowStats {
            value,
            pushes: shared.pushes.load(Ordering::Relaxed) as u64,
            relabels: shared.relabels.load(Ordering::Relaxed) as u64,
            gap_nodes: shared.gap_lift_nodes.load(Ordering::Relaxed) as u64,
            gap_relabels: shared.gap_events.load(Ordering::Relaxed) as u64,
            ..FlowStats::default()
        };
        g.set_capacities(cap);
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::assert_max_flow;

    #[test]
    fn single_thread_matches_reference() {
        let mut g = crate::maxflow::tests::clrs();
        let stats = LockFree::with_threads(1).solve(&mut g).unwrap();
        assert_eq!(stats.value, 23);
        assert_max_flow(&g, 23).unwrap();
    }

    #[test]
    fn multi_thread_matches_reference() {
        for threads in [2, 4] {
            let mut g = crate::maxflow::tests::clrs();
            let stats = LockFree::with_threads(threads).solve(&mut g).unwrap();
            assert_eq!(stats.value, 23, "threads={threads}");
            assert_max_flow(&g, 23).unwrap();
        }
    }

    #[test]
    fn arg_variant_matches_reference() {
        for threads in [1, 2, 4] {
            let mut g = crate::maxflow::tests::clrs();
            let stats = LockFree::with_arg(threads).solve(&mut g).unwrap();
            assert_eq!(stats.value, 23, "arg threads={threads}");
            assert_max_flow(&g, 23).unwrap();
        }
    }

    #[test]
    fn arg_on_random_networks() {
        use crate::graph::csr::NetworkBuilder;
        let mut rng = crate::util::Rng::seeded(101);
        for case in 0..8 {
            let nn = 5 + rng.index(10);
            let mut b = NetworkBuilder::new(nn, 0, nn - 1);
            for _ in 0..3 * nn {
                let u = rng.index(nn);
                let v = (u + 1 + rng.index(nn - 1)) % nn;
                b.add_edge(u, v, rng.range_i64(0, 15), 0);
            }
            let base = b.build().unwrap();
            let mut g0 = base.clone();
            let want = crate::maxflow::dinic::Dinic.solve(&mut g0).unwrap().value;
            let mut g = base.clone();
            let stats = LockFree::with_arg(2).solve(&mut g).unwrap();
            assert_eq!(stats.value, want, "case={case}");
            assert_max_flow(&g, stats.value).unwrap();
        }
    }

    #[test]
    fn relaxed_orderings_on_random_networks() {
        // arg_on_random_networks-style sweep for the plain engine: the
        // relaxed Acquire/Release/Relaxed orderings must keep every
        // random instance exact at real thread counts (run under
        // --release in CI, where reordering is most likely to bite).
        use crate::graph::csr::NetworkBuilder;
        let mut rng = crate::util::Rng::seeded(4242);
        for case in 0..10 {
            let nn = 5 + rng.index(12);
            let mut b = NetworkBuilder::new(nn, 0, nn - 1);
            for _ in 0..3 * nn {
                let u = rng.index(nn);
                let v = (u + 1 + rng.index(nn - 1)) % nn;
                b.add_edge(u, v, rng.range_i64(0, 15), 0);
            }
            let base = b.build().unwrap();
            let mut g0 = base.clone();
            let want = crate::maxflow::dinic::Dinic.solve(&mut g0).unwrap().value;
            for threads in [1, 2, 4] {
                let mut g = base.clone();
                let stats = LockFree::with_threads(threads).solve(&mut g).unwrap();
                assert_eq!(stats.value, want, "case={case} threads={threads}");
                assert_max_flow(&g, stats.value).unwrap();
            }
        }
    }

    #[test]
    fn gap_variant_matches_reference() {
        for threads in [1, 2, 4] {
            let mut g = crate::maxflow::tests::clrs();
            let stats = LockFree::with_threads(threads)
                .with_gap()
                .solve(&mut g)
                .unwrap();
            assert_eq!(stats.value, 23, "gap threads={threads}");
            assert_max_flow(&g, 23).unwrap();
        }
    }

    #[test]
    fn gap_on_random_networks() {
        // The gap trigger only ever schedules raising-only snapshot
        // passes, so every instance must stay exact — with and without
        // ARG running alongside.
        use crate::graph::csr::NetworkBuilder;
        let mut rng = crate::util::Rng::seeded(777);
        for case in 0..8 {
            let nn = 5 + rng.index(10);
            let mut b = NetworkBuilder::new(nn, 0, nn - 1);
            for _ in 0..3 * nn {
                let u = rng.index(nn);
                let v = (u + 1 + rng.index(nn - 1)) % nn;
                b.add_edge(u, v, rng.range_i64(0, 15), 0);
            }
            let base = b.build().unwrap();
            let mut g0 = base.clone();
            let want = crate::maxflow::dinic::Dinic.solve(&mut g0).unwrap().value;
            for engine in [
                LockFree::with_threads(2).with_gap(),
                LockFree::with_arg(2).with_gap(),
            ] {
                let mut g = base.clone();
                let stats = engine.solve(&mut g).unwrap();
                assert_eq!(stats.value, want, "case={case} {}", engine.name());
                assert_max_flow(&g, stats.value).unwrap();
            }
        }
    }

    #[test]
    fn cancelled_solve_returns_typed_error() {
        let mut g = crate::maxflow::tests::clrs();
        let token = CancelToken::new();
        token.cancel();
        let err = LockFree::with_threads(2)
            .with_cancel(token)
            .solve(&mut g)
            .unwrap_err();
        assert!(Cancelled::caused(&err), "{err:#}");
    }

    #[test]
    fn op_count_within_theoretical_bound() {
        let mut g = crate::maxflow::tests::clrs();
        let stats = LockFree::with_threads(2).solve(&mut g).unwrap();
        let n = 6u64;
        let m = 9u64 * 2;
        // O(V^2 E) bound with a generous constant.
        assert!(stats.work() <= 16 * n * n * m);
    }
}
