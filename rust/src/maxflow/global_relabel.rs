//! Global + gap relabeling heuristics (§4.2) shared by the push-relabel
//! engines: a backwards BFS from the sink assigns exact residual
//! distances; unreached nodes are lifted to `n` (gap relabeling), removing
//! them from useful work until their excess drains back to the source.
//!
//! The pass exists twice: the classic queue BFS ([`global_relabel`])
//! and a stripe-parallel twin ([`global_relabel_striped`]) on the
//! shared frontier substrate (`crate::parallel`) — node ids are
//! partitioned into contiguous stripes, each BFS level expands with
//! per-stripe local queues, and cross-stripe discoveries commit through
//! the parity-coloured two-pass.  The twins are bit-exact (BFS
//! distances are unique regardless of visit order); engines pick the
//! striped path on large instances when a [`WorkerPool`] is lent
//! ([`global_relabel_auto`]).

use std::collections::VecDeque;

use crate::graph::csr::FlowNetwork;
use crate::parallel::{deal, Lanes, Stripes, StripedFrontier};
use crate::service::pool::WorkerPool;

/// Below this node count the sequential BFS wins outright (the striped
/// pass costs a few batch barriers per level), so
/// [`global_relabel_auto`] does not bother the pool.
pub const STRIPED_RELABEL_MIN_NODES: usize = 256;

/// Result of a global relabel pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalRelabelOutcome {
    /// Nodes assigned a finite BFS distance.
    pub reached: usize,
    /// Nodes lifted to `n` by the gap step.
    pub gap_lifted: usize,
}

/// Recompute `h` as exact distances-to-sink in the residual graph
/// (heights of unreachable nodes jump to `n`, the paper's gap step).
/// The source keeps height `n` (its distance class by definition).
pub fn global_relabel(g: &FlowNetwork, h: &mut [i64]) -> GlobalRelabelOutcome {
    let n = g.node_count();
    debug_assert_eq!(h.len(), n);
    let (s, t) = (g.source(), g.sink());

    let mut dist = vec![-1i64; n];
    dist[t] = 0;
    let mut q = VecDeque::new();
    q.push_back(t);
    let mut reached = 1;
    while let Some(u) = q.pop_front() {
        for &e in g.out_edges(u) {
            // BFS follows *reverse* residual arcs: we can relabel v from u
            // when the arc v->u has residual capacity, i.e. the mate of
            // (u->v) entry has residual > 0.
            let v = g.edge_head(e);
            if dist[v] < 0 && g.residual(e ^ 1) > 0 {
                dist[v] = dist[u] + 1;
                reached += 1;
                if v != s {
                    q.push_back(v);
                }
            }
        }
    }

    // Second phase (Cherkassky-Goldberg): nodes that cannot reach the
    // sink get `n + distance-to-source` so their excess drains back to s
    // directly (parking everything at exactly n livelocks CYCLE-bounded
    // engines: each host round would erase the climb above n).
    let mut dist_s = vec![-1i64; n];
    dist_s[s] = 0;
    let mut qs = VecDeque::new();
    qs.push_back(s);
    while let Some(u) = qs.pop_front() {
        for &e in g.out_edges(u) {
            let v = g.edge_head(e);
            if dist[v] < 0 && dist_s[v] < 0 && g.residual(e ^ 1) > 0 {
                dist_s[v] = dist_s[u] + 1;
                qs.push_back(v);
            }
        }
    }

    let mut gap_lifted = 0;
    for v in 0..n {
        if v == s {
            h[v] = n as i64;
        } else if dist[v] >= 0 {
            h[v] = dist[v];
        } else {
            if h[v] < n as i64 {
                gap_lifted += 1;
            }
            h[v] = if dist_s[v] >= 0 {
                n as i64 + dist_s[v]
            } else {
                2 * n as i64 // unreachable from both terminals: inert
            };
        }
    }
    GlobalRelabelOutcome {
        reached,
        gap_lifted,
    }
}

/// Reusable buffers of the striped relabel: distance planes plus the
/// level-synchronous frontier.  Engines allocate one per solve so the
/// queues and outboxes survive across the periodic relabels.
#[derive(Debug, Default)]
pub struct RelabelScratch {
    dist: Vec<i32>,
    dist_s: Vec<i32>,
    frontier: StripedFrontier,
    stripe_gap: Vec<u64>,
}

/// Stripe-parallel twin of [`global_relabel`], bit-exact at any stripe
/// count and on any [`Lanes`]: both reverse BFS passes run
/// level-synchronously on the [`StripedFrontier`], and the height
/// write-back (with gap counting) is a parallel sweep over the same
/// stripes.
pub fn global_relabel_striped(
    g: &FlowNetwork,
    h: &mut [i64],
    scratch: &mut RelabelScratch,
    lanes: &Lanes<'_>,
) -> GlobalRelabelOutcome {
    let n = g.node_count();
    debug_assert_eq!(h.len(), n);
    let (s, t) = (g.source(), g.sink());
    let stripes = Stripes::new(n, lanes.width() * 2);
    let ns = stripes.n_stripes();
    let sl = stripes.stripe_len();

    let RelabelScratch {
        dist,
        dist_s,
        frontier,
        stripe_gap,
    } = scratch;

    // Pass 1: distance-to-sink over reverse residual arcs.  The source
    // is assigned a distance when reached (it counts as `reached`, like
    // the sequential pass) but never expanded.
    dist.clear();
    dist.resize(n, -1);
    frontier.reset(stripes);
    dist[t] = 0;
    frontier.seed(t);
    let neigh = |u: usize, emit: &mut dyn FnMut(usize)| {
        for &e in g.out_edges(u) {
            if g.residual(e ^ 1) > 0 {
                emit(g.edge_head(e));
            }
        }
    };
    let assigned = frontier.run(dist, 0, Some(s), &neigh, lanes);
    let reached = 1 + assigned as usize;

    // Pass 2 (Cherkassky–Goldberg): distance-to-source for nodes the
    // sink BFS missed, masked by the (now read-only) sink distances.
    dist_s.clear();
    dist_s.resize(n, -1);
    frontier.reset(stripes);
    dist_s[s] = 0;
    frontier.seed(s);
    {
        let dist_ro: &[i32] = dist;
        let neigh_s = |u: usize, emit: &mut dyn FnMut(usize)| {
            for &e in g.out_edges(u) {
                let v = g.edge_head(e);
                if dist_ro[v] < 0 && g.residual(e ^ 1) > 0 {
                    emit(v);
                }
            }
        };
        frontier.run(dist_s, 0, None, &neigh_s, lanes);
    }

    // Write-back, gap counting per stripe.
    stripe_gap.clear();
    stripe_gap.resize(ns, 0);
    {
        let mut tasks = Vec::with_capacity(ns);
        let iter = h
            .chunks_mut(sl)
            .zip(dist.chunks(sl))
            .zip(dist_s.chunks(sl))
            .zip(stripe_gap.iter_mut())
            .enumerate();
        for (o, (((h, d), ds), gap)) in iter {
            tasks.push((o * sl, h, d, ds, gap));
        }
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for group in deal(tasks, lanes.width()) {
            jobs.push(Box::new(move || {
                for (base, h, d, ds, gap) in group {
                    for lc in 0..h.len() {
                        let v = base + lc;
                        if v == s {
                            h[lc] = n as i64;
                        } else if d[lc] >= 0 {
                            h[lc] = d[lc] as i64;
                        } else {
                            if h[lc] < n as i64 {
                                *gap += 1;
                            }
                            h[lc] = if ds[lc] >= 0 {
                                n as i64 + ds[lc] as i64
                            } else {
                                2 * n as i64
                            };
                        }
                    }
                }
            }));
        }
        lanes.run(jobs);
    }

    GlobalRelabelOutcome {
        reached,
        gap_lifted: stripe_gap.iter().sum::<u64>() as usize,
    }
}

/// What the engines call: the striped pass on the lent pool for large
/// instances, the sequential queue BFS otherwise.  Identical results
/// either way — this is purely a latency switch.
///
/// This is also where the CSR engines' global-relabel time enters the
/// observability spine: one chokepoint instead of seven call sites
/// across fifo/highest/hybrid.  Global relabels run every Θ(n)
/// relabels, so the Timer read plus one registry touch is far off the
/// push/relabel hot path.
pub fn global_relabel_auto(
    g: &FlowNetwork,
    h: &mut [i64],
    pool: Option<&WorkerPool>,
    scratch: &mut RelabelScratch,
) -> GlobalRelabelOutcome {
    let t = crate::util::Timer::start();
    let out = match pool {
        Some(pool) if g.node_count() >= STRIPED_RELABEL_MIN_NODES => {
            global_relabel_striped(g, h, scratch, &Lanes::Pool(pool))
        }
        _ => global_relabel(g, h),
    };
    crate::obs::record_phase_secs("csr", crate::obs::Phase::GlobalRelabel, t.elapsed());
    out
}

/// Cancel height-violating residual arcs (`h(u) > h(v) + 1`) by pushing
/// the full residual through them — Algorithm 4.8 lines 1-6.  Needed when
/// a CYCLE-bounded engine stops mid-stream before recomputing heights.
/// Returns the number of cancelled arcs.
pub fn cancel_violations(g: &mut FlowNetwork, h: &[i64], e: &mut [i64]) -> usize {
    let mut cancelled = 0;
    for u in 0..g.node_count() {
        for idx in 0..g.out_edges(u).len() {
            let eid = g.out_edges(u)[idx];
            let v = g.edge_head(eid);
            let r = g.residual(eid);
            if r > 0 && h[u] > h[v] + 1 {
                g.push(eid, r);
                e[u] -= r;
                e[v] += r;
                cancelled += 1;
            }
        }
    }
    cancelled
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::NetworkBuilder;

    #[test]
    fn distances_on_fresh_chain() {
        // s -> a -> b -> t, all residual: dist(t)=0, b=1, a=2, s stays n.
        let mut b = NetworkBuilder::new(4, 0, 3);
        b.add_edge(0, 1, 5, 0);
        b.add_edge(1, 2, 5, 0);
        b.add_edge(2, 3, 5, 0);
        let g = b.build().unwrap();
        let mut h = vec![0i64; 4];
        let out = global_relabel(&g, &mut h);
        assert_eq!(h, vec![4, 2, 1, 0]);
        assert_eq!(out.reached, 4);
        assert_eq!(out.gap_lifted, 0);
    }

    #[test]
    fn saturated_arc_breaks_reachability() {
        let mut b = NetworkBuilder::new(4, 0, 3);
        let e01 = b.add_edge(0, 1, 5, 0);
        let e13 = b.add_edge(1, 3, 5, 0);
        b.add_edge(0, 2, 5, 0); // 2 has no arc to t
        let mut g = b.build().unwrap();
        g.push(e01, 5);
        g.push(e13, 5); // arc 1->3 saturated: 1 now reachable only via 3->1 mate
        let mut h = vec![0i64; 4];
        let out = global_relabel(&g, &mut h);
        // Arc 1->3 is saturated so neither 1 nor 2 reaches t; both reach
        // the source through residual reverse arcs and get n + dist_s.
        assert_eq!(h[3], 0);
        assert_eq!(h[1], 5); // n + 1 (residual arc 1->0 via the mate)
        assert_eq!(h[2], 8); // 2n: no flow ever reached 2, inert
        assert_eq!(out.gap_lifted, 2);
    }

    #[test]
    fn striped_twin_matches_sequential_on_unit_cases() {
        // The two unit instances above, plus a partially pushed chain,
        // across lane kinds and (via lane width) stripe counts.
        let cases: Vec<FlowNetwork> = {
            let mut v = Vec::new();
            let mut b = NetworkBuilder::new(4, 0, 3);
            b.add_edge(0, 1, 5, 0);
            b.add_edge(1, 2, 5, 0);
            b.add_edge(2, 3, 5, 0);
            v.push(b.build().unwrap());
            let mut b = NetworkBuilder::new(4, 0, 3);
            let e01 = b.add_edge(0, 1, 5, 0);
            let e13 = b.add_edge(1, 3, 5, 0);
            b.add_edge(0, 2, 5, 0);
            let mut g = b.build().unwrap();
            g.push(e01, 5);
            g.push(e13, 5);
            v.push(g);
            v
        };
        let pool = WorkerPool::new(3);
        for (i, g) in cases.iter().enumerate() {
            let mut h_seq = vec![0i64; g.node_count()];
            let want = global_relabel(g, &mut h_seq);
            for lanes in [Lanes::Seq, Lanes::Scoped { threads: 3 }, Lanes::Pool(&pool)] {
                let mut h_par = vec![0i64; g.node_count()];
                let mut scratch = RelabelScratch::default();
                let got = global_relabel_striped(g, &mut h_par, &mut scratch, &lanes);
                assert_eq!(h_par, h_seq, "case {i} lanes={}", lanes.width());
                assert_eq!(got, want, "case {i} outcome");
                // Scratch reuse: a second run must be idempotent.
                let again = global_relabel_striped(g, &mut h_par, &mut scratch, &lanes);
                assert_eq!(h_par, h_seq, "case {i} reuse");
                assert_eq!(again.reached, want.reached, "case {i} reuse outcome");
            }
        }
    }

    #[test]
    fn auto_routes_by_size_and_stays_exact() {
        // A long chain over the striped threshold: auto must take the
        // striped path on a pool and still match the sequential twin.
        let n = STRIPED_RELABEL_MIN_NODES + 20;
        let mut b = NetworkBuilder::new(n, 0, n - 1);
        for i in 0..n - 1 {
            b.add_edge(i, i + 1, 3, 1);
        }
        let g = b.build().unwrap();
        let mut h_seq = vec![0i64; n];
        let want = global_relabel(&g, &mut h_seq);
        let pool = WorkerPool::new(4);
        let mut h_auto = vec![0i64; n];
        let mut scratch = RelabelScratch::default();
        let got = global_relabel_auto(&g, &mut h_auto, Some(&pool), &mut scratch);
        assert_eq!(h_auto, h_seq);
        assert_eq!(got, want);
    }

    #[test]
    fn cancel_violations_pushes_back() {
        let mut b = NetworkBuilder::new(3, 0, 2);
        let e = b.add_edge(0, 1, 5, 0);
        b.add_edge(1, 2, 1, 0);
        let mut g = b.build().unwrap();
        g.push(e, 5);
        // Pretend node 1 was relabelled sky-high with excess.
        let h = vec![3, 9, 0];
        let mut ex = vec![0i64, 5, 0];
        // Both residual arcs out of node 1 violate: the mate 1->0
        // (h(1)=9 > h(0)+1=4) and 1->2 (h(1)=9 > h(2)+1=1); Algorithm 4.8
        // cancels them all, leaving node 1 with a transient deficit.
        let cancelled = cancel_violations(&mut g, &h, &mut ex);
        assert_eq!(cancelled, 2);
        assert_eq!(ex[1], -1);
        assert_eq!(ex[0], 5);
        assert_eq!(ex[2], 1);
        assert_eq!(g.residual(e), 5); // flow undone
    }
}
