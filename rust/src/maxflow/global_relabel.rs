//! Global + gap relabeling heuristics (§4.2) shared by the push-relabel
//! engines: a backwards BFS from the sink assigns exact residual
//! distances; unreached nodes are lifted to `n` (gap relabeling), removing
//! them from useful work until their excess drains back to the source.

use std::collections::VecDeque;

use crate::graph::csr::FlowNetwork;

/// Result of a global relabel pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalRelabelOutcome {
    /// Nodes assigned a finite BFS distance.
    pub reached: usize,
    /// Nodes lifted to `n` by the gap step.
    pub gap_lifted: usize,
}

/// Recompute `h` as exact distances-to-sink in the residual graph
/// (heights of unreachable nodes jump to `n`, the paper's gap step).
/// The source keeps height `n` (its distance class by definition).
pub fn global_relabel(g: &FlowNetwork, h: &mut [i64]) -> GlobalRelabelOutcome {
    let n = g.node_count();
    debug_assert_eq!(h.len(), n);
    let (s, t) = (g.source(), g.sink());

    let mut dist = vec![-1i64; n];
    dist[t] = 0;
    let mut q = VecDeque::new();
    q.push_back(t);
    let mut reached = 1;
    while let Some(u) = q.pop_front() {
        for &e in g.out_edges(u) {
            // BFS follows *reverse* residual arcs: we can relabel v from u
            // when the arc v->u has residual capacity, i.e. the mate of
            // (u->v) entry has residual > 0.
            let v = g.edge_head(e);
            if dist[v] < 0 && g.residual(e ^ 1) > 0 {
                dist[v] = dist[u] + 1;
                reached += 1;
                if v != s {
                    q.push_back(v);
                }
            }
        }
    }

    // Second phase (Cherkassky-Goldberg): nodes that cannot reach the
    // sink get `n + distance-to-source` so their excess drains back to s
    // directly (parking everything at exactly n livelocks CYCLE-bounded
    // engines: each host round would erase the climb above n).
    let mut dist_s = vec![-1i64; n];
    dist_s[s] = 0;
    let mut qs = VecDeque::new();
    qs.push_back(s);
    while let Some(u) = qs.pop_front() {
        for &e in g.out_edges(u) {
            let v = g.edge_head(e);
            if dist[v] < 0 && dist_s[v] < 0 && g.residual(e ^ 1) > 0 {
                dist_s[v] = dist_s[u] + 1;
                qs.push_back(v);
            }
        }
    }

    let mut gap_lifted = 0;
    for v in 0..n {
        if v == s {
            h[v] = n as i64;
        } else if dist[v] >= 0 {
            h[v] = dist[v];
        } else {
            if h[v] < n as i64 {
                gap_lifted += 1;
            }
            h[v] = if dist_s[v] >= 0 {
                n as i64 + dist_s[v]
            } else {
                2 * n as i64 // unreachable from both terminals: inert
            };
        }
    }
    GlobalRelabelOutcome {
        reached,
        gap_lifted,
    }
}

/// Cancel height-violating residual arcs (`h(u) > h(v) + 1`) by pushing
/// the full residual through them — Algorithm 4.8 lines 1-6.  Needed when
/// a CYCLE-bounded engine stops mid-stream before recomputing heights.
/// Returns the number of cancelled arcs.
pub fn cancel_violations(g: &mut FlowNetwork, h: &[i64], e: &mut [i64]) -> usize {
    let mut cancelled = 0;
    for u in 0..g.node_count() {
        for idx in 0..g.out_edges(u).len() {
            let eid = g.out_edges(u)[idx];
            let v = g.edge_head(eid);
            let r = g.residual(eid);
            if r > 0 && h[u] > h[v] + 1 {
                g.push(eid, r);
                e[u] -= r;
                e[v] += r;
                cancelled += 1;
            }
        }
    }
    cancelled
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::NetworkBuilder;

    #[test]
    fn distances_on_fresh_chain() {
        // s -> a -> b -> t, all residual: dist(t)=0, b=1, a=2, s stays n.
        let mut b = NetworkBuilder::new(4, 0, 3);
        b.add_edge(0, 1, 5, 0);
        b.add_edge(1, 2, 5, 0);
        b.add_edge(2, 3, 5, 0);
        let g = b.build().unwrap();
        let mut h = vec![0i64; 4];
        let out = global_relabel(&g, &mut h);
        assert_eq!(h, vec![4, 2, 1, 0]);
        assert_eq!(out.reached, 4);
        assert_eq!(out.gap_lifted, 0);
    }

    #[test]
    fn saturated_arc_breaks_reachability() {
        let mut b = NetworkBuilder::new(4, 0, 3);
        let e01 = b.add_edge(0, 1, 5, 0);
        let e13 = b.add_edge(1, 3, 5, 0);
        b.add_edge(0, 2, 5, 0); // 2 has no arc to t
        let mut g = b.build().unwrap();
        g.push(e01, 5);
        g.push(e13, 5); // arc 1->3 saturated: 1 now reachable only via 3->1 mate
        let mut h = vec![0i64; 4];
        let out = global_relabel(&g, &mut h);
        // Arc 1->3 is saturated so neither 1 nor 2 reaches t; both reach
        // the source through residual reverse arcs and get n + dist_s.
        assert_eq!(h[3], 0);
        assert_eq!(h[1], 5); // n + 1 (residual arc 1->0 via the mate)
        assert_eq!(h[2], 8); // 2n: no flow ever reached 2, inert
        assert_eq!(out.gap_lifted, 2);
    }

    #[test]
    fn cancel_violations_pushes_back() {
        let mut b = NetworkBuilder::new(3, 0, 2);
        let e = b.add_edge(0, 1, 5, 0);
        b.add_edge(1, 2, 1, 0);
        let mut g = b.build().unwrap();
        g.push(e, 5);
        // Pretend node 1 was relabelled sky-high with excess.
        let h = vec![3, 9, 0];
        let mut ex = vec![0i64, 5, 0];
        // Both residual arcs out of node 1 violate: the mate 1->0
        // (h(1)=9 > h(0)+1=4) and 1->2 (h(1)=9 > h(2)+1=1); Algorithm 4.8
        // cancels them all, leaving node 1 with a transient deficit.
        let cancelled = cancel_violations(&mut g, &h, &mut ex);
        assert_eq!(cancelled, 2);
        assert_eq!(ex[1], -1);
        assert_eq!(ex[0], 5);
        assert_eq!(ex[2], 1);
        assert_eq!(g.residual(e), 5); // flow undone
    }
}
