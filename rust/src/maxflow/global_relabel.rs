//! Global + gap relabeling heuristics (§4.2) shared by the push-relabel
//! engines: a backwards BFS from the sink assigns exact residual
//! distances; unreached nodes are lifted to `n` (gap relabeling), removing
//! them from useful work until their excess drains back to the source.
//!
//! The pass exists twice: the classic queue BFS ([`global_relabel`])
//! and a stripe-parallel twin ([`global_relabel_striped`]) on the
//! shared frontier substrate (`crate::parallel`) — node ids are
//! partitioned into contiguous stripes, each BFS level expands with
//! per-stripe local queues, and cross-stripe discoveries commit through
//! the parity-coloured two-pass.  The twins are bit-exact (BFS
//! distances are unique regardless of visit order); engines pick the
//! striped path on large instances when a [`WorkerPool`] is lent
//! ([`global_relabel_auto`]).

use std::collections::VecDeque;

use crate::graph::csr::FlowNetwork;
use crate::parallel::{deal, Lanes, Stripes, StripedFrontier};
use crate::service::pool::WorkerPool;

/// Below this node count the sequential BFS wins outright (the striped
/// pass costs a few batch barriers per level), so
/// [`global_relabel_auto`] does not bother the pool.  This is the
/// *default* gate; services thread their configured value
/// (`[maxflow] striped_relabel_min_nodes`) through
/// [`global_relabel_auto_with`].
pub const STRIPED_RELABEL_MIN_NODES: usize = 256;

/// Incremental height-bucket occupancy for the gap heuristic: one
/// counter per height `0..n` (heights `>= n` never gate a gap — those
/// nodes are already cut off from the sink).  Engines decrement/
/// increment at every relabel ([`GapBuckets::on_relabel`]); when a
/// bucket `0 < d < n` empties, every node stranded at `d < h < n` can
/// be lifted in one batched pass ([`gap_lift`] / [`gap_lift_striped`]).
#[derive(Debug, Default, Clone)]
pub struct GapBuckets {
    counts: Vec<u32>,
    n: usize,
}

impl GapBuckets {
    /// Recount from scratch (after a global relabel rewrote `h`).
    pub fn rebuild(&mut self, h: &[i64]) {
        let n = h.len();
        self.n = n;
        self.counts.clear();
        self.counts.resize(n, 0);
        for &hv in h {
            if hv >= 0 && (hv as usize) < n {
                self.counts[hv as usize] += 1;
            }
        }
    }

    /// Adopt pre-counted buckets (the striped relabel's write-back
    /// counts them as a by-product; see
    /// [`global_relabel_striped_with_buckets`]).
    fn adopt(&mut self, counts: &mut Vec<u32>, n: usize) {
        self.n = n;
        std::mem::swap(&mut self.counts, counts);
    }

    /// Record a relabel `old -> new`.  Returns `Some(old)` when the old
    /// bucket emptied at a gap-relevant height (`0 < old < n`) — the
    /// caller should then run a batched lift.
    #[inline]
    pub fn on_relabel(&mut self, old: i64, new: i64) -> Option<i64> {
        let mut gap = None;
        if old >= 0 && (old as usize) < self.n {
            let c = &mut self.counts[old as usize];
            debug_assert!(*c > 0, "bucket {old} underflow");
            *c -= 1;
            if *c == 0 && old > 0 {
                gap = Some(old);
            }
        }
        if new >= 0 && (new as usize) < self.n {
            self.counts[new as usize] += 1;
        }
        gap
    }

    /// Occupancy of height bucket `d` (0 outside the tracked range).
    pub fn count(&self, d: i64) -> u32 {
        if d >= 0 && (d as usize) < self.n {
            self.counts[d as usize]
        } else {
            0
        }
    }

    /// Zero every bucket strictly above `gap_h` (they were just lifted
    /// out of the tracked range).
    fn clear_above(&mut self, gap_h: i64) {
        let from = (gap_h.max(0) as usize + 1).min(self.counts.len());
        for c in &mut self.counts[from..] {
            *c = 0;
        }
    }
}

/// Batched sequential gap lift: every node with `gap_h < h[v] < n`
/// rises to `n + 1` (the empty bucket proves it cannot reach the sink;
/// `n + 1` keeps the labeling valid among the lifted set and lets
/// excess drain back to the source).  The source sits at exactly `n`
/// and the sink at a height `<= gap_h`, so neither is touched.
/// Returns the number of nodes lifted.
pub fn gap_lift(h: &mut [i64], buckets: &mut GapBuckets, gap_h: i64) -> usize {
    let n = h.len() as i64;
    let mut lifted = 0usize;
    for hv in h.iter_mut() {
        if *hv > gap_h && *hv < n {
            *hv = n + 1;
            lifted += 1;
        }
    }
    buckets.clear_above(gap_h);
    lifted
}

/// Stripe-parallel twin of [`gap_lift`]: the height plane is dealt out
/// as disjoint stripe chunks, every stripe lifts its own slice and
/// tallies into its own counter slot, and the tallies merge in one
/// owner pass.  Bit-exact with the sequential lift (each node's test
/// and target are independent of every other node's).
pub fn gap_lift_striped(
    h: &mut [i64],
    buckets: &mut GapBuckets,
    gap_h: i64,
    lanes: &Lanes<'_>,
    stripe_lift: &mut Vec<u64>,
) -> usize {
    let n = h.len();
    let stripes = Stripes::new(n, lanes.width() * 2);
    let ns = stripes.n_stripes();
    stripe_lift.clear();
    stripe_lift.resize(ns, 0);
    {
        let mut tasks = Vec::with_capacity(ns);
        for (chunk, lift) in h.chunks_mut(stripes.stripe_len()).zip(stripe_lift.iter_mut()) {
            tasks.push((chunk, lift));
        }
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for group in deal(tasks, lanes.width()) {
            jobs.push(Box::new(move || {
                for (chunk, lift) in group {
                    for hv in chunk.iter_mut() {
                        if *hv > gap_h && *hv < n as i64 {
                            *hv = n as i64 + 1;
                            *lift += 1;
                        }
                    }
                }
            }));
        }
        lanes.run(jobs);
    }
    buckets.clear_above(gap_h);
    stripe_lift.iter().sum::<u64>() as usize
}

/// Result of a global relabel pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalRelabelOutcome {
    /// Nodes assigned a finite BFS distance.
    pub reached: usize,
    /// Nodes lifted to `n` by the gap step.
    pub gap_lifted: usize,
}

/// Recompute `h` as exact distances-to-sink in the residual graph
/// (heights of unreachable nodes jump to `n`, the paper's gap step).
/// The source keeps height `n` (its distance class by definition).
pub fn global_relabel(g: &FlowNetwork, h: &mut [i64]) -> GlobalRelabelOutcome {
    let n = g.node_count();
    debug_assert_eq!(h.len(), n);
    let (s, t) = (g.source(), g.sink());

    let mut dist = vec![-1i64; n];
    dist[t] = 0;
    let mut q = VecDeque::new();
    q.push_back(t);
    let mut reached = 1;
    while let Some(u) = q.pop_front() {
        for &e in g.out_edges(u) {
            // BFS follows *reverse* residual arcs: we can relabel v from u
            // when the arc v->u has residual capacity, i.e. the mate of
            // (u->v) entry has residual > 0.
            let v = g.edge_head(e);
            if dist[v] < 0 && g.residual(e ^ 1) > 0 {
                dist[v] = dist[u] + 1;
                reached += 1;
                if v != s {
                    q.push_back(v);
                }
            }
        }
    }

    // Second phase (Cherkassky-Goldberg): nodes that cannot reach the
    // sink get `n + distance-to-source` so their excess drains back to s
    // directly (parking everything at exactly n livelocks CYCLE-bounded
    // engines: each host round would erase the climb above n).
    let mut dist_s = vec![-1i64; n];
    dist_s[s] = 0;
    let mut qs = VecDeque::new();
    qs.push_back(s);
    while let Some(u) = qs.pop_front() {
        for &e in g.out_edges(u) {
            let v = g.edge_head(e);
            if dist[v] < 0 && dist_s[v] < 0 && g.residual(e ^ 1) > 0 {
                dist_s[v] = dist_s[u] + 1;
                qs.push_back(v);
            }
        }
    }

    let mut gap_lifted = 0;
    for v in 0..n {
        if v == s {
            h[v] = n as i64;
        } else if dist[v] >= 0 {
            h[v] = dist[v];
        } else {
            if h[v] < n as i64 {
                gap_lifted += 1;
            }
            h[v] = if dist_s[v] >= 0 {
                n as i64 + dist_s[v]
            } else {
                2 * n as i64 // unreachable from both terminals: inert
            };
        }
    }
    GlobalRelabelOutcome {
        reached,
        gap_lifted,
    }
}

/// Reusable buffers of the striped relabel: distance planes plus the
/// level-synchronous frontier.  Engines allocate one per solve so the
/// queues and outboxes survive across the periodic relabels.
#[derive(Debug, Default)]
pub struct RelabelScratch {
    dist: Vec<i32>,
    dist_s: Vec<i32>,
    frontier: StripedFrontier,
    stripe_gap: Vec<u64>,
    /// Per-stripe height-bucket tallies (flat `ns * n`) for the
    /// bucket-counting write-back.
    stripe_counts: Vec<u32>,
    /// Merged bucket counts handed to the caller's [`GapBuckets`].
    bucket_counts: Vec<u32>,
    /// Per-stripe lift tallies for [`gap_lift_striped`].
    pub(crate) stripe_lift: Vec<u64>,
    /// One "chosen path" debug log per scratch lifetime (one per solve).
    logged: bool,
}

/// Stripe-parallel twin of [`global_relabel`], bit-exact at any stripe
/// count and on any [`Lanes`]: both reverse BFS passes run
/// level-synchronously on the [`StripedFrontier`], and the height
/// write-back (with gap counting) is a parallel sweep over the same
/// stripes.
pub fn global_relabel_striped(
    g: &FlowNetwork,
    h: &mut [i64],
    scratch: &mut RelabelScratch,
    lanes: &Lanes<'_>,
) -> GlobalRelabelOutcome {
    global_relabel_striped_with_buckets(g, h, scratch, lanes, None)
}

/// [`global_relabel_striped`], optionally refreshing the caller's
/// [`GapBuckets`] as a by-product: every write-back stripe tallies its
/// own chunk's fresh heights into a private counter slice, and the
/// tallies merge in one owner pass over disjoint bucket ranges — the
/// gap structure is rebuilt without a second sequential O(n) scan.
pub fn global_relabel_striped_with_buckets(
    g: &FlowNetwork,
    h: &mut [i64],
    scratch: &mut RelabelScratch,
    lanes: &Lanes<'_>,
    buckets: Option<&mut GapBuckets>,
) -> GlobalRelabelOutcome {
    let n = g.node_count();
    debug_assert_eq!(h.len(), n);
    let (s, t) = (g.source(), g.sink());
    let stripes = Stripes::new(n, lanes.width() * 2);
    let ns = stripes.n_stripes();
    let sl = stripes.stripe_len();

    let RelabelScratch {
        dist,
        dist_s,
        frontier,
        stripe_gap,
        stripe_counts,
        bucket_counts,
        ..
    } = scratch;

    // Pass 1: distance-to-sink over reverse residual arcs.  The source
    // is assigned a distance when reached (it counts as `reached`, like
    // the sequential pass) but never expanded.
    dist.clear();
    dist.resize(n, -1);
    frontier.reset(stripes);
    dist[t] = 0;
    frontier.seed(t);
    let neigh = |u: usize, emit: &mut dyn FnMut(usize)| {
        for &e in g.out_edges(u) {
            if g.residual(e ^ 1) > 0 {
                emit(g.edge_head(e));
            }
        }
    };
    let assigned = frontier.run(dist, 0, Some(s), &neigh, lanes);
    let reached = 1 + assigned as usize;

    // Pass 2 (Cherkassky–Goldberg): distance-to-source for nodes the
    // sink BFS missed, masked by the (now read-only) sink distances.
    dist_s.clear();
    dist_s.resize(n, -1);
    frontier.reset(stripes);
    dist_s[s] = 0;
    frontier.seed(s);
    {
        let dist_ro: &[i32] = dist;
        let neigh_s = |u: usize, emit: &mut dyn FnMut(usize)| {
            for &e in g.out_edges(u) {
                let v = g.edge_head(e);
                if dist_ro[v] < 0 && g.residual(e ^ 1) > 0 {
                    emit(v);
                }
            }
        };
        frontier.run(dist_s, 0, None, &neigh_s, lanes);
    }

    // Write-back, gap counting per stripe — and, when the caller keeps
    // gap buckets, a per-stripe height-bucket tally as a by-product.
    let counting = buckets.is_some();
    stripe_gap.clear();
    stripe_gap.resize(ns, 0);
    stripe_counts.clear();
    if counting {
        stripe_counts.resize(ns * n, 0);
    }
    {
        let mut count_chunks: Vec<Option<&mut [u32]>> = if counting {
            stripe_counts.chunks_mut(n).map(Some).collect()
        } else {
            (0..ns).map(|_| None).collect()
        };
        let mut tasks = Vec::with_capacity(ns);
        let iter = h
            .chunks_mut(sl)
            .zip(dist.chunks(sl))
            .zip(dist_s.chunks(sl))
            .zip(stripe_gap.iter_mut())
            .zip(count_chunks.drain(..))
            .enumerate();
        for (o, ((((h, d), ds), gap), counts)) in iter {
            tasks.push((o * sl, h, d, ds, gap, counts));
        }
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for group in deal(tasks, lanes.width()) {
            jobs.push(Box::new(move || {
                for (base, h, d, ds, gap, mut counts) in group {
                    for lc in 0..h.len() {
                        let v = base + lc;
                        if v == s {
                            h[lc] = n as i64;
                        } else if d[lc] >= 0 {
                            h[lc] = d[lc] as i64;
                            if let Some(c) = counts.as_deref_mut() {
                                // Distances to the sink are < n by
                                // construction (simple residual paths).
                                c[d[lc] as usize] += 1;
                            }
                        } else {
                            if h[lc] < n as i64 {
                                *gap += 1;
                            }
                            h[lc] = if ds[lc] >= 0 {
                                n as i64 + ds[lc] as i64
                            } else {
                                2 * n as i64
                            };
                        }
                    }
                }
            }));
        }
        lanes.run(jobs);
    }

    if let Some(buckets) = buckets {
        // Single owner pass: disjoint bucket ranges are dealt to the
        // lanes and each owner sums the per-stripe tallies for its own
        // range — no atomics, no second sequential scan.
        bucket_counts.clear();
        bucket_counts.resize(n, 0);
        {
            let stripe_counts: &[u32] = stripe_counts;
            let merge = Stripes::new(n, lanes.width() * 2);
            let msl = merge.stripe_len();
            let mut tasks = Vec::with_capacity(merge.n_stripes());
            for (o, out) in bucket_counts.chunks_mut(msl).enumerate() {
                tasks.push((o * msl, out));
            }
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for group in deal(tasks, lanes.width()) {
                jobs.push(Box::new(move || {
                    for (base, out) in group {
                        for p in 0..ns {
                            let col = &stripe_counts[p * n + base..p * n + base + out.len()];
                            for (o, c) in out.iter_mut().zip(col) {
                                *o += c;
                            }
                        }
                    }
                }));
            }
            lanes.run(jobs);
        }
        buckets.adopt(bucket_counts, n);
    }

    GlobalRelabelOutcome {
        reached,
        gap_lifted: stripe_gap.iter().sum::<u64>() as usize,
    }
}

/// What the engines call: the striped pass on the lent pool for large
/// instances, the sequential queue BFS otherwise.  Identical results
/// either way — this is purely a latency switch.
///
/// This is also where the CSR engines' global-relabel time enters the
/// observability spine: one chokepoint instead of seven call sites
/// across fifo/highest/hybrid.  Global relabels run every Θ(n)
/// relabels, so the Timer read plus one registry touch is far off the
/// push/relabel hot path.
pub fn global_relabel_auto(
    g: &FlowNetwork,
    h: &mut [i64],
    pool: Option<&WorkerPool>,
    scratch: &mut RelabelScratch,
) -> GlobalRelabelOutcome {
    global_relabel_auto_with(g, h, pool, scratch, STRIPED_RELABEL_MIN_NODES, None)
}

/// [`global_relabel_auto`] with an explicit striped-path size gate
/// (`[maxflow] striped_relabel_min_nodes`; default
/// [`STRIPED_RELABEL_MIN_NODES`]) and an optional [`GapBuckets`]
/// refresh.  The chosen path is logged once per scratch lifetime (one
/// line per solve) at debug level.
pub fn global_relabel_auto_with(
    g: &FlowNetwork,
    h: &mut [i64],
    pool: Option<&WorkerPool>,
    scratch: &mut RelabelScratch,
    min_nodes: usize,
    buckets: Option<&mut GapBuckets>,
) -> GlobalRelabelOutcome {
    let t = crate::util::Timer::start();
    let striped = pool.is_some() && g.node_count() >= min_nodes;
    if !scratch.logged {
        crate::log_debug!(
            "global relabel path: {} (n={}, gate={}, pool={})",
            if striped { "striped" } else { "sequential" },
            g.node_count(),
            min_nodes,
            pool.is_some()
        );
        scratch.logged = true;
    }
    let out = if striped {
        let lanes = Lanes::Pool(pool.expect("striped implies pool"));
        global_relabel_striped_with_buckets(g, h, scratch, &lanes, buckets)
    } else {
        let out = global_relabel(g, h);
        if let Some(b) = buckets {
            b.rebuild(h);
        }
        out
    };
    crate::obs::record_phase_secs("csr", crate::obs::Phase::GlobalRelabel, t.elapsed());
    out
}

/// Cancel height-violating residual arcs (`h(u) > h(v) + 1`) by pushing
/// the full residual through them — Algorithm 4.8 lines 1-6.  Needed when
/// a CYCLE-bounded engine stops mid-stream before recomputing heights.
/// Returns the number of cancelled arcs.
pub fn cancel_violations(g: &mut FlowNetwork, h: &[i64], e: &mut [i64]) -> usize {
    let mut cancelled = 0;
    for u in 0..g.node_count() {
        for idx in 0..g.out_edges(u).len() {
            let eid = g.out_edges(u)[idx];
            let v = g.edge_head(eid);
            let r = g.residual(eid);
            if r > 0 && h[u] > h[v] + 1 {
                g.push(eid, r);
                e[u] -= r;
                e[v] += r;
                cancelled += 1;
            }
        }
    }
    cancelled
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::NetworkBuilder;

    #[test]
    fn distances_on_fresh_chain() {
        // s -> a -> b -> t, all residual: dist(t)=0, b=1, a=2, s stays n.
        let mut b = NetworkBuilder::new(4, 0, 3);
        b.add_edge(0, 1, 5, 0);
        b.add_edge(1, 2, 5, 0);
        b.add_edge(2, 3, 5, 0);
        let g = b.build().unwrap();
        let mut h = vec![0i64; 4];
        let out = global_relabel(&g, &mut h);
        assert_eq!(h, vec![4, 2, 1, 0]);
        assert_eq!(out.reached, 4);
        assert_eq!(out.gap_lifted, 0);
    }

    #[test]
    fn saturated_arc_breaks_reachability() {
        let mut b = NetworkBuilder::new(4, 0, 3);
        let e01 = b.add_edge(0, 1, 5, 0);
        let e13 = b.add_edge(1, 3, 5, 0);
        b.add_edge(0, 2, 5, 0); // 2 has no arc to t
        let mut g = b.build().unwrap();
        g.push(e01, 5);
        g.push(e13, 5); // arc 1->3 saturated: 1 now reachable only via 3->1 mate
        let mut h = vec![0i64; 4];
        let out = global_relabel(&g, &mut h);
        // Arc 1->3 is saturated so neither 1 nor 2 reaches t; both reach
        // the source through residual reverse arcs and get n + dist_s.
        assert_eq!(h[3], 0);
        assert_eq!(h[1], 5); // n + 1 (residual arc 1->0 via the mate)
        assert_eq!(h[2], 8); // 2n: no flow ever reached 2, inert
        assert_eq!(out.gap_lifted, 2);
    }

    #[test]
    fn striped_twin_matches_sequential_on_unit_cases() {
        // The two unit instances above, plus a partially pushed chain,
        // across lane kinds and (via lane width) stripe counts.
        let cases: Vec<FlowNetwork> = {
            let mut v = Vec::new();
            let mut b = NetworkBuilder::new(4, 0, 3);
            b.add_edge(0, 1, 5, 0);
            b.add_edge(1, 2, 5, 0);
            b.add_edge(2, 3, 5, 0);
            v.push(b.build().unwrap());
            let mut b = NetworkBuilder::new(4, 0, 3);
            let e01 = b.add_edge(0, 1, 5, 0);
            let e13 = b.add_edge(1, 3, 5, 0);
            b.add_edge(0, 2, 5, 0);
            let mut g = b.build().unwrap();
            g.push(e01, 5);
            g.push(e13, 5);
            v.push(g);
            v
        };
        let pool = WorkerPool::new(3);
        for (i, g) in cases.iter().enumerate() {
            let mut h_seq = vec![0i64; g.node_count()];
            let want = global_relabel(g, &mut h_seq);
            for lanes in [Lanes::Seq, Lanes::Scoped { threads: 3 }, Lanes::Pool(&pool)] {
                let mut h_par = vec![0i64; g.node_count()];
                let mut scratch = RelabelScratch::default();
                let got = global_relabel_striped(g, &mut h_par, &mut scratch, &lanes);
                assert_eq!(h_par, h_seq, "case {i} lanes={}", lanes.width());
                assert_eq!(got, want, "case {i} outcome");
                // Scratch reuse: a second run must be idempotent.
                let again = global_relabel_striped(g, &mut h_par, &mut scratch, &lanes);
                assert_eq!(h_par, h_seq, "case {i} reuse");
                assert_eq!(again.reached, want.reached, "case {i} reuse outcome");
            }
        }
    }

    #[test]
    fn auto_routes_by_size_and_stays_exact() {
        // A long chain over the striped threshold: auto must take the
        // striped path on a pool and still match the sequential twin.
        let n = STRIPED_RELABEL_MIN_NODES + 20;
        let mut b = NetworkBuilder::new(n, 0, n - 1);
        for i in 0..n - 1 {
            b.add_edge(i, i + 1, 3, 1);
        }
        let g = b.build().unwrap();
        let mut h_seq = vec![0i64; n];
        let want = global_relabel(&g, &mut h_seq);
        let pool = WorkerPool::new(4);
        let mut h_auto = vec![0i64; n];
        let mut scratch = RelabelScratch::default();
        let got = global_relabel_auto(&g, &mut h_auto, Some(&pool), &mut scratch);
        assert_eq!(h_auto, h_seq);
        assert_eq!(got, want);
    }

    #[test]
    fn gap_buckets_track_relabels_and_detect_gaps() {
        // n = 6; heights 0..n tracked.  One node each at 1 and 2, two
        // at 3; source parked at n.
        let h = vec![6, 1, 2, 3, 3, 0];
        let mut b = GapBuckets::default();
        b.rebuild(&h);
        assert_eq!(b.count(0), 1);
        assert_eq!(b.count(1), 1);
        assert_eq!(b.count(2), 1);
        assert_eq!(b.count(3), 2);
        assert_eq!(b.count(6), 0); // out of tracked range
        // Relabel within the tracked range: no gap while 2 stays
        // occupied... moving the node out of 2 empties it.
        assert_eq!(b.on_relabel(3, 4), None);
        assert_eq!(b.on_relabel(2, 4), Some(2));
        assert_eq!(b.count(4), 2);
        // Bucket 0 can never gate a gap.
        let mut b0 = GapBuckets::default();
        b0.rebuild(&[0, 3]); // n = 2: only height 0 and 1 tracked... 3 untracked
        assert_eq!(b0.on_relabel(0, 1), None);
        // Leaving the tracked range decrements only the old bucket.
        let mut b1 = GapBuckets::default();
        b1.rebuild(&[0, 1, 1, 5]);
        assert_eq!(b1.on_relabel(1, 9), None);
        assert_eq!(b1.on_relabel(1, 9), Some(1));
        assert_eq!(b1.count(1), 0);
    }

    #[test]
    fn gap_lift_twins_lift_exactly_the_stranded_set() {
        // A manufactured mid-solve gap: bucket 4 is empty, nodes sit at
        // 2, 3 (below: stay), 5, 7 (stranded: lift), n=10 (source:
        // stay), 11 (already above n: stay).
        let h0: Vec<i64> = vec![10, 2, 3, 5, 7, 11, 3, 9, 0, 5];
        let n = h0.len() as i64;
        let gap_h = 4i64;
        let want: Vec<i64> = h0
            .iter()
            .map(|&hv| if hv > gap_h && hv < n { n + 1 } else { hv })
            .collect();
        let stranded = h0.iter().filter(|&&hv| hv > gap_h && hv < n).count();
        assert_eq!(stranded, 4);

        let mut h_seq = h0.clone();
        let mut b_seq = GapBuckets::default();
        b_seq.rebuild(&h_seq);
        let lifted = gap_lift(&mut h_seq, &mut b_seq, gap_h);
        assert_eq!(lifted, stranded);
        assert_eq!(h_seq, want);
        for d in (gap_h + 1)..n {
            assert_eq!(b_seq.count(d), 0, "bucket {d} not cleared");
        }
        assert_eq!(b_seq.count(2), 1);
        assert_eq!(b_seq.count(3), 2);

        let pool = WorkerPool::new(3);
        for lanes in [Lanes::Seq, Lanes::Scoped { threads: 3 }, Lanes::Pool(&pool)] {
            let mut h_par = h0.clone();
            let mut b_par = GapBuckets::default();
            b_par.rebuild(&h_par);
            let mut stripe_lift = Vec::new();
            let got = gap_lift_striped(&mut h_par, &mut b_par, gap_h, &lanes, &mut stripe_lift);
            assert_eq!(got, stranded, "lanes={}", lanes.width());
            assert_eq!(h_par, want, "lanes={}", lanes.width());
        }
    }

    #[test]
    fn striped_bucket_counting_matches_sequential_rebuild() {
        // Partially pushed chain + the unit cases: the bucket counts
        // produced by the striped write-back must equal a sequential
        // rebuild of the same (identical) heights.
        let n = STRIPED_RELABEL_MIN_NODES + 20;
        let mut b = NetworkBuilder::new(n, 0, n - 1);
        for i in 0..n - 1 {
            b.add_edge(i, i + 1, 3, 1);
        }
        let g = b.build().unwrap();
        let mut h_seq = vec![0i64; n];
        global_relabel(&g, &mut h_seq);
        let mut want = GapBuckets::default();
        want.rebuild(&h_seq);

        let pool = WorkerPool::new(4);
        for lanes in [Lanes::Seq, Lanes::Scoped { threads: 4 }, Lanes::Pool(&pool)] {
            let mut h_par = vec![0i64; n];
            let mut scratch = RelabelScratch::default();
            let mut got = GapBuckets::default();
            global_relabel_striped_with_buckets(&g, &mut h_par, &mut scratch, &lanes, Some(&mut got));
            assert_eq!(h_par, h_seq);
            for d in 0..n as i64 {
                assert_eq!(got.count(d), want.count(d), "bucket {d} lanes={}", lanes.width());
            }
        }

        // The auto path with a gate above n must stay sequential and
        // still refresh the buckets.
        let mut h_auto = vec![0i64; n];
        let mut scratch = RelabelScratch::default();
        let mut got = GapBuckets::default();
        global_relabel_auto_with(&g, &mut h_auto, Some(&pool), &mut scratch, n + 1, Some(&mut got));
        assert_eq!(h_auto, h_seq);
        for d in 0..n as i64 {
            assert_eq!(got.count(d), want.count(d), "auto bucket {d}");
        }
    }

    #[test]
    fn cancel_violations_pushes_back() {
        let mut b = NetworkBuilder::new(3, 0, 2);
        let e = b.add_edge(0, 1, 5, 0);
        b.add_edge(1, 2, 1, 0);
        let mut g = b.build().unwrap();
        g.push(e, 5);
        // Pretend node 1 was relabelled sky-high with excess.
        let h = vec![3, 9, 0];
        let mut ex = vec![0i64, 5, 0];
        // Both residual arcs out of node 1 violate: the mate 1->0
        // (h(1)=9 > h(0)+1=4) and 1->2 (h(1)=9 > h(2)+1=1); Algorithm 4.8
        // cancels them all, leaving node 1 with a transient deficit.
        let cancelled = cancel_violations(&mut g, &h, &mut ex);
        assert_eq!(cancelled, 2);
        assert_eq!(ex[1], -1);
        assert_eq!(ex[0], 5);
        assert_eq!(ex[2], 1);
        assert_eq!(g.residual(e), 5); // flow undone
    }
}
