//! The CPU–GPU hybrid scheme of Hong & He (Algorithm 4.6–4.8) on the CSR
//! representation: run `CYCLE` Hong-style push/relabel operations
//! ("device" phase, here executed natively), then return to the "host"
//! for violation cancellation + global relabel + gap, until
//! `e(s) + e(t) = ExcessTotal`.
//!
//! The grid-specialised, PJRT-backed version of the same loop lives in
//! `coordinator::maxflow_driver`; this engine is its general-graph twin
//! and the reference for the E4 CYCLE sweep on CSR instances.

use std::sync::Arc;

use anyhow::Result;

use crate::graph::csr::FlowNetwork;
use crate::service::pool::WorkerPool;
use crate::util::CancelToken;

use super::global_relabel::{
    cancel_violations, gap_lift, gap_lift_striped, global_relabel_auto_with, GapBuckets,
    RelabelScratch, STRIPED_RELABEL_MIN_NODES,
};
use super::{FlowStats, MaxFlowSolver, ScalingMode};
use crate::parallel::Lanes;

#[derive(Debug, Clone)]
pub struct Hybrid {
    /// Device-phase operation budget between host rounds (paper: 7000).
    pub cycle: u64,
    /// Run the global relabel + gap heuristics between rounds.
    pub heuristics: bool,
    /// Incremental gap relabeling inside the device phase (bucket
    /// occupancy maintained at every Hong relabel; batched lift when a
    /// bucket below `n` empties).  Off by default.
    pub gap: bool,
    /// Δ-phase excess scaling for the device sweep (see
    /// [`ScalingMode`]); `Off` by default.
    pub scaling: ScalingMode,
    /// Node-count gate for the striped relabel / gap-lift paths;
    /// mirrors `[maxflow] striped_relabel_min_nodes`.
    pub striped_relabel_min_nodes: usize,
    /// Worker pool for the striped host-round relabel on large
    /// instances (the general-graph twin of the grid solver's striped
    /// host rounds).
    pub relabel_pool: Option<Arc<WorkerPool>>,
    /// Cooperative cancellation, polled once per host round.
    pub cancel: Option<CancelToken>,
}

impl Default for Hybrid {
    fn default() -> Self {
        Self {
            cycle: 7000,
            heuristics: true,
            gap: false,
            scaling: ScalingMode::Off,
            striped_relabel_min_nodes: STRIPED_RELABEL_MIN_NODES,
            relabel_pool: None,
            cancel: None,
        }
    }
}

impl Hybrid {
    pub fn with_cycle(cycle: u64) -> Self {
        Self {
            cycle,
            ..Self::default()
        }
    }

    pub fn no_heuristics(cycle: u64) -> Self {
        Self {
            cycle,
            heuristics: false,
            ..Self::default()
        }
    }

    pub fn with_gap(mut self) -> Self {
        self.gap = true;
        self
    }

    pub fn with_scaling(mut self, mode: ScalingMode) -> Self {
        self.scaling = mode;
        self
    }

    pub fn with_striped_min_nodes(mut self, min_nodes: usize) -> Self {
        self.striped_relabel_min_nodes = min_nodes;
        self
    }

    pub fn with_relabel_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.relabel_pool = Some(pool);
        self
    }

    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Batched gap lift, striped over the lent pool on large instances.
    fn lift_gap(
        &self,
        h: &mut [i64],
        buckets: &mut GapBuckets,
        gap_h: i64,
        rscratch: &mut RelabelScratch,
    ) -> usize {
        if let Some(pool) = self.relabel_pool.as_deref() {
            if h.len() >= self.striped_relabel_min_nodes {
                return gap_lift_striped(
                    h,
                    buckets,
                    gap_h,
                    &Lanes::Pool(pool),
                    &mut rscratch.stripe_lift,
                );
            }
        }
        gap_lift(h, buckets, gap_h)
    }
}

impl MaxFlowSolver for Hybrid {
    fn name(&self) -> &'static str {
        match (self.heuristics, self.gap, self.scaling == ScalingMode::Delta) {
            (_, true, true) => "hybrid+gap+scale",
            (_, true, false) => "hybrid+gap",
            (_, false, true) => "hybrid+scale",
            (true, false, false) => "hybrid-cycle",
            (false, false, false) => "hybrid-noheur",
        }
    }

    fn solve(&self, g: &mut FlowNetwork) -> Result<FlowStats> {
        let mut stats = FlowStats::default();
        let n = g.node_count();
        let (s, t) = (g.source(), g.sink());

        let mut h = vec![0i64; n];
        let mut excess = vec![0i64; n];
        h[s] = n as i64;
        let mut excess_total = 0i64;
        for idx in 0..g.out_edges(s).len() {
            let e = g.out_edges(s)[idx];
            let c = g.residual(e);
            if c > 0 {
                let v = g.edge_head(e);
                g.push(e, c);
                excess[v] += c;
                excess_total += c;
            }
        }

        // e(s) counts flow returned to the source.
        let mut rscratch = RelabelScratch::default();
        let mut buckets = if self.gap { Some(GapBuckets::default()) } else { None };
        if let Some(b) = buckets.as_mut() {
            b.rebuild(&h);
        }
        let height_cap = 4 * n as i64;
        while excess[s] + excess[t] < excess_total {
            // Host-round boundary: the same safe give-up point as the
            // grid solver's.
            if let Some(c) = &self.cancel {
                c.check()?;
            }
            // Δ-phase admission for the device sweep: only nodes with
            // excess ≥ Δ take Hong steps; Δ halves when a sweep at the
            // current threshold makes no progress.  Δ = 1 (the default)
            // is exactly the pre-scaling `excess > 0` admission.
            let mut delta = 1i64;
            if self.scaling == ScalingMode::Delta {
                let max_e = (0..n)
                    .filter(|&v| v != s && v != t)
                    .map(|v| excess[v])
                    .max()
                    .unwrap_or(0);
                while delta <= max_e / 2 {
                    delta *= 2;
                }
            }
            // "Device" phase: CYCLE Hong operations, round-robin.
            let mut ops = 0u64;
            let mut progress = true;
            while ops < self.cycle && progress {
                progress = false;
                for x in 0..n {
                    if x == s || x == t || excess[x] < delta {
                        continue;
                    }
                    // Lowest residual neighbour (Algorithm 4.5 lines 4-9).
                    let mut best_h = i64::MAX;
                    let mut best_e = None;
                    for &eid in g.out_edges(x) {
                        if g.residual(eid) > 0 {
                            let hy = h[g.edge_head(eid)];
                            if hy < best_h {
                                best_h = hy;
                                best_e = Some(eid);
                            }
                        }
                    }
                    let Some(eid) = best_e else { continue };
                    if h[x] > best_h {
                        let amt = excess[x].min(g.residual(eid));
                        let y = g.edge_head(eid);
                        g.push(eid, amt);
                        excess[x] -= amt;
                        excess[y] += amt;
                        stats.pushes += 1;
                    } else if best_h < height_cap {
                        let old_h = h[x];
                        h[x] = best_h + 1;
                        stats.relabels += 1;
                        if let Some(b) = buckets.as_mut() {
                            if let Some(gap_h) = b.on_relabel(old_h, h[x]) {
                                let lifted = self.lift_gap(&mut h, b, gap_h, &mut rscratch);
                                if lifted > 0 {
                                    stats.gap_relabels += 1;
                                    stats.gap_nodes += lifted as u64;
                                }
                            }
                        }
                    } else {
                        continue;
                    }
                    ops += 1;
                    progress = true;
                    if ops >= self.cycle {
                        break;
                    }
                }
                if !progress && delta > 1 {
                    delta /= 2;
                    progress = true;
                }
            }

            // "Host" phase (Algorithm 4.8 global relabeling):
            stats.rounds += 1;
            if self.heuristics {
                let cancelled = cancel_violations(g, &h, &mut excess);
                let _ = cancelled;
                let out = global_relabel_auto_with(
                    g,
                    &mut h,
                    self.relabel_pool.as_deref(),
                    &mut rscratch,
                    self.striped_relabel_min_nodes,
                    buckets.as_mut(),
                );
                stats.global_relabels += 1;
                stats.gap_nodes += out.gap_lifted as u64;
            } else if !progress && ops == 0 {
                // Without heuristics the device phase alone must finish;
                // if no operation applied and the loop condition still
                // holds, excess is stuck (cannot happen per theory, but
                // guard against an infinite loop).
                anyhow::bail!("hybrid without heuristics wedged");
            }
        }

        stats.value = excess[t];
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::assert_max_flow;

    #[test]
    fn solves_clrs_across_cycles() {
        for cycle in [1, 7, 100, 7000] {
            let mut g = crate::maxflow::tests::clrs();
            let stats = Hybrid::with_cycle(cycle).solve(&mut g).unwrap();
            assert_eq!(stats.value, 23, "cycle={cycle}");
            assert_max_flow(&g, 23).unwrap();
        }
    }

    #[test]
    fn smaller_cycle_means_more_host_rounds() {
        let mut g1 = crate::maxflow::tests::clrs();
        let small = Hybrid::with_cycle(2).solve(&mut g1).unwrap();
        let mut g2 = crate::maxflow::tests::clrs();
        let large = Hybrid::with_cycle(10_000).solve(&mut g2).unwrap();
        assert!(small.rounds >= large.rounds);
    }

    #[test]
    fn works_without_heuristics() {
        let mut g = crate::maxflow::tests::clrs();
        let stats = Hybrid::no_heuristics(1_000_000).solve(&mut g).unwrap();
        assert_eq!(stats.value, 23);
    }

    #[test]
    fn gap_and_scaling_variants_solve_clrs() {
        for engine in [
            Hybrid::default().with_gap(),
            Hybrid::default().with_scaling(ScalingMode::Delta),
            Hybrid::default().with_gap().with_scaling(ScalingMode::Delta),
            Hybrid::with_cycle(3).with_gap(),
            Hybrid::no_heuristics(1_000_000).with_gap(),
        ] {
            let mut g = crate::maxflow::tests::clrs();
            let stats = engine.solve(&mut g).unwrap();
            assert_eq!(stats.value, 23, "{}", engine.name());
            assert_max_flow(&g, 23).unwrap();
        }
    }

    #[test]
    fn device_phase_gap_fires_without_host_heuristics() {
        // s → a → b → t with the sink arc as bottleneck: with host
        // heuristics off, only the in-device gap machinery can
        // shortcut the stranded nodes' climb back to the source.  The
        // round-robin Hong sweep empties bucket 1 when a relabels past
        // it, lifting both a and b in one batch.
        let mut b = crate::graph::csr::NetworkBuilder::new(4, 0, 3);
        b.add_edge(0, 1, 5, 0);
        b.add_edge(1, 2, 5, 0);
        b.add_edge(2, 3, 2, 0);
        let mut g = b.build().unwrap();
        let stats = Hybrid::no_heuristics(1_000_000)
            .with_gap()
            .solve(&mut g)
            .unwrap();
        assert_eq!(stats.value, 2);
        assert_max_flow(&g, 2).unwrap();
        assert!(stats.gap_relabels > 0, "stats: {stats:?}");
        assert!(stats.gap_nodes >= 2 * stats.gap_relabels);
    }
}
