//! FIFO push-relabel (Goldberg–Tarjan), the paper's §4.1 generic algorithm
//! with the §4.2 heuristics: active nodes are discharged in FIFO order;
//! a global relabel (BFS + gap) runs every `relabel_freq * n` relabels.
//! Opt-in extras on the same loop: incremental gap relabeling
//! ([`GapBuckets`]) and Δ-phase excess scaling ([`ScalingMode`]).

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use crate::graph::csr::FlowNetwork;
use crate::parallel::Lanes;
use crate::service::pool::WorkerPool;
use crate::util::CancelToken;

use super::global_relabel::{
    gap_lift, gap_lift_striped, global_relabel_auto_with, GapBuckets, RelabelScratch,
    STRIPED_RELABEL_MIN_NODES,
};
use super::{FlowStats, MaxFlowSolver, ScalingMode};

/// FIFO push-relabel engine.
#[derive(Debug, Clone)]
pub struct FifoPushRelabel {
    /// Run the global relabel heuristic every `freq * n` relabels;
    /// `None` disables it (the "generic" row of the E3 ablation).
    pub global_relabel_freq: Option<f64>,
    /// Incremental gap relabeling: maintain height-bucket occupancy at
    /// every relabel and, when a bucket `0 < d < n` empties, lift every
    /// node stranded above the gap to `n + 1` in one batched pass.
    /// Off by default (bit-exact with the pre-gap engine).
    pub gap: bool,
    /// Δ-phase excess scaling (see [`ScalingMode`]); `Off` by default.
    pub scaling: ScalingMode,
    /// Node-count gate below which the striped relabel / gap-lift paths
    /// fall back to the sequential ones.  Mirrors
    /// `[maxflow] striped_relabel_min_nodes` in the service config.
    pub striped_relabel_min_nodes: usize,
    /// Worker pool the periodic global relabel borrows on large
    /// instances (`None` = always the sequential BFS; results are
    /// identical either way).
    pub relabel_pool: Option<Arc<WorkerPool>>,
    /// Cooperative cancellation, polled at the global-relabel entry
    /// points (the engine's natural round boundaries).
    pub cancel: Option<CancelToken>,
}

impl Default for FifoPushRelabel {
    fn default() -> Self {
        Self {
            global_relabel_freq: Some(1.0),
            gap: false,
            scaling: ScalingMode::Off,
            striped_relabel_min_nodes: STRIPED_RELABEL_MIN_NODES,
            relabel_pool: None,
            cancel: None,
        }
    }
}

impl FifoPushRelabel {
    pub fn generic() -> Self {
        Self {
            global_relabel_freq: None,
            ..Self::default()
        }
    }

    pub fn with_gap(mut self) -> Self {
        self.gap = true;
        self
    }

    pub fn with_scaling(mut self, mode: ScalingMode) -> Self {
        self.scaling = mode;
        self
    }

    pub fn with_striped_min_nodes(mut self, min_nodes: usize) -> Self {
        self.striped_relabel_min_nodes = min_nodes;
        self
    }

    pub fn with_relabel_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.relabel_pool = Some(pool);
        self
    }

    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Batched gap lift, striped over the lent pool on large instances.
    fn lift_gap(
        &self,
        h: &mut [i64],
        buckets: &mut GapBuckets,
        gap_h: i64,
        rscratch: &mut RelabelScratch,
    ) -> usize {
        if let Some(pool) = self.relabel_pool.as_deref() {
            if h.len() >= self.striped_relabel_min_nodes {
                return gap_lift_striped(
                    h,
                    buckets,
                    gap_h,
                    &Lanes::Pool(pool),
                    &mut rscratch.stripe_lift,
                );
            }
        }
        gap_lift(h, buckets, gap_h)
    }
}

impl MaxFlowSolver for FifoPushRelabel {
    fn name(&self) -> &'static str {
        match (
            self.global_relabel_freq.is_some(),
            self.gap,
            self.scaling == ScalingMode::Delta,
        ) {
            (true, false, false) => "fifo+global",
            (false, false, false) => "fifo-generic",
            (_, true, false) => "fifo+gap",
            (_, false, true) => "fifo+scale",
            (_, true, true) => "fifo+gap+scale",
        }
    }

    fn solve(&self, g: &mut FlowNetwork) -> Result<FlowStats> {
        let mut stats = FlowStats::default();
        let n = g.node_count();
        let (s, t) = (g.source(), g.sink());

        let mut h = vec![0i64; n];
        let mut excess = vec![0i64; n];
        let mut in_queue = vec![false; n];
        let mut queue = VecDeque::new();

        // Init (Algorithm 4.1): saturate source arcs.
        h[s] = n as i64;
        for idx in 0..g.out_edges(s).len() {
            let e = g.out_edges(s)[idx];
            let c = g.residual(e);
            if c > 0 {
                let v = g.edge_head(e);
                g.push(e, c);
                excess[v] += c;
                excess[s] -= c;
                stats.pushes += 1;
                if v != t && v != s && !in_queue[v] {
                    in_queue[v] = true;
                    queue.push_back(v);
                }
            }
        }
        let mut rscratch = RelabelScratch::default();
        let mut buckets = if self.gap { Some(GapBuckets::default()) } else { None };
        if let Some(c) = &self.cancel {
            c.check()?;
        }
        if self.global_relabel_freq.is_some() {
            // Initial exact heights help as much as the periodic ones.
            let out = global_relabel_auto_with(
                g,
                &mut h,
                self.relabel_pool.as_deref(),
                &mut rscratch,
                self.striped_relabel_min_nodes,
                buckets.as_mut(),
            );
            stats.global_relabels += 1;
            stats.gap_nodes += out.gap_lifted as u64;
        } else if let Some(b) = buckets.as_mut() {
            b.rebuild(&h);
        }

        self.discharge(
            g,
            &mut h,
            &mut excess,
            &mut queue,
            &mut in_queue,
            &mut buckets,
            &mut rscratch,
            &mut stats,
        )?;

        stats.value = excess[t];
        Ok(stats)
    }
}

impl FifoPushRelabel {
    /// Warm resume: continue the FIFO engine from an arbitrary preflow
    /// already stored in `g`'s residuals, with `excess` tracking each
    /// node's outstanding excess (interior entries must be
    /// non-negative — the repair in [`crate::maxflow::warm`] guarantees
    /// it).  Source arcs are re-saturated first (edits may have opened
    /// residual capacity there; Hong's Init applied to the difference)
    /// and heights are rebuilt from scratch by an exact global relabel —
    /// whatever labeling the previous run ended with is stale after a
    /// repair.  The returned `value` is read off the sink's incident
    /// residuals, so it includes the flow the warm state already
    /// committed, and equals a cold solve of the edited network exactly
    /// (the max-flow value is unique).
    pub fn resume(&self, g: &mut FlowNetwork, excess: &mut [i64]) -> Result<FlowStats> {
        let mut stats = FlowStats::default();
        let n = g.node_count();
        let (s, t) = (g.source(), g.sink());
        assert_eq!(excess.len(), n, "excess length mismatch");

        let mut h = vec![0i64; n];
        h[s] = n as i64;
        let mut in_queue = vec![false; n];
        let mut queue = VecDeque::new();

        for idx in 0..g.out_edges(s).len() {
            let e = g.out_edges(s)[idx];
            let c = g.residual(e);
            if c > 0 {
                let v = g.edge_head(e);
                g.push(e, c);
                excess[v] += c;
                excess[s] -= c;
                stats.pushes += 1;
            }
        }
        for v in 0..n {
            if v != s && v != t && excess[v] > 0 {
                in_queue[v] = true;
                queue.push_back(v);
            }
        }
        if let Some(c) = &self.cancel {
            c.check()?;
        }
        // Always rebuild heights, even for the "generic" configuration:
        // a warm resume has no valid labeling to start from.
        let mut rscratch = RelabelScratch::default();
        let mut buckets = if self.gap { Some(GapBuckets::default()) } else { None };
        let out = global_relabel_auto_with(
            g,
            &mut h,
            self.relabel_pool.as_deref(),
            &mut rscratch,
            self.striped_relabel_min_nodes,
            buckets.as_mut(),
        );
        stats.global_relabels += 1;
        stats.gap_nodes += out.gap_lifted as u64;

        self.discharge(
            g,
            &mut h,
            excess,
            &mut queue,
            &mut in_queue,
            &mut buckets,
            &mut rscratch,
            &mut stats,
        )?;

        stats.value = g
            .out_edges(t)
            .iter()
            .map(|&e| g.residual(e) - g.capacity0(e))
            .sum();
        Ok(stats)
    }

    /// The FIFO discharge loop shared by cold [`MaxFlowSolver::solve`]
    /// and warm [`FifoPushRelabel::resume`].
    #[allow(clippy::too_many_arguments)]
    fn discharge(
        &self,
        g: &mut FlowNetwork,
        h: &mut [i64],
        excess: &mut [i64],
        queue: &mut VecDeque<usize>,
        in_queue: &mut [bool],
        buckets: &mut Option<GapBuckets>,
        rscratch: &mut RelabelScratch,
        stats: &mut FlowStats,
    ) -> Result<()> {
        let n = g.node_count();
        let (s, t) = (g.source(), g.sink());
        let mut cur = vec![0usize; n]; // current-arc pointers
        let relabel_budget = |freq: f64| (freq * n as f64).max(1.0) as u64;
        let mut relabels_since_global = 0u64;

        // Δ-phase excess scaling: admit a node to the queue only while
        // its excess is ≥ Δ; halve Δ when the queue drains.  With Δ = 1
        // (scaling off) the admission test `excess ≥ 1` is exactly the
        // pre-scaling "has excess" condition, so the default engine is
        // bit-identical.
        let mut delta = 1i64;
        if self.scaling == ScalingMode::Delta {
            let max_e = (0..n)
                .filter(|&v| v != s && v != t)
                .map(|v| excess[v])
                .max()
                .unwrap_or(0);
            while delta <= max_e / 2 {
                delta *= 2;
            }
            if delta > 1 {
                // Defer already-queued nodes below the opening Δ; the
                // later phases re-admit them.
                queue.retain(|&v| {
                    let keep = excess[v] >= delta;
                    if !keep {
                        in_queue[v] = false;
                    }
                    keep
                });
            }
        }

        loop {
            while let Some(u) = queue.pop_front() {
                in_queue[u] = false;
                // Discharge u fully.
                while excess[u] > 0 {
                    if h[u] >= 2 * n as i64 {
                        break; // cannot route anywhere anymore (defensive)
                    }
                    let out = g.out_edges(u);
                    if cur[u] == out.len() {
                        // Relabel: minimum neighbouring height + 1.
                        let mut min_h = i64::MAX;
                        for &e in out {
                            if g.residual(e) > 0 {
                                min_h = min_h.min(h[g.edge_head(e)]);
                            }
                        }
                        if min_h == i64::MAX {
                            break; // isolated with excess: stuck by construction
                        }
                        let old_h = h[u];
                        h[u] = min_h + 1;
                        cur[u] = 0;
                        stats.relabels += 1;
                        relabels_since_global += 1;
                        if let Some(b) = buckets.as_mut() {
                            if let Some(gap_h) = b.on_relabel(old_h, h[u]) {
                                let lifted = self.lift_gap(h, b, gap_h, rscratch);
                                if lifted > 0 {
                                    stats.gap_relabels += 1;
                                    stats.gap_nodes += lifted as u64;
                                }
                            }
                        }
                        if let Some(freq) = self.global_relabel_freq {
                            if relabels_since_global >= relabel_budget(freq) {
                                if let Some(c) = &self.cancel {
                                    c.check()?;
                                }
                                let out = global_relabel_auto_with(
                                    g,
                                    h,
                                    self.relabel_pool.as_deref(),
                                    rscratch,
                                    self.striped_relabel_min_nodes,
                                    buckets.as_mut(),
                                );
                                stats.global_relabels += 1;
                                stats.gap_nodes += out.gap_lifted as u64;
                                relabels_since_global = 0;
                            }
                        }
                        continue;
                    }
                    let e = out[cur[u]];
                    let v = g.edge_head(e);
                    if g.residual(e) > 0 && h[u] == h[v] + 1 {
                        let delta_f = excess[u].min(g.residual(e));
                        g.push(e, delta_f);
                        excess[u] -= delta_f;
                        excess[v] += delta_f;
                        stats.pushes += 1;
                        if v != s && v != t && !in_queue[v] && excess[v] >= delta {
                            in_queue[v] = true;
                            queue.push_back(v);
                        }
                    } else {
                        cur[u] += 1;
                    }
                }
            }
            if self.scaling != ScalingMode::Delta || delta <= 1 {
                break;
            }
            delta /= 2;
            stats.rounds += 1;
            for v in 0..n {
                if v != s && v != t && excess[v] >= delta && !in_queue[v] && h[v] < 2 * n as i64 {
                    in_queue[v] = true;
                    queue.push_back(v);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::assert_max_flow;

    #[test]
    fn solves_clrs_with_and_without_heuristic() {
        for engine in [
            FifoPushRelabel::default(),
            FifoPushRelabel::generic(),
            FifoPushRelabel::default().with_gap(),
            FifoPushRelabel::default().with_scaling(ScalingMode::Delta),
            FifoPushRelabel::default()
                .with_gap()
                .with_scaling(ScalingMode::Delta),
            FifoPushRelabel::generic().with_gap(),
        ] {
            let mut g = crate::maxflow::tests::clrs();
            let stats = engine.solve(&mut g).unwrap();
            assert_eq!(stats.value, 23, "{}", engine.name());
            assert_max_flow(&g, 23).unwrap();
        }
    }

    #[test]
    fn heuristic_reduces_relabels_on_deep_chain() {
        // Chain with a dead-end branch: generic wastes relabels.
        let build = || {
            let mut b = crate::graph::csr::NetworkBuilder::new(30, 0, 29);
            for i in 0..29 {
                b.add_edge(i, i + 1, 3, 0);
            }
            // Dead-end spur off node 1 that traps excess.
            b.add_edge(1, 15, 2, 0);
            b.build().unwrap()
        };
        let mut g1 = build();
        let with = FifoPushRelabel::default().solve(&mut g1).unwrap();
        let mut g2 = build();
        let without = FifoPushRelabel::generic().solve(&mut g2).unwrap();
        assert_eq!(with.value, without.value);
        assert!(
            with.work() <= without.work(),
            "heuristic made things worse: {} > {}",
            with.work(),
            without.work()
        );
    }

    #[test]
    fn gap_fires_on_a_manufactured_bottleneck() {
        // s → a → b → t with the sink arc as bottleneck: 3 units of
        // excess must return to the source, and on the way node a's
        // relabel from height 1 to 3 empties bucket 1 while both a and
        // b sit above it — a guaranteed gap event lifting exactly
        // {a, b} to n + 1.  Run the generic+gap configuration (no
        // global relabel) so the gap heuristic is the only batched
        // lift in play.
        let mut b = crate::graph::csr::NetworkBuilder::new(4, 0, 3);
        b.add_edge(0, 1, 5, 0);
        b.add_edge(1, 2, 5, 0);
        b.add_edge(2, 3, 2, 0);
        let mut g = b.build().unwrap();
        let stats = FifoPushRelabel::generic().with_gap().solve(&mut g).unwrap();
        assert_eq!(stats.value, 2);
        assert_max_flow(&g, 2).unwrap();
        assert!(
            stats.gap_relabels > 0,
            "expected at least one gap event, stats: {stats:?}"
        );
        assert!(stats.gap_nodes >= 2 * stats.gap_relabels);
    }

    #[test]
    fn scaling_phases_are_counted_and_value_matches() {
        let build = || {
            let mut b = crate::graph::csr::NetworkBuilder::new(20, 0, 19);
            for i in 0..19 {
                b.add_edge(i, i + 1, 1 << (i % 7), 0);
            }
            b.add_edge(0, 10, 128, 0);
            b.add_edge(10, 19, 64, 0);
            b.build().unwrap()
        };
        let mut g1 = build();
        let base = FifoPushRelabel::default().solve(&mut g1).unwrap();
        let mut g2 = build();
        let scaled = FifoPushRelabel::default()
            .with_scaling(ScalingMode::Delta)
            .solve(&mut g2)
            .unwrap();
        assert_eq!(base.value, scaled.value);
        assert!(scaled.rounds > 0, "Δ-phases should be counted in rounds");
        // Scaling only reorders discharges: the final residual network
        // must still be a maximum flow.
        assert_max_flow(&g2, scaled.value).unwrap();
    }
}
