//! Dinic's algorithm: BFS level graph + DFS blocking flows, `O(V^2 E)` —
//! the strongest sequential augmenting-path baseline in the comparison
//! tables (E2/E3).

use std::collections::VecDeque;

use anyhow::Result;

use crate::graph::csr::FlowNetwork;

use super::{FlowStats, MaxFlowSolver};

pub struct Dinic;

impl Dinic {
    fn bfs_levels(g: &FlowNetwork, levels: &mut [i32]) -> bool {
        levels.iter_mut().for_each(|l| *l = -1);
        let s = g.source();
        levels[s] = 0;
        let mut q = VecDeque::new();
        q.push_back(s);
        while let Some(u) = q.pop_front() {
            for &e in g.out_edges(u) {
                let v = g.edge_head(e);
                if levels[v] < 0 && g.residual(e) > 0 {
                    levels[v] = levels[u] + 1;
                    q.push_back(v);
                }
            }
        }
        levels[g.sink()] >= 0
    }

    /// Iterative DFS pushing a blocking flow; `iter[u]` is the current-arc
    /// pointer into `g.out_edges(u)`.
    fn dfs_augment(
        g: &mut FlowNetwork,
        levels: &[i32],
        iter: &mut [usize],
        pushes: &mut u64,
    ) -> i64 {
        let (s, t) = (g.source(), g.sink());
        let mut path: Vec<u32> = Vec::new();
        let mut total = 0i64;
        loop {
            let u = path
                .last()
                .map(|&e| g.edge_head(e))
                .unwrap_or(s);
            if u == t {
                // Augment along the path.
                let mut bottleneck = i64::MAX;
                for &e in &path {
                    bottleneck = bottleneck.min(g.residual(e));
                }
                for &e in &path {
                    g.push(e, bottleneck);
                    *pushes += 1;
                }
                total += bottleneck;
                // Retreat to the first saturated edge.
                let mut cut = 0;
                for (i, &e) in path.iter().enumerate() {
                    if g.residual(e) == 0 {
                        cut = i;
                        break;
                    }
                }
                path.truncate(cut);
                continue;
            }
            // Advance along an admissible current arc.
            let out = g.out_edges(u);
            let mut advanced = false;
            while iter[u] < out.len() {
                let e = out[iter[u]];
                let v = g.edge_head(e);
                if g.residual(e) > 0 && levels[v] == levels[u] + 1 {
                    path.push(e);
                    advanced = true;
                    break;
                }
                iter[u] += 1;
            }
            if advanced {
                continue;
            }
            // Dead end: retreat (or finish if at the source).
            if let Some(e) = path.pop() {
                let prev = g.edge_head(e ^ 1);
                iter[prev] += 1;
            } else {
                break;
            }
        }
        total
    }
}

impl MaxFlowSolver for Dinic {
    fn name(&self) -> &'static str {
        "dinic"
    }

    fn solve(&self, g: &mut FlowNetwork) -> Result<FlowStats> {
        let mut stats = FlowStats::default();
        let n = g.node_count();
        let mut levels = vec![-1i32; n];
        let mut iter = vec![0usize; n];
        while Self::bfs_levels(g, &mut levels) {
            stats.rounds += 1;
            iter.iter_mut().for_each(|i| *i = 0);
            stats.value += Self::dfs_augment(g, &levels, &mut iter, &mut stats.pushes);
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::NetworkBuilder;
    use crate::graph::validate::assert_max_flow;

    #[test]
    fn solves_clrs() {
        let mut g = crate::maxflow::tests::clrs();
        let stats = Dinic.solve(&mut g).unwrap();
        assert_eq!(stats.value, 23);
        assert_max_flow(&g, 23).unwrap();
    }

    #[test]
    fn phases_bounded_by_paths() {
        // Long chain: one phase suffices.
        let mut b = NetworkBuilder::new(10, 0, 9);
        for i in 0..9 {
            b.add_edge(i, i + 1, 5, 0);
        }
        let mut g = b.build().unwrap();
        let stats = Dinic.solve(&mut g).unwrap();
        assert_eq!(stats.value, 5);
        assert!(stats.rounds <= 2);
    }

    #[test]
    fn bipartite_unit_graph() {
        // 3x3 unit bipartite, perfect matching flow = 3.
        let mut b = NetworkBuilder::new(8, 0, 7);
        for x in 1..=3 {
            b.add_edge(0, x, 1, 0);
            b.add_edge(x + 3, 7, 1, 0);
        }
        for x in 1..=3 {
            for y in 4..=6 {
                b.add_edge(x, y, 1, 0);
            }
        }
        let mut g = b.build().unwrap();
        let stats = Dinic.solve(&mut g).unwrap();
        assert_eq!(stats.value, 3);
        assert_max_flow(&g, 3).unwrap();
    }
}
