//! Two-label image segmentation through the full §4 pipeline:
//! image -> binary MRF (intensity unaries + contrast-sensitive Potts) ->
//! KZ grid network -> hybrid push-relabel -> min cut -> labels.

use anyhow::Result;

use crate::graph::validate::min_cut_side;
use crate::gridflow::{GridExecutor, HybridGridSolver};
use crate::maxflow::{dinic::Dinic, MaxFlowSolver};

use super::kz::{build_kz_network, labels_from_cut};
use super::mrf::{BinaryMrf, PairwiseTerm};

/// Segmentation output.
#[derive(Debug, Clone)]
pub struct SegmentationResult {
    /// 0 = background, 1 = foreground, row-major.
    pub labels: Vec<u8>,
    /// MAP energy of the labelling.
    pub energy: i64,
    /// The min-cut / max-flow value.
    pub flow: i64,
    /// Foreground pixel count.
    pub foreground: usize,
}

/// Build the MRF for an intensity image: bright pixels prefer label 1.
pub fn image_mrf(img: &[u8], height: usize, width: usize, lambda: i64) -> BinaryMrf {
    assert_eq!(img.len(), height * width);
    let mut mrf = BinaryMrf::new(height, width);
    let sigma = 30.0f64;
    for (p, &v) in img.iter().enumerate() {
        let v = v as i64;
        // Class means: bg = 60, fg = 200 (matches workloads::grid_gen).
        mrf.unary[p] = ((v - 60).abs() / 4, (v - 200).abs() / 4);
    }
    let contrast = |a: u8, b: u8| -> PairwiseTerm {
        let d = (a as f64 - b as f64).abs();
        PairwiseTerm::potts(((lambda as f64) * (-d / sigma).exp()).round() as i64 + 1)
    };
    for i in 0..height {
        for j in 0..width {
            let p = mrf.cell(i, j);
            if i + 1 < height {
                mrf.pair_s[p] = Some(contrast(img[p], img[(i + 1) * width + j]));
            }
            if j + 1 < width {
                mrf.pair_e[p] = Some(contrast(img[p], img[p + 1]));
            }
        }
    }
    mrf
}

/// Segment with the sequential CSR baseline (Dinic) — used for parity.
pub fn segment_image_baseline(
    img: &[u8],
    height: usize,
    width: usize,
    lambda: i64,
) -> Result<SegmentationResult> {
    let mrf = image_mrf(img, height, width, lambda);
    let kz = build_kz_network(&mrf)?;
    let mut g = kz.network.to_flow_network();
    let stats = Dinic.solve(&mut g)?;
    let labels = labels_from_cut(&min_cut_side(&g), kz.network.cells());
    Ok(SegmentationResult {
        energy: stats.value + kz.constant,
        flow: stats.value,
        foreground: labels.iter().filter(|&&l| l == 1).count(),
        labels,
    })
}

/// Segment with the hybrid grid engine (the paper's pipeline).  The cut
/// side is recovered by a residual BFS on the CSR conversion of the
/// *solved* grid state.
pub fn segment_image(
    img: &[u8],
    height: usize,
    width: usize,
    lambda: i64,
    exec: &mut dyn GridExecutor,
) -> Result<SegmentationResult> {
    let mrf = image_mrf(img, height, width, lambda);
    let kz = build_kz_network(&mrf)?;
    let solver = HybridGridSolver::default();
    let report = solver.solve(&kz.network, exec)?;

    // The min-cut *value* comes from the grid solve; the cut *side* is
    // recomputed on the CSR view (an independent Dinic solve would also
    // do, but the value parity below certifies both).
    let mut g = kz.network.to_flow_network();
    let stats = Dinic.solve(&mut g)?;
    anyhow::ensure!(
        stats.value == report.flow,
        "grid engine flow {} != baseline {}",
        report.flow,
        stats.value
    );
    let labels = labels_from_cut(&min_cut_side(&g), kz.network.cells());
    Ok(SegmentationResult {
        energy: report.flow + kz.constant,
        flow: report.flow,
        foreground: labels.iter().filter(|&&l| l == 1).count(),
        labels,
    })
}

/// Render a labelling as ASCII art (examples + debugging).
pub fn ascii_render(labels: &[u8], height: usize, width: usize) -> String {
    let mut out = String::with_capacity((width + 1) * height);
    for i in 0..height {
        for j in 0..width {
            out.push(if labels[i * width + j] == 1 { '#' } else { '.' });
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gridflow::NativeGridExecutor;
    use crate::workloads::grid_gen::synthetic_image;

    #[test]
    fn segmentation_recovers_the_blob() {
        let mut rng = crate::util::Rng::seeded(61);
        let (hh, ww) = (16, 16);
        let img = synthetic_image(&mut rng, hh, ww);
        let mut exec = NativeGridExecutor::default();
        let seg = segment_image(&img, hh, ww, 12, &mut exec).unwrap();
        // The blob is roughly pi*r^2 with r ~ 0.2-0.35 of 16 -> 10..38 px.
        assert!(
            seg.foreground > 5 && seg.foreground < hh * ww - 5,
            "degenerate segmentation: {} fg",
            seg.foreground
        );
        // Bright pixels should mostly be labelled foreground.
        let hits = img
            .iter()
            .zip(&seg.labels)
            .filter(|&(&v, &l)| (v > 130) == (l == 1))
            .count();
        assert!(hits * 10 >= hh * ww * 9, "agreement {hits}/{}", hh * ww);
    }

    #[test]
    fn hybrid_energy_matches_baseline() {
        let mut rng = crate::util::Rng::seeded(67);
        let img = synthetic_image(&mut rng, 12, 12);
        let mut exec = NativeGridExecutor::default();
        let a = segment_image(&img, 12, 12, 10, &mut exec).unwrap();
        let b = segment_image_baseline(&img, 12, 12, 10).unwrap();
        assert_eq!(a.energy, b.energy);
        assert_eq!(a.flow, b.flow);
    }

    #[test]
    fn labelling_is_map_optimal_on_tiny_image() {
        let img: Vec<u8> = vec![200, 200, 60, 60, 200, 200, 60, 60, 60, 60, 60, 60];
        let mrf = image_mrf(&img, 3, 4, 5);
        let seg = segment_image_baseline(&img, 3, 4, 5).unwrap();
        let (_, want) = mrf.brute_force_min();
        assert_eq!(seg.energy, want);
    }
}
