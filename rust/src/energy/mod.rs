//! MAP-MRF energy minimisation via graph cuts — the §1/§4 application
//! (Kolmogorov & Zabih: "What energy functions can be minimized via graph
//! cuts?").
//!
//! A binary MRF energy `E(L) = Σ θ_p(l_p) + Σ θ_pq(l_p, l_q)` over a
//! 4-connected grid is *regular* (graph-representable) when every
//! pairwise term satisfies `θ(0,0) + θ(1,1) <= θ(0,1) + θ(1,0)`; the KZ
//! construction turns it into an s-t grid network whose min cut equals
//! the minimum energy (up to an additive constant).

pub mod kz;
pub mod mrf;
pub mod segmentation;

pub use kz::{build_kz_network, KzReport};
pub use mrf::{BinaryMrf, PairwiseTerm};
pub use segmentation::{segment_image, SegmentationResult};
