//! The Kolmogorov–Zabih construction: a regular binary MRF becomes an s-t
//! grid network whose minimum cut value equals the minimum energy minus a
//! constant.  This is the §4 application pipeline: the construction
//! "maintains the grid structure, suitable for the CUDA architecture" —
//! and for our dense wave kernel equally.
//!
//! Convention: label 0 = source side, label 1 = sink side.
//! * `θ_p(1)` contributes to `cap(s→p)` (cut when p is labelled 1);
//! * `θ_p(0)` contributes to `cap(p→t)`;
//! * a regular pairwise term (A,B,C,D) decomposes as
//!   `A + (C-A)·p + (D-C)·q + (B+C-A-D)·(1-p)·q`, the last part becoming
//!   the neighbour arc `p→q` with capacity `B+C-A-D >= 0`.

use anyhow::{ensure, Result};

use crate::graph::grid::{E, N, S, W};
use crate::graph::GridNetwork;

use super::mrf::BinaryMrf;

/// Construction output: the network plus the additive energy constant.
#[derive(Debug, Clone)]
pub struct KzReport {
    pub network: GridNetwork,
    /// `min_energy = min_cut + constant`.
    pub constant: i64,
}

/// Build the KZ network for a regular MRF.
pub fn build_kz_network(mrf: &BinaryMrf) -> Result<KzReport> {
    ensure!(mrf.is_regular(), "MRF is not regular: not graph-representable");
    let (hh, ww) = (mrf.height, mrf.width);
    let cells = hh * ww;
    // Accumulated unary contributions: cost of label 1 -> s_arc, label 0 -> t_arc.
    let mut s_arc = vec![0i64; cells];
    let mut t_arc = vec![0i64; cells];
    let mut constant = 0i64;
    let mut net = GridNetwork::zeros(hh, ww);

    for (p, &(u0, u1)) in mrf.unary.iter().enumerate() {
        t_arc[p] += u0;
        s_arc[p] += u1;
    }

    let add_linear = |p: usize, coeff: i64, s_arc: &mut [i64], t_arc: &mut [i64], constant: &mut i64| {
        // coeff * [label(p) = 1]
        if coeff >= 0 {
            s_arc[p] += coeff;
        } else {
            *constant += coeff;
            t_arc[p] += -coeff;
        }
    };

    for i in 0..hh {
        for j in 0..ww {
            let p = mrf.cell(i, j);
            let pairs = [
                (mrf.pair_s[p], S, i + 1 < hh, (i + 1, j)),
                (mrf.pair_e[p], E, j + 1 < ww, (i, j + 1)),
            ];
            for (term, dir, ok, (qi, qj)) in pairs {
                let Some(t) = term else { continue };
                ensure!(ok, "pairwise term on a border arc");
                let q = mrf.cell(qi, qj);
                let (a, b, c, d) = (t.e00, t.e01, t.e10, t.e11);
                constant += a;
                add_linear(p, c - a, &mut s_arc, &mut t_arc, &mut constant);
                add_linear(q, d - c, &mut s_arc, &mut t_arc, &mut constant);
                let cap = b + c - a - d;
                ensure!(cap >= 0, "regularity violated");
                // Arc p -> q (cut when p ∈ S, q ∈ T).
                let arc = net.arc(dir, i, j);
                net.cap[arc] += cap;
            }
        }
    }

    // Fold unary accumulations into terminal capacities; subtract the
    // common part min(s,t) per pixel (it is paid in every cut).
    for p in 0..cells {
        let m = s_arc[p].min(t_arc[p]);
        constant += m;
        net.cap_source[p] = s_arc[p] - m;
        net.cap_sink[p] = t_arc[p] - m;
    }
    let _ = (N, W); // direction constants referenced for doc symmetry
    Ok(KzReport {
        network: net,
        constant,
    })
}

/// Recover the optimal labelling from a *solved* CSR view of the KZ
/// network: label 0 for source-reachable nodes, 1 otherwise.
pub fn labels_from_cut(reachable: &[bool], cells: usize) -> Vec<u8> {
    (0..cells).map(|p| if reachable[p] { 0 } else { 1 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::mrf::PairwiseTerm;
    use crate::graph::validate::min_cut_side;
    use crate::maxflow::{dinic::Dinic, MaxFlowSolver};

    fn solve_and_extract(mrf: &BinaryMrf) -> (Vec<u8>, i64) {
        let kz = build_kz_network(mrf).unwrap();
        let mut g = kz.network.to_flow_network();
        let stats = Dinic.solve(&mut g).unwrap();
        let reach = min_cut_side(&g);
        let labels = labels_from_cut(&reach, kz.network.cells());
        (labels, stats.value + kz.constant)
    }

    #[test]
    fn matches_brute_force_on_random_small_mrfs() {
        let mut rng = crate::util::Rng::seeded(53);
        for _ in 0..12 {
            let (hh, ww) = (2 + rng.index(2), 2 + rng.index(2));
            let mut mrf = BinaryMrf::new(hh, ww);
            for p in 0..hh * ww {
                mrf.unary[p] = (rng.range_i64(0, 20), rng.range_i64(0, 20));
            }
            for i in 0..hh {
                for j in 0..ww {
                    let p = mrf.cell(i, j);
                    if i + 1 < hh {
                        mrf.pair_s[p] = Some(PairwiseTerm::potts(rng.range_i64(0, 8)));
                    }
                    if j + 1 < ww {
                        mrf.pair_e[p] = Some(PairwiseTerm::potts(rng.range_i64(0, 8)));
                    }
                }
            }
            let (labels, cut_energy) = solve_and_extract(&mrf);
            let (_, want) = mrf.brute_force_min();
            assert_eq!(cut_energy, want, "cut value + constant != min energy");
            assert_eq!(mrf.energy(&labels), want, "extracted labels not optimal");
        }
    }

    #[test]
    fn general_regular_terms_supported() {
        let mut rng = crate::util::Rng::seeded(59);
        for _ in 0..8 {
            let mut mrf = BinaryMrf::new(2, 2);
            for p in 0..4 {
                mrf.unary[p] = (rng.range_i64(0, 15), rng.range_i64(0, 15));
            }
            // Random regular tables: pick B, C, then A + D <= B + C.
            let mut regular = || {
                let b = rng.range_i64(0, 10);
                let c = rng.range_i64(0, 10);
                let a = rng.range_i64(0, (b + c).min(6));
                let d = (b + c - a).min(rng.range_i64(0, 6));
                PairwiseTerm {
                    e00: a,
                    e01: b,
                    e10: c,
                    e11: d,
                }
            };
            mrf.pair_s[0] = Some(regular());
            mrf.pair_e[0] = Some(regular());
            mrf.pair_s[1] = Some(regular());
            mrf.pair_e[2] = Some(regular());
            assert!(mrf.is_regular());
            let (labels, cut_energy) = solve_and_extract(&mrf);
            let (_, want) = mrf.brute_force_min();
            assert_eq!(cut_energy, want);
            assert_eq!(mrf.energy(&labels), want);
        }
    }

    #[test]
    fn irregular_mrf_rejected() {
        let mut mrf = BinaryMrf::new(1, 2);
        mrf.pair_e[0] = Some(PairwiseTerm {
            e00: 10,
            e01: 0,
            e10: 0,
            e11: 10,
        });
        assert!(build_kz_network(&mrf).is_err());
    }
}
