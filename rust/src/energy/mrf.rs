//! Binary MRF energies over 4-connected grids.

/// Pairwise term table `θ(l_p, l_q)` for one neighbour pair.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PairwiseTerm {
    pub e00: i64,
    pub e01: i64,
    pub e10: i64,
    pub e11: i64,
}

impl PairwiseTerm {
    /// Potts smoothness: 0 on agreement, `lambda` on disagreement.
    pub fn potts(lambda: i64) -> Self {
        Self {
            e00: 0,
            e01: lambda,
            e10: lambda,
            e11: 0,
        }
    }

    /// KZ regularity: representable by graph cuts (Kolmogorov–Zabih Thm).
    pub fn is_regular(&self) -> bool {
        self.e00 + self.e11 <= self.e01 + self.e10
    }
}

/// A binary MRF on an `height x width` grid: unary terms per pixel and
/// pairwise terms per S/E neighbour pair.
#[derive(Debug, Clone)]
pub struct BinaryMrf {
    pub height: usize,
    pub width: usize,
    /// `unary[p] = (θ_p(0), θ_p(1))`, label 0 = background/source side.
    pub unary: Vec<(i64, i64)>,
    /// Pairwise term for (p, south(p)); `None` at the bottom row.
    pub pair_s: Vec<Option<PairwiseTerm>>,
    /// Pairwise term for (p, east(p)); `None` at the last column.
    pub pair_e: Vec<Option<PairwiseTerm>>,
}

impl BinaryMrf {
    pub fn new(height: usize, width: usize) -> Self {
        let n = height * width;
        Self {
            height,
            width,
            unary: vec![(0, 0); n],
            pair_s: vec![None; n],
            pair_e: vec![None; n],
        }
    }

    #[inline]
    pub fn cell(&self, i: usize, j: usize) -> usize {
        i * self.width + j
    }

    /// True iff every pairwise term is regular (graph-representable).
    pub fn is_regular(&self) -> bool {
        self.pair_s
            .iter()
            .chain(self.pair_e.iter())
            .flatten()
            .all(PairwiseTerm::is_regular)
    }

    /// Evaluate the energy of a labelling (`labels[p] ∈ {0,1}`).
    pub fn energy(&self, labels: &[u8]) -> i64 {
        assert_eq!(labels.len(), self.unary.len());
        let mut e = 0i64;
        for (p, &(u0, u1)) in self.unary.iter().enumerate() {
            e += if labels[p] == 0 { u0 } else { u1 };
        }
        for i in 0..self.height {
            for j in 0..self.width {
                let p = self.cell(i, j);
                if let Some(t) = self.pair_s[p] {
                    let q = self.cell(i + 1, j);
                    e += pair_value(t, labels[p], labels[q]);
                }
                if let Some(t) = self.pair_e[p] {
                    let q = self.cell(i, j + 1);
                    e += pair_value(t, labels[p], labels[q]);
                }
            }
        }
        e
    }

    /// Exhaustive minimiser for tiny grids (tests only).
    pub fn brute_force_min(&self) -> (Vec<u8>, i64) {
        let n = self.unary.len();
        assert!(n <= 20, "brute force limited to 20 pixels");
        let mut best = (vec![0u8; n], i64::MAX);
        for mask in 0u32..(1 << n) {
            let labels: Vec<u8> = (0..n).map(|p| ((mask >> p) & 1) as u8).collect();
            let e = self.energy(&labels);
            if e < best.1 {
                best = (labels, e);
            }
        }
        best
    }
}

fn pair_value(t: PairwiseTerm, lp: u8, lq: u8) -> i64 {
    match (lp, lq) {
        (0, 0) => t.e00,
        (0, 1) => t.e01,
        (1, 0) => t.e10,
        _ => t.e11,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn potts_is_regular() {
        assert!(PairwiseTerm::potts(5).is_regular());
        let bad = PairwiseTerm {
            e00: 10,
            e01: 0,
            e10: 0,
            e11: 10,
        };
        assert!(!bad.is_regular());
    }

    #[test]
    fn energy_evaluation() {
        let mut mrf = BinaryMrf::new(1, 2);
        mrf.unary[0] = (1, 5);
        mrf.unary[1] = (4, 2);
        mrf.pair_e[0] = Some(PairwiseTerm::potts(3));
        assert_eq!(mrf.energy(&[0, 0]), 1 + 4);
        assert_eq!(mrf.energy(&[0, 1]), 1 + 2 + 3);
        assert_eq!(mrf.energy(&[1, 1]), 5 + 2);
    }

    #[test]
    fn brute_force_finds_min() {
        let mut mrf = BinaryMrf::new(2, 2);
        for p in 0..4 {
            mrf.unary[p] = (if p == 0 { 10 } else { 0 }, if p == 0 { 0 } else { 10 });
        }
        mrf.pair_e[0] = Some(PairwiseTerm::potts(1));
        mrf.pair_s[0] = Some(PairwiseTerm::potts(1));
        let (labels, e) = mrf.brute_force_min();
        // Pixel 0 wants label 1, others want 0; smoothness cost 2 paid.
        assert_eq!(labels[0], 1);
        assert_eq!(&labels[1..], &[0, 0, 0]);
        assert_eq!(e, 2);
    }
}
