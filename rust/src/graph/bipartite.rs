//! Complete-bipartite assignment instances (§5): weight matrices, the
//! integer cost scaling the algorithms need, padding to artifact sizes,
//! and the explicit reduction to a max-flow-min-cost network (Fig. 1).

use super::csr::{FlowNetwork, NetworkBuilder};

/// A max-weight assignment instance on the complete bipartite graph
/// `K_{n,n}` with non-negative integer weights.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AssignmentInstance {
    pub n: usize,
    /// Row-major `w[x * n + y] = w(x, y) >= 0`.
    pub weights: Vec<i64>,
}

impl AssignmentInstance {
    pub fn new(n: usize, weights: Vec<i64>) -> Self {
        assert_eq!(weights.len(), n * n, "weight matrix must be n*n");
        assert!(weights.iter().all(|&w| w >= 0), "weights must be >= 0");
        Self { n, weights }
    }

    #[inline]
    pub fn weight(&self, x: usize, y: usize) -> i64 {
        self.weights[x * self.n + y]
    }

    pub fn max_weight(&self) -> i64 {
        self.weights.iter().copied().max().unwrap_or(0)
    }

    /// Total weight of an assignment given as `y = assign[x]`.
    pub fn assignment_weight(&self, assign: &[usize]) -> i64 {
        assert_eq!(assign.len(), self.n);
        assign
            .iter()
            .enumerate()
            .map(|(x, &y)| self.weight(x, y))
            .sum()
    }

    /// Is `assign` a permutation of `0..n`?
    pub fn is_permutation(assign: &[usize]) -> bool {
        let n = assign.len();
        let mut seen = vec![false; n];
        for &y in assign {
            if y >= n || seen[y] {
                return false;
            }
            seen[y] = true;
        }
        true
    }

    /// Scaled min-cost matrix for the cost-scaling engines:
    /// `c(x,y) = -w(x,y) * (n + 1)` (max-weight -> min-cost; the (n+1)
    /// factor makes 1-optimality certify optimality, DESIGN.md §7).
    pub fn scaled_costs_i32(&self) -> Vec<i32> {
        let k = (self.n + 1) as i64;
        self.weights
            .iter()
            .map(|&w| {
                let c = -w * k;
                assert!(c >= i32::MIN as i64, "scaled cost overflows i32");
                c as i32
            })
            .collect()
    }

    pub fn scaled_costs_i64(&self) -> Vec<i64> {
        let k = (self.n + 1) as i64;
        self.weights.iter().map(|&w| -w * k).collect()
    }

    /// Initial epsilon for the scaling loop: the largest |scaled cost|.
    pub fn initial_epsilon(&self) -> i64 {
        (self.max_weight() * (self.n + 1) as i64).max(1)
    }

    /// Pad to an `m x m` instance (`m >= n`) with zero-weight arcs.  With
    /// non-negative weights the optimum restricted to the real block is
    /// preserved; `unpad_assignment` completes any real->dummy rows.
    pub fn pad(&self, m: usize) -> AssignmentInstance {
        assert!(m >= self.n);
        let mut w = vec![0i64; m * m];
        for x in 0..self.n {
            w[x * m..x * m + self.n].copy_from_slice(&self.weights[x * self.n..(x + 1) * self.n]);
        }
        AssignmentInstance::new(m, w)
    }

    /// Restrict a padded solution back to `n` rows, re-matching any row
    /// that was assigned a dummy column to a free real column (possible
    /// only at equal weight for non-negative instances solved optimally;
    /// the validators double-check).
    pub fn unpad_assignment(&self, padded: &[usize]) -> Vec<usize> {
        let n = self.n;
        let mut assign: Vec<Option<usize>> = padded[..n]
            .iter()
            .map(|&y| if y < n { Some(y) } else { None })
            .collect();
        let mut used = vec![false; n];
        for y in assign.iter().flatten() {
            used[*y] = true;
        }
        let mut free: Vec<usize> = (0..n).filter(|&y| !used[y]).collect();
        for slot in assign.iter_mut() {
            if slot.is_none() {
                *slot = free.pop();
            }
        }
        assign.into_iter().map(|y| y.expect("perfect matching")).collect()
    }

    /// The paper's §5 reduction: instance `I = (G, w)` to a max-flow
    /// min-cost instance `I' = (G', u, c)` *plus* source/sink, for the
    /// reduction-soundness bench (E1).  Costs are returned alongside since
    /// `FlowNetwork` itself is cost-free.
    ///
    /// Node ids: X = 0..n, Y = n..2n, s = 2n, t = 2n+1.
    pub fn to_mincost_network(&self) -> (FlowNetwork, Vec<i64>) {
        let n = self.n;
        let mut b = NetworkBuilder::new(2 * n + 2, 2 * n, 2 * n + 1);
        let mut costs = Vec::new();
        for x in 0..n {
            for y in 0..n {
                // u(x,y) = 1, c(x,y) = -w (min-cost form of max-weight).
                b.add_edge(x, n + y, 1, 0);
                costs.push(-self.weight(x, y));
            }
        }
        for x in 0..n {
            b.add_edge(2 * n, x, 1, 0);
            costs.push(0);
        }
        for y in 0..n {
            b.add_edge(n + y, 2 * n + 1, 1, 0);
            costs.push(0);
        }
        (b.build().expect("well-formed"), costs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst3() -> AssignmentInstance {
        AssignmentInstance::new(3, vec![5, 1, 0, 2, 8, 1, 0, 3, 9])
    }

    #[test]
    fn weight_accessors() {
        let a = inst3();
        assert_eq!(a.weight(1, 1), 8);
        assert_eq!(a.max_weight(), 9);
        assert_eq!(a.assignment_weight(&[0, 1, 2]), 22);
    }

    #[test]
    fn permutation_check() {
        assert!(AssignmentInstance::is_permutation(&[2, 0, 1]));
        assert!(!AssignmentInstance::is_permutation(&[0, 0, 1]));
        assert!(!AssignmentInstance::is_permutation(&[0, 1, 3]));
    }

    #[test]
    fn scaling_matches_design() {
        let a = inst3();
        let c = a.scaled_costs_i64();
        assert_eq!(c[0], -5 * 4);
        assert_eq!(a.initial_epsilon(), 36);
    }

    #[test]
    fn pad_preserves_real_block() {
        let a = inst3();
        let p = a.pad(5);
        assert_eq!(p.n, 5);
        assert_eq!(p.weight(1, 1), 8);
        assert_eq!(p.weight(1, 4), 0);
        assert_eq!(p.weight(4, 1), 0);
    }

    #[test]
    fn unpad_completes_dummy_rows() {
        let a = inst3();
        // Padded solution where x=2 went to dummy column 4; columns 0,1 used.
        let assign = a.unpad_assignment(&[0, 1, 4, 2, 3]);
        assert!(AssignmentInstance::is_permutation(&assign));
        assert_eq!(assign[0], 0);
        assert_eq!(assign[1], 1);
        assert_eq!(assign[2], 2);
    }

    #[test]
    fn mincost_reduction_shape() {
        let a = inst3();
        let (f, costs) = a.to_mincost_network();
        assert_eq!(f.node_count(), 8);
        assert_eq!(f.edge_pair_count(), 9 + 3 + 3);
        assert_eq!(costs.len(), f.edge_pair_count());
        assert_eq!(costs[4], -8); // arc (1,1)
    }
}
