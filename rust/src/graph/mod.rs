//! Graph substrates: the flow-network and bipartite-instance types every
//! engine operates on, plus DIMACS I/O and solution validators.

pub mod bipartite;
pub mod csr;
pub mod dimacs;
pub mod grid;
pub mod validate;

pub use bipartite::AssignmentInstance;
pub use csr::{EdgeId, FlowNetwork, NetworkBuilder};
pub use grid::{GridCsrIndex, GridNetwork};
