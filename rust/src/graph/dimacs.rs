//! DIMACS file formats: `.max` (max-flow) and `.asn` (assignment), both
//! reader and writer — the interchange the original max-flow/matching
//! community (and Goldberg's codes the paper builds on) uses.
//!
//! Max-flow:
//! ```text
//! c comment
//! p max <nodes> <arcs>
//! n <id> s
//! n <id> t
//! a <from> <to> <cap>          (1-based ids)
//! ```
//!
//! Assignment:
//! ```text
//! p asn <nodes> <arcs>
//! n <id>                        (source-side node)
//! a <x> <y> <weight>
//! ```

use anyhow::{bail, ensure, Context, Result};

use super::bipartite::AssignmentInstance;
use super::csr::{FlowNetwork, NetworkBuilder};

/// Parsed `.max` file (kept as an edge list so callers can build either a
/// CSR network or a grid).
#[derive(Debug, Clone)]
pub struct MaxFlowFile {
    pub nodes: usize,
    pub source: usize,
    pub sink: usize,
    /// 0-based (from, to, cap).
    pub arcs: Vec<(usize, usize, i64)>,
}

impl MaxFlowFile {
    pub fn parse(text: &str) -> Result<Self> {
        let mut nodes = None;
        let mut arcs_decl = 0usize;
        let mut source = None;
        let mut sink = None;
        let mut arcs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            let mut it = line.split_whitespace();
            match it.next() {
                None | Some("c") => {}
                Some("p") => {
                    ensure!(it.next() == Some("max"), "line {}: not a max problem", lineno + 1);
                    nodes = Some(it.next().context("missing node count")?.parse()?);
                    arcs_decl = it.next().context("missing arc count")?.parse()?;
                }
                Some("n") => {
                    let id: usize = it.next().context("missing node id")?.parse()?;
                    match it.next() {
                        Some("s") => source = Some(id - 1),
                        Some("t") => sink = Some(id - 1),
                        other => bail!("line {}: bad node designator {other:?}", lineno + 1),
                    }
                }
                Some("a") => {
                    let u: usize = it.next().context("missing tail")?.parse()?;
                    let v: usize = it.next().context("missing head")?.parse()?;
                    let c: i64 = it.next().context("missing cap")?.parse()?;
                    ensure!(c >= 0, "line {}: negative capacity", lineno + 1);
                    arcs.push((u - 1, v - 1, c));
                }
                Some(other) => bail!("line {}: unknown record {other:?}", lineno + 1),
            }
        }
        let nodes = nodes.context("no problem line")?;
        ensure!(
            arcs.len() == arcs_decl,
            "declared {} arcs, found {}",
            arcs_decl,
            arcs.len()
        );
        Ok(Self {
            nodes,
            source: source.context("no source")?,
            sink: sink.context("no sink")?,
            arcs,
        })
    }

    pub fn to_network(&self) -> Result<FlowNetwork> {
        let mut b = NetworkBuilder::new(self.nodes, self.source, self.sink);
        for &(u, v, c) in &self.arcs {
            ensure!(u < self.nodes && v < self.nodes, "arc out of range");
            if u != v {
                b.add_edge(u, v, c, 0);
            }
        }
        b.build()
    }
}

/// Serialize a network (build-time capacities) to `.max` format.
pub fn write_max_flow(g: &FlowNetwork) -> String {
    let mut arcs = Vec::new();
    for (u, v, c0, _) in g.edges() {
        if c0 > 0 {
            arcs.push((u, v, c0));
        }
    }
    let mut out = String::new();
    out.push_str("c flowmatch export\n");
    out.push_str(&format!("p max {} {}\n", g.node_count(), arcs.len()));
    out.push_str(&format!("n {} s\n", g.source() + 1));
    out.push_str(&format!("n {} t\n", g.sink() + 1));
    for (u, v, c) in arcs {
        out.push_str(&format!("a {} {} {}\n", u + 1, v + 1, c));
    }
    out
}

/// Parse a complete-bipartite `.asn` file into an [`AssignmentInstance`].
/// Missing arcs get weight 0 (the formats allow sparse listings).
pub fn parse_assignment(text: &str) -> Result<AssignmentInstance> {
    let mut nodes = None;
    let mut sources = Vec::new();
    let mut arcs: Vec<(usize, usize, i64)> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        let mut it = line.split_whitespace();
        match it.next() {
            None | Some("c") => {}
            Some("p") => {
                ensure!(it.next() == Some("asn"), "line {}: not an asn problem", lineno + 1);
                nodes = Some(it.next().context("missing node count")?.parse::<usize>()?);
            }
            Some("n") => sources.push(it.next().context("node id")?.parse::<usize>()? - 1),
            Some("a") => {
                let x: usize = it.next().context("tail")?.parse()?;
                let y: usize = it.next().context("head")?.parse()?;
                let w: i64 = it.next().context("weight")?.parse()?;
                arcs.push((x - 1, y - 1, w));
            }
            Some(other) => bail!("line {}: unknown record {other:?}", lineno + 1),
        }
    }
    let nodes = nodes.context("no problem line")?;
    ensure!(nodes % 2 == 0, "asn node count must be even");
    let n = nodes / 2;
    ensure!(
        sources.len() == n,
        "expected {} source-side nodes, got {}",
        n,
        sources.len()
    );
    let mut weights = vec![0i64; n * n];
    for (x, y, w) in arcs {
        ensure!(x < n, "source-side id {} out of range", x + 1);
        ensure!((n..2 * n).contains(&y), "sink-side id {} out of range", y + 1);
        ensure!(w >= 0, "negative weight");
        weights[x * n + (y - n)] = w;
    }
    Ok(AssignmentInstance::new(n, weights))
}

/// Serialize an assignment instance to `.asn` (zero-weight arcs elided).
pub fn write_assignment(inst: &AssignmentInstance) -> String {
    let n = inst.n;
    let arcs: Vec<(usize, usize, i64)> = (0..n)
        .flat_map(|x| (0..n).map(move |y| (x, y, inst.weight(x, y))))
        .filter(|&(_, _, w)| w > 0)
        .collect();
    let mut out = String::new();
    out.push_str("c flowmatch export\n");
    out.push_str(&format!("p asn {} {}\n", 2 * n, arcs.len()));
    for x in 0..n {
        out.push_str(&format!("n {}\n", x + 1));
    }
    for (x, y, w) in arcs {
        out.push_str(&format!("a {} {} {}\n", x + 1, n + y + 1, w));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxflow_roundtrip() {
        let text = "c demo\np max 4 5\nn 1 s\nn 4 t\na 1 2 3\na 2 4 3\na 1 3 2\na 3 4 2\na 2 3 1\n";
        let parsed = MaxFlowFile::parse(text).unwrap();
        assert_eq!(parsed.nodes, 4);
        assert_eq!(parsed.source, 0);
        assert_eq!(parsed.sink, 3);
        assert_eq!(parsed.arcs.len(), 5);
        let g = parsed.to_network().unwrap();
        let re = write_max_flow(&g);
        let reparsed = MaxFlowFile::parse(&re).unwrap();
        let mut a1 = parsed.arcs.clone();
        let mut a2 = reparsed.arcs.clone();
        a1.sort();
        a2.sort();
        assert_eq!(a1, a2);
    }

    #[test]
    fn maxflow_rejects_malformed() {
        assert!(MaxFlowFile::parse("p max 2 1\nn 1 s\nn 2 t\n").is_err()); // arc count
        assert!(MaxFlowFile::parse("p min 2 0\nn 1 s\nn 2 t\n").is_err());
        assert!(MaxFlowFile::parse("a 1 2 3\n").is_err()); // no p line
    }

    #[test]
    fn assignment_roundtrip() {
        let inst = AssignmentInstance::new(3, vec![5, 0, 2, 0, 7, 0, 1, 0, 9]);
        let text = write_assignment(&inst);
        let parsed = parse_assignment(&text).unwrap();
        assert_eq!(parsed, inst);
    }

    #[test]
    fn assignment_rejects_bad_sides() {
        let text = "p asn 4 1\nn 1\nn 2\na 1 2 5\n"; // head must be in 3..4
        assert!(parse_assignment(text).is_err());
    }
}
