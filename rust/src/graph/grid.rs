//! Grid flow networks (the §4 instance class: 4-connected grids from
//! MRF/graph-cut constructions) in the dense SoA layout the device kernel
//! uses, with converters to the general CSR representation for the
//! sequential baselines.

use super::csr::{EdgeId, FlowNetwork, NetworkBuilder};

/// Arc directions, matching python/compile/kernels/grid_wave.py.
pub const N: usize = 0;
pub const S: usize = 1;
pub const W: usize = 2;
pub const E: usize = 3;

/// `(di, dj)` per direction.
pub const DIRS: [(i64, i64); 4] = [(-1, 0), (1, 0), (0, -1), (0, 1)];
/// Opposite direction index.
pub const OPP: [usize; 4] = [S, N, E, W];

/// A grid max-flow *instance*: immutable initial capacities.
///
/// `cap[d][i][j]` is the neighbour-arc capacity, `cap_sink` the (x, t)
/// terminal capacity and `cap_source` the (s, x) terminal capacity (the
/// Kolmogorov–Zabih construction only ever attaches a pixel to one of the
/// two terminals, but both arrays are allowed to be non-zero).
#[derive(Debug, Clone)]
pub struct GridNetwork {
    pub height: usize,
    pub width: usize,
    /// Arc-major `[4 * height * width]`.
    pub cap: Vec<i64>,
    pub cap_sink: Vec<i64>,
    pub cap_source: Vec<i64>,
}

impl GridNetwork {
    pub fn zeros(height: usize, width: usize) -> Self {
        assert!(height > 0 && width > 0);
        let n = height * width;
        Self {
            height,
            width,
            cap: vec![0; 4 * n],
            cap_sink: vec![0; n],
            cap_source: vec![0; n],
        }
    }

    #[inline]
    pub fn cells(&self) -> usize {
        self.height * self.width
    }

    #[inline]
    pub fn cell(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.height && j < self.width);
        i * self.width + j
    }

    /// Neighbour cell index in direction `d`, if inside the grid.
    #[inline]
    pub fn neighbour(&self, i: usize, j: usize, d: usize) -> Option<(usize, usize)> {
        let (di, dj) = DIRS[d];
        let ni = i as i64 + di;
        let nj = j as i64 + dj;
        if ni >= 0 && nj >= 0 && (ni as usize) < self.height && (nj as usize) < self.width {
            Some((ni as usize, nj as usize))
        } else {
            None
        }
    }

    #[inline]
    pub fn arc(&self, d: usize, i: usize, j: usize) -> usize {
        d * self.cells() + self.cell(i, j)
    }

    pub fn set_neighbour_cap(&mut self, i: usize, j: usize, d: usize, cap: i64) {
        assert!(self.neighbour(i, j, d).is_some(), "arc leaves the grid");
        assert!(cap >= 0);
        let a = self.arc(d, i, j);
        self.cap[a] = cap;
    }

    /// Zero any arcs that would leave the grid (defensive normalisation
    /// after bulk-filling `cap`).
    pub fn clear_border_arcs(&mut self) {
        for i in 0..self.height {
            for j in 0..self.width {
                for d in 0..4 {
                    if self.neighbour(i, j, d).is_none() {
                        let a = self.arc(d, i, j);
                        self.cap[a] = 0;
                    }
                }
            }
        }
    }

    /// Total capacity leaving the source — Hong's `ExcessTotal`.
    pub fn excess_total(&self) -> i64 {
        self.cap_source.iter().sum()
    }

    /// Node ids in the CSR view: cells row-major, then source, then sink.
    pub fn source_id(&self) -> usize {
        self.cells()
    }

    pub fn sink_id(&self) -> usize {
        self.cells() + 1
    }

    /// Convert to the general representation for the sequential baselines.
    /// Neighbour arcs become directed pairs with the *stored* capacity in
    /// each direction (each grid arc appears once per orientation, so we
    /// emit the pair from the lexicographically smaller side with both
    /// orientations' capacities).
    pub fn to_flow_network(&self) -> FlowNetwork {
        let n = self.cells() + 2;
        let mut b = NetworkBuilder::new(n, self.source_id(), self.sink_id());
        for i in 0..self.height {
            for j in 0..self.width {
                let u = self.cell(i, j);
                // Emit S and E pairs only (each undirected neighbour pair
                // once), pairing with the neighbour's opposite capacity.
                for &d in &[S, E] {
                    if let Some((ni, nj)) = self.neighbour(i, j, d) {
                        let fwd = self.cap[self.arc(d, i, j)];
                        let bwd = self.cap[self.arc(OPP[d], ni, nj)];
                        if fwd > 0 || bwd > 0 {
                            b.add_edge(u, self.cell(ni, nj), fwd, bwd);
                        }
                    }
                }
                let cs = self.cap_source[u];
                if cs > 0 {
                    b.add_edge(self.source_id(), u, cs, 0);
                }
                let ct = self.cap_sink[u];
                if ct > 0 {
                    b.add_edge(u, self.sink_id(), ct, 0);
                }
            }
        }
        b.build().expect("grid network is well-formed")
    }

    /// Like [`GridNetwork::to_flow_network`], but *delta-complete*: every
    /// neighbour pair and every terminal arc is emitted even at capacity
    /// zero, and the returned [`GridCsrIndex`] maps grid arcs to their
    /// CSR edge ids.  Warm-start sessions need both — an edit stream may
    /// raise an arc that started at zero, and the repair addresses edges
    /// by id (`maxflow::warm`).
    pub fn to_flow_network_indexed(&self) -> (FlowNetwork, GridCsrIndex) {
        let n = self.cells() + 2;
        let cells = self.cells();
        let mut b = NetworkBuilder::new(n, self.source_id(), self.sink_id());
        let mut idx = GridCsrIndex {
            height: self.height,
            width: self.width,
            arc_edge: vec![EdgeId::MAX; 4 * cells],
            source_edge: vec![EdgeId::MAX; cells],
            sink_edge: vec![EdgeId::MAX; cells],
        };
        for i in 0..self.height {
            for j in 0..self.width {
                let u = self.cell(i, j);
                for &d in &[S, E] {
                    if let Some((ni, nj)) = self.neighbour(i, j, d) {
                        let fwd = self.cap[self.arc(d, i, j)];
                        let bwd = self.cap[self.arc(OPP[d], ni, nj)];
                        let ef = b.add_edge(u, self.cell(ni, nj), fwd, bwd);
                        idx.arc_edge[self.arc(d, i, j)] = ef;
                        idx.arc_edge[self.arc(OPP[d], ni, nj)] = ef ^ 1;
                    }
                }
                idx.source_edge[u] = b.add_edge(self.source_id(), u, self.cap_source[u], 0);
                idx.sink_edge[u] = b.add_edge(u, self.sink_id(), self.cap_sink[u], 0);
            }
        }
        (b.build().expect("grid network is well-formed"), idx)
    }
}

/// Grid arc → CSR edge id map produced by
/// [`GridNetwork::to_flow_network_indexed`].
#[derive(Debug, Clone)]
pub struct GridCsrIndex {
    height: usize,
    width: usize,
    /// Arc-major (`dir * cells + cell`), `EdgeId::MAX` where the arc
    /// leaves the grid.
    arc_edge: Vec<EdgeId>,
    source_edge: Vec<EdgeId>,
    sink_edge: Vec<EdgeId>,
}

impl GridCsrIndex {
    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Edge id of the directed neighbour arc `dir` out of `(i, j)`;
    /// `None` when it leaves the grid.
    pub fn arc(&self, dir: usize, i: usize, j: usize) -> Option<EdgeId> {
        assert!(dir < 4 && i < self.height && j < self.width, "arc off-grid");
        let cells = self.height * self.width;
        let e = self.arc_edge[dir * cells + i * self.width + j];
        (e != EdgeId::MAX).then_some(e)
    }

    /// Edge id of the `(s, x)` arc of cell `(i, j)`.
    pub fn source(&self, i: usize, j: usize) -> EdgeId {
        assert!(i < self.height && j < self.width, "cell off-grid");
        self.source_edge[i * self.width + j]
    }

    /// Edge id of the `(x, t)` arc of cell `(i, j)`.
    pub fn sink(&self, i: usize, j: usize) -> EdgeId {
        assert!(i < self.height && j < self.width, "cell off-grid");
        self.sink_edge[i * self.width + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_helpers() {
        let g = GridNetwork::zeros(3, 4);
        assert_eq!(g.cells(), 12);
        assert_eq!(g.cell(2, 3), 11);
        assert_eq!(g.neighbour(0, 0, N), None);
        assert_eq!(g.neighbour(0, 0, S), Some((1, 0)));
        assert_eq!(g.neighbour(1, 3, E), None);
        assert_eq!(g.neighbour(1, 2, E), Some((1, 3)));
        assert_eq!(g.source_id(), 12);
        assert_eq!(g.sink_id(), 13);
    }

    #[test]
    fn csr_conversion_roundtrips_arc_capacities() {
        let mut g = GridNetwork::zeros(2, 2);
        g.set_neighbour_cap(0, 0, E, 5);
        g.set_neighbour_cap(0, 1, W, 2); // reverse of the same pair
        g.set_neighbour_cap(0, 0, S, 7);
        let c00 = g.cell(0, 0);
        let c11 = g.cell(1, 1);
        g.cap_source[c00] = 9;
        g.cap_sink[c11] = 4;
        let f = g.to_flow_network();
        assert_eq!(f.node_count(), 6);
        // Pairs: (0,0)-(0,1) with 5/2, (0,0)-(1,0) with 7/0, s->(0,0), (1,1)->t.
        assert_eq!(f.edge_pair_count(), 4);
        let mut caps: Vec<(usize, usize, i64)> = f
            .edges()
            .filter(|&(_, _, c0, _)| c0 > 0)
            .map(|(u, v, c0, _)| (u, v, c0))
            .collect();
        caps.sort();
        assert!(caps.contains(&(0, 1, 5)));
        assert!(caps.contains(&(1, 0, 2)));
        assert!(caps.contains(&(0, 2, 7)));
        assert!(caps.contains(&(4, 0, 9)));
        assert!(caps.contains(&(3, 5, 4)));
    }

    #[test]
    fn excess_total_sums_source_caps() {
        let mut g = GridNetwork::zeros(2, 2);
        g.cap_source[0] = 3;
        g.cap_source[3] = 4;
        assert_eq!(g.excess_total(), 7);
    }

    #[test]
    fn clear_border_arcs_zeroes_outward() {
        let mut g = GridNetwork::zeros(2, 2);
        g.cap.fill(9);
        g.clear_border_arcs();
        assert_eq!(g.cap[g.arc(N, 0, 0)], 0);
        assert_eq!(g.cap[g.arc(S, 0, 0)], 9);
        assert_eq!(g.cap[g.arc(E, 1, 1)], 0);
        assert_eq!(g.cap[g.arc(W, 1, 1)], 9);
    }
}
