//! Solution validators: independent certificates that an engine's output
//! is a feasible maximum flow / optimal assignment.
//!
//! Used by every integration and property test — an engine is only
//! considered correct when it carries a certificate, not when it matches
//! another engine.

use std::collections::VecDeque;

use anyhow::{bail, ensure, Result};

use super::csr::FlowNetwork;

/// Checks that the current residual state of `g` encodes a feasible s-t
/// flow of value `claimed`, and that it is *maximum* by exhibiting a
/// saturated s-t cut (max-flow/min-cut certificate).
pub fn assert_max_flow(g: &FlowNetwork, claimed: i64) -> Result<()> {
    ensure!(claimed >= 0, "negative flow value {claimed}");

    // Feasibility: residuals within [0, cap0 + mate cap0] are structural
    // (push keeps pair sums constant); check non-negativity + pair sums.
    for e in 0..(g.edge_pair_count() * 2) as u32 {
        let r = g.residual(e);
        ensure!(r >= 0, "edge {e} has negative residual {r}");
    }
    for p in 0..g.edge_pair_count() as u32 {
        let (e, m) = (2 * p, 2 * p + 1);
        ensure!(
            g.residual(e) + g.residual(m) == g.capacity0(e) + g.capacity0(m),
            "pair {p} lost mass"
        );
    }

    // Conservation: net outflow zero everywhere except s/t.
    let mut net = vec![0i64; g.node_count()];
    for u in 0..g.node_count() {
        for &e in g.out_edges(u) {
            net[u] += g.flow(e);
        }
    }
    for v in 0..g.node_count() {
        if v == g.source() || v == g.sink() {
            continue;
        }
        ensure!(net[v] == 0, "node {v} violates conservation: {}", net[v]);
    }
    ensure!(
        net[g.source()] == claimed,
        "source outflow {} != claimed {claimed}",
        net[g.source()]
    );
    ensure!(
        net[g.sink()] == -claimed,
        "sink inflow {} != claimed {claimed}",
        -net[g.sink()]
    );

    // Maximality: BFS in the residual graph from s must not reach t, and
    // the saturated cut's original capacity must equal the flow value.
    let reach = residual_reachable(g, g.source());
    if reach[g.sink()] {
        bail!("augmenting path exists: flow is not maximum");
    }
    let mut cut_cap = 0i64;
    for u in 0..g.node_count() {
        if !reach[u] {
            continue;
        }
        for &e in g.out_edges(u) {
            if !reach[g.edge_head(e)] {
                cut_cap += g.capacity0(e);
            }
        }
    }
    ensure!(
        cut_cap == claimed,
        "cut capacity {cut_cap} != flow value {claimed} (weak duality violated?)"
    );
    Ok(())
}

/// Nodes reachable from `from` through positive-residual edges.
pub fn residual_reachable(g: &FlowNetwork, from: usize) -> Vec<bool> {
    let mut seen = vec![false; g.node_count()];
    let mut q = VecDeque::new();
    seen[from] = true;
    q.push_back(from);
    while let Some(u) = q.pop_front() {
        for &e in g.out_edges(u) {
            let v = g.edge_head(e);
            if g.residual(e) > 0 && !seen[v] {
                seen[v] = true;
                q.push_back(v);
            }
        }
    }
    seen
}

/// The s-side of the min cut (for graph-cut applications: label = reachable).
pub fn min_cut_side(g: &FlowNetwork) -> Vec<bool> {
    residual_reachable(g, g.source())
}

/// Certifies optimality of an assignment via LP duality: prices (dual
/// potentials) must dominate every arc and be tight on matched arcs
/// (complementary slackness).  Works on the *scaled min-cost* view.
pub fn assert_optimal_assignment(
    n: usize,
    scaled_cost: &[i64],
    assign: &[usize],
    px: &[i64],
    py: &[i64],
) -> Result<()> {
    ensure!(assign.len() == n && px.len() == n && py.len() == n);
    ensure!(
        super::bipartite::AssignmentInstance::is_permutation(assign),
        "not a permutation"
    );
    // Feasibility of duals: c(x,y) + px(x) - py(y) >= -(n) for all arcs is
    // epsilon-optimality; for the *certificate* we use exact duality on the
    // unscaled integers instead: reconstruct unit prices.
    // c_p(x,y) >= 0 for all (x,y) and == 0 on matched arcs certifies
    // optimality of a min-cost perfect matching.
    for x in 0..n {
        for y in 0..n {
            let rc = scaled_cost[x * n + y] + px[x] - py[y];
            ensure!(
                rc >= 0,
                "dual infeasible at ({x},{y}): reduced cost {rc} < 0"
            );
        }
    }
    for (x, &y) in assign.iter().enumerate() {
        let rc = scaled_cost[x * n + y] + px[x] - py[y];
        ensure!(
            rc == 0,
            "complementary slackness violated at ({x},{y}): {rc}"
        );
    }
    Ok(())
}

/// Weaker check used when an engine does not expose duals: compare the
/// achieved weight against a reference optimum.
pub fn assert_assignment_weight(
    inst: &super::bipartite::AssignmentInstance,
    assign: &[usize],
    optimal_weight: i64,
) -> Result<()> {
    ensure!(
        super::bipartite::AssignmentInstance::is_permutation(assign),
        "not a permutation"
    );
    let w = inst.assignment_weight(assign);
    ensure!(
        w == optimal_weight,
        "assignment weight {w} != optimum {optimal_weight}"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::NetworkBuilder;

    fn saturated_diamond() -> FlowNetwork {
        let mut b = NetworkBuilder::new(4, 0, 3);
        let e1 = b.add_edge(0, 1, 3, 0);
        let e2 = b.add_edge(1, 3, 3, 0);
        let e3 = b.add_edge(0, 2, 2, 0);
        let e4 = b.add_edge(2, 3, 2, 0);
        let mut g = b.build().unwrap();
        for e in [e1, e2] {
            g.push(e, 3);
        }
        for e in [e3, e4] {
            g.push(e, 2);
        }
        g
    }

    #[test]
    fn certifies_max_flow() {
        let g = saturated_diamond();
        assert_max_flow(&g, 5).unwrap();
    }

    #[test]
    fn rejects_wrong_value() {
        let g = saturated_diamond();
        assert!(assert_max_flow(&g, 4).is_err());
    }

    #[test]
    fn rejects_non_maximum_flow() {
        let mut b = NetworkBuilder::new(3, 0, 2);
        let e = b.add_edge(0, 1, 2, 0);
        b.add_edge(1, 2, 2, 0);
        let mut g = b.build().unwrap();
        g.push(e, 1);
        // Feasible as a preflow? No: node 1 has net inflow 1 -> conservation
        // fails, and value 1 is also not maximum.
        assert!(assert_max_flow(&g, 1).is_err());
    }

    #[test]
    fn cut_side_is_source_side() {
        let g = saturated_diamond();
        let side = min_cut_side(&g);
        assert!(side[0]);
        assert!(!side[3]);
    }

    #[test]
    fn assignment_duality_certificate() {
        // 2x2: w = [[3, 1], [1, 2]]; optimum = diag = 5.
        // Scaled costs c = -3w: [[-9,-3],[-3,-6]].
        let cost = vec![-9, -3, -3, -6];
        // Duals: px + (-c row min adjustments); pick px=[9,6], py=[0,0]:
        // rc(0,0)=-9+9=0, rc(0,1)=-3+9=6>=0, rc(1,0)=-3+6=3, rc(1,1)=0.
        assert_optimal_assignment(2, &cost, &[0, 1], &[9, 6], &[0, 0]).unwrap();
        // Off-optimal matching fails slackness.
        assert!(assert_optimal_assignment(2, &cost, &[1, 0], &[9, 6], &[0, 0]).is_err());
    }
}
