//! Residual flow network in CSR form with paired reverse edges.
//!
//! Every call to [`NetworkBuilder::add_edge`] creates a *pair* of edges
//! `(2k, 2k+1)` that are each other's reverses, so `eid ^ 1` is the mate —
//! the same trick the paper uses with its `adj.mate` pointer (§4.6).  All
//! engines (sequential, lock-free, hybrid) operate on this structure.

use anyhow::{ensure, Result};

/// Index of a directed edge; `eid ^ 1` is its reverse mate.
pub type EdgeId = u32;

/// Immutable topology + mutable residual capacities of an s-t network.
#[derive(Debug, Clone)]
pub struct FlowNetwork {
    n: usize,
    s: usize,
    t: usize,
    /// CSR offsets into `adj`, length n + 1.
    adj_off: Vec<u32>,
    /// Edge ids ordered by tail node.
    adj: Vec<EdgeId>,
    /// Head (target) of each edge.
    head: Vec<u32>,
    /// Current residual capacity of each edge.
    cap: Vec<i64>,
    /// Residual capacity at build time (to extract flows later).
    cap0: Vec<i64>,
}

impl FlowNetwork {
    pub fn node_count(&self) -> usize {
        self.n
    }

    pub fn edge_pair_count(&self) -> usize {
        self.head.len() / 2
    }

    pub fn source(&self) -> usize {
        self.s
    }

    pub fn sink(&self) -> usize {
        self.t
    }

    /// Edge ids leaving `v` (both orientations of incident pairs).
    #[inline]
    pub fn out_edges(&self, v: usize) -> &[EdgeId] {
        &self.adj[self.adj_off[v] as usize..self.adj_off[v + 1] as usize]
    }

    #[inline]
    pub fn edge_head(&self, e: EdgeId) -> usize {
        self.head[e as usize] as usize
    }

    #[inline]
    pub fn residual(&self, e: EdgeId) -> i64 {
        self.cap[e as usize]
    }

    /// Push `delta` along `e` (decreasing its residual, increasing the
    /// mate's).  Panics in debug builds if `delta` exceeds the residual.
    #[inline]
    pub fn push(&mut self, e: EdgeId, delta: i64) {
        debug_assert!(delta >= 0 && delta <= self.cap[e as usize]);
        self.cap[e as usize] -= delta;
        self.cap[(e ^ 1) as usize] += delta;
    }

    /// Net flow currently on `e`: positive if flow moved in e's direction.
    #[inline]
    pub fn flow(&self, e: EdgeId) -> i64 {
        self.cap0[e as usize] - self.cap[e as usize]
    }

    /// Original (build-time) capacity of `e`.
    #[inline]
    pub fn capacity0(&self, e: EdgeId) -> i64 {
        self.cap0[e as usize]
    }

    /// Reset all residuals to build-time capacities.
    pub fn reset(&mut self) {
        self.cap.copy_from_slice(&self.cap0);
    }

    /// Overwrite one edge's build-time capacity *and* residual — the
    /// primitive warm-start repair uses to re-point an edited edge at
    /// its new capacity while preserving the flow it decides to keep
    /// (`maxflow::warm`).  The mate is untouched; callers move flow
    /// with [`FlowNetwork::push`] first so the pair stays consistent.
    pub fn set_capacity(&mut self, e: EdgeId, cap0: i64, residual: i64) {
        assert!(cap0 >= 0 && residual >= 0, "negative capacity");
        self.cap0[e as usize] = cap0;
        self.cap[e as usize] = residual;
    }

    /// Value currently flowing out of the source (net).
    pub fn source_outflow(&self) -> i64 {
        self.out_edges(self.s).iter().map(|&e| self.flow(e)).sum()
    }

    /// Direct mutable access for engines that manage capacities wholesale
    /// (the lock-free engine snapshots into atomics and writes back).
    pub fn capacities(&self) -> &[i64] {
        &self.cap
    }

    pub fn set_capacities(&mut self, cap: Vec<i64>) {
        assert_eq!(cap.len(), self.cap.len());
        self.cap = cap;
    }

    /// All edges as (tail, head, cap0, residual) for inspection/IO.
    pub fn edges(&self) -> impl Iterator<Item = (usize, usize, i64, i64)> + '_ {
        (0..self.n).flat_map(move |u| {
            self.out_edges(u)
                .iter()
                .map(move |&e| (u, self.edge_head(e), self.capacity0(e), self.residual(e)))
        })
    }
}

/// Incremental builder; `build()` freezes the CSR layout.
#[derive(Debug, Clone)]
pub struct NetworkBuilder {
    n: usize,
    s: usize,
    t: usize,
    // (tail, head, cap_fwd, cap_bwd) per pair.
    pairs: Vec<(u32, u32, i64, i64)>,
}

impl NetworkBuilder {
    pub fn new(n: usize, s: usize, t: usize) -> Self {
        assert!(s < n && t < n && s != t, "bad source/sink");
        Self {
            n,
            s,
            t,
            pairs: Vec::new(),
        }
    }

    /// Add the directed edge `u -> v` with capacity `cap` and a reverse
    /// capacity `rcap` (0 for plain directed edges).  Returns the forward
    /// edge id.
    pub fn add_edge(&mut self, u: usize, v: usize, cap: i64, rcap: i64) -> EdgeId {
        assert!(u < self.n && v < self.n && u != v, "bad edge {u}->{v}");
        assert!(cap >= 0 && rcap >= 0, "negative capacity");
        let id = (self.pairs.len() * 2) as EdgeId;
        self.pairs.push((u as u32, v as u32, cap, rcap));
        id
    }

    pub fn build(self) -> Result<FlowNetwork> {
        ensure!(self.n >= 2, "network needs at least s and t");
        let m2 = self.pairs.len() * 2;
        let mut head = vec![0u32; m2];
        let mut cap = vec![0i64; m2];
        let mut deg = vec![0u32; self.n + 1];
        for &(u, v, _, _) in &self.pairs {
            deg[u as usize + 1] += 1;
            deg[v as usize + 1] += 1;
        }
        for i in 0..self.n {
            deg[i + 1] += deg[i];
        }
        let adj_off = deg.clone();
        let mut cursor = deg;
        let mut adj = vec![0 as EdgeId; m2];
        for (k, &(u, v, c, rc)) in self.pairs.iter().enumerate() {
            let ef = (2 * k) as EdgeId;
            let eb = ef + 1;
            head[ef as usize] = v;
            head[eb as usize] = u;
            cap[ef as usize] = c;
            cap[eb as usize] = rc;
            adj[cursor[u as usize] as usize] = ef;
            cursor[u as usize] += 1;
            adj[cursor[v as usize] as usize] = eb;
            cursor[v as usize] += 1;
        }
        let cap0 = cap.clone();
        Ok(FlowNetwork {
            n: self.n,
            s: self.s,
            t: self.t,
            adj_off,
            adj,
            head,
            cap,
            cap0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> FlowNetwork {
        // s=0, t=3, two disjoint paths of capacity 3 and 2.
        let mut b = NetworkBuilder::new(4, 0, 3);
        b.add_edge(0, 1, 3, 0);
        b.add_edge(1, 3, 3, 0);
        b.add_edge(0, 2, 2, 0);
        b.add_edge(2, 3, 2, 0);
        b.build().unwrap()
    }

    #[test]
    fn mate_pairing() {
        let g = diamond();
        for e in 0..(g.edge_pair_count() * 2) as EdgeId {
            let mate = e ^ 1;
            assert_eq!(g.edge_head(mate), {
                // mate's head is e's tail: find e in tail's out list
                let mut tail = usize::MAX;
                for u in 0..g.node_count() {
                    if g.out_edges(u).contains(&e) {
                        tail = u;
                    }
                }
                tail
            });
        }
    }

    #[test]
    fn push_moves_residual_to_mate() {
        let mut g = diamond();
        let e = g.out_edges(0)[0];
        let before = g.residual(e);
        g.push(e, 2);
        assert_eq!(g.residual(e), before - 2);
        assert_eq!(g.residual(e ^ 1), 2);
        assert_eq!(g.flow(e), 2);
        g.push(e ^ 1, 1); // partial undo
        assert_eq!(g.flow(e), 1);
    }

    #[test]
    fn adjacency_is_complete() {
        let g = diamond();
        let total: usize = (0..4).map(|v| g.out_edges(v).len()).sum();
        assert_eq!(total, 8); // 4 pairs * 2 directions
        assert_eq!(g.out_edges(0).len(), 2);
        assert_eq!(g.out_edges(3).len(), 2);
    }

    #[test]
    fn reset_restores_capacities() {
        let mut g = diamond();
        let e = g.out_edges(0)[0];
        g.push(e, 3);
        g.reset();
        assert_eq!(g.residual(e), 3);
        assert_eq!(g.flow(e), 0);
    }

    #[test]
    #[should_panic(expected = "bad edge")]
    fn self_loops_rejected() {
        let mut b = NetworkBuilder::new(3, 0, 2);
        b.add_edge(1, 1, 5, 0);
    }
}
