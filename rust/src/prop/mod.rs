//! In-tree property-testing harness (no `proptest` in the offline image).
//!
//! Provides seeded case generation, a `forall` runner with first-failure
//! reporting and a simple halving shrinker for sized inputs.  Tests fix the
//! master seed so failures are reproducible; the failing case's seed is
//! printed so it can be replayed directly.
//!
//! ```
//! use flowmatch::prop::{forall, Config};
//! forall(Config::cases(100).seed(7), |rng| {
//!     let n = rng.index(50);
//!     let v: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     if w == v { Ok(()) } else { Err("double reverse changed vec".into()) }
//! });
//! ```

use crate::util::Rng;

/// Property-run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub name: &'static str,
}

impl Config {
    pub fn cases(cases: usize) -> Self {
        Self {
            cases,
            seed: 0x5EED_F00D,
            name: "property",
        }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn named(mut self, name: &'static str) -> Self {
        self.name = name;
        self
    }
}

/// Run `prop` for `config.cases` independently-seeded cases; panics with
/// the case seed on the first failure.
pub fn forall(config: Config, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let mut master = Rng::seeded(config.seed);
    for case in 0..config.cases {
        let case_seed = master.next_u64();
        let mut rng = Rng::seeded(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {:?} failed at case {}/{} (replay seed {:#x}): {}",
                config.name, case, config.cases, case_seed, msg
            );
        }
    }
}

/// Replay a single case by seed (paste the seed from a failure report).
pub fn replay(case_seed: u64, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let mut rng = Rng::seeded(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed case {case_seed:#x} failed: {msg}");
    }
}

/// Check helper: `ensure!`-style early return for property closures.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Check equality with a readable message.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $what:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{}: {:?} != {:?}", $what, a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(Config::cases(25).seed(1), |rng| {
            count += 1;
            let v = rng.below(100);
            prop_assert!(v < 100, "below out of range: {v}");
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        forall(Config::cases(50).seed(2).named("always fails"), |_rng| {
            Err("nope".into())
        });
    }

    #[test]
    fn replay_reruns_a_case() {
        replay(0xDEAD, |rng| {
            prop_assert!(rng.below(10) < 10, "impossible");
            Ok(())
        });
    }
}
