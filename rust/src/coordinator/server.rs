//! Legacy batched assignment service — now a thin shim over the
//! sharded solver pool (`crate::service`).
//!
//! The original implementation here owned its own device thread and
//! queue; that runtime has been generalised into
//! [`SolverPool`](crate::service::SolverPool), which serves *both*
//! problem families with persistent workers, size-class sharding, and
//! admission control.  This module keeps the assignment-only API
//! (`submit` a matching instance, receive a [`ServiceReply`]) so the
//! §6 real-time callers (CLI `serve`, E7 benches) are unchanged: one
//! pool worker plays the old device thread, the PJRT driver is cached
//! on it, and oversized instances are rejected by the pool's admission
//! control instead of ad-hoc checks.

use std::sync::mpsc;

use anyhow::Result;

use crate::graph::AssignmentInstance;
use crate::service::{
    AssignBackend, PoolConfig, ProblemInstance, ReplyError, RouterConfig, ShardConfig,
    SolveOutcome, SolveReply, SolverPool,
};

/// Service configuration (legacy shape).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Kept for API compatibility only; the pool drains continuously.
    pub max_batch: usize,
    /// Prefer the PJRT backend when artifacts are discoverable.
    pub use_pjrt: bool,
    /// Maximum instance size accepted.
    pub max_n: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            use_pjrt: true,
            max_n: 64,
        }
    }
}

/// Reply for one request.
#[derive(Debug, Clone)]
pub struct ServiceReply {
    pub id: u64,
    pub assignment: Vec<usize>,
    pub weight: i64,
    /// Seconds from submit to completion.
    pub latency: f64,
    /// Seconds spent queued before solving started.
    pub queue_delay: f64,
    pub backend: &'static str,
}

/// Aggregate service statistics, returned at shutdown.
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub served: usize,
    /// The pool drains continuously; kept equal to `served` for
    /// report-shape compatibility.
    pub batches: usize,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub mean_latency: f64,
    pub throughput_rps: f64,
    pub backend: &'static str,
    /// Requests served per backend name, from the pool's routing
    /// telemetry (the legacy `backend` field keeps the old pjrt/native
    /// dichotomy).
    pub backends: Vec<(&'static str, usize)>,
    /// Retry attempts the pool made across all requests.
    pub retries: u64,
    /// Circuit breakers not closed at shutdown.
    pub breakers_open: usize,
}

/// Receiver for one reply; adapts the pool's [`SolveReply`] to the
/// legacy [`ServiceReply`] at `recv` time.
pub struct ReplyReceiver {
    rx: mpsc::Receiver<Result<SolveReply, ReplyError>>,
}

impl ReplyReceiver {
    pub fn recv(&self) -> Result<Result<ServiceReply, String>, mpsc::RecvError> {
        // The legacy API reports errors as strings; the typed
        // `ReplyError` renders the same "too large" / "queue full"
        // messages old callers match on.
        Ok(self
            .rx
            .recv()?
            .map_err(|e| e.to_string())
            .and_then(convert_reply))
    }
}

fn convert_reply(reply: SolveReply) -> Result<ServiceReply, String> {
    match reply.outcome {
        SolveOutcome::Assignment(r) => Ok(ServiceReply {
            id: reply.id,
            assignment: r.assignment,
            weight: r.weight,
            latency: reply.latency,
            queue_delay: reply.queue_delay,
            // The legacy report distinguished only the device path from
            // "some native engine".
            backend: if reply.backend == "pjrt" { "pjrt" } else { "native" },
        }),
        SolveOutcome::Grid(_) => Err("assignment service received a grid reply".to_string()),
    }
}

/// Handle to the running service.
pub struct AssignmentService {
    pool: SolverPool,
    use_pjrt: bool,
}

impl AssignmentService {
    /// Start the service: one pool worker in the old device-thread
    /// role (the PJRT handles are `!Send`, so they cache on it).
    pub fn start(cfg: ServiceConfig) -> Self {
        let max_units = cfg.max_n.max(1) * cfg.max_n.max(1);
        let pool_cfg = PoolConfig {
            workers: 1,
            shard: ShardConfig {
                // Every admitted instance lands in the Small lane; the
                // admission cap is the old `max_n` check.  The legacy
                // queue was unbounded, so the shim must not introduce
                // backpressure rejections old callers never handled.
                small_max_units: max_units,
                medium_max_units: max_units,
                max_units,
                queue_depth: usize::MAX,
            },
            router: RouterConfig {
                // The old fallback engine was the dense wave twin.
                assign: [AssignBackend::WaveCsa; 3],
                use_pjrt: cfg.use_pjrt,
                pjrt_max_n: cfg.max_n,
                ..RouterConfig::default()
            },
            session_budget_mb: 64,
        };
        Self {
            pool: SolverPool::start(pool_cfg),
            use_pjrt: cfg.use_pjrt,
        }
    }

    /// Submit an instance; returns a receiver for the reply.  A
    /// rejection (oversized, queue full) arrives through the receiver
    /// as `Err(reason)`.
    pub fn submit(&self, instance: AssignmentInstance) -> ReplyReceiver {
        ReplyReceiver {
            rx: self.pool.submit(ProblemInstance::Assignment(instance)),
        }
    }

    /// Stop the worker and collect the aggregate report.
    pub fn shutdown(self) -> Result<ServiceReport> {
        let use_pjrt = self.use_pjrt;
        let report = self.pool.shutdown();
        let backend = if use_pjrt && report.served_by("pjrt") > 0 {
            "pjrt"
        } else {
            "native"
        };
        let s = report.latency;
        Ok(ServiceReport {
            served: report.served,
            batches: report.served,
            p50_latency: s.as_ref().map_or(0.0, |s| s.p50),
            p99_latency: s.as_ref().map_or(0.0, |s| s.p99),
            mean_latency: s.as_ref().map_or(0.0, |s| s.mean),
            throughput_rps: report.throughput_rps,
            backend,
            retries: report.retries,
            breakers_open: report.breakers_open(),
            backends: report.backends,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;
    use crate::assignment::AssignmentSolver;
    use crate::util::Rng;
    use crate::workloads::bipartite_gen::uniform_costs;

    #[test]
    fn service_solves_requests_natively() {
        let service = AssignmentService::start(ServiceConfig {
            use_pjrt: false,
            max_batch: 4,
            max_n: 32,
        });
        let mut rng = Rng::seeded(81);
        let mut receivers = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..6 {
            let inst = uniform_costs(&mut rng, 10, 100);
            wants.push(Hungarian.solve(&inst).unwrap().weight);
            receivers.push(service.submit(inst));
        }
        for (rx, want) in receivers.into_iter().zip(wants) {
            let reply = rx.recv().unwrap().unwrap();
            assert_eq!(reply.weight, want);
            assert!(reply.latency >= 0.0);
            assert_eq!(reply.backend, "native");
        }
        let report = service.shutdown().unwrap();
        assert_eq!(report.served, 6);
        assert!(report.batches >= 1);
        // The per-backend breakdown names the real engine behind the
        // legacy "native" label (the shim's fallback is the wave twin).
        assert_eq!(report.backends, vec![("csa-wave", 6)]);
    }

    #[test]
    fn oversized_requests_rejected() {
        let service = AssignmentService::start(ServiceConfig {
            use_pjrt: false,
            max_batch: 2,
            max_n: 4,
        });
        let mut rng = Rng::seeded(83);
        let inst = uniform_costs(&mut rng, 8, 10);
        let rx = service.submit(inst);
        let reply = rx.recv().unwrap();
        assert!(reply.is_err());
        assert!(reply.unwrap_err().contains("too large"));
    }
}
