//! Batched assignment service — the deployment shape of the paper's §6
//! claim ("about 1/20 s, which allows for real-time applications"): a
//! dedicated device thread owns the PJRT state (the `xla` handles are
//! `!Send`, exactly like a CUDA context) and serves matching requests
//! from a queue, draining them in batches.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::assignment::wave::WaveCsa;
use crate::assignment::AssignmentSolver;
use crate::graph::AssignmentInstance;
use crate::runtime::ArtifactRegistry;

use super::assignment_driver::PjrtAssignmentDriver;
use super::metrics::LatencyRecorder;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Max requests drained per batch.
    pub max_batch: usize,
    /// Prefer the PJRT backend when artifacts are discoverable.
    pub use_pjrt: bool,
    /// Maximum instance size accepted.
    pub max_n: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            use_pjrt: true,
            max_n: 64,
        }
    }
}

/// Reply for one request.
#[derive(Debug, Clone)]
pub struct ServiceReply {
    pub id: u64,
    pub assignment: Vec<usize>,
    pub weight: i64,
    /// Seconds from submit to completion.
    pub latency: f64,
    /// Seconds spent queued before solving started.
    pub queue_delay: f64,
    pub backend: &'static str,
}

struct Job {
    id: u64,
    instance: AssignmentInstance,
    submitted: Instant,
    reply: mpsc::Sender<Result<ServiceReply, String>>,
}

enum Msg {
    Job(Box<Job>),
    Shutdown(mpsc::Sender<ServiceReport>),
}

/// Aggregate service statistics, returned at shutdown.
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub served: usize,
    pub batches: usize,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub mean_latency: f64,
    pub throughput_rps: f64,
    pub backend: &'static str,
}

/// Handle to the running service (clonable submitter).
pub struct AssignmentService {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl AssignmentService {
    /// Start the device thread.
    pub fn start(cfg: ServiceConfig) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || worker_loop(cfg, rx));
        Self {
            tx,
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Submit an instance; returns a receiver for the reply.
    pub fn submit(
        &self,
        instance: AssignmentInstance,
    ) -> mpsc::Receiver<Result<ServiceReply, String>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let job = Job {
            id,
            instance,
            submitted: Instant::now(),
            reply: reply_tx,
        };
        // A send failure means the worker died; the receiver will report
        // a disconnect to the caller.
        let _ = self.tx.send(Msg::Job(Box::new(job)));
        reply_rx
    }

    /// Stop the worker and collect the aggregate report.
    pub fn shutdown(mut self) -> Result<ServiceReport> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Shutdown(tx))
            .map_err(|_| anyhow::anyhow!("service already stopped"))?;
        let report = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("service dropped the report"))?;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        Ok(report)
    }
}

impl Drop for AssignmentService {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let (tx, _rx) = mpsc::channel();
            let _ = self.tx.send(Msg::Shutdown(tx));
            let _ = w.join();
        }
    }
}

fn worker_loop(cfg: ServiceConfig, rx: mpsc::Receiver<Msg>) {
    // Device state lives on this thread only.
    let mut driver: Option<PjrtAssignmentDriver> = if cfg.use_pjrt {
        ArtifactRegistry::discover()
            .ok()
            .and_then(|reg| PjrtAssignmentDriver::for_size(&reg, cfg.max_n).ok())
    } else {
        None
    };
    let backend: &'static str = if driver.is_some() { "pjrt" } else { "native" };
    let fallback = WaveCsa::default();

    let mut recorder = LatencyRecorder::new();
    let mut batches = 0usize;

    let solve = |job: &Job, driver: &mut Option<PjrtAssignmentDriver>| {
        let queue_delay = job.submitted.elapsed().as_secs_f64();
        let outcome = if job.instance.n > cfg.max_n {
            Err(format!(
                "instance n={} exceeds service max_n={}",
                job.instance.n, cfg.max_n
            ))
        } else {
            let solved = match driver {
                Some(d) => d.solve(&job.instance).map(|(r, _)| r),
                None => fallback.solve(&job.instance),
            };
            solved.map_err(|e| e.to_string())
        };
        (queue_delay, outcome)
    };

    loop {
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        // Drain a batch.
        let mut batch = Vec::new();
        let mut shutdown: Option<mpsc::Sender<ServiceReport>> = None;
        match first {
            Msg::Job(j) => batch.push(j),
            Msg::Shutdown(tx) => shutdown = Some(tx),
        }
        while shutdown.is_none() && batch.len() < cfg.max_batch {
            match rx.try_recv() {
                Ok(Msg::Job(j)) => batch.push(j),
                Ok(Msg::Shutdown(tx)) => {
                    shutdown = Some(tx);
                    break;
                }
                Err(_) => break,
            }
        }
        if !batch.is_empty() {
            batches += 1;
        }
        for job in batch {
            let (queue_delay, outcome) = solve(&job, &mut driver);
            let latency = job.submitted.elapsed().as_secs_f64();
            recorder.record(latency);
            let reply = outcome.map(|r| ServiceReply {
                id: job.id,
                assignment: r.assignment,
                weight: r.weight,
                latency,
                queue_delay,
                backend,
            });
            let _ = job.reply.send(reply);
        }
        if let Some(tx) = shutdown {
            let summary = recorder.summary();
            let report = ServiceReport {
                served: recorder.count(),
                batches,
                p50_latency: summary.as_ref().map_or(0.0, |s| s.p50),
                p99_latency: summary.as_ref().map_or(0.0, |s| s.p99),
                mean_latency: summary.as_ref().map_or(0.0, |s| s.mean),
                throughput_rps: recorder.throughput(),
                backend,
            };
            let _ = tx.send(report);
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;
    use crate::util::Rng;
    use crate::workloads::bipartite_gen::uniform_costs;

    #[test]
    fn service_solves_requests_natively() {
        let service = AssignmentService::start(ServiceConfig {
            use_pjrt: false,
            max_batch: 4,
            max_n: 32,
        });
        let mut rng = Rng::seeded(81);
        let mut receivers = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..6 {
            let inst = uniform_costs(&mut rng, 10, 100);
            wants.push(Hungarian.solve(&inst).unwrap().weight);
            receivers.push(service.submit(inst));
        }
        for (rx, want) in receivers.into_iter().zip(wants) {
            let reply = rx.recv().unwrap().unwrap();
            assert_eq!(reply.weight, want);
            assert!(reply.latency >= 0.0);
            assert_eq!(reply.backend, "native");
        }
        let report = service.shutdown().unwrap();
        assert_eq!(report.served, 6);
        assert!(report.batches >= 1);
    }

    #[test]
    fn oversized_requests_rejected() {
        let service = AssignmentService::start(ServiceConfig {
            use_pjrt: false,
            max_batch: 2,
            max_n: 4,
        });
        let mut rng = Rng::seeded(83);
        let inst = uniform_costs(&mut rng, 8, 10);
        let rx = service.submit(inst);
        let reply = rx.recv().unwrap();
        assert!(reply.is_err());
    }
}
