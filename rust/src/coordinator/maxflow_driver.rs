//! Grid max-flow driver: pick the device phase (PJRT artifact when one
//! matches the shape, native wave engine otherwise) and run the hybrid
//! scheme.  This is Algorithm 4.6 with PJRT in the CUDA role.

use anyhow::Result;

use crate::graph::GridNetwork;
use crate::gridflow::{GridSolveReport, HybridGridSolver, NativeGridExecutor};
use crate::runtime::{ArtifactRegistry, GridDevice};

/// Which device phase backed a solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Native,
}

/// Solve `net` with the hybrid scheme; prefers the PJRT artifact.
/// Returns the report plus the backend used.
pub fn solve_grid(
    net: &GridNetwork,
    cycle_waves: usize,
    registry: Option<&ArtifactRegistry>,
) -> Result<(GridSolveReport, Backend)> {
    let solver = HybridGridSolver::with_cycle(cycle_waves);
    if let Some(reg) = registry {
        if let Ok(mut dev) = GridDevice::for_shape(reg, net.height, net.width) {
            let report = solver.solve(net, &mut dev)?;
            return Ok((report, Backend::Pjrt));
        }
    }
    let mut exec = NativeGridExecutor::default();
    let report = solver.solve(net, &mut exec)?;
    Ok((report, Backend::Native))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maxflow::{dinic::Dinic, MaxFlowSolver};
    use crate::util::Rng;
    use crate::workloads::grid_gen::random_grid;

    #[test]
    fn native_fallback_matches_baseline() {
        let mut rng = Rng::seeded(77);
        let net = random_grid(&mut rng, 6, 6, 8, 0.3, 0.3);
        let (report, backend) = solve_grid(&net, 128, None).unwrap();
        assert_eq!(backend, Backend::Native);
        let mut g = net.to_flow_network();
        let want = Dinic.solve(&mut g).unwrap();
        assert_eq!(report.flow, want.value);
    }
}
