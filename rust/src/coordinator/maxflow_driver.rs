//! Grid max-flow driver: pick the device phase (PJRT artifact when one
//! matches the shape, a native wave engine otherwise) and run the hybrid
//! scheme.  This is Algorithm 4.6 with PJRT in the CUDA role; the tiled
//! multi-threaded engine stands in when several host cores are the best
//! hardware available.

use anyhow::Result;

use crate::graph::GridNetwork;
use crate::gridflow::{
    GridSolveReport, HybridGridSolver, NativeGridExecutor, NativeParGridExecutor,
};
use crate::runtime::{ArtifactRegistry, GridDevice};

/// Which device phase backed a solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Native,
    NativePar,
}

/// Device-phase selection for [`solve_grid_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridEngine {
    /// PJRT artifact when one matches the shape, else the sequential
    /// native twin.
    Auto,
    /// Force the single-threaded native twin.
    Native,
    /// Force the multi-threaded tiled engine (bit-exact with `Native`).
    NativePar { threads: usize, tile_rows: usize },
}

/// Solve `net` with the hybrid scheme; prefers the PJRT artifact.
/// Returns the report plus the backend used.
pub fn solve_grid(
    net: &GridNetwork,
    cycle_waves: usize,
    registry: Option<&ArtifactRegistry>,
) -> Result<(GridSolveReport, Backend)> {
    solve_grid_with(net, cycle_waves, registry, GridEngine::Auto)
}

/// Solve `net` with an explicit device-phase choice.
pub fn solve_grid_with(
    net: &GridNetwork,
    cycle_waves: usize,
    registry: Option<&ArtifactRegistry>,
    engine: GridEngine,
) -> Result<(GridSolveReport, Backend)> {
    let solver = HybridGridSolver::with_cycle(cycle_waves);
    match engine {
        GridEngine::NativePar { threads, tile_rows } => {
            let mut exec = NativeParGridExecutor::new(threads, tile_rows);
            let report = solver.solve(net, &mut exec)?;
            return Ok((report, Backend::NativePar));
        }
        GridEngine::Native => {
            let mut exec = NativeGridExecutor::default();
            let report = solver.solve(net, &mut exec)?;
            return Ok((report, Backend::Native));
        }
        GridEngine::Auto => {}
    }
    if let Some(reg) = registry {
        if let Ok(mut dev) = GridDevice::for_shape(reg, net.height, net.width) {
            let report = solver.solve(net, &mut dev)?;
            return Ok((report, Backend::Pjrt));
        }
    }
    let mut exec = NativeGridExecutor::default();
    let report = solver.solve(net, &mut exec)?;
    Ok((report, Backend::Native))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maxflow::{dinic::Dinic, MaxFlowSolver};
    use crate::util::Rng;
    use crate::workloads::grid_gen::random_grid;

    #[test]
    fn native_fallback_matches_baseline() {
        let mut rng = Rng::seeded(77);
        let net = random_grid(&mut rng, 6, 6, 8, 0.3, 0.3);
        let (report, backend) = solve_grid(&net, 128, None).unwrap();
        assert_eq!(backend, Backend::Native);
        let mut g = net.to_flow_network();
        let want = Dinic.solve(&mut g).unwrap();
        assert_eq!(report.flow, want.value);
    }

    #[test]
    fn forced_parallel_engine_matches_baseline() {
        let mut rng = Rng::seeded(78);
        let net = random_grid(&mut rng, 7, 9, 10, 0.3, 0.3);
        let (seq, b0) = solve_grid_with(&net, 128, None, GridEngine::Native).unwrap();
        assert_eq!(b0, Backend::Native);
        for (threads, tile_rows) in [(1, 2), (2, 3), (4, 16)] {
            let (par, b1) = solve_grid_with(
                &net,
                128,
                None,
                GridEngine::NativePar { threads, tile_rows },
            )
            .unwrap();
            assert_eq!(b1, Backend::NativePar);
            assert_eq!(par.flow, seq.flow, "t={threads} tr={tile_rows}");
            assert_eq!(par.waves, seq.waves, "t={threads} tr={tile_rows}");
        }
    }
}
