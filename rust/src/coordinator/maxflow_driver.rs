//! Grid max-flow driver: pick the device phase (PJRT artifact when one
//! matches the shape, a native wave engine otherwise) and run the hybrid
//! scheme.  This is Algorithm 4.6 with PJRT in the CUDA role; the tiled
//! multi-threaded engine stands in when several host cores are the best
//! hardware available.

use std::sync::Arc;

use anyhow::Result;

use crate::graph::GridNetwork;
use crate::gridflow::{
    padded_class, BatchGridSolver, GridSolveReport, HostRounds, HybridGridSolver,
    NativeGridExecutor, NativeParGridExecutor,
};
use crate::runtime::{ArtifactRegistry, BatchedGridDriver, GridDevice, SimGridDevice};
use crate::service::pool::WorkerPool;
use crate::util::CancelToken;

/// Which device phase backed a solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Native,
    NativePar,
}

/// Device-phase selection for [`solve_grid_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridEngine {
    /// PJRT artifact when one matches the shape, else the sequential
    /// native twin.
    Auto,
    /// Force the device path: the PJRT artifact when one matches the
    /// shape, else the deterministic host-simulated device
    /// ([`SimGridDevice`] — same packed wire format, bit-exact waves),
    /// so the path is exercisable in device-free containers.
    Pjrt,
    /// Force the single-threaded native twin.
    Native,
    /// Force the multi-threaded tiled engine (bit-exact with `Native`).
    NativePar { threads: usize, tile_rows: usize },
}

/// Solve `net` with the hybrid scheme; prefers the PJRT artifact.
/// Returns the report plus the backend used.
pub fn solve_grid(
    net: &GridNetwork,
    cycle_waves: usize,
    registry: Option<&ArtifactRegistry>,
) -> Result<(GridSolveReport, Backend)> {
    solve_grid_with(net, cycle_waves, registry, GridEngine::Auto)
}

/// Solve `net` with an explicit device-phase choice.
pub fn solve_grid_with(
    net: &GridNetwork,
    cycle_waves: usize,
    registry: Option<&ArtifactRegistry>,
    engine: GridEngine,
) -> Result<(GridSolveReport, Backend)> {
    solve_grid_opts(net, cycle_waves, registry, engine, HostRounds::Seq, None)
}

/// Solve `net` with an explicit device-phase choice *and* host-round
/// policy.  With `host_rounds = Striped`, the host BFS fans out on
/// `pool` — pass one when solving in a loop so the worker threads are
/// reused across solves; with `None` a pool is created for this call
/// (on `NativePar` it also carries the wave phases, bit-exact either
/// way).
pub fn solve_grid_opts(
    net: &GridNetwork,
    cycle_waves: usize,
    registry: Option<&ArtifactRegistry>,
    engine: GridEngine,
    host_rounds: HostRounds,
    pool: Option<Arc<WorkerPool>>,
) -> Result<(GridSolveReport, Backend)> {
    let pool = match (host_rounds, pool) {
        (HostRounds::Seq, _) => None,
        (HostRounds::Striped, Some(p)) => Some(p),
        (HostRounds::Striped, None) => {
            let width = match engine {
                GridEngine::NativePar { threads, .. } => threads.max(1),
                _ => std::thread::available_parallelism().map_or(4, |n| n.get()).min(8),
            };
            Some(Arc::new(WorkerPool::new(width)))
        }
    };
    let mut solver = HybridGridSolver::with_cycle(cycle_waves).with_host_rounds(host_rounds);
    if let Some(p) = &pool {
        solver = solver.with_host_pool(Arc::clone(p));
    }
    match engine {
        GridEngine::NativePar { threads, tile_rows } => {
            let mut exec = NativeParGridExecutor::new(threads, tile_rows);
            if let Some(p) = &pool {
                exec = exec.with_pool(Arc::clone(p));
            }
            let report = solver.solve(net, &mut exec)?;
            return Ok((report, Backend::NativePar));
        }
        GridEngine::Native => {
            let mut exec = NativeGridExecutor::default();
            let report = solver.solve(net, &mut exec)?;
            return Ok((report, Backend::Native));
        }
        GridEngine::Pjrt => {
            if let Some(reg) = registry {
                if let Ok(mut dev) = GridDevice::for_shape(reg, net.height, net.width) {
                    let report = solver.solve(net, &mut dev)?;
                    return Ok((report, Backend::Pjrt));
                }
            }
            // No artifact for this shape: the host-simulated device
            // keeps the path deterministic (and bit-exact with Native).
            let mut dev = SimGridDevice::for_shape(net.height, net.width);
            let report = solver.solve(net, &mut dev)?;
            return Ok((report, Backend::Pjrt));
        }
        GridEngine::Auto => {}
    }
    if let Some(reg) = registry {
        if let Ok(mut dev) = GridDevice::for_shape(reg, net.height, net.width) {
            let report = solver.solve(net, &mut dev)?;
            return Ok((report, Backend::Pjrt));
        }
    }
    let mut exec = NativeGridExecutor::default();
    let report = solver.solve(net, &mut exec)?;
    Ok((report, Backend::Native))
}

/// Batched device entry point: solve K grid instances of one padded
/// size class as joint device dispatches (see
/// [`crate::runtime::BatchedGridDriver`]).  `cancels[k]` carries slot
/// k's own deadline — an expired slot retires with the typed
/// [`crate::util::Cancelled`] error while its batchmates solve on.
///
/// Today the dispatches run on the deterministic host-simulated device
/// (bit-exact with the native oracle); a PJRT artifact compiled for the
/// padded `[K, planes, Hmax, Wmax]` shape slots in behind the same
/// driver when the toolchain lands (`registry` is accepted now so call
/// sites don't change).
pub fn solve_grid_batch(
    nets: &[&GridNetwork],
    cycle_waves: usize,
    _registry: Option<&ArtifactRegistry>,
    cancels: &[Option<CancelToken>],
) -> Result<Vec<Result<GridSolveReport>>> {
    let (hmax, wmax) = padded_class(nets);
    let mut driver = BatchedGridDriver::for_class(hmax, wmax);
    BatchGridSolver::with_cycle(cycle_waves).solve_batch(nets, cancels, &mut driver)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maxflow::{dinic::Dinic, MaxFlowSolver};
    use crate::util::Rng;
    use crate::workloads::grid_gen::random_grid;

    #[test]
    fn native_fallback_matches_baseline() {
        let mut rng = Rng::seeded(77);
        let net = random_grid(&mut rng, 6, 6, 8, 0.3, 0.3);
        let (report, backend) = solve_grid(&net, 128, None).unwrap();
        assert_eq!(backend, Backend::Native);
        let mut g = net.to_flow_network();
        let want = Dinic.solve(&mut g).unwrap();
        assert_eq!(report.flow, want.value);
    }

    #[test]
    fn striped_host_rounds_match_sequential_rounds() {
        use crate::gridflow::HostRounds;

        let mut rng = Rng::seeded(79);
        let net = random_grid(&mut rng, 11, 8, 12, 0.3, 0.3);
        let pool = Arc::new(WorkerPool::new(3));
        for engine in [
            GridEngine::Native,
            GridEngine::NativePar { threads: 3, tile_rows: 2 },
        ] {
            let (seq, _) = solve_grid_opts(&net, 96, None, engine, HostRounds::Seq, None).unwrap();
            // Once with a caller-lent pool, once letting the driver
            // create its own.
            let (par, _) = solve_grid_opts(
                &net,
                96,
                None,
                engine,
                HostRounds::Striped,
                Some(Arc::clone(&pool)),
            )
            .unwrap();
            let (par2, _) =
                solve_grid_opts(&net, 96, None, engine, HostRounds::Striped, None).unwrap();
            assert_eq!(par2.flow, seq.flow, "{engine:?} own-pool");
            assert_eq!(par2.waves, seq.waves, "{engine:?} own-pool");
            assert_eq!(par.flow, seq.flow, "{engine:?}");
            assert_eq!(par.waves, seq.waves, "{engine:?}");
            assert_eq!(par.pushes, seq.pushes, "{engine:?}");
            assert_eq!(par.relabels, seq.relabels, "{engine:?}");
            assert_eq!(par.gap_cells, seq.gap_cells, "{engine:?}");
            assert_eq!(par.cancelled_arcs, seq.cancelled_arcs, "{engine:?}");
        }
    }

    /// The explicit device path (host-simulated without an artifact) is
    /// the native engine's bit-exact twin through the packed wire format.
    #[test]
    fn forced_pjrt_sim_engine_matches_baseline() {
        let mut rng = Rng::seeded(82);
        let net = random_grid(&mut rng, 6, 9, 10, 0.3, 0.3);
        let (seq, b0) = solve_grid_with(&net, 128, None, GridEngine::Native).unwrap();
        assert_eq!(b0, Backend::Native);
        let (dev, b1) = solve_grid_with(&net, 128, None, GridEngine::Pjrt).unwrap();
        assert_eq!(b1, Backend::Pjrt);
        assert_eq!(dev.flow, seq.flow);
        assert_eq!(dev.waves, seq.waves);
        assert_eq!(dev.pushes, seq.pushes);
        assert_eq!(dev.relabels, seq.relabels);
        assert_eq!(dev.host_rounds, seq.host_rounds);
    }

    /// The batched entry point reproduces every per-instance device
    /// solve (which itself matches Native) across a ragged batch.
    #[test]
    fn batched_entry_point_matches_per_instance() {
        let nets: Vec<GridNetwork> = [(83u64, 5, 8), (84, 8, 5), (85, 8, 8)]
            .iter()
            .map(|&(seed, h, w)| {
                let mut rng = Rng::seeded(seed);
                random_grid(&mut rng, h, w, 10, 0.3, 0.3)
            })
            .collect();
        let refs: Vec<&GridNetwork> = nets.iter().collect();
        let cancels = vec![None; refs.len()];
        let got = solve_grid_batch(&refs, 96, None, &cancels).unwrap();
        for (k, (net, report)) in nets.iter().zip(got).enumerate() {
            let report = report.unwrap();
            let (solo, _) = solve_grid_with(net, 96, None, GridEngine::Pjrt).unwrap();
            assert_eq!(report.flow, solo.flow, "slot {k}");
            assert_eq!(report.waves, solo.waves, "slot {k}");
            assert_eq!(report.pushes, solo.pushes, "slot {k}");
            assert_eq!(report.relabels, solo.relabels, "slot {k}");
            assert_eq!(report.host_rounds, solo.host_rounds, "slot {k}");
        }
    }

    #[test]
    fn forced_parallel_engine_matches_baseline() {
        let mut rng = Rng::seeded(78);
        let net = random_grid(&mut rng, 7, 9, 10, 0.3, 0.3);
        let (seq, b0) = solve_grid_with(&net, 128, None, GridEngine::Native).unwrap();
        assert_eq!(b0, Backend::Native);
        for (threads, tile_rows) in [(1, 2), (2, 3), (4, 16)] {
            let (par, b1) = solve_grid_with(
                &net,
                128,
                None,
                GridEngine::NativePar { threads, tile_rows },
            )
            .unwrap();
            assert_eq!(b1, Backend::NativePar);
            assert_eq!(par.flow, seq.flow, "t={threads} tr={tile_rows}");
            assert_eq!(par.waves, seq.waves, "t={threads} tr={tile_rows}");
        }
    }
}
