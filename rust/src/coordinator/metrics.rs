//! Latency/throughput metrics for the service (E7 reporting).
//!
//! The recorder implementation lives in [`crate::util::stats`] so the
//! coordinator shim and the solver pool share one accounting substrate;
//! this module keeps the historical `coordinator::metrics` path alive.

pub use crate::util::stats::LatencyRecorder;
