//! Latency/throughput metrics for the service (E7 reporting).

use crate::util::stats::Summary;

/// Accumulates per-request latencies (seconds).
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
    started: Option<std::time::Instant>,
    finished: Option<std::time::Instant>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_start(&mut self) {
        self.started.get_or_insert_with(std::time::Instant::now);
    }

    pub fn record(&mut self, latency_secs: f64) {
        self.mark_start();
        self.samples.push(latency_secs);
        self.finished = Some(std::time::Instant::now());
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn summary(&self) -> Option<Summary> {
        Summary::of(&self.samples)
    }

    /// Requests per second over the recording window.
    pub fn throughput(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => self.samples.len() as f64 / (b - a).as_secs_f64(),
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut r = LatencyRecorder::new();
        r.record(0.010);
        r.record(0.020);
        r.record(0.030);
        let s = r.summary().unwrap();
        assert_eq!(s.count, 3);
        assert!((s.mean - 0.020).abs() < 1e-9);
        assert!(r.throughput() >= 0.0);
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::new();
        assert!(r.summary().is_none());
        assert_eq!(r.throughput(), 0.0);
    }
}
