//! PJRT-backed assignment solver: the cost-scaling outer loop on the
//! host, the lock-free refine waves on the device (the paper's §5.5
//! architecture), with the price-update heuristic run host-side between
//! device rounds and instances padded up to the artifact size.

use anyhow::Result;

use crate::assignment::price_update::price_update;
use crate::assignment::scaling::{epsilon_schedule, CsaState};
use crate::assignment::{AssignStats, AssignmentResult};
use crate::graph::AssignmentInstance;
use crate::runtime::device::CsaWireState;
use crate::runtime::{ArtifactRegistry, CsaDevice};

/// Per-solve telemetry beyond the engine counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct SolveTelemetry {
    pub device_rounds: u64,
    pub host_price_updates: u64,
    pub padded_n: usize,
    pub device_seconds: f64,
    pub host_seconds: f64,
}

/// The driver; owns one compiled artifact (device kernels are shape-
/// specialised, so one driver serves all instances with `n <= padded_n`).
pub struct PjrtAssignmentDriver {
    dev: CsaDevice,
    /// Device super-step budget per round (`outer`); CYCLE = outer * K_INNER.
    pub outer_per_round: i32,
    /// Run the host price-update heuristic between device rounds.
    pub price_updates: bool,
    /// Scaling factor (paper: ALPHA = 10).
    pub alpha: i64,
}

impl PjrtAssignmentDriver {
    pub fn for_size(reg: &ArtifactRegistry, n: usize) -> Result<Self> {
        Ok(Self {
            dev: CsaDevice::for_size(reg, n)?,
            outer_per_round: 64,
            price_updates: true,
            alpha: 10,
        })
    }

    pub fn padded_n(&self) -> usize {
        self.dev.n
    }

    fn state_to_wire(st: &CsaState, cost: &[i32]) -> CsaWireState {
        CsaWireState {
            n: st.n,
            cost: cost.to_vec(),
            f: st.f.clone(),
            px: st.px.iter().map(|&v| v as i32).collect(),
            py: st.py.iter().map(|&v| v as i32).collect(),
            ex: st.ex.iter().map(|&v| v as i32).collect(),
            ey: st.ey.iter().map(|&v| v as i32).collect(),
        }
    }

    fn wire_to_state(wire: &CsaWireState, st: &mut CsaState) {
        st.f.copy_from_slice(&wire.f);
        for (d, s) in st.px.iter_mut().zip(&wire.px) {
            *d = *s as i64;
        }
        for (d, s) in st.py.iter_mut().zip(&wire.py) {
            *d = *s as i64;
        }
        for (d, s) in st.ex.iter_mut().zip(&wire.ex) {
            *d = *s as i64;
        }
        for (d, s) in st.ey.iter_mut().zip(&wire.ey) {
            *d = *s as i64;
        }
    }

    /// Solve a (possibly smaller) instance.
    pub fn solve(&mut self, inst: &AssignmentInstance) -> Result<(AssignmentResult, SolveTelemetry)> {
        let m = self.dev.n;
        anyhow::ensure!(inst.n <= m, "instance n={} exceeds artifact n={m}", inst.n);
        let padded = if inst.n == m {
            inst.clone()
        } else {
            inst.pad(m)
        };
        let cost_i32 = padded.scaled_costs_i32();
        let (mut st, eps0) = CsaState::new(&padded);
        let mut stats = AssignStats::default();
        let mut tel = SolveTelemetry {
            padded_n: m,
            ..Default::default()
        };

        for eps in epsilon_schedule(eps0, self.alpha) {
            let host_t = crate::util::Timer::start();
            st.reset_refine(eps);
            tel.host_seconds += host_t.elapsed();
            let mut wire = Self::state_to_wire(&st, &cost_i32);
            loop {
                let dev_t = crate::util::Timer::start();
                let step = self.dev.step(&mut wire, eps as i32, self.outer_per_round)?;
                tel.device_seconds += dev_t.elapsed();
                tel.device_rounds += 1;
                stats.pushes += step.pushes as u64;
                stats.relabels += step.relabels as u64;
                stats.waves += step.waves as u64;
                if step.active() == 0 {
                    break;
                }
                if self.price_updates {
                    // Host heuristic round (paper §5.5: heuristics between
                    // kernel launches): pull prices, bucket-Dijkstra, push
                    // only the updated prices back (PERF: the cost matrix
                    // and flows are unchanged by the heuristic — rebuilding
                    // the whole wire image copied n² ints per round).
                    let host_t = crate::util::Timer::start();
                    Self::wire_to_state(&wire, &mut st);
                    price_update(&mut st, eps);
                    stats.price_updates += 1;
                    tel.host_price_updates += 1;
                    for (d, s) in wire.px.iter_mut().zip(&st.px) {
                        *d = *s as i32;
                    }
                    for (d, s) in wire.py.iter_mut().zip(&st.py) {
                        *d = *s as i32;
                    }
                    tel.host_seconds += host_t.elapsed();
                }
            }
            Self::wire_to_state(&wire, &mut st);
            stats.refines += 1;
            anyhow::ensure!(st.is_flow(), "device refine at eps={eps} incomplete");
        }

        let padded_assign = st.assignment();
        let assignment = inst.unpad_assignment(&padded_assign);
        let weight = inst.assignment_weight(&assignment);
        Ok((
            AssignmentResult {
                assignment,
                weight,
                stats,
            },
            tel,
        ))
    }
}
