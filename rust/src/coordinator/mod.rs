//! L3 coordination: the paper's CPU–GPU hybrid drivers with the PJRT
//! device in the GPU role, plus the legacy assignment-service shim
//! (the runtime itself now lives in `crate::service`).

pub mod assignment_driver;
pub mod maxflow_driver;
pub mod server;

pub use assignment_driver::{PjrtAssignmentDriver, SolveTelemetry};
pub use maxflow_driver::{
    solve_grid, solve_grid_batch, solve_grid_opts, solve_grid_with, Backend, GridEngine,
};
// Deprecated alias: the recorder lives in `util::stats` since PR 4 and
// the `coordinator::metrics` shim module is gone — import
// `util::stats::LatencyRecorder` in new code; this re-export keeps the
// old `coordinator::LatencyRecorder` path compiling.
pub use crate::util::stats::LatencyRecorder;
pub use server::{AssignmentService, ReplyReceiver, ServiceConfig, ServiceReply, ServiceReport};
