//! L3 coordination: the paper's CPU–GPU hybrid drivers with the PJRT
//! device in the GPU role, plus the legacy assignment-service shim
//! (the runtime itself now lives in `crate::service`).

pub mod assignment_driver;
pub mod maxflow_driver;
pub mod metrics;
pub mod server;

pub use assignment_driver::{PjrtAssignmentDriver, SolveTelemetry};
pub use maxflow_driver::{solve_grid, solve_grid_with, Backend, GridEngine};
pub use metrics::LatencyRecorder;
pub use server::{AssignmentService, ReplyReceiver, ServiceConfig, ServiceReply, ServiceReport};
