//! L3 coordination: the paper's CPU–GPU hybrid drivers with the PJRT
//! device in the GPU role, plus the batched assignment service that
//! serves the §6 real-time use case.

pub mod assignment_driver;
pub mod maxflow_driver;
pub mod metrics;
pub mod server;

pub use assignment_driver::{PjrtAssignmentDriver, SolveTelemetry};
pub use maxflow_driver::{solve_grid, solve_grid_with, Backend, GridEngine};
pub use metrics::LatencyRecorder;
pub use server::{AssignmentService, ServiceConfig, ServiceReply, ServiceReport};
