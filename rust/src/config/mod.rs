//! Config system: typed `key = value` files (a TOML subset: sections,
//! comments, strings/ints/floats/bools) merged with CLI overrides —
//! enough to parameterise the launcher and the benches reproducibly.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A flat config: section-qualified keys (`section.key`) to raw strings.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected key = value", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert(key, val);
        }
        Ok(Self { values })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Overlay `other` on top of `self` (later wins).
    pub fn merge(&mut self, other: &Config) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key} = {v:?}")),
        }
    }

    pub fn get_i64(&self, key: &str, default: i64) -> Result<i64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key} = {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key} = {v:?}")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("config {key} = {v:?} is not a bool"),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (idx, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..idx],
            _ => {}
        }
    }
    line
}

/// Built-in presets for the launcher (`--preset`).
pub fn preset(name: &str) -> Result<Config> {
    let text = match name {
        // The paper's §5.5/§6 operating point.  `engine` picks the grid
        // device phase: auto (PJRT if an artifact matches, else native),
        // native, or native-par (the tiled multi-threaded twin with
        // `threads` workers over `tile_rows`-row stripes).
        // `[gridflow] host_rounds` picks the hybrid solver's host-round
        // policy (seq | striped); striped is bit-exact and parallel
        // whenever a worker pool is attached, so both presets opt in.
        "paper" => {
            "[assign]\nalpha = 10\nmax_n = 30\nmax_weight = 100\ncycle = 1024\n\
             [maxflow]\ncycle = 7000\nheuristics = true\nengine = \"auto\"\n\
             threads = 4\ntile_rows = 16\nstriped_relabel_min_nodes = 256\n\
             [gridflow]\nhost_rounds = \"striped\"\nstripe_balance = \"fixed\"\n\
             commit = \"two_pass\"\n\
             [service]\nworkers = 4\nqueue_depth = 64\nsmall_units = 2048\n\
             medium_units = 8192\nmax_units = 1048576\nuse_pjrt = true\n\
             assign_small = \"hungarian\"\nassign_medium = \"csa-lockfree\"\n\
             assign_large = \"csa-lockfree\"\ngrid_small = \"native\"\n\
             grid_medium = \"native-par\"\ngrid_large = \"native-par\"\n\
             cycle = 1024\nthreads = 4\ntile_rows = 16\nalpha = 10\n\
             routing = \"static\"\nprobe_every = 8\nspill_depth = 8\n\
             max_retries = 2\nretry_backoff_ms = 2\n\
             breaker_threshold = 3\nbreaker_cooldown = 8\n\
             batch_max = 1\nbatch_linger_us = 200\n\
             session_budget_mb = 64\n"
        }
        // Small smoke setting for CI.
        "smoke" => {
            "[assign]\nalpha = 10\nmax_n = 8\nmax_weight = 20\ncycle = 64\n\
             [maxflow]\ncycle = 64\nheuristics = true\nengine = \"auto\"\n\
             threads = 2\ntile_rows = 4\nstriped_relabel_min_nodes = 256\n\
             [gridflow]\nhost_rounds = \"striped\"\nstripe_balance = \"fixed\"\n\
             commit = \"two_pass\"\n\
             [service]\nworkers = 2\nqueue_depth = 16\nsmall_units = 512\n\
             medium_units = 4096\nmax_units = 65536\nuse_pjrt = false\n\
             cycle = 128\nthreads = 2\ntile_rows = 4\n\
             routing = \"static\"\nprobe_every = 4\nspill_depth = 4\n\
             max_retries = 1\nretry_backoff_ms = 1\n\
             breaker_threshold = 2\nbreaker_cooldown = 4\n\
             batch_max = 1\nbatch_linger_us = 200\n\
             session_budget_mb = 8\n"
        }
        other => bail!("unknown preset {other:?} (try: paper, smoke)"),
    };
    Config::parse(text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_types_comments() {
        let cfg = Config::parse(
            "# top\ncycle = 7000\n[assign]\nalpha = 10 # inline\nname = \"paper # not comment\"\nfast = true\nratio = 0.5\n",
        )
        .unwrap();
        assert_eq!(cfg.get_i64("cycle", 0).unwrap(), 7000);
        assert_eq!(cfg.get_i64("assign.alpha", 0).unwrap(), 10);
        assert_eq!(cfg.get("assign.name"), Some("paper # not comment"));
        assert!(cfg.get_bool("assign.fast", false).unwrap());
        assert!((cfg.get_f64("assign.ratio", 0.0).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn merge_overrides() {
        let mut a = Config::parse("x = 1\ny = 2\n").unwrap();
        let b = Config::parse("y = 3\nz = 4\n").unwrap();
        a.merge(&b);
        assert_eq!(a.get_i64("x", 0).unwrap(), 1);
        assert_eq!(a.get_i64("y", 0).unwrap(), 3);
        assert_eq!(a.get_i64("z", 0).unwrap(), 4);
    }

    #[test]
    fn malformed_rejected() {
        assert!(Config::parse("just a line\n").is_err());
        assert!(Config::parse("b = maybe\n").unwrap().get_bool("b", true).is_err());
    }

    #[test]
    fn presets_load() {
        let p = preset("paper").unwrap();
        assert_eq!(p.get_i64("maxflow.cycle", 0).unwrap(), 7000);
        assert_eq!(p.get_i64("assign.alpha", 0).unwrap(), 10);
        assert_eq!(p.get("maxflow.engine"), Some("auto"));
        assert_eq!(p.get_usize("maxflow.threads", 0).unwrap(), 4);
        assert_eq!(p.get_usize("maxflow.tile_rows", 0).unwrap(), 16);
        assert_eq!(p.get("gridflow.host_rounds"), Some("striped"));
        // Striped-substrate tuning ships in its bit-exact default; the
        // keys are present so operators can flip them in one place.
        assert_eq!(p.get("gridflow.stripe_balance"), Some("fixed"));
        assert_eq!(p.get("gridflow.commit"), Some("two_pass"));
        assert_eq!(
            p.get_usize("maxflow.striped_relabel_min_nodes", 0).unwrap(),
            256
        );
        let s = preset("smoke").unwrap();
        assert_eq!(s.get("gridflow.host_rounds"), Some("striped"));
        assert_eq!(s.get("gridflow.stripe_balance"), Some("fixed"));
        assert_eq!(s.get("gridflow.commit"), Some("two_pass"));
        assert!(preset("nope").is_err());
    }

    #[test]
    fn presets_carry_service_section() {
        let p = preset("paper").unwrap();
        assert_eq!(p.get_usize("service.workers", 0).unwrap(), 4);
        assert_eq!(p.get_usize("service.queue_depth", 0).unwrap(), 64);
        assert_eq!(p.get("service.assign_small"), Some("hungarian"));
        assert_eq!(p.get("service.grid_large"), Some("native-par"));
        assert!(p.get_bool("service.use_pjrt", false).unwrap());
        // Routing keys: static stays the out-of-the-box behaviour.
        assert_eq!(p.get("service.routing"), Some("static"));
        assert_eq!(p.get_usize("service.probe_every", 0).unwrap(), 8);
        assert_eq!(p.get_usize("service.spill_depth", 0).unwrap(), 8);
        let s = preset("smoke").unwrap();
        assert_eq!(s.get_usize("service.workers", 0).unwrap(), 2);
        assert!(!s.get_bool("service.use_pjrt", true).unwrap());
        assert_eq!(s.get("service.routing"), Some("static"));
        // Batching ships config-gated **off** in both presets: at
        // batch_max = 1 routing is bit-identical to pre-batching.
        for preset in [&p, &s] {
            assert_eq!(preset.get_usize("service.batch_max", 0).unwrap(), 1);
            assert_eq!(preset.get_usize("service.batch_linger_us", 0).unwrap(), 200);
        }
    }
}
