//! Device-state initialisation from a grid instance (Hong's Init,
//! Algorithm 4.7): source arcs are pre-saturated into excess, the reverse
//! arcs `u_f(x, s)` carry the returned-flow capacity.

use crate::graph::GridNetwork;
use crate::runtime::device::GridWireState;

/// Build the initial wire state and `ExcessTotal` for `net`.
pub fn init_state(net: &GridNetwork) -> (GridWireState, i64) {
    let (hh, ww) = (net.height, net.width);
    let cells = hh * ww;
    let mut st = GridWireState::zeros(hh, ww);
    for a in 0..4 * cells {
        let c = net.cap[a];
        assert!(c <= i32::MAX as i64, "capacity too large for device i32");
        st.cap[a] = c as i32;
    }
    for c in 0..cells {
        st.cap_sink[c] = net.cap_sink[c] as i32;
        // Hong Init lines 9-12: u_f(s,x) = 0, u_f(x,s) = u_sx, e(x) = u_sx.
        st.cap_src[c] = net.cap_source[c] as i32;
        st.e[c] = net.cap_source[c] as i32;
        st.h[c] = 0;
    }
    (st, net.excess_total())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::grid::E;

    #[test]
    fn init_moves_source_caps_to_excess() {
        let mut net = GridNetwork::zeros(2, 2);
        net.cap_source[0] = 7;
        net.cap_sink[3] = 4;
        net.set_neighbour_cap(0, 0, E, 5);
        let (st, total) = init_state(&net);
        assert_eq!(total, 7);
        assert_eq!(st.e[0], 7);
        assert_eq!(st.cap_src[0], 7);
        assert_eq!(st.cap_sink[3], 4);
        assert_eq!(st.cap[3 * 4], 5); // E plane, cell 0
        assert_eq!(st.h, vec![0; 4]);
    }
}
