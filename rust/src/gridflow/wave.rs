//! Native synchronous wave: a bit-exact Rust twin of the Pallas grid
//! kernel (python/compile/kernels/grid_wave.py).
//!
//! Two uses: (a) the device-free fallback executor, (b) the cross-language
//! oracle — integration tests drive the PJRT artifact and this engine on
//! the same instance and require *identical* trajectories, which pins the
//! kernel's semantics (snapshot heights, arc-order tie-breaking,
//! lowest-neighbour selection) across the language boundary.

use crate::runtime::device::GridWireState;

/// Arc order must match the kernel: N, S, W, E, sink, source.
pub(super) const DIRS: [(i64, i64); 4] = [(-1, 0), (1, 0), (0, -1), (0, 1)];
pub(super) const OPP: [usize; 4] = [1, 0, 3, 2];
const INF: i64 = 1 << 30;

/// Per-wave counters (kernel stats without the carried totals).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WaveStats {
    pub sink_flow: i64,
    pub src_flow: i64,
    pub pushes: i64,
    pub relabels: i64,
}

/// Decision taken by one cell in the snapshot phase.  Shared with the
/// tiled parallel engine (`par_wave`), which stores the same decisions
/// in per-tile slices.
#[derive(Debug, Clone, Copy)]
pub(super) enum Decision {
    None,
    Push { arc: usize, delta: i32 },
    Relabel { new_h: i32 },
}

/// Decision for one active cell against the immutable pre-wave snapshot:
/// lowest residual neighbour with first-minimum tie-break in arc order
/// (matching `jnp.argmin`), then push if strictly lower, else relabel.
///
/// This is the single source of truth for decision semantics — both the
/// sequential engine and the tiled parallel engine call it, so the two
/// cannot drift.  Caller guarantees `st.e[c] > 0`.
#[inline]
pub(super) fn decide(st: &GridWireState, c: usize) -> Decision {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    let v_total = (cells + 2) as i64;
    let (i, j) = (c / ww, c % ww);
    let mut best_h = INF;
    let mut best_a = usize::MAX;
    for (a, &(di, dj)) in DIRS.iter().enumerate() {
        let (ni, nj) = (i as i64 + di, j as i64 + dj);
        if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
            continue;
        }
        if st.cap[a * cells + c] > 0 {
            let hn = st.h[(ni as usize) * ww + nj as usize] as i64;
            if hn < best_h {
                best_h = hn;
                best_a = a;
            }
        }
    }
    if st.cap_sink[c] > 0 && 0 < best_h {
        best_h = 0;
        best_a = 4;
    }
    if st.cap_src[c] > 0 && v_total < best_h {
        best_h = v_total;
        best_a = 5;
    }
    if best_a == usize::MAX {
        return Decision::None;
    }
    if (st.h[c] as i64) > best_h {
        let cap = match best_a {
            4 => st.cap_sink[c],
            5 => st.cap_src[c],
            a => st.cap[a * cells + c],
        };
        Decision::Push {
            arc: best_a,
            delta: st.e[c].min(cap),
        }
    } else {
        Decision::Relabel {
            new_h: (best_h + 1) as i32,
        }
    }
}

/// Reusable per-wave scratch (PERF: reused buffers + an incrementally
/// maintained active-cell list replace the two full-grid scans per wave;
/// see EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct WaveScratch {
    decisions: Vec<Decision>,
    /// Cells with positive excess (maintained across waves).
    active: Vec<u32>,
    on_list: Vec<bool>,
    /// Dimensions the active list was built for (guards reuse).
    pub(super) built_for: Option<(usize, usize)>,
}

impl WaveScratch {
    /// (Re)build the active list from the state — call after any external
    /// mutation of `e` (host rounds, fresh instances).
    pub fn rebuild(&mut self, st: &GridWireState) {
        let cells = st.cells();
        self.on_list.clear();
        self.on_list.resize(cells, false);
        self.active.clear();
        for c in 0..cells {
            if st.e[c] > 0 {
                self.active.push(c as u32);
                self.on_list[c] = true;
            }
        }
        self.decisions.clear();
        self.decisions.resize(cells, Decision::None);
        self.built_for = Some((st.height, st.width));
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }
}

/// One synchronous wave with snapshot-then-apply semantics; mutates the
/// state in place and returns this wave's counters.  Allocating
/// convenience wrapper around [`native_wave_with`].
pub fn native_wave(st: &mut GridWireState) -> WaveStats {
    let mut scratch = WaveScratch::default();
    native_wave_with(st, &mut scratch)
}

/// One wave using caller-provided scratch (the hot-loop entry point).
///
/// The decision phase reads only (and the apply phase writes only), so
/// snapshot semantics hold without copying the height plane: decisions
/// are fully computed against the pre-wave state before any mutation.
pub fn native_wave_with(st: &mut GridWireState, scratch: &mut WaveScratch) -> WaveStats {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;

    if scratch.built_for != Some((hh, ww)) {
        scratch.rebuild(st);
    }

    // --- Decision phase against an immutable snapshot -------------------
    // Only cells on the active list can decide anything; the list is a
    // strict superset of {e > 0} (stale zero-excess entries are skipped
    // and dropped below).
    for idx in 0..scratch.active.len() {
        let c = scratch.active[idx] as usize;
        if st.e[c] <= 0 {
            continue;
        }
        scratch.decisions[c] = decide(st, c);
    }

    // --- Apply phase -----------------------------------------------------
    // Iterate the same list; newly activated receivers are appended for
    // the *next* wave (they had no decision this wave).  The list is then
    // compacted to exactly {e > 0}.
    let mut stats = WaveStats::default();
    for idx in 0..scratch.active.len() {
        let c = scratch.active[idx] as usize;
        match std::mem::replace(&mut scratch.decisions[c], Decision::None) {
            Decision::None => {}
            Decision::Relabel { new_h } => {
                st.h[c] = new_h;
                stats.relabels += 1;
            }
            Decision::Push { arc, delta } => {
                stats.pushes += 1;
                st.e[c] -= delta;
                match arc {
                    4 => {
                        st.cap_sink[c] -= delta;
                        stats.sink_flow += delta as i64;
                    }
                    5 => {
                        st.cap_src[c] -= delta;
                        stats.src_flow += delta as i64;
                    }
                    a => {
                        let (i, j) = (c / ww, c % ww);
                        let (di, dj) = DIRS[a];
                        let nc = ((i as i64 + di) as usize) * ww + (j as i64 + dj) as usize;
                        st.cap[a * cells + c] -= delta;
                        st.cap[OPP[a] * cells + nc] += delta;
                        st.e[nc] += delta;
                        if !scratch.on_list[nc] {
                            scratch.on_list[nc] = true;
                            scratch.active.push(nc as u32);
                        }
                    }
                }
            }
        }
    }

    // Compact: drop entries whose excess is gone.
    let mut w = 0;
    for r in 0..scratch.active.len() {
        let c = scratch.active[r] as usize;
        if st.e[c] > 0 {
            scratch.active[w] = scratch.active[r];
            w += 1;
        } else {
            scratch.on_list[c] = false;
        }
    }
    scratch.active.truncate(w);
    stats
}

/// Count of active cells (device-side quiescence signal).
pub fn active_cells(st: &GridWireState) -> usize {
    st.e.iter().filter(|&&e| e > 0).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> GridWireState {
        // 1x3: src arcs at cell 0, sink at cell 2, chain capacity 2.
        let mut st = GridWireState::zeros(1, 3);
        st.e[0] = 4;
        st.cap_src[0] = 4;
        st.cap_sink[2] = 3;
        st.cap[3 * 3] = 2; // E from cell 0
        st.cap[3 * 3 + 1] = 2; // E from cell 1
        st
    }

    #[test]
    fn wave_sequence_routes_flow_east() {
        let mut st = tiny();
        let mut total_sink = 0;
        let mut total_src = 0;
        for _ in 0..200 {
            if active_cells(&st) == 0 {
                break;
            }
            let w = native_wave(&mut st);
            total_sink += w.sink_flow;
            total_src += w.src_flow;
        }
        assert_eq!(active_cells(&st), 0);
        assert_eq!(total_sink, 2); // bottleneck: chain capacity
        assert_eq!(total_src, 2); // remainder returns to the source
    }

    #[test]
    fn push_prefers_sink_over_equal_height_neighbour() {
        let mut st = GridWireState::zeros(1, 2);
        st.e[0] = 1;
        st.h[0] = 1;
        st.cap[3 * 2] = 5; // E arc to neighbour at h=0
        st.cap_sink[0] = 5; // sink also at height 0
        let w = native_wave(&mut st);
        // Arc order: E (index 3) is checked before sink (4), but the sink
        // replaces only on strictly lower height; both are 0, so E wins —
        // matching jnp.argmin's first-minimum over arc order.
        assert_eq!(w.pushes, 1);
        assert_eq!(w.sink_flow, 0);
        assert_eq!(st.e[1], 1);
    }

    #[test]
    fn relabel_takes_min_plus_one() {
        let mut st = GridWireState::zeros(1, 2);
        st.e[0] = 1;
        st.h[0] = 0;
        st.h[1] = 7;
        st.cap[3 * 2] = 5;
        let w = native_wave(&mut st);
        assert_eq!(w.relabels, 1);
        assert_eq!(st.h[0], 8);
    }

    #[test]
    fn mass_is_conserved_every_wave() {
        let mut st = tiny();
        for _ in 0..50 {
            let before: i64 = st.e.iter().map(|&e| e as i64).sum();
            let w = native_wave(&mut st);
            let after: i64 = st.e.iter().map(|&e| e as i64).sum();
            assert_eq!(after + w.sink_flow + w.src_flow, before);
        }
    }
}
