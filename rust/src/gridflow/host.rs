//! Host phase of the hybrid scheme on grid states (Algorithm 4.8): cancel
//! height-violating residual arcs, then a backwards BFS from the sink
//! assigns exact distances, and the gap step parks unreached cells at |V|.
//!
//! In the paper this is the C procedure the CUDA kernel returns control
//! to every CYCLE iterations; here it runs between PJRT super-steps.
//!
//! PERF: the passes are frontier-seeded instead of full-grid scans.
//! Violation cancelling visits only cells that currently hold excess
//! (cancelling exists to return trapped excess; an arc at an excess-free
//! cell moves no mass a wave could not move itself), and the two BFS
//! passes seed from cached terminal-cell lists — residual terminal
//! capacity only ever shrinks during a solve, so the cells with initial
//! `cap_sink/cap_src > 0` are a fixed superset.  [`HostScratch`] also
//! reuses the distance/queue buffers across rounds.
//!
//! Every pass also has a stripe-parallel twin (`*_par`) on the shared
//! frontier substrate (`crate::parallel`): the grid is partitioned into
//! row stripes, each stripe owns its cells, and cross-stripe effects
//! (BFS discoveries, cancel receive-sides) travel through per-stripe
//! outboxes committed by the owner (parity two-pass by default; one
//! merged batch under [`CommitMode::Merged`]).  With
//! [`StripeBalance::Weighted`] the stripe boundaries are re-cut between
//! host rounds from the observed excess frontier, row-aligned.  The
//! twins are **bit-exact** with the sequential passes at any stripe
//! count, any boundary placement, and on any [`Lanes`]: BFS distances
//! are visit-order independent, and the deferred cancel ops are
//! additive increments to reverse arcs that can never themselves
//! violate (a violation both ways would need `h(x) > h(y) + 1` and
//! `h(y) > h(x) + 1`).

use std::collections::VecDeque;

use crate::parallel::{
    CommitMode, CrossOp, Lanes, ParTuning, StripeBalance, StripeCuts, Stripes, StripedFrontier,
};
use crate::runtime::device::GridWireState;

const DIRS: [(i64, i64); 4] = [(-1, 0), (1, 0), (0, -1), (0, 1)];
const OPP: [usize; 4] = [1, 0, 3, 2];

/// Outcome counters of one host round.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HostRoundStats {
    pub cancelled_arcs: u64,
    pub reached_cells: u64,
    pub gap_cells: u64,
    /// Flow returned to the source by violation cancellation on (x, s)
    /// arcs (must be credited to the solver's src_flow total).
    pub src_returned: i64,
}

/// Per-solve host scratch: cached terminal seed lists plus reusable BFS
/// buffers.  Build once per solve with [`HostScratch::for_state`] — the
/// terminal caches are supersets only for states whose terminal caps
/// never grow, which holds within a solve but not across solves.
#[derive(Debug, Default)]
pub struct HostScratch {
    /// Cells whose sink arc had residual capacity at construction time
    /// (a fixed superset of the current sink frontier).
    sink_cells: Vec<u32>,
    /// Same for source arcs.
    src_cells: Vec<u32>,
    /// Snapshot of the excess-bearing cells taken by `cancel_violations_with`.
    active: Vec<u32>,
    dist: Vec<i32>,
    dist_s: Vec<i32>,
    queue: VecDeque<usize>,
    /// Striped twins: the reusable BFS frontier plus per-stripe buffers
    /// (excess snapshots, cross-stripe cancel outboxes, counters).
    frontier: StripedFrontier,
    stripe_active: Vec<Vec<u32>>,
    cancel_out: Vec<Vec<CrossOp>>,
    stripe_cancel: Vec<(u64, i64)>,
    stripe_gap: Vec<u64>,
    /// Balance/commit tuning for the striped passes (sticky; set by the
    /// solver from its config).  The default — fixed uniform stripes,
    /// parity two-pass commits — is the historical behaviour exactly.
    tuning: ParTuning,
    /// Current stripe boundaries of the striped passes.  Uniform under
    /// `StripeBalance::Fixed`; re-cut between host rounds from the
    /// observed excess frontier under `Weighted` (row-aligned, so W/E
    /// cancels stay intra-stripe).  Results are partition-independent —
    /// only the work split moves.
    cuts: StripeCuts,
    stripe_weights: Vec<u64>,
    /// Host-round boundary re-cuts performed (weighted mode only),
    /// drained by [`HostScratch::take_rebalances`] for telemetry.
    rebalances: u64,
    /// Cumulative seconds the cancel / relabel passes have run through
    /// this scratch (filled by [`host_round_with`] / [`host_round_par`]).
    /// The solver reads deltas into its phase breakdown; the timing
    /// lives here and not on [`HostRoundStats`] so the stats stay a pure
    /// `Eq` outcome value the seq-vs-par bit-exactness tests compare.
    pub cancel_seconds: f64,
    pub relabel_seconds: f64,
}

/// Row-stripe partition the striped host passes use: about twice as
/// many stripes as lanes, so the ragged tail balances.
fn host_stripes(st: &GridWireState, lanes: &Lanes<'_>) -> Stripes {
    Stripes::rows(st.height, st.width, lanes.width() * 2)
}

impl HostScratch {
    pub fn for_state(st: &GridWireState) -> Self {
        let cells = st.cells();
        let mut sink_cells = Vec::new();
        let mut src_cells = Vec::new();
        for c in 0..cells {
            if st.cap_sink[c] > 0 {
                sink_cells.push(c as u32);
            }
            if st.cap_src[c] > 0 {
                src_cells.push(c as u32);
            }
        }
        Self {
            sink_cells,
            src_cells,
            ..Default::default()
        }
    }

    /// Balance/commit tuning for the striped passes.  Sticky across
    /// rounds; forwarded to the embedded BFS frontier so its levels use
    /// the same discipline.
    pub fn set_tuning(&mut self, tuning: ParTuning) {
        self.tuning = tuning;
        self.frontier.set_tuning(tuning);
    }

    pub fn tuning(&self) -> ParTuning {
        self.tuning
    }

    /// Weighted boundary re-cuts since the last call — host-round
    /// boundary re-cuts plus the frontier's per-level re-cuts (both 0
    /// in `Fixed` mode).  Drained for the solver's phase breakdown.
    pub fn take_rebalances(&mut self) -> u64 {
        std::mem::take(&mut self.rebalances) + self.frontier.take_rebalances()
    }

    /// The striped passes' current partition, rebuilt uniform whenever
    /// the geometry (or lane width) changed since the last pass.
    fn resolve_cuts(&mut self, stripes: Stripes) -> &StripeCuts {
        if self.cuts.len() != stripes.len() || self.cuts.n_stripes() != stripes.n_stripes() {
            self.cuts = StripeCuts::uniform(stripes);
        }
        &self.cuts
    }
}

/// Cancel residual arcs with `h(x) > h(y) + 1` by pushing their full
/// residual (Algorithm 4.8 lines 1-6), seeded from the excess frontier:
/// only cells with `e > 0` are visited (snapshot taken before any
/// cancel, in cell order — cells a cancel activates are handled by the
/// waves or the next round).  Terminal arcs: the sink counts as height 0
/// (never violated: pushing to the sink is always allowed), the source
/// as height |V|.
pub fn cancel_violations_with(st: &mut GridWireState, scratch: &mut HostScratch) -> (u64, i64) {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    let v_total = (cells + 2) as i64;
    scratch.active.clear();
    for c in 0..cells {
        if st.e[c] > 0 {
            scratch.active.push(c as u32);
        }
    }
    let mut cancelled = 0;
    let mut src_returned = 0i64;
    for &c in &scratch.active {
        let c = c as usize;
        let (i, j) = (c / ww, c % ww);
        for (a, &(di, dj)) in DIRS.iter().enumerate() {
            let (ni, nj) = (i as i64 + di, j as i64 + dj);
            if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                continue;
            }
            let nc = (ni as usize) * ww + nj as usize;
            let r = st.cap[a * cells + c];
            if r > 0 && (st.h[c] as i64) > st.h[nc] as i64 + 1 {
                st.cap[a * cells + c] = 0;
                st.cap[OPP[a] * cells + nc] += r;
                st.e[c] -= r;
                st.e[nc] += r;
                cancelled += 1;
            }
        }
        // Source arc: violation when h(x) > |V| + 1.
        let r = st.cap_src[c];
        if r > 0 && (st.h[c] as i64) > v_total + 1 {
            st.cap_src[c] = 0;
            st.e[c] -= r;
            src_returned += r as i64;
            cancelled += 1;
        }
    }
    (cancelled, src_returned)
}

/// Allocating wrapper around [`cancel_violations_with`].
pub fn cancel_violations(st: &mut GridWireState) -> (u64, i64) {
    let mut scratch = HostScratch::for_state(st);
    cancel_violations_with(st, &mut scratch)
}

/// Global relabel: heights become exact BFS distances to the sink in the
/// residual graph; unreached cells are parked at |V| (gap relabeling,
/// §4.6 "for each unvisited node ... sets its height to |V|").  Seeds
/// come from the scratch's cached terminal lists.
pub fn global_relabel_with(st: &mut GridWireState, scratch: &mut HostScratch) -> HostRoundStats {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    let v_total = (cells + 2) as i32;

    let dist = &mut scratch.dist;
    dist.clear();
    dist.resize(cells, -1);
    let q = &mut scratch.queue;
    q.clear();
    // Distance 1: cells with residual arc to the sink.
    for &c in &scratch.sink_cells {
        let c = c as usize;
        if st.cap_sink[c] > 0 {
            dist[c] = 1;
            q.push_back(c);
        }
    }
    let mut reached = q.len() as u64;
    while let Some(c) = q.pop_front() {
        let (i, j) = (c / ww, c % ww);
        // Traverse reverse residual arcs: neighbour n can reach c if the
        // arc n->c has residual capacity, i.e. cap[a_from_n][n] > 0 where
        // a_from_n points from n to c (= OPP of the arc c->n).
        for (a, &(di, dj)) in DIRS.iter().enumerate() {
            let (ni, nj) = (i as i64 + di, j as i64 + dj);
            if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                continue;
            }
            let nc = (ni as usize) * ww + nj as usize;
            if dist[nc] < 0 && st.cap[OPP[a] * cells + nc] > 0 {
                dist[nc] = dist[c] + 1;
                reached += 1;
                q.push_back(nc);
            }
        }
    }

    // Second phase (Cherkassky–Goldberg): cells that cannot reach the
    // sink get `|V| + distance-to-source`, so their excess routes back to
    // the source instead of re-climbing from the |V| plateau every round
    // (plain `h = |V|` livelocks when CYCLE is smaller than the climb).
    let dist_s = &mut scratch.dist_s;
    dist_s.clear();
    dist_s.resize(cells, -1);
    for &c in &scratch.src_cells {
        let c = c as usize;
        if dist[c] < 0 && st.cap_src[c] > 0 {
            dist_s[c] = 1;
            q.push_back(c);
        }
    }
    while let Some(c) = q.pop_front() {
        let (i, j) = (c / ww, c % ww);
        for (a, &(di, dj)) in DIRS.iter().enumerate() {
            let (ni, nj) = (i as i64 + di, j as i64 + dj);
            if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                continue;
            }
            let nc = (ni as usize) * ww + nj as usize;
            if dist[nc] < 0 && dist_s[nc] < 0 && st.cap[OPP[a] * cells + nc] > 0 {
                dist_s[nc] = dist_s[c] + 1;
                q.push_back(nc);
            }
        }
    }

    let mut gap = 0;
    for c in 0..cells {
        st.h[c] = if dist[c] >= 0 {
            dist[c]
        } else {
            gap += 1;
            if dist_s[c] >= 0 {
                v_total + dist_s[c]
            } else {
                // Unreachable from both terminals: inert (no excess can
                // sit here by the preflow invariant).
                2 * v_total
            }
        };
    }
    HostRoundStats {
        cancelled_arcs: 0,
        reached_cells: reached,
        gap_cells: gap,
        src_returned: 0,
    }
}

/// Allocating wrapper around [`global_relabel_with`].
pub fn global_relabel(st: &mut GridWireState) -> HostRoundStats {
    let mut scratch = HostScratch::for_state(st);
    global_relabel_with(st, &mut scratch)
}

/// Full host round: cancel violations then global+gap relabel.
pub fn host_round_with(st: &mut GridWireState, scratch: &mut HostScratch) -> HostRoundStats {
    let t = crate::util::Timer::start();
    let (cancelled, src_returned) = cancel_violations_with(st, scratch);
    scratch.cancel_seconds += t.elapsed();
    let t = crate::util::Timer::start();
    let mut out = global_relabel_with(st, scratch);
    scratch.relabel_seconds += t.elapsed();
    out.cancelled_arcs = cancelled;
    out.src_returned = src_returned;
    out
}

/// Allocating wrapper around [`host_round_with`].
pub fn host_round(st: &mut GridWireState) -> HostRoundStats {
    let mut scratch = HostScratch::for_state(st);
    host_round_with(st, &mut scratch)
}

// ---------------------------------------------------------------------------
// Stripe-parallel twins (the shared frontier substrate)
// ---------------------------------------------------------------------------

/// Stripe-parallel twin of [`cancel_violations_with`], bit-exact at any
/// stripe count.  Each stripe snapshots and cancels its own excess
/// cells; the receive side of a cancel that crosses a stripe boundary
/// (`cap[opp] += r`, `e[nc] += r`) is deferred to a per-stripe outbox
/// and applied by the owning stripe in the parity commit.  Safe because
/// a cancel's receive side can never change another cell's decision:
/// the reverse arc it feeds cannot itself violate (that would need
/// `h(x) > h(y)+1` *and* `h(y) > h(x)+1`), heights are never written,
/// and the active snapshot is taken before any cancel — exactly the
/// sequential pass's contract.
pub fn cancel_violations_par(
    st: &mut GridWireState,
    scratch: &mut HostScratch,
    lanes: &Lanes<'_>,
) -> (u64, i64) {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    let v_total = (cells + 2) as i64;
    let stripes = host_stripes(st, lanes);
    let ns = stripes.n_stripes();
    scratch.resolve_cuts(stripes);

    scratch.cancel_out.iter_mut().for_each(Vec::clear);
    scratch.cancel_out.resize_with(ns * ns, Vec::new);
    scratch.stripe_active.iter_mut().for_each(Vec::clear);
    scratch.stripe_active.resize_with(ns, Vec::new);
    scratch.stripe_cancel.clear();
    scratch.stripe_cancel.resize(ns, (0, 0));

    // Heights are read-only this pass; everything the stripes mutate is
    // lent out as disjoint per-stripe chunks.
    let GridWireState {
        h, e, cap, cap_src, ..
    } = st;
    let h: &[i32] = h;
    let (cap_n, rest) = cap.split_at_mut(cells);
    let (cap_s, rest) = rest.split_at_mut(cells);
    let (cap_w, cap_e) = rest.split_at_mut(cells);

    struct CancelStripe<'a> {
        base: usize,
        cuts: &'a StripeCuts,
        e: &'a mut [i32],
        cap_n: &'a mut [i32],
        cap_s: &'a mut [i32],
        cap_w: &'a mut [i32],
        cap_e: &'a mut [i32],
        cap_src: &'a mut [i32],
        active: &'a mut Vec<u32>,
        row: &'a mut [Vec<CrossOp>],
        counts: &'a mut (u64, i64),
    }

    // Pass 1: snapshot + cancel, owner-side effects applied in place.
    {
        let cuts = &scratch.cuts;
        let mut tasks = Vec::with_capacity(ns);
        let iter = cuts
            .split_mut(e)
            .into_iter()
            .zip(cuts.split_mut(cap_n))
            .zip(cuts.split_mut(cap_s))
            .zip(cuts.split_mut(cap_w))
            .zip(cuts.split_mut(cap_e))
            .zip(cuts.split_mut(cap_src))
            .zip(scratch.stripe_active.iter_mut())
            .zip(scratch.cancel_out.chunks_mut(ns))
            .zip(scratch.stripe_cancel.iter_mut())
            .enumerate();
        for (s, ((((((((e, cap_n), cap_s), cap_w), cap_e), cap_src), active), row), counts)) in
            iter
        {
            tasks.push(CancelStripe {
                base: cuts.start(s),
                cuts,
                e,
                cap_n,
                cap_s,
                cap_w,
                cap_e,
                cap_src,
                active,
                row,
                counts,
            });
        }
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for group in crate::parallel::deal(tasks, lanes.width()) {
            jobs.push(Box::new(move || {
                for task in group {
                    let CancelStripe {
                        base,
                        cuts,
                        e,
                        cap_n,
                        cap_s,
                        cap_w,
                        cap_e,
                        cap_src,
                        active,
                        row,
                        counts,
                    } = task;
                    // Snapshot before any cancel: the stripe
                    // concatenation equals the sequential global
                    // snapshot (receive sides only ever add excess, so
                    // live checks would over-collect — snapshot, like
                    // the sequential pass, does not).
                    for (lc, &ev) in e.iter().enumerate() {
                        if ev > 0 {
                            active.push((base + lc) as u32);
                        }
                    }
                    let end = base + e.len();
                    for &c in active.iter() {
                        let c = c as usize;
                        let lc = c - base;
                        let (i, j) = (c / ww, c % ww);
                        for (a, &(di, dj)) in DIRS.iter().enumerate() {
                            let (ni, nj) = (i as i64 + di, j as i64 + dj);
                            if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                                continue;
                            }
                            let nc = (ni as usize) * ww + nj as usize;
                            let r = match a {
                                0 => cap_n[lc],
                                1 => cap_s[lc],
                                2 => cap_w[lc],
                                _ => cap_e[lc],
                            };
                            if r > 0 && (h[c] as i64) > h[nc] as i64 + 1 {
                                match a {
                                    0 => cap_n[lc] = 0,
                                    1 => cap_s[lc] = 0,
                                    2 => cap_w[lc] = 0,
                                    _ => cap_e[lc] = 0,
                                }
                                e[lc] -= r;
                                counts.0 += 1;
                                if nc >= base && nc < end {
                                    let ln = nc - base;
                                    match OPP[a] {
                                        0 => cap_n[ln] += r,
                                        1 => cap_s[ln] += r,
                                        2 => cap_w[ln] += r,
                                        _ => cap_e[ln] += r,
                                    }
                                    e[ln] += r;
                                } else {
                                    row[cuts.owner(nc)].push(CrossOp {
                                        cell: nc as u32,
                                        arc: OPP[a] as u8,
                                        delta: r,
                                    });
                                }
                            }
                        }
                        // Source arc: violation when h(x) > |V| + 1.
                        let r = cap_src[lc];
                        if r > 0 && (h[c] as i64) > v_total + 1 {
                            cap_src[lc] = 0;
                            e[lc] -= r;
                            counts.1 += r as i64;
                            counts.0 += 1;
                        }
                    }
                }
            }));
        }
        lanes.run(jobs);
    }

    // Pass 2: owner-exclusive commit of the deferred receive sides.
    // Under `CommitMode::TwoPass` the owners run parity-coloured —
    // even-index stripes, then odd (the oracle protocol); `Merged` runs
    // every owner in one batch.  Both are safe for the same reason: a
    // commit writes only the owner's chunks and reads only outboxes
    // that are immutable for the whole phase, and all increments are
    // additive, so the final state equals the sequential in-order
    // apply.  Skipped outright when no cancel crossed a stripe boundary
    // (the common steady-state round).  Each owner scans every
    // producer's column (not just ±1): after a weighted re-cut a stripe
    // can be empty, so adjacency in stripe index no longer implies
    // adjacency in rows.  Non-adjacent columns are empty vectors.
    if scratch.cancel_out.iter().any(|b| !b.is_empty()) {
        struct CancelCommit<'a> {
            owner: usize,
            base: usize,
            e: &'a mut [i32],
            cap_n: &'a mut [i32],
            cap_s: &'a mut [i32],
            cap_w: &'a mut [i32],
            cap_e: &'a mut [i32],
        }
        let out: &[Vec<CrossOp>] = &scratch.cancel_out;
        let cuts = &scratch.cuts;
        let mut tasks = Vec::with_capacity(ns);
        let iter = cuts
            .split_mut(e)
            .into_iter()
            .zip(cuts.split_mut(cap_n))
            .zip(cuts.split_mut(cap_s))
            .zip(cuts.split_mut(cap_w))
            .zip(cuts.split_mut(cap_e))
            .enumerate();
        for (o, ((((e, cap_n), cap_s), cap_w), cap_e)) in iter {
            tasks.push(CancelCommit {
                owner: o,
                base: cuts.start(o),
                e,
                cap_n,
                cap_s,
                cap_w,
                cap_e,
            });
        }
        let passes: Vec<Vec<CancelCommit<'_>>> = match scratch.tuning.commit {
            CommitMode::Merged => vec![tasks],
            CommitMode::TwoPass => {
                let (even, odd): (Vec<_>, Vec<_>) =
                    tasks.into_iter().partition(|t| t.owner % 2 == 0);
                vec![even, odd]
            }
        };
        for pass in passes {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for group in crate::parallel::deal(pass, lanes.width()) {
                jobs.push(Box::new(move || {
                    for task in group {
                        for p in 0..ns {
                            for op in &out[p * ns + task.owner] {
                                let lv = op.cell as usize - task.base;
                                match op.arc {
                                    0 => task.cap_n[lv] += op.delta,
                                    1 => task.cap_s[lv] += op.delta,
                                    2 => task.cap_w[lv] += op.delta,
                                    _ => task.cap_e[lv] += op.delta,
                                }
                                task.e[lv] += op.delta;
                            }
                        }
                    }
                }));
            }
            lanes.run(jobs);
        }
    }

    let mut cancelled = 0u64;
    let mut src_returned = 0i64;
    for &(c, s) in &scratch.stripe_cancel {
        cancelled += c;
        src_returned += s;
    }
    (cancelled, src_returned)
}

/// Stripe-parallel twin of [`global_relabel_with`]: the two reverse
/// BFS passes run level-synchronously on the [`StripedFrontier`]
/// (identical distances — shortest distances are unique regardless of
/// visit order), and the height write-back is an embarrassingly
/// parallel sweep over the same stripes.
pub fn global_relabel_par(
    st: &mut GridWireState,
    scratch: &mut HostScratch,
    lanes: &Lanes<'_>,
) -> HostRoundStats {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    let v_total = (cells + 2) as i32;
    let stripes = host_stripes(st, lanes);
    let ns = stripes.n_stripes();
    scratch.resolve_cuts(stripes);

    let HostScratch {
        sink_cells,
        src_cells,
        dist,
        dist_s,
        frontier,
        stripe_gap,
        cuts,
        ..
    } = scratch;
    let cuts: &StripeCuts = cuts;

    // Pass 1: distance-to-sink over reverse residual arcs.
    dist.clear();
    dist.resize(cells, -1);
    frontier.reset(stripes);
    let mut seeded = 0u64;
    for &c in sink_cells.iter() {
        let c = c as usize;
        if st.cap_sink[c] > 0 {
            dist[c] = 1;
            frontier.seed(c);
            seeded += 1;
        }
    }
    let assigned = {
        let st_ro: &GridWireState = st;
        let neigh = |c: usize, emit: &mut dyn FnMut(usize)| {
            let (i, j) = (c / ww, c % ww);
            for (a, &(di, dj)) in DIRS.iter().enumerate() {
                let (ni, nj) = (i as i64 + di, j as i64 + dj);
                if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                    continue;
                }
                let nc = (ni as usize) * ww + nj as usize;
                if st_ro.cap[OPP[a] * cells + nc] > 0 {
                    emit(nc);
                }
            }
        };
        frontier.run(dist, 1, None, &neigh, lanes)
    };
    let reached = seeded + assigned;

    // Pass 2 (Cherkassky–Goldberg): distance-to-source for cells the
    // sink BFS missed, masked by the (now read-only) sink distances.
    dist_s.clear();
    dist_s.resize(cells, -1);
    frontier.reset(stripes);
    for &c in src_cells.iter() {
        let c = c as usize;
        if dist[c] < 0 && st.cap_src[c] > 0 {
            dist_s[c] = 1;
            frontier.seed(c);
        }
    }
    {
        let st_ro: &GridWireState = st;
        let dist_ro: &[i32] = dist;
        let neigh = |c: usize, emit: &mut dyn FnMut(usize)| {
            let (i, j) = (c / ww, c % ww);
            for (a, &(di, dj)) in DIRS.iter().enumerate() {
                let (ni, nj) = (i as i64 + di, j as i64 + dj);
                if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                    continue;
                }
                let nc = (ni as usize) * ww + nj as usize;
                if dist_ro[nc] < 0 && st_ro.cap[OPP[a] * cells + nc] > 0 {
                    emit(nc);
                }
            }
        };
        frontier.run(dist_s, 1, None, &neigh, lanes);
    }

    // Write-back: heights from distances, gap counting per stripe.
    stripe_gap.clear();
    stripe_gap.resize(ns, 0);
    {
        let mut tasks = Vec::with_capacity(ns);
        let iter = cuts
            .split_mut(&mut st.h)
            .into_iter()
            .zip(cuts.split_mut(dist))
            .zip(cuts.split_mut(dist_s))
            .zip(stripe_gap.iter_mut());
        for (((h, d), ds), gap) in iter {
            tasks.push((h, d, ds, gap));
        }
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for group in crate::parallel::deal(tasks, lanes.width()) {
            jobs.push(Box::new(move || {
                for (h, d, ds, gap) in group {
                    for lc in 0..h.len() {
                        h[lc] = if d[lc] >= 0 {
                            d[lc]
                        } else {
                            *gap += 1;
                            if ds[lc] >= 0 {
                                v_total + ds[lc]
                            } else {
                                2 * v_total
                            }
                        };
                    }
                }
            }));
        }
        lanes.run(jobs);
    }

    HostRoundStats {
        cancelled_arcs: 0,
        reached_cells: reached,
        gap_cells: stripe_gap.iter().sum(),
        src_returned: 0,
    }
}

/// Stripe-parallel twin of [`host_round_with`]: cancel then relabel,
/// both on the frontier substrate.  Bit-exact with the sequential round
/// on any lanes.
pub fn host_round_par(
    st: &mut GridWireState,
    scratch: &mut HostScratch,
    lanes: &Lanes<'_>,
) -> HostRoundStats {
    let t = crate::util::Timer::start();
    let (cancelled, src_returned) = cancel_violations_par(st, scratch, lanes);
    scratch.cancel_seconds += t.elapsed();
    // Weighted mode, between rounds: re-cut the stripe boundaries from
    // the excess frontier the cancel pass just snapshotted (per-stripe
    // active-cell counts), row-aligned so W/E receive sides stay
    // intra-stripe.  Bit-exactness is untouched — every striped pass is
    // partition-independent; only the coming passes' work split moves.
    if scratch.tuning.balance == StripeBalance::Weighted && scratch.cuts.n_stripes() > 1 {
        scratch.stripe_weights.clear();
        scratch
            .stripe_weights
            .extend(scratch.stripe_active.iter().map(|a| a.len() as u64));
        let new_cuts = scratch.cuts.rebalance(&scratch.stripe_weights, st.width);
        if new_cuts != scratch.cuts {
            scratch.cuts = new_cuts;
            scratch.rebalances += 1;
        }
    }
    let t = crate::util::Timer::start();
    let mut out = global_relabel_par(st, scratch, lanes);
    scratch.relabel_seconds += t.elapsed();
    out.cancelled_arcs = cancelled;
    out.src_returned = src_returned;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bfs_heights_on_fresh_column() {
        // 3x1 column, sink arc at the bottom cell, full interior caps.
        let mut st = GridWireState::zeros(3, 1);
        st.cap_sink[2] = 5;
        st.cap[3] = 2; // S from cell 0 (S plane starts at cells=3)
        st.cap[4] = 2; // S from cell 1
        let out = global_relabel(&mut st);
        assert_eq!(st.h, vec![3, 2, 1]);
        assert_eq!(out.reached_cells, 3);
        assert_eq!(out.gap_cells, 0);
    }

    #[test]
    fn unreachable_cells_gap_above_v() {
        let mut st = GridWireState::zeros(2, 2);
        st.cap_sink[0] = 1;
        st.cap_src[3] = 1;
        // No interior capacity: cells 1..3 cannot reach the sink; cell 3
        // reaches the source directly, cells 1-2 reach neither terminal.
        let out = global_relabel(&mut st);
        assert_eq!(st.h[0], 1);
        assert_eq!(st.h[3], 7); // |V| + 1, routes excess back to s
        assert_eq!(st.h[1], 12); // 2|V|: inert
        assert_eq!(st.h[2], 12);
        assert_eq!(out.gap_cells, 3);
    }

    #[test]
    fn source_side_distances_route_back() {
        // 1x3 row: src arc at cell 0, no sink arcs, full interior caps.
        let mut st = GridWireState::zeros(1, 3);
        st.cap_src[0] = 5;
        st.cap[3 * 3] = 2; // E from 0
        st.cap[3 * 3 + 1] = 2; // E from 1
        st.cap[2 * 3 + 1] = 2; // W from 1
        st.cap[2 * 3 + 2] = 2; // W from 2
        global_relabel(&mut st);
        assert_eq!(st.h, vec![6, 7, 8]); // |V|=5: 5+1, 5+2, 5+3
    }

    #[test]
    fn violation_cancelling_pushes_back() {
        let mut st = GridWireState::zeros(1, 2);
        // Residual arc 0 -> 1 (E) while h(0) >> h(1): must be cancelled.
        st.cap[3 * 2] = 4;
        st.h[0] = 9;
        st.h[1] = 0;
        st.e[0] = 2;
        let (cancelled, src_ret) = cancel_violations(&mut st);
        assert_eq!(cancelled, 1);
        assert_eq!(src_ret, 0);
        assert_eq!(st.cap[3 * 2], 0);
        assert_eq!(st.cap[2 * 2 + 1], 4); // W mate at cell 1
        assert_eq!(st.e[0], -2);
        assert_eq!(st.e[1], 4);
    }

    #[test]
    fn cancel_skips_excess_free_cells() {
        // Same violating arc but no excess anywhere: the frontier pass
        // leaves it for the relabel to fix (heights are rewritten anyway)
        // instead of perturbing the residual graph.
        let mut st = GridWireState::zeros(1, 2);
        st.cap[3 * 2] = 4;
        st.h[0] = 9;
        let (cancelled, src_ret) = cancel_violations(&mut st);
        assert_eq!(cancelled, 0);
        assert_eq!(src_ret, 0);
        assert_eq!(st.cap[3 * 2], 4);
    }

    fn assert_state_eq(a: &GridWireState, b: &GridWireState, ctx: &str) {
        assert_eq!(a.h, b.h, "{ctx}: heights");
        assert_eq!(a.e, b.e, "{ctx}: excess");
        assert_eq!(a.cap, b.cap, "{ctx}: caps");
        assert_eq!(a.cap_sink, b.cap_sink, "{ctx}: sink caps");
        assert_eq!(a.cap_src, b.cap_src, "{ctx}: src caps");
    }

    /// Adversarial mid-execution state: arbitrary heights/excess so
    /// violations, source returns, and unreachable pockets all occur.
    fn mid_state(seed: u64, hh: usize, ww: usize) -> GridWireState {
        let mut rng = crate::util::Rng::seeded(seed);
        let cells = hh * ww;
        let mut st = GridWireState::zeros(hh, ww);
        for c in 0..cells {
            st.h[c] = (rng.next_u64() % (2 * cells as u64 + 6)) as i32;
            st.e[c] = (rng.next_u64() % 6) as i32;
            st.cap_sink[c] = (rng.next_u64() % 4) as i32;
            st.cap_src[c] = (rng.next_u64() % 4) as i32;
        }
        for a in 0..4 {
            for c in 0..cells {
                st.cap[a * cells + c] = (rng.next_u64() % 5) as i32;
            }
        }
        // Arcs leaving the grid do not exist.
        for j in 0..ww {
            st.cap[j] = 0; // N from top row
            st.cap[cells + (hh - 1) * ww + j] = 0; // S from bottom row
        }
        for i in 0..hh {
            st.cap[2 * cells + i * ww] = 0; // W from col 0
            st.cap[3 * cells + i * ww + ww - 1] = 0; // E from last col
        }
        st
    }

    fn all_tunings() -> Vec<ParTuning> {
        let mut out = Vec::new();
        for balance in [StripeBalance::Fixed, StripeBalance::Weighted] {
            for commit in [CommitMode::TwoPass, CommitMode::Merged] {
                out.push(ParTuning { balance, commit });
            }
        }
        out
    }

    #[test]
    fn striped_round_bit_exact_with_sequential() {
        use crate::parallel::Lanes;
        use crate::service::pool::WorkerPool;

        let pool = WorkerPool::new(3);
        for (seed, hh, ww) in [(1u64, 1usize, 1usize), (2, 5, 7), (3, 16, 3), (4, 9, 9), (5, 1, 24)] {
            for lanes in [Lanes::Seq, Lanes::Scoped { threads: 3 }, Lanes::Pool(&pool)] {
                for tuning in all_tunings() {
                    let mut seq = mid_state(seed, hh, ww);
                    let mut par = seq.clone();
                    let mut ss = HostScratch::for_state(&seq);
                    let mut ps = HostScratch::for_state(&par);
                    ps.set_tuning(tuning);
                    let ctx =
                        format!("seed={seed} {hh}x{ww} lanes={} {tuning:?}", lanes.width());
                    // Several rounds through the same scratches, so the
                    // reused stripe buffers (and any weighted re-cuts
                    // carried across rounds) are exercised too.
                    for round in 0..3 {
                        let a = host_round_with(&mut seq, &mut ss);
                        let b = host_round_par(&mut par, &mut ps, &lanes);
                        assert_eq!(a, b, "{ctx}: stats at round {round}");
                        assert_state_eq(&seq, &par, &format!("{ctx} round {round}"));
                    }
                }
            }
        }
    }

    #[test]
    fn weighted_rounds_recut_on_skewed_excess_and_stay_exact() {
        use crate::parallel::Lanes;

        // All excess concentrated in the bottom rows: the uniform cuts
        // leave most stripes idle, so weighted mode must re-cut at
        // least once — and the re-cut rounds must stay bit-exact.
        let (hh, ww) = (16usize, 3usize);
        let mut seq = mid_state(21, hh, ww);
        for c in 0..(hh - 2) * ww {
            seq.e[c] = 0;
        }
        for c in (hh - 2) * ww..hh * ww {
            seq.e[c] = 3;
        }
        let mut par = seq.clone();
        let mut ss = HostScratch::for_state(&seq);
        let mut ps = HostScratch::for_state(&par);
        ps.set_tuning(ParTuning {
            balance: StripeBalance::Weighted,
            commit: CommitMode::Merged,
        });
        let lanes = Lanes::Scoped { threads: 3 };
        for round in 0..3 {
            let a = host_round_with(&mut seq, &mut ss);
            let b = host_round_par(&mut par, &mut ps, &lanes);
            assert_eq!(a, b, "stats at round {round}");
            assert_state_eq(&seq, &par, &format!("round {round}"));
        }
        assert!(ps.take_rebalances() > 0, "skewed excess never re-cut");
        assert_eq!(ps.take_rebalances(), 0, "take must drain");
        // Fixed-mode scratches never report re-cuts.
        assert_eq!(ss.take_rebalances(), 0);
    }

    #[test]
    fn striped_passes_bit_exact_individually() {
        use crate::parallel::Lanes;

        for (seed, hh, ww) in [(11u64, 4usize, 11usize), (12, 13, 2)] {
            let mut seq = mid_state(seed, hh, ww);
            let mut par = seq.clone();
            let mut ss = HostScratch::for_state(&seq);
            let mut ps = HostScratch::for_state(&par);
            let lanes = Lanes::Scoped { threads: 4 };
            assert_eq!(
                cancel_violations_with(&mut seq, &mut ss),
                cancel_violations_par(&mut par, &mut ps, &lanes),
                "cancel stats seed={seed}"
            );
            assert_state_eq(&seq, &par, &format!("after cancel seed={seed}"));
            assert_eq!(
                global_relabel_with(&mut seq, &mut ss),
                global_relabel_par(&mut par, &mut ps, &lanes),
                "relabel stats seed={seed}"
            );
            assert_state_eq(&seq, &par, &format!("after relabel seed={seed}"));
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh_rounds() {
        // Driving rounds through one scratch must equal fresh wrappers.
        let mut a = GridWireState::zeros(3, 3);
        a.cap_sink[8] = 3;
        a.cap_src[0] = 3;
        a.e[0] = 3;
        a.cap[9 + 1] = 2; // S plane
        a.cap[9 + 4] = 2;
        a.cap[3 * 9] = 2; // E plane
        let mut b = a.clone();
        let mut scratch = HostScratch::for_state(&a);
        for _ in 0..3 {
            let x = host_round_with(&mut a, &mut scratch);
            let y = host_round(&mut b);
            assert_eq!(x, y);
            assert_eq!(a.h, b.h);
            assert_eq!(a.e, b.e);
            assert_eq!(a.cap, b.cap);
        }
    }
}
