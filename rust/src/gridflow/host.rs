//! Host phase of the hybrid scheme on grid states (Algorithm 4.8): cancel
//! height-violating residual arcs, then a backwards BFS from the sink
//! assigns exact distances, and the gap step parks unreached cells at |V|.
//!
//! In the paper this is the C procedure the CUDA kernel returns control
//! to every CYCLE iterations; here it runs between PJRT super-steps.

use std::collections::VecDeque;

use crate::runtime::device::GridWireState;

const DIRS: [(i64, i64); 4] = [(-1, 0), (1, 0), (0, -1), (0, 1)];
const OPP: [usize; 4] = [1, 0, 3, 2];

/// Outcome counters of one host round.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HostRoundStats {
    pub cancelled_arcs: u64,
    pub reached_cells: u64,
    pub gap_cells: u64,
    /// Flow returned to the source by violation cancellation on (x, s)
    /// arcs (must be credited to the solver's src_flow total).
    pub src_returned: i64,
}

/// Cancel residual arcs with `h(x) > h(y) + 1` by pushing their full
/// residual (Algorithm 4.8 lines 1-6).  Terminal arcs: the sink counts as
/// height 0 (never violated: pushing to the sink is always allowed), the
/// source as height |V|.
pub fn cancel_violations(st: &mut GridWireState) -> (u64, i64) {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    let v_total = (cells + 2) as i64;
    let mut cancelled = 0;
    let mut src_returned = 0i64;
    for i in 0..hh {
        for j in 0..ww {
            let c = i * ww + j;
            for (a, &(di, dj)) in DIRS.iter().enumerate() {
                let (ni, nj) = (i as i64 + di, j as i64 + dj);
                if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                    continue;
                }
                let nc = (ni as usize) * ww + nj as usize;
                let r = st.cap[a * cells + c];
                if r > 0 && (st.h[c] as i64) > st.h[nc] as i64 + 1 {
                    st.cap[a * cells + c] = 0;
                    st.cap[OPP[a] * cells + nc] += r;
                    st.e[c] -= r;
                    st.e[nc] += r;
                    cancelled += 1;
                }
            }
            // Source arc: violation when h(x) > |V| + 1.
            let r = st.cap_src[c];
            if r > 0 && (st.h[c] as i64) > v_total + 1 {
                st.cap_src[c] = 0;
                st.e[c] -= r;
                src_returned += r as i64;
                cancelled += 1;
            }
        }
    }
    (cancelled, src_returned)
}

/// Global relabel: heights become exact BFS distances to the sink in the
/// residual graph; unreached cells are parked at |V| (gap relabeling,
/// §4.6 "for each unvisited node ... sets its height to |V|").
pub fn global_relabel(st: &mut GridWireState) -> HostRoundStats {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    let v_total = (cells + 2) as i32;

    let mut dist = vec![-1i32; cells];
    let mut q = VecDeque::new();
    // Distance 1: cells with residual arc to the sink.
    for c in 0..cells {
        if st.cap_sink[c] > 0 {
            dist[c] = 1;
            q.push_back(c);
        }
    }
    let mut reached = q.len() as u64;
    while let Some(c) = q.pop_front() {
        let (i, j) = (c / ww, c % ww);
        // Traverse reverse residual arcs: neighbour n can reach c if the
        // arc n->c has residual capacity, i.e. cap[a_from_n][n] > 0 where
        // a_from_n points from n to c (= OPP of the arc c->n).
        for (a, &(di, dj)) in DIRS.iter().enumerate() {
            let (ni, nj) = (i as i64 + di, j as i64 + dj);
            if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                continue;
            }
            let nc = (ni as usize) * ww + nj as usize;
            if dist[nc] < 0 && st.cap[OPP[a] * cells + nc] > 0 {
                dist[nc] = dist[c] + 1;
                reached += 1;
                q.push_back(nc);
            }
        }
    }

    // Second phase (Cherkassky–Goldberg): cells that cannot reach the
    // sink get `|V| + distance-to-source`, so their excess routes back to
    // the source instead of re-climbing from the |V| plateau every round
    // (plain `h = |V|` livelocks when CYCLE is smaller than the climb).
    let mut dist_s = vec![-1i32; cells];
    let mut qs = VecDeque::new();
    for c in 0..cells {
        if dist[c] < 0 && st.cap_src[c] > 0 {
            dist_s[c] = 1;
            qs.push_back(c);
        }
    }
    while let Some(c) = qs.pop_front() {
        let (i, j) = (c / ww, c % ww);
        for (a, &(di, dj)) in DIRS.iter().enumerate() {
            let (ni, nj) = (i as i64 + di, j as i64 + dj);
            if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                continue;
            }
            let nc = (ni as usize) * ww + nj as usize;
            if dist[nc] < 0 && dist_s[nc] < 0 && st.cap[OPP[a] * cells + nc] > 0 {
                dist_s[nc] = dist_s[c] + 1;
                qs.push_back(nc);
            }
        }
    }

    let mut gap = 0;
    for c in 0..cells {
        st.h[c] = if dist[c] >= 0 {
            dist[c]
        } else {
            gap += 1;
            if dist_s[c] >= 0 {
                v_total + dist_s[c]
            } else {
                // Unreachable from both terminals: inert (no excess can
                // sit here by the preflow invariant).
                2 * v_total
            }
        };
    }
    HostRoundStats {
        cancelled_arcs: 0,
        reached_cells: reached,
        gap_cells: gap,
        src_returned: 0,
    }
}

/// Full host round: cancel violations then global+gap relabel.
pub fn host_round(st: &mut GridWireState) -> HostRoundStats {
    let (cancelled, src_returned) = cancel_violations(st);
    let mut out = global_relabel(st);
    out.cancelled_arcs = cancelled;
    out.src_returned = src_returned;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bfs_heights_on_fresh_column() {
        // 3x1 column, sink arc at the bottom cell, full interior caps.
        let mut st = GridWireState::zeros(3, 1);
        st.cap_sink[2] = 5;
        st.cap[1 * 3 + 0] = 2; // S from cell 0
        st.cap[1 * 3 + 1] = 2; // S from cell 1
        let out = global_relabel(&mut st);
        assert_eq!(st.h, vec![3, 2, 1]);
        assert_eq!(out.reached_cells, 3);
        assert_eq!(out.gap_cells, 0);
    }

    #[test]
    fn unreachable_cells_gap_above_v() {
        let mut st = GridWireState::zeros(2, 2);
        st.cap_sink[0] = 1;
        st.cap_src[3] = 1;
        // No interior capacity: cells 1..3 cannot reach the sink; cell 3
        // reaches the source directly, cells 1-2 reach neither terminal.
        let out = global_relabel(&mut st);
        assert_eq!(st.h[0], 1);
        assert_eq!(st.h[3], 7); // |V| + 1, routes excess back to s
        assert_eq!(st.h[1], 12); // 2|V|: inert
        assert_eq!(st.h[2], 12);
        assert_eq!(out.gap_cells, 3);
    }

    #[test]
    fn source_side_distances_route_back() {
        // 1x3 row: src arc at cell 0, no sink arcs, full interior caps.
        let mut st = GridWireState::zeros(1, 3);
        st.cap_src[0] = 5;
        st.cap[3 * 3] = 2; // E from 0
        st.cap[3 * 3 + 1] = 2; // E from 1
        st.cap[2 * 3 + 1] = 2; // W from 1
        st.cap[2 * 3 + 2] = 2; // W from 2
        global_relabel(&mut st);
        assert_eq!(st.h, vec![6, 7, 8]); // |V|=5: 5+1, 5+2, 5+3
    }

    #[test]
    fn violation_cancelling_pushes_back() {
        let mut st = GridWireState::zeros(1, 2);
        // Residual arc 0 -> 1 (E) while h(0) >> h(1): must be cancelled.
        st.cap[3 * 2] = 4;
        st.h[0] = 9;
        st.h[1] = 0;
        st.e[0] = 2;
        let (cancelled, src_ret) = cancel_violations(&mut st);
        assert_eq!(cancelled, 1);
        assert_eq!(src_ret, 0);
        assert_eq!(st.cap[3 * 2], 0);
        assert_eq!(st.cap[2 * 2 + 1], 4); // W mate at cell 1
        assert_eq!(st.e[0], -2);
        assert_eq!(st.e[1], 4);
    }
}
