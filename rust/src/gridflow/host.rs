//! Host phase of the hybrid scheme on grid states (Algorithm 4.8): cancel
//! height-violating residual arcs, then a backwards BFS from the sink
//! assigns exact distances, and the gap step parks unreached cells at |V|.
//!
//! In the paper this is the C procedure the CUDA kernel returns control
//! to every CYCLE iterations; here it runs between PJRT super-steps.
//!
//! PERF: the passes are frontier-seeded instead of full-grid scans.
//! Violation cancelling visits only cells that currently hold excess
//! (cancelling exists to return trapped excess; an arc at an excess-free
//! cell moves no mass a wave could not move itself), and the two BFS
//! passes seed from cached terminal-cell lists — residual terminal
//! capacity only ever shrinks during a solve, so the cells with initial
//! `cap_sink/cap_src > 0` are a fixed superset.  [`HostScratch`] also
//! reuses the distance/queue buffers across rounds.

use std::collections::VecDeque;

use crate::runtime::device::GridWireState;

const DIRS: [(i64, i64); 4] = [(-1, 0), (1, 0), (0, -1), (0, 1)];
const OPP: [usize; 4] = [1, 0, 3, 2];

/// Outcome counters of one host round.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HostRoundStats {
    pub cancelled_arcs: u64,
    pub reached_cells: u64,
    pub gap_cells: u64,
    /// Flow returned to the source by violation cancellation on (x, s)
    /// arcs (must be credited to the solver's src_flow total).
    pub src_returned: i64,
}

/// Per-solve host scratch: cached terminal seed lists plus reusable BFS
/// buffers.  Build once per solve with [`HostScratch::for_state`] — the
/// terminal caches are supersets only for states whose terminal caps
/// never grow, which holds within a solve but not across solves.
#[derive(Debug, Default)]
pub struct HostScratch {
    /// Cells whose sink arc had residual capacity at construction time
    /// (a fixed superset of the current sink frontier).
    sink_cells: Vec<u32>,
    /// Same for source arcs.
    src_cells: Vec<u32>,
    /// Snapshot of the excess-bearing cells taken by `cancel_violations_with`.
    active: Vec<u32>,
    dist: Vec<i32>,
    dist_s: Vec<i32>,
    queue: VecDeque<usize>,
}

impl HostScratch {
    pub fn for_state(st: &GridWireState) -> Self {
        let cells = st.cells();
        let mut sink_cells = Vec::new();
        let mut src_cells = Vec::new();
        for c in 0..cells {
            if st.cap_sink[c] > 0 {
                sink_cells.push(c as u32);
            }
            if st.cap_src[c] > 0 {
                src_cells.push(c as u32);
            }
        }
        Self {
            sink_cells,
            src_cells,
            ..Default::default()
        }
    }
}

/// Cancel residual arcs with `h(x) > h(y) + 1` by pushing their full
/// residual (Algorithm 4.8 lines 1-6), seeded from the excess frontier:
/// only cells with `e > 0` are visited (snapshot taken before any
/// cancel, in cell order — cells a cancel activates are handled by the
/// waves or the next round).  Terminal arcs: the sink counts as height 0
/// (never violated: pushing to the sink is always allowed), the source
/// as height |V|.
pub fn cancel_violations_with(st: &mut GridWireState, scratch: &mut HostScratch) -> (u64, i64) {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    let v_total = (cells + 2) as i64;
    scratch.active.clear();
    for c in 0..cells {
        if st.e[c] > 0 {
            scratch.active.push(c as u32);
        }
    }
    let mut cancelled = 0;
    let mut src_returned = 0i64;
    for &c in &scratch.active {
        let c = c as usize;
        let (i, j) = (c / ww, c % ww);
        for (a, &(di, dj)) in DIRS.iter().enumerate() {
            let (ni, nj) = (i as i64 + di, j as i64 + dj);
            if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                continue;
            }
            let nc = (ni as usize) * ww + nj as usize;
            let r = st.cap[a * cells + c];
            if r > 0 && (st.h[c] as i64) > st.h[nc] as i64 + 1 {
                st.cap[a * cells + c] = 0;
                st.cap[OPP[a] * cells + nc] += r;
                st.e[c] -= r;
                st.e[nc] += r;
                cancelled += 1;
            }
        }
        // Source arc: violation when h(x) > |V| + 1.
        let r = st.cap_src[c];
        if r > 0 && (st.h[c] as i64) > v_total + 1 {
            st.cap_src[c] = 0;
            st.e[c] -= r;
            src_returned += r as i64;
            cancelled += 1;
        }
    }
    (cancelled, src_returned)
}

/// Allocating wrapper around [`cancel_violations_with`].
pub fn cancel_violations(st: &mut GridWireState) -> (u64, i64) {
    let mut scratch = HostScratch::for_state(st);
    cancel_violations_with(st, &mut scratch)
}

/// Global relabel: heights become exact BFS distances to the sink in the
/// residual graph; unreached cells are parked at |V| (gap relabeling,
/// §4.6 "for each unvisited node ... sets its height to |V|").  Seeds
/// come from the scratch's cached terminal lists.
pub fn global_relabel_with(st: &mut GridWireState, scratch: &mut HostScratch) -> HostRoundStats {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    let v_total = (cells + 2) as i32;

    let dist = &mut scratch.dist;
    dist.clear();
    dist.resize(cells, -1);
    let q = &mut scratch.queue;
    q.clear();
    // Distance 1: cells with residual arc to the sink.
    for &c in &scratch.sink_cells {
        let c = c as usize;
        if st.cap_sink[c] > 0 {
            dist[c] = 1;
            q.push_back(c);
        }
    }
    let mut reached = q.len() as u64;
    while let Some(c) = q.pop_front() {
        let (i, j) = (c / ww, c % ww);
        // Traverse reverse residual arcs: neighbour n can reach c if the
        // arc n->c has residual capacity, i.e. cap[a_from_n][n] > 0 where
        // a_from_n points from n to c (= OPP of the arc c->n).
        for (a, &(di, dj)) in DIRS.iter().enumerate() {
            let (ni, nj) = (i as i64 + di, j as i64 + dj);
            if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                continue;
            }
            let nc = (ni as usize) * ww + nj as usize;
            if dist[nc] < 0 && st.cap[OPP[a] * cells + nc] > 0 {
                dist[nc] = dist[c] + 1;
                reached += 1;
                q.push_back(nc);
            }
        }
    }

    // Second phase (Cherkassky–Goldberg): cells that cannot reach the
    // sink get `|V| + distance-to-source`, so their excess routes back to
    // the source instead of re-climbing from the |V| plateau every round
    // (plain `h = |V|` livelocks when CYCLE is smaller than the climb).
    let dist_s = &mut scratch.dist_s;
    dist_s.clear();
    dist_s.resize(cells, -1);
    for &c in &scratch.src_cells {
        let c = c as usize;
        if dist[c] < 0 && st.cap_src[c] > 0 {
            dist_s[c] = 1;
            q.push_back(c);
        }
    }
    while let Some(c) = q.pop_front() {
        let (i, j) = (c / ww, c % ww);
        for (a, &(di, dj)) in DIRS.iter().enumerate() {
            let (ni, nj) = (i as i64 + di, j as i64 + dj);
            if ni < 0 || nj < 0 || ni >= hh as i64 || nj >= ww as i64 {
                continue;
            }
            let nc = (ni as usize) * ww + nj as usize;
            if dist[nc] < 0 && dist_s[nc] < 0 && st.cap[OPP[a] * cells + nc] > 0 {
                dist_s[nc] = dist_s[c] + 1;
                q.push_back(nc);
            }
        }
    }

    let mut gap = 0;
    for c in 0..cells {
        st.h[c] = if dist[c] >= 0 {
            dist[c]
        } else {
            gap += 1;
            if dist_s[c] >= 0 {
                v_total + dist_s[c]
            } else {
                // Unreachable from both terminals: inert (no excess can
                // sit here by the preflow invariant).
                2 * v_total
            }
        };
    }
    HostRoundStats {
        cancelled_arcs: 0,
        reached_cells: reached,
        gap_cells: gap,
        src_returned: 0,
    }
}

/// Allocating wrapper around [`global_relabel_with`].
pub fn global_relabel(st: &mut GridWireState) -> HostRoundStats {
    let mut scratch = HostScratch::for_state(st);
    global_relabel_with(st, &mut scratch)
}

/// Full host round: cancel violations then global+gap relabel.
pub fn host_round_with(st: &mut GridWireState, scratch: &mut HostScratch) -> HostRoundStats {
    let (cancelled, src_returned) = cancel_violations_with(st, scratch);
    let mut out = global_relabel_with(st, scratch);
    out.cancelled_arcs = cancelled;
    out.src_returned = src_returned;
    out
}

/// Allocating wrapper around [`host_round_with`].
pub fn host_round(st: &mut GridWireState) -> HostRoundStats {
    let mut scratch = HostScratch::for_state(st);
    host_round_with(st, &mut scratch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bfs_heights_on_fresh_column() {
        // 3x1 column, sink arc at the bottom cell, full interior caps.
        let mut st = GridWireState::zeros(3, 1);
        st.cap_sink[2] = 5;
        st.cap[3] = 2; // S from cell 0 (S plane starts at cells=3)
        st.cap[4] = 2; // S from cell 1
        let out = global_relabel(&mut st);
        assert_eq!(st.h, vec![3, 2, 1]);
        assert_eq!(out.reached_cells, 3);
        assert_eq!(out.gap_cells, 0);
    }

    #[test]
    fn unreachable_cells_gap_above_v() {
        let mut st = GridWireState::zeros(2, 2);
        st.cap_sink[0] = 1;
        st.cap_src[3] = 1;
        // No interior capacity: cells 1..3 cannot reach the sink; cell 3
        // reaches the source directly, cells 1-2 reach neither terminal.
        let out = global_relabel(&mut st);
        assert_eq!(st.h[0], 1);
        assert_eq!(st.h[3], 7); // |V| + 1, routes excess back to s
        assert_eq!(st.h[1], 12); // 2|V|: inert
        assert_eq!(st.h[2], 12);
        assert_eq!(out.gap_cells, 3);
    }

    #[test]
    fn source_side_distances_route_back() {
        // 1x3 row: src arc at cell 0, no sink arcs, full interior caps.
        let mut st = GridWireState::zeros(1, 3);
        st.cap_src[0] = 5;
        st.cap[3 * 3] = 2; // E from 0
        st.cap[3 * 3 + 1] = 2; // E from 1
        st.cap[2 * 3 + 1] = 2; // W from 1
        st.cap[2 * 3 + 2] = 2; // W from 2
        global_relabel(&mut st);
        assert_eq!(st.h, vec![6, 7, 8]); // |V|=5: 5+1, 5+2, 5+3
    }

    #[test]
    fn violation_cancelling_pushes_back() {
        let mut st = GridWireState::zeros(1, 2);
        // Residual arc 0 -> 1 (E) while h(0) >> h(1): must be cancelled.
        st.cap[3 * 2] = 4;
        st.h[0] = 9;
        st.h[1] = 0;
        st.e[0] = 2;
        let (cancelled, src_ret) = cancel_violations(&mut st);
        assert_eq!(cancelled, 1);
        assert_eq!(src_ret, 0);
        assert_eq!(st.cap[3 * 2], 0);
        assert_eq!(st.cap[2 * 2 + 1], 4); // W mate at cell 1
        assert_eq!(st.e[0], -2);
        assert_eq!(st.e[1], 4);
    }

    #[test]
    fn cancel_skips_excess_free_cells() {
        // Same violating arc but no excess anywhere: the frontier pass
        // leaves it for the relabel to fix (heights are rewritten anyway)
        // instead of perturbing the residual graph.
        let mut st = GridWireState::zeros(1, 2);
        st.cap[3 * 2] = 4;
        st.h[0] = 9;
        let (cancelled, src_ret) = cancel_violations(&mut st);
        assert_eq!(cancelled, 0);
        assert_eq!(src_ret, 0);
        assert_eq!(st.cap[3 * 2], 4);
    }

    #[test]
    fn scratch_reuse_matches_fresh_rounds() {
        // Driving rounds through one scratch must equal fresh wrappers.
        let mut a = GridWireState::zeros(3, 3);
        a.cap_sink[8] = 3;
        a.cap_src[0] = 3;
        a.e[0] = 3;
        a.cap[9 + 1] = 2; // S plane
        a.cap[9 + 4] = 2;
        a.cap[3 * 9] = 2; // E plane
        let mut b = a.clone();
        let mut scratch = HostScratch::for_state(&a);
        for _ in 0..3 {
            let x = host_round_with(&mut a, &mut scratch);
            let y = host_round(&mut b);
            assert_eq!(x, y);
            assert_eq!(a.h, b.h);
            assert_eq!(a.e, b.e);
            assert_eq!(a.cap, b.cap);
        }
    }
}
