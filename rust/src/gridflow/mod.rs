//! Grid push-relabel execution: the dense wave engine (a bit-exact native
//! twin of the L1 Pallas kernel), the host-side heuristics of the hybrid
//! scheme, and the solver that alternates the two — with the device phase
//! served either natively or by the PJRT artifact.

pub mod batch;
pub mod host;
pub mod par_wave;
pub mod solver;
pub mod state;
pub mod warm;
pub mod wave;

pub use batch::{padded_class, BatchGridSolver};
pub use par_wave::{par_wave_pooled, par_wave_with, NativeParGridExecutor, ParWaveScratch};
pub use solver::{GridExecutor, GridSolveReport, HostRounds, HybridGridSolver, NativeGridExecutor};
pub use state::init_state;
pub use warm::{CapacityDelta, WarmState};
pub use wave::{native_wave, WaveStats};
