//! The hybrid grid solver: device super-steps (native waves or the PJRT
//! artifact) alternating with host rounds (violation cancel + global/gap
//! relabel), Algorithm 4.6's loop `while e(s) + e(t) < ExcessTotal`.

use std::sync::Arc;

use anyhow::Result;

use crate::graph::GridNetwork;
use crate::obs::{self, Phase, PhaseBreakdown};
use crate::parallel::{Lanes, ParTuning};
use crate::runtime::device::{GridStepStats, GridWireState};
use crate::service::pool::WorkerPool;
use crate::util::CancelToken;

use super::host;
use super::state::init_state;
#[cfg(feature = "paranoid")]
use super::wave::active_cells;
use super::wave::{native_wave_with, WaveScratch};

/// Host-round execution policy of the hybrid solver: the classic
/// sequential passes, or their stripe-parallel twins on the shared
/// frontier substrate (`crate::parallel`).  The twins are bit-exact, so
/// this is purely a performance switch (`[gridflow] host_rounds`,
/// CLI `--host-rounds`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum HostRounds {
    #[default]
    Seq,
    Striped,
}

impl HostRounds {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "seq" => HostRounds::Seq,
            "striped" => HostRounds::Striped,
            other => anyhow::bail!("unknown host_rounds {other:?} (expected seq, striped)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            HostRounds::Seq => "seq",
            HostRounds::Striped => "striped",
        }
    }
}

/// A device that can advance the grid state by up to `outer * k_inner`
/// waves.  Implemented natively below (sequential and tiled-parallel)
/// and by `runtime::GridDevice`.
pub trait GridExecutor {
    fn k_inner(&self) -> usize;
    fn superstep(&mut self, st: &mut GridWireState, outer: i32) -> Result<GridStepStats>;
    fn name(&self) -> &'static str;
    /// The host mutated the state outside `superstep` (fresh instance,
    /// violation cancel, …): drop any cached active sets.  Devices that
    /// re-derive activity on-device (PJRT) ignore this.
    fn invalidate(&mut self) {}
    /// Worker pool the solver's striped host rounds may borrow between
    /// super-steps.  `None` (the default) keeps striped host rounds on
    /// the sequential lanes fallback — same results, no threads.
    fn host_pool(&self) -> Option<Arc<WorkerPool>> {
        None
    }
}

/// Pure-Rust executor: runs the bit-exact kernel twin in-process.
pub struct NativeGridExecutor {
    pub k_inner: usize,
    scratch: WaveScratch,
    needs_rebuild: bool,
}

impl NativeGridExecutor {
    pub fn with_k_inner(k_inner: usize) -> Self {
        Self {
            k_inner,
            scratch: WaveScratch::default(),
            needs_rebuild: true,
        }
    }
}

impl Default for NativeGridExecutor {
    fn default() -> Self {
        Self::with_k_inner(16)
    }
}

impl GridExecutor for NativeGridExecutor {
    fn k_inner(&self) -> usize {
        self.k_inner
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn invalidate(&mut self) {
        self.needs_rebuild = true;
    }

    fn superstep(&mut self, st: &mut GridWireState, outer: i32) -> Result<GridStepStats> {
        let mut stats = GridStepStats::default();
        let budget = outer as i64 * self.k_inner as i64;
        // The active list is rebuilt only when the host announced a
        // mutation (`invalidate`) or the dims changed, and maintained
        // incrementally inside the waves otherwise (PERF: the old code
        // rescanned the grid on every superstep even when no host round
        // had touched the state; see EXPERIMENTS.md §Parallel-Wave).
        if self.needs_rebuild || self.scratch.built_for != Some((st.height, st.width)) {
            self.scratch.rebuild(st);
            self.needs_rebuild = false;
        }
        for _ in 0..budget {
            if self.scratch.active_count() == 0 {
                break;
            }
            let w = native_wave_with(st, &mut self.scratch);
            stats.sink_flow += w.sink_flow;
            stats.src_flow += w.src_flow;
            stats.pushes += w.pushes;
            stats.relabels += w.relabels;
            stats.waves += 1;
        }
        // O(cells) scan per superstep: too hot even for debug CI runs,
        // so it only exists under the `paranoid` feature.
        #[cfg(feature = "paranoid")]
        debug_assert_eq!(self.scratch.active_count(), active_cells(st));
        stats.active = self.scratch.active_count() as i64;
        Ok(stats)
    }
}

/// PJRT-backed executor.
impl GridExecutor for crate::runtime::GridDevice {
    fn k_inner(&self) -> usize {
        self.k_inner
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn superstep(&mut self, st: &mut GridWireState, outer: i32) -> Result<GridStepStats> {
        self.step(st, outer)
    }
}

/// Solve report: flow value + the operational counters of the hybrid loop.
#[derive(Debug, Clone, Default)]
pub struct GridSolveReport {
    pub flow: i64,
    pub excess_total: i64,
    pub host_rounds: u64,
    pub waves: i64,
    pub pushes: i64,
    pub relabels: i64,
    pub gap_cells: u64,
    pub cancelled_arcs: u64,
    pub device_seconds: f64,
    pub host_seconds: f64,
    /// Per-phase breakdown of the same wall-clock: `wave_compute` ≈
    /// `device_seconds`, `cancel + global_relabel` ≈ `host_seconds`.
    pub phases: PhaseBreakdown,
}

/// The hybrid solver (Algorithm 4.6 shape).
pub struct HybridGridSolver {
    /// Waves per host round = `CYCLE` (the paper's 7000 maps to
    /// `outer = CYCLE / k_inner` device iterations per super-step).
    pub cycle_waves: usize,
    /// Run the host heuristics between super-steps.
    pub heuristics: bool,
    /// Abort threshold.
    pub max_rounds: u64,
    /// Sequential host rounds, or the stripe-parallel twins (bit-exact;
    /// parallel when a pool is available).
    pub host_rounds: HostRounds,
    /// Striped-pass tuning for the host-round twins: stripe balancing
    /// (`[gridflow] stripe_balance`) and commit batching (`[gridflow]
    /// commit`).  Ignored by sequential host rounds; the default is the
    /// prior behaviour exactly.
    pub tuning: ParTuning,
    /// Explicit pool for striped host rounds.  Takes precedence over
    /// the executor's own pool ([`GridExecutor::host_pool`]); lets
    /// callers parallelise host rounds behind executors that have no
    /// worker threads of their own (sequential native, PJRT).
    pub host_pool: Option<Arc<WorkerPool>>,
    /// Cooperative cancellation (deadline / caller gave up), polled at
    /// host-round boundaries.  A cancelled solve returns the typed
    /// [`crate::util::Cancelled`] error.
    pub cancel: Option<CancelToken>,
}

impl Default for HybridGridSolver {
    fn default() -> Self {
        Self {
            cycle_waves: 512,
            heuristics: true,
            max_rounds: 100_000,
            host_rounds: HostRounds::Seq,
            tuning: ParTuning::default(),
            host_pool: None,
            cancel: None,
        }
    }
}

impl HybridGridSolver {
    pub fn with_cycle(cycle_waves: usize) -> Self {
        Self {
            cycle_waves: cycle_waves.max(1),
            ..Self::default()
        }
    }

    pub fn no_heuristics(cycle_waves: usize) -> Self {
        Self {
            cycle_waves: cycle_waves.max(1),
            heuristics: false,
            ..Self::default()
        }
    }

    pub fn with_host_rounds(mut self, host_rounds: HostRounds) -> Self {
        self.host_rounds = host_rounds;
        self
    }

    pub fn with_tuning(mut self, tuning: ParTuning) -> Self {
        self.tuning = tuning;
        self
    }

    pub fn with_host_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.host_pool = Some(pool);
        self
    }

    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Run to completion on `net` using `exec` for the device phase.
    pub fn solve(&self, net: &GridNetwork, exec: &mut dyn GridExecutor) -> Result<GridSolveReport> {
        self.solve_state(net, exec).map(|(report, _)| report)
    }

    /// Like [`HybridGridSolver::solve`], but also hands back the final
    /// wire state (residual caps, heights, zero excess) — the snapshot
    /// warm-start sessions keep to repair and resume after graph edits
    /// (`super::warm`).
    pub fn solve_state(
        &self,
        net: &GridNetwork,
        exec: &mut dyn GridExecutor,
    ) -> Result<(GridSolveReport, GridWireState)> {
        let (mut st, excess_total) = init_state(net);
        let report = self.resume(&mut st, excess_total, 0, 0, exec)?;
        Ok((report, st))
    }

    /// Run the hybrid loop from an arbitrary preflow state.  A cold
    /// solve is `resume(init_state(net), excess_total, 0, 0)`; a warm
    /// resume seeds the mass accounting with the flow the repaired state
    /// already commits: `sink_committed` units sitting at the sink
    /// (`Σ net.cap_sink − st.cap_sink`) and `src_committed` units
    /// already returned to the source (`Σ net.cap_source − st.cap_src`).
    /// The loop's invariant `sink + src + in-flight excess ==
    /// excess_total` is unchanged — only the starting totals move.
    pub fn resume(
        &self,
        st: &mut GridWireState,
        excess_total: i64,
        sink_committed: i64,
        src_committed: i64,
        exec: &mut dyn GridExecutor,
    ) -> Result<GridSolveReport> {
        let mut report = GridSolveReport {
            excess_total,
            ..Default::default()
        };
        // Unknown state: whatever the executor cached belongs to a
        // previous solve (or to the pre-repair state).
        exec.invalidate();
        // Fresh scratch: the cached terminal seed lists are only valid
        // for states whose terminal caps never grow, which holds from
        // here on but not across an edit that raised them.
        let mut hscratch = host::HostScratch::for_state(st);
        hscratch.set_tuning(self.tuning);

        // Striped host rounds run on the solver's explicit pool, else
        // the executor's (the service's native-par backend); with
        // neither they fall back to sequential lanes — same results
        // either way.
        let striped = self.host_rounds == HostRounds::Striped;
        let host_pool = if striped {
            self.host_pool.clone().or_else(|| exec.host_pool())
        } else {
            None
        };
        let lanes = match &host_pool {
            Some(p) => Lanes::Pool(p.as_ref()),
            None => Lanes::Seq,
        };

        // Exact initial heights (the hybrid scheme begins with a global
        // relabel — same as copying h to the device in Algorithm 4.6).
        if let Some(c) = &self.cancel {
            c.check()?;
        }
        if self.heuristics {
            let t = crate::util::Timer::start();
            let out = if striped {
                host::global_relabel_par(st, &mut hscratch, &lanes)
            } else {
                host::global_relabel_with(st, &mut hscratch)
            };
            report.gap_cells += out.gap_cells;
            // A relabel that parked unreachable cells at |V| is one gap
            // event (the grid twin of the CSR engines' batched lift).
            if out.gap_cells > 0 {
                report.phases.gap_relabels += 1;
            }
            let secs = t.elapsed();
            report.host_seconds += secs;
            report.phases.add(Phase::GlobalRelabel, secs);
            report.phases.global_relabels += 1;
        }

        let outer = (self.cycle_waves as i64 + exec.k_inner() as i64 - 1) / exec.k_inner() as i64;
        let mut sink_total = sink_committed;
        let mut src_total = src_committed;

        loop {
            // Host-round boundary: the cheapest safe point to give up —
            // the state is consistent and no device step is in flight.
            if let Some(c) = &self.cancel {
                c.check()?;
            }
            let t = crate::util::Timer::start();
            let stats = exec.superstep(st, outer as i32)?;
            let secs = t.elapsed();
            report.device_seconds += secs;
            report.phases.add(Phase::WaveCompute, secs);
            sink_total += stats.sink_flow;
            src_total += stats.src_flow;
            report.waves += stats.waves;
            report.pushes += stats.pushes;
            report.relabels += stats.relabels;
            report.host_rounds += 1;

            if sink_total + src_total >= excess_total && stats.active == 0 {
                break;
            }
            anyhow::ensure!(
                report.host_rounds < self.max_rounds,
                "hybrid grid solve exceeded {} rounds (sink={} src={} total={})",
                self.max_rounds,
                sink_total,
                src_total,
                excess_total
            );

            if self.heuristics {
                let t = crate::util::Timer::start();
                // The round writes its split (cancel vs relabel) into the
                // scratch's cumulative clocks; the deltas go to the phases.
                let (c0, r0) = (hscratch.cancel_seconds, hscratch.relabel_seconds);
                let out = if striped {
                    host::host_round_par(st, &mut hscratch, &lanes)
                } else {
                    host::host_round_with(st, &mut hscratch)
                };
                src_total += out.src_returned;
                report.gap_cells += out.gap_cells;
                if out.gap_cells > 0 {
                    report.phases.gap_relabels += 1;
                }
                report.cancelled_arcs += out.cancelled_arcs;
                report.host_seconds += t.elapsed();
                report.phases.add(Phase::Cancel, hscratch.cancel_seconds - c0);
                report
                    .phases
                    .add(Phase::GlobalRelabel, hscratch.relabel_seconds - r0);
                report.phases.global_relabels += 1;
                exec.invalidate();
            }
        }

        anyhow::ensure!(
            sink_total + src_total == excess_total,
            "mass accounting broken: sink {} + src {} != total {}",
            sink_total,
            src_total,
            excess_total
        );
        report.flow = sink_total;
        report.phases.pushes = report.pushes.max(0) as u64;
        report.phases.relabels = report.relabels.max(0) as u64;
        report.phases.waves = report.waves.max(0) as u64;
        report.phases.rebalances = hscratch.take_rebalances();
        obs::record_phases("grid", &report.phases);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::grid::{E, S};
    use crate::maxflow::{self, MaxFlowSolver};

    fn demo_net() -> GridNetwork {
        let mut net = GridNetwork::zeros(4, 4);
        for j in 0..4 {
            let top = net.cell(0, j);
            let bot = net.cell(3, j);
            net.cap_source[top] = 4;
            net.cap_sink[bot] = 3;
        }
        for i in 0..4 {
            for j in 0..4 {
                if i + 1 < 4 {
                    net.set_neighbour_cap(i, j, S, 2);
                }
                if j + 1 < 4 {
                    net.set_neighbour_cap(i, j, E, 1);
                }
            }
        }
        net
    }

    #[test]
    fn native_hybrid_matches_sequential_reference() {
        let net = demo_net();
        let mut exec = NativeGridExecutor::default();
        let report = HybridGridSolver::with_cycle(32)
            .solve(&net, &mut exec)
            .unwrap();

        let mut g = net.to_flow_network();
        let want = maxflow::dinic::Dinic.solve(&mut g).unwrap();
        assert_eq!(report.flow, want.value);
    }

    #[test]
    fn cycle_extremes_agree() {
        let net = demo_net();
        let mut flows = Vec::new();
        for cycle in [1, 4, 64, 4096] {
            let mut exec = NativeGridExecutor::default();
            let report = HybridGridSolver::with_cycle(cycle)
                .solve(&net, &mut exec)
                .unwrap();
            flows.push(report.flow);
        }
        assert!(flows.windows(2).all(|w| w[0] == w[1]), "{flows:?}");
    }

    #[test]
    fn no_heuristics_still_correct() {
        let net = demo_net();
        let mut exec = NativeGridExecutor::default();
        let report = HybridGridSolver::no_heuristics(1_000_000)
            .solve(&net, &mut exec)
            .unwrap();
        let mut g = net.to_flow_network();
        let want = maxflow::dinic::Dinic.solve(&mut g).unwrap();
        assert_eq!(report.flow, want.value);
    }

    #[test]
    fn tuned_striped_host_rounds_match_sequential_host_rounds() {
        use crate::parallel::{CommitMode, StripeBalance};

        let net = demo_net();
        let mut exec = NativeGridExecutor::default();
        let want = HybridGridSolver::with_cycle(8)
            .solve(&net, &mut exec)
            .unwrap();
        for balance in [StripeBalance::Fixed, StripeBalance::Weighted] {
            for commit in [CommitMode::TwoPass, CommitMode::Merged] {
                let tuning = ParTuning { balance, commit };
                let mut exec = NativeGridExecutor::default();
                let got = HybridGridSolver::with_cycle(8)
                    .with_host_rounds(HostRounds::Striped)
                    .with_tuning(tuning)
                    .solve(&net, &mut exec)
                    .unwrap();
                assert_eq!(got.flow, want.flow, "{tuning:?}");
                assert_eq!(got.waves, want.waves, "{tuning:?}");
                assert_eq!(got.host_rounds, want.host_rounds, "{tuning:?}");
                assert_eq!(got.gap_cells, want.gap_cells, "{tuning:?}");
                assert_eq!(got.cancelled_arcs, want.cancelled_arcs, "{tuning:?}");
                assert_eq!(
                    got.phases.gap_relabels, want.phases.gap_relabels,
                    "{tuning:?}"
                );
            }
        }
    }

    #[test]
    fn cancelled_token_aborts_solve_with_typed_error() {
        let net = demo_net();
        let mut exec = NativeGridExecutor::default();
        let token = CancelToken::new();
        token.cancel();
        let err = HybridGridSolver::with_cycle(32)
            .with_cancel(token)
            .solve(&net, &mut exec)
            .unwrap_err();
        assert!(crate::util::Cancelled::caused(&err), "{err:#}");
    }

    #[test]
    fn heuristics_reduce_waves() {
        let net = demo_net();
        let mut e1 = NativeGridExecutor::default();
        let with = HybridGridSolver::with_cycle(64)
            .solve(&net, &mut e1)
            .unwrap();
        let mut e2 = NativeGridExecutor::default();
        let without = HybridGridSolver::no_heuristics(1_000_000)
            .solve(&net, &mut e2)
            .unwrap();
        assert!(
            with.waves <= without.waves,
            "heuristics should not increase waves: {} vs {}",
            with.waves,
            without.waves
        );
    }
}
