//! Warm-start solving for dynamic grids: keep the final preflow state
//! of a completed solve, repair it locally when the instance's
//! capacities change, and resume the hybrid loop from the affected
//! frontier instead of from scratch ("Scalable Maxflow Processing for
//! Dynamic Graphs" — retain residuals, repair, re-run).
//!
//! The repair is purely local arithmetic on the edited arcs plus a
//! deficit-pullback cascade:
//!
//! * **Neighbour arc** set to `u'`: with pair flow `f = u - resid`
//!   (negative when the mate carries the flow), the flow is clamped to
//!   `f' = min(f, u')` and the over-commitment `f - f'` refunded as
//!   excess at the tail / debited at the head.
//! * **Sink cap** set to `u'`: flow already at the sink above `u'` is
//!   refunded to the cell as excess.
//! * **Source cap** set to `u'`: draw above `u'` is debited (a deficit).
//! * **Re-saturation**: every source arc is then re-saturated to its new
//!   capacity (Hong's Init does exactly this cold).  The wire state has
//!   no representation of un-drawn forward source capacity — `cap_src`
//!   *is* the draw — and an edit elsewhere can make previously-returned
//!   supply routable, so all of it must re-enter the network.  The
//!   resumed solve's first global relabel routes the hopeless part
//!   straight back (`|V| + dist_s` heights).
//! * **Deficits** (`e < 0`): resolved by taking flow back — first the
//!   cell's own sink commitment, then outgoing neighbour flow, pulled
//!   back along the cascade.  A deficit cell always has positive
//!   outflow (`e = draw + in - out - sink < 0` forces `out + sink > 0`)
//!   and every pullback strictly reduces total flow mass, so the
//!   cascade terminates.
//!
//! After repair the state is a valid preflow of the edited network with
//! `sink_committed + Σe == excess_total`, so
//! [`HybridGridSolver::resume`] runs the unmodified hybrid loop seeded
//! with the committed totals.  Heights are left stale on purpose: the
//! resume's initial global relabel (stripe-parallel under
//! `host_rounds = striped`) rebuilds an exact labeling, which is the
//! repair BFS of the paper.  The max-flow *value* is unique, so a warm
//! resume is bit-exact with a cold solve of the edited network — the
//! differential oracle `tests/integration_sessions.rs` pins.

use anyhow::{ensure, Result};

use crate::graph::grid::OPP;
use crate::graph::GridNetwork;
use crate::runtime::device::GridWireState;

use super::solver::{GridExecutor, GridSolveReport, HybridGridSolver};
use super::state::init_state;

/// One capacity edit: set an arc of the instance to a new capacity.
/// Absolute (not additive) so a delta stream is replayable and the
/// cold-solve oracle is trivial to materialise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CapacityDelta {
    /// The directed neighbour arc `dir` out of cell `(i, j)`.
    Arc { i: usize, j: usize, dir: usize, cap: i64 },
    /// The `(x, t)` terminal arc of cell `(i, j)`.
    Sink { i: usize, j: usize, cap: i64 },
    /// The `(s, x)` terminal arc of cell `(i, j)`.
    Source { i: usize, j: usize, cap: i64 },
}

impl CapacityDelta {
    /// Apply the edit to a plain instance — the *definition* of the
    /// edit's semantics, shared by the warm repair and the cold-solve
    /// oracle (trace materialisation).
    pub fn apply_to(&self, net: &mut GridNetwork) -> Result<()> {
        match *self {
            CapacityDelta::Arc { i, j, dir, cap } => {
                ensure!(dir < 4, "bad arc direction {dir}");
                ensure!(
                    net.neighbour(i, j, dir).is_some(),
                    "delta arc ({i},{j}) dir {dir} leaves the grid"
                );
                check_cap(cap)?;
                let a = net.arc(dir, i, j);
                net.cap[a] = cap;
            }
            CapacityDelta::Sink { i, j, cap } => {
                ensure!(i < net.height && j < net.width, "delta cell off-grid");
                check_cap(cap)?;
                let c = net.cell(i, j);
                net.cap_sink[c] = cap;
            }
            CapacityDelta::Source { i, j, cap } => {
                ensure!(i < net.height && j < net.width, "delta cell off-grid");
                check_cap(cap)?;
                let c = net.cell(i, j);
                net.cap_source[c] = cap;
            }
        }
        Ok(())
    }
}

fn check_cap(cap: i64) -> Result<()> {
    ensure!(cap >= 0, "negative capacity {cap}");
    ensure!(cap <= i32::MAX as i64, "capacity too large for device i32");
    Ok(())
}

/// Snapshot of a completed grid solve a session keeps between requests:
/// the current (edited) instance plus the repaired preflow state.
#[derive(Debug, Clone)]
pub struct WarmState {
    net: GridNetwork,
    st: GridWireState,
}

impl WarmState {
    /// Cold-solve `net` and keep the final state for later deltas.
    pub fn solve_cold(
        net: GridNetwork,
        solver: &HybridGridSolver,
        exec: &mut dyn GridExecutor,
    ) -> Result<(GridSolveReport, WarmState)> {
        let (report, st) = solver.solve_state(&net, exec)?;
        Ok((report, WarmState { net, st }))
    }

    /// Adopt a completed state produced elsewhere (tests).
    pub fn from_parts(net: GridNetwork, st: GridWireState) -> Self {
        Self { net, st }
    }

    /// The current (post-edit) instance this state is a preflow of.
    pub fn net(&self) -> &GridNetwork {
        &self.net
    }

    /// Approximate resident size, for the session store's LRU budget:
    /// 6 i64 lanes of the instance + 8 i32 lanes of the wire state.
    pub fn approx_bytes(&self) -> usize {
        self.net.cells() * 80 + 256
    }

    /// Edit the instance and repair the preflow locally (no solving).
    /// After this the state satisfies `sink_committed + Σe ==
    /// excess_total` for the edited network and [`WarmState::resume`]
    /// can pick it up.
    pub fn apply_deltas(&mut self, deltas: &[CapacityDelta]) -> Result<()> {
        let ww = self.net.width;
        let cells = self.net.cells();
        for d in deltas {
            match *d {
                CapacityDelta::Arc { i, j, dir, cap } => {
                    // Repair against the *current* stored capacity, then
                    // commit the new one, so repeated edits of one arc
                    // compose.
                    ensure!(dir < 4, "bad arc direction {dir}");
                    ensure!(
                        self.net.neighbour(i, j, dir).is_some(),
                        "delta arc ({i},{j}) dir {dir} leaves the grid"
                    );
                    check_cap(cap)?;
                    let c = i * ww + j;
                    let a = dir * cells + c;
                    let (ni, nj) = self.net.neighbour(i, j, dir).unwrap();
                    let nc = ni * ww + nj;
                    let mate = OPP[dir] * cells + nc;
                    let old = self.net.cap[a];
                    // Pair flow oriented c -> nc (negative: the mate
                    // carries it); only clamping from above can be
                    // needed, since resid_bwd = o_bwd + f >= 0 already
                    // bounds f from below.
                    let f = old - self.st.cap[a] as i64;
                    let f_new = f.min(cap);
                    self.st.cap[a] = (cap - f_new) as i32;
                    self.st.cap[mate] -= (f - f_new) as i32;
                    let refund = (f - f_new) as i32;
                    self.st.e[c] += refund;
                    self.st.e[nc] -= refund;
                    self.net.cap[a] = cap;
                }
                CapacityDelta::Sink { i, j, cap } => {
                    ensure!(i < self.net.height && j < self.net.width, "delta cell off-grid");
                    check_cap(cap)?;
                    let c = i * ww + j;
                    let consumed = self.net.cap_sink[c] - self.st.cap_sink[c] as i64;
                    let refund = (consumed - cap).max(0);
                    self.st.cap_sink[c] = (cap - consumed.min(cap)) as i32;
                    self.st.e[c] += refund as i32;
                    self.net.cap_sink[c] = cap;
                }
                CapacityDelta::Source { i, j, cap } => {
                    ensure!(i < self.net.height && j < self.net.width, "delta cell off-grid");
                    check_cap(cap)?;
                    let c = i * ww + j;
                    let drawn = self.st.cap_src[c] as i64;
                    if cap < drawn {
                        // Draw above the new cap is debited; the deficit
                        // pass below takes the flow back.
                        self.st.cap_src[c] = cap as i32;
                        self.st.e[c] -= (drawn - cap) as i32;
                    }
                    self.net.cap_source[c] = cap;
                }
            }
        }

        // Re-saturate every source arc to its (possibly new) capacity —
        // exactly Hong's Init, applied to the difference.
        for c in 0..cells {
            let y = self.net.cap_source[c] - self.st.cap_src[c] as i64;
            debug_assert!(y >= 0, "source draw above capacity at cell {c}");
            if y > 0 {
                self.st.cap_src[c] = self.net.cap_source[c] as i32;
                self.st.e[c] += y as i32;
            }
        }

        self.resolve_deficits()?;

        // The repaired state must be a preflow of the edited network
        // with consistent mass accounting; resume() re-checks the same
        // identity at termination.
        debug_assert_eq!(
            self.sink_committed() + self.excess_sum(),
            self.net.excess_total(),
            "repair broke mass accounting"
        );
        Ok(())
    }

    /// Pull flow back out of deficit cells until every excess is
    /// non-negative again.
    fn resolve_deficits(&mut self) -> Result<()> {
        let ww = self.net.width;
        let cells = self.net.cells();
        let mut work: Vec<usize> = (0..cells).filter(|&c| self.st.e[c] < 0).collect();
        while let Some(c) = work.pop() {
            // A cascade may have refilled it since it was queued.
            if self.st.e[c] >= 0 {
                continue;
            }
            // 1. Reclaim the cell's own sink commitment.
            let committed = self.net.cap_sink[c] - self.st.cap_sink[c] as i64;
            if committed > 0 {
                let z = committed.min(-(self.st.e[c] as i64));
                self.st.cap_sink[c] += z as i32;
                self.st.e[c] += z as i32;
            }
            // 2. Pull back outgoing neighbour flow (debiting the head,
            //    which may cascade).
            for dir in 0..4 {
                if self.st.e[c] >= 0 {
                    break;
                }
                let (i, j) = (c / ww, c % ww);
                let Some((ni, nj)) = self.net.neighbour(i, j, dir) else {
                    continue;
                };
                let a = dir * cells + c;
                let out = self.net.cap[a] - self.st.cap[a] as i64;
                if out <= 0 {
                    continue;
                }
                let w = out.min(-(self.st.e[c] as i64));
                let nc = ni * ww + nj;
                let mate = OPP[dir] * cells + nc;
                self.st.cap[a] += w as i32;
                self.st.cap[mate] -= w as i32;
                self.st.e[c] += w as i32;
                self.st.e[nc] -= w as i32;
                if self.st.e[nc] < 0 {
                    work.push(nc);
                }
            }
            // Always resolvable: a deficit cell has positive outflow.
            ensure!(
                self.st.e[c] >= 0,
                "unresolvable deficit {} at cell {c}",
                self.st.e[c]
            );
        }
        Ok(())
    }

    fn sink_committed(&self) -> i64 {
        (0..self.net.cells())
            .map(|c| self.net.cap_sink[c] - self.st.cap_sink[c] as i64)
            .sum()
    }

    fn src_committed(&self) -> i64 {
        (0..self.net.cells())
            .map(|c| self.net.cap_source[c] - self.st.cap_src[c] as i64)
            .sum()
    }

    fn excess_sum(&self) -> i64 {
        self.st.e.iter().map(|&e| e as i64).sum()
    }

    /// Resume the hybrid loop on the repaired state.  Requires the
    /// solver's heuristics: the stale heights are only made valid again
    /// by the initial global relabel.
    pub fn resume(
        &mut self,
        solver: &HybridGridSolver,
        exec: &mut dyn GridExecutor,
    ) -> Result<GridSolveReport> {
        ensure!(
            solver.heuristics,
            "warm resume needs host heuristics (stale heights are only \
             repaired by the initial global relabel)"
        );
        let excess_total = self.net.excess_total();
        let sink_committed = self.sink_committed();
        let src_committed = self.src_committed();
        solver.resume(&mut self.st, excess_total, sink_committed, src_committed, exec)
    }

    /// Edit + repair + resume in one call — the session update path.
    pub fn update(
        &mut self,
        deltas: &[CapacityDelta],
        solver: &HybridGridSolver,
        exec: &mut dyn GridExecutor,
    ) -> Result<GridSolveReport> {
        self.apply_deltas(deltas)?;
        self.resume(solver, exec)
    }
}

/// Rebuild a [`WarmState`] from scratch — the cold baseline the
/// differential tests compare against (also exercises `solve_state`).
pub fn cold_state(net: &GridNetwork) -> (GridWireState, i64) {
    init_state(net)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::grid::{E, S};
    use crate::gridflow::NativeGridExecutor;
    use crate::maxflow::{self, MaxFlowSolver};
    use crate::util::Rng;
    use crate::workloads::random_grid;

    fn cold_flow(net: &GridNetwork) -> i64 {
        let mut g = net.to_flow_network();
        maxflow::dinic::Dinic.solve(&mut g).unwrap().value
    }

    fn random_deltas(rng: &mut Rng, net: &GridNetwork, count: usize, max_cap: i64) -> Vec<CapacityDelta> {
        let mut out = Vec::new();
        while out.len() < count {
            let i = (rng.next_u64() % net.height as u64) as usize;
            let j = (rng.next_u64() % net.width as u64) as usize;
            let cap = (rng.next_u64() % (max_cap as u64 + 1)) as i64;
            let d = match rng.next_u64() % 6 {
                0 => CapacityDelta::Sink { i, j, cap },
                1 => CapacityDelta::Source { i, j, cap },
                k => {
                    let dir = (k as usize - 2) % 4;
                    if net.neighbour(i, j, dir).is_none() {
                        continue;
                    }
                    CapacityDelta::Arc { i, j, dir, cap }
                }
            };
            out.push(d);
        }
        out
    }

    #[test]
    fn warm_matches_cold_over_random_edit_stream() {
        for seed in [1u64, 2, 3] {
            let mut rng = Rng::seeded(seed);
            let net = random_grid(&mut rng, 8, 7, 9, 0.3, 0.3);
            let solver = HybridGridSolver::with_cycle(64);
            let mut exec = NativeGridExecutor::default();
            let (_, mut warm) = WarmState::solve_cold(net, &solver, &mut exec).unwrap();
            for step in 0..4 {
                let deltas = random_deltas(&mut rng, warm.net(), 5, 9);
                let report = warm.update(&deltas, &solver, &mut exec).unwrap();
                let want = cold_flow(warm.net());
                assert_eq!(report.flow, want, "seed {seed} step {step}");
            }
        }
    }

    #[test]
    fn arc_decrease_under_flow_refunds_and_cascades() {
        // A single 3-cell path s -> (0,0) -> (0,1) -> (0,2) -> t carrying
        // 4 units; cutting the middle arc to 1 must pull 3 units all the
        // way back and re-settle at flow 1.
        let mut net = GridNetwork::zeros(1, 3);
        net.cap_source[0] = 4;
        net.cap_sink[2] = 4;
        net.set_neighbour_cap(0, 0, E, 4);
        net.set_neighbour_cap(0, 1, E, 4);
        let solver = HybridGridSolver::with_cycle(16);
        let mut exec = NativeGridExecutor::default();
        let (first, mut warm) = WarmState::solve_cold(net, &solver, &mut exec).unwrap();
        assert_eq!(first.flow, 4);
        let report = warm
            .update(&[CapacityDelta::Arc { i: 0, j: 1, dir: E, cap: 1 }], &solver, &mut exec)
            .unwrap();
        assert_eq!(report.flow, 1);
        assert_eq!(cold_flow(warm.net()), 1);
    }

    #[test]
    fn sink_and_source_cuts_refund() {
        let mut net = GridNetwork::zeros(2, 2);
        net.cap_source[0] = 5;
        net.cap_sink[3] = 5;
        net.set_neighbour_cap(0, 0, S, 5);
        net.set_neighbour_cap(1, 0, E, 5);
        let solver = HybridGridSolver::with_cycle(16);
        let mut exec = NativeGridExecutor::default();
        let (first, mut warm) = WarmState::solve_cold(net, &solver, &mut exec).unwrap();
        assert_eq!(first.flow, 5);
        // Halve the sink side, then the source side.
        let r = warm
            .update(&[CapacityDelta::Sink { i: 1, j: 1, cap: 2 }], &solver, &mut exec)
            .unwrap();
        assert_eq!(r.flow, 2);
        let r = warm
            .update(&[CapacityDelta::Source { i: 0, j: 0, cap: 1 }], &solver, &mut exec)
            .unwrap();
        assert_eq!(r.flow, 1);
        assert_eq!(cold_flow(warm.net()), 1);
    }

    #[test]
    fn capacity_increase_reuses_committed_flow() {
        // Widening a saturated bottleneck lets previously returned
        // supply through — the re-saturation step must re-inject it.
        let mut net = GridNetwork::zeros(1, 2);
        net.cap_source[0] = 6;
        net.cap_sink[1] = 6;
        net.set_neighbour_cap(0, 0, E, 2);
        let solver = HybridGridSolver::with_cycle(16);
        let mut exec = NativeGridExecutor::default();
        let (first, mut warm) = WarmState::solve_cold(net, &solver, &mut exec).unwrap();
        assert_eq!(first.flow, 2);
        let r = warm
            .update(&[CapacityDelta::Arc { i: 0, j: 0, dir: E, cap: 6 }], &solver, &mut exec)
            .unwrap();
        assert_eq!(r.flow, 6);
    }

    #[test]
    fn off_grid_delta_rejected() {
        let mut net = GridNetwork::zeros(2, 2);
        net.cap_source[0] = 1;
        let solver = HybridGridSolver::with_cycle(16);
        let mut exec = NativeGridExecutor::default();
        let (_, mut warm) = WarmState::solve_cold(net, &solver, &mut exec).unwrap();
        assert!(warm
            .apply_deltas(&[CapacityDelta::Arc { i: 0, j: 0, dir: 0, cap: 1 }])
            .is_err(), "N arc out of the top row leaves the grid");
        assert!(warm
            .apply_deltas(&[CapacityDelta::Sink { i: 5, j: 0, cap: 1 }])
            .is_err());
        assert!(warm
            .apply_deltas(&[CapacityDelta::Source { i: 0, j: 0, cap: -1 }])
            .is_err());
    }

    #[test]
    fn warm_resume_requires_heuristics() {
        let mut net = GridNetwork::zeros(1, 2);
        net.cap_source[0] = 1;
        net.cap_sink[1] = 1;
        net.set_neighbour_cap(0, 0, E, 1);
        let solver = HybridGridSolver::with_cycle(16);
        let mut exec = NativeGridExecutor::default();
        let (_, mut warm) = WarmState::solve_cold(net, &solver, &mut exec).unwrap();
        let bare = HybridGridSolver::no_heuristics(16);
        assert!(warm.resume(&bare, &mut exec).is_err());
    }
}
