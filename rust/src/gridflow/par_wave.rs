//! Multi-threaded tiled wave engine: the paper's parallel push-relabel
//! wave executed across row-stripe tiles by real OS threads, bit-exact
//! with the sequential twin (`wave::native_wave_with`).
//!
//! Why this is possible without changing semantics: the wave already has
//! snapshot-then-apply structure.  The decision phase reads only the
//! pre-wave state, so partitioning the active set over threads is
//! embarrassingly parallel.  The apply phase is a sum of per-cell
//! updates that are either *owner-exclusive* (h, sink/src pushes, the
//! send side of a neighbour push) or *additive* (the receive side:
//! `cap[opp] += delta`, `e[nc] += delta`), so any execution order yields
//! the same state.  Row-stripe tiles make every W/E push and every
//! interior N/S push land inside the owning tile; only pushes crossing a
//! stripe boundary have a foreign receive side, and those are recorded
//! as [`CrossOp`]s and applied by the parity-coloured reconciliation
//! pass (even tiles then odd tiles own their borders — the same commit
//! shape as `crate::parallel::frontier`).  Compaction runs after
//! reconciliation so the surviving active set is exactly `{e > 0}` —
//! the same set the sequential engine keeps.
//!
//! The protocol (4 phases per wave) was validated against an executable
//! model before this implementation: 1 680 differential cases (shapes ×
//! tile sizes × thread counts × host-mutation cycles) bit-exact in
//! per-wave stats, state, active set, and on-list flags.

use std::ops::Range;
use std::sync::Arc;

use anyhow::Result;

use crate::parallel::{CommitMode, CrossOp, ParTuning};
use crate::runtime::device::{GridStepStats, GridWireState};
use crate::service::pool::WorkerPool;

use super::solver::GridExecutor;
use super::wave::{decide, Decision, WaveStats, DIRS, OPP};

/// One row stripe: the cell range it owns, its active list, and the
/// per-wave stats produced by its worker (border ops live in
/// [`ParWaveScratch::borders`], indexed by tile).
#[derive(Debug)]
struct Tile {
    cells: Range<usize>,
    active: Vec<u32>,
    stats: WaveStats,
}

/// Reusable scratch of the tiled engine: per-tile active lists replace
/// the sequential engine's single global list; `decisions` and
/// `on_list` are global arrays whose tile sub-ranges are disjoint (tiles
/// are contiguous in cell index), so they can be lent to workers as
/// non-overlapping `chunks_mut` slices.
#[derive(Debug)]
pub struct ParWaveScratch {
    tile_rows: usize,
    tiles: Vec<Tile>,
    /// Per-tile border-op outboxes (`borders[t]` = ops tile `t`'s apply
    /// deferred), kept outside [`Tile`] so the reconcile pass can read
    /// every outbox while the owning tiles mutate their active lists.
    borders: Vec<Vec<CrossOp>>,
    decisions: Vec<Decision>,
    on_list: Vec<bool>,
    /// How the border reconcile batches its owner tasks: the parity
    /// two-pass (default, the oracle protocol) or one merged batch —
    /// safe either way because owners write disjoint tile slices and
    /// the outboxes are immutable for the whole phase.
    commit: CommitMode,
    pub(super) built_for: Option<(usize, usize)>,
}

impl ParWaveScratch {
    pub fn new(tile_rows: usize) -> Self {
        Self {
            tile_rows: tile_rows.max(1),
            tiles: Vec::new(),
            borders: Vec::new(),
            decisions: Vec::new(),
            on_list: Vec::new(),
            commit: CommitMode::default(),
            built_for: None,
        }
    }

    pub fn tile_rows(&self) -> usize {
        self.tile_rows
    }

    pub fn set_commit(&mut self, commit: CommitMode) {
        self.commit = commit;
    }

    /// (Re)build the per-tile active lists from the state — call after
    /// any external mutation of `e` (host rounds, fresh instances).
    pub fn rebuild(&mut self, st: &GridWireState) {
        let (hh, ww) = (st.height, st.width);
        let cells = hh * ww;
        self.on_list.clear();
        self.on_list.resize(cells, false);
        self.decisions.clear();
        self.decisions.resize(cells, Decision::None);
        let n_tiles = hh.div_ceil(self.tile_rows);
        self.tiles.clear();
        for t in 0..n_tiles {
            let r0 = t * self.tile_rows;
            let r1 = (r0 + self.tile_rows).min(hh);
            let range = r0 * ww..r1 * ww;
            let mut active = Vec::new();
            for c in range.clone() {
                if st.e[c] > 0 {
                    active.push(c as u32);
                    self.on_list[c] = true;
                }
            }
            self.tiles.push(Tile {
                cells: range,
                active,
                stats: WaveStats::default(),
            });
        }
        self.borders.iter_mut().for_each(Vec::clear);
        self.borders.resize_with(n_tiles, Vec::new);
        self.built_for = Some((hh, ww));
    }

    pub fn active_count(&self) -> usize {
        self.tiles.iter().map(|t| t.active.len()).sum()
    }
}

/// Everything a worker may touch while applying one tile: the tile
/// itself plus the tile's sub-slices of the state planes.  All slices
/// are indexed by `cell - tile.cells.start`.
struct TileJob<'a> {
    tile: &'a mut Tile,
    border: &'a mut Vec<CrossOp>,
    h: &'a mut [i32],
    e: &'a mut [i32],
    cap_n: &'a mut [i32],
    cap_s: &'a mut [i32],
    cap_w: &'a mut [i32],
    cap_e: &'a mut [i32],
    cap_sink: &'a mut [i32],
    cap_src: &'a mut [i32],
    on_list: &'a mut [bool],
    decisions: &'a mut [Decision],
}

/// Apply one tile's decisions.  Owner-exclusive and intra-tile effects
/// land immediately; cross-tile receive sides are deferred as border
/// ops.  Mirrors the sequential apply loop exactly (fixed-length
/// iteration; receivers activated for the *next* wave).
fn apply_tile(job: TileJob<'_>, ww: usize) {
    let TileJob {
        tile,
        border,
        h,
        e,
        cap_n,
        cap_s,
        cap_w,
        cap_e,
        cap_sink,
        cap_src,
        on_list,
        decisions,
    } = job;
    let base = tile.cells.start;
    let end = tile.cells.end;
    border.clear();
    let mut stats = WaveStats::default();
    let n0 = tile.active.len();
    for idx in 0..n0 {
        let c = tile.active[idx] as usize;
        let lc = c - base;
        match std::mem::replace(&mut decisions[lc], Decision::None) {
            Decision::None => {}
            Decision::Relabel { new_h } => {
                h[lc] = new_h;
                stats.relabels += 1;
            }
            Decision::Push { arc, delta } => {
                stats.pushes += 1;
                e[lc] -= delta;
                match arc {
                    4 => {
                        cap_sink[lc] -= delta;
                        stats.sink_flow += delta as i64;
                    }
                    5 => {
                        cap_src[lc] -= delta;
                        stats.src_flow += delta as i64;
                    }
                    a => {
                        let (di, dj) = DIRS[a];
                        // In-bounds by construction: `decide` only picks
                        // arcs that stay on the grid.
                        let nc = (c as i64 + di * ww as i64 + dj) as usize;
                        match a {
                            0 => cap_n[lc] -= delta,
                            1 => cap_s[lc] -= delta,
                            2 => cap_w[lc] -= delta,
                            _ => cap_e[lc] -= delta,
                        }
                        if nc >= base && nc < end {
                            let ln = nc - base;
                            match OPP[a] {
                                0 => cap_n[ln] += delta,
                                1 => cap_s[ln] += delta,
                                2 => cap_w[ln] += delta,
                                _ => cap_e[ln] += delta,
                            }
                            e[ln] += delta;
                            if !on_list[ln] {
                                on_list[ln] = true;
                                tile.active.push(nc as u32);
                            }
                        } else {
                            border.push(CrossOp {
                                cell: nc as u32,
                                arc: OPP[a] as u8,
                                delta,
                            });
                        }
                    }
                }
            }
        }
    }
    tile.stats = stats;
}

/// Execute one batch of per-worker jobs: on the persistent pool when
/// one is lent, otherwise on freshly scoped threads (the original
/// engine shape, still used when no pool exists).  Returns how many
/// jobs panicked — the wave propagates that as a solver error instead
/// of unwinding the caller (a panicked tile job must not take a
/// request worker down with it).
fn run_workers<'env>(pool: Option<&WorkerPool>, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) -> usize {
    match pool {
        Some(p) => p.try_run_batch(jobs),
        None => {
            let panicked = std::sync::atomic::AtomicUsize::new(0);
            let panicked_ref = &panicked;
            std::thread::scope(|s| {
                for job in jobs {
                    s.spawn(move || {
                        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
                            panicked_ref.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    });
                }
            });
            panicked.load(std::sync::atomic::Ordering::Relaxed)
        }
    }
}

/// One synchronous wave executed by `threads` workers over row-stripe
/// tiles; bit-exact with [`super::wave::native_wave_with`] (same stats,
/// same state trajectory, same surviving active set).  `Err` means a
/// tile job panicked and the state may be torn — the caller must
/// discard this solve (the hybrid solver rebuilds from `init_state`
/// on the next attempt).
pub fn par_wave_with(
    st: &mut GridWireState,
    scratch: &mut ParWaveScratch,
    threads: usize,
) -> Result<WaveStats> {
    par_wave_exec(st, scratch, threads, None)
}

/// Same wave, but the workers are the persistent [`WorkerPool`]
/// threads instead of per-wave scoped spawns — two condvar wakeups per
/// wave instead of two spawn/join rounds.  Bit-exact with
/// [`par_wave_with`] at any thread count: tile→worker partitioning only
/// affects which thread applies a tile, and tiles are disjoint.
pub fn par_wave_pooled(
    st: &mut GridWireState,
    scratch: &mut ParWaveScratch,
    pool: &WorkerPool,
) -> Result<WaveStats> {
    par_wave_exec(st, scratch, pool.threads(), Some(pool))
}

fn par_wave_exec(
    st: &mut GridWireState,
    scratch: &mut ParWaveScratch,
    threads: usize,
    pool: Option<&WorkerPool>,
) -> Result<WaveStats> {
    let (hh, ww) = (st.height, st.width);
    let cells = hh * ww;
    if scratch.built_for != Some((hh, ww)) {
        scratch.rebuild(st);
    }
    let n_tiles = scratch.tiles.len();
    let threads = threads.max(1).min(n_tiles.max(1));
    let tile_cells = (scratch.tile_rows * ww).max(1);

    // --- Phase 1: decision, parallel over tiles -------------------------
    // Workers read the whole pre-wave state immutably and write disjoint
    // per-tile slices of the decision array.
    {
        let st_ref: &GridWireState = st;
        let tiles = &scratch.tiles;
        let mut per_worker: Vec<Vec<(&Tile, &mut [Decision])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (t, chunk) in scratch.decisions.chunks_mut(tile_cells).enumerate() {
            per_worker[t % threads].push((&tiles[t], chunk));
        }
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
        for work in per_worker {
            jobs.push(Box::new(move || {
                for (tile, decisions) in work {
                    let base = tile.cells.start;
                    for &c in &tile.active {
                        let c = c as usize;
                        if st_ref.e[c] <= 0 {
                            continue;
                        }
                        decisions[c - base] = decide(st_ref, c);
                    }
                }
            }));
        }
        let panicked = run_workers(pool, jobs);
        anyhow::ensure!(panicked == 0, "{panicked} decision job(s) panicked");
    }

    // --- Phase 2: apply, parallel with owned interiors ------------------
    // Every state plane is lent out as disjoint per-tile chunks (tiles
    // are contiguous cell ranges), so workers mutate without locks.
    {
        let (cap_n, rest) = st.cap.split_at_mut(cells);
        let (cap_s, rest) = rest.split_at_mut(cells);
        let (cap_w, cap_e) = rest.split_at_mut(cells);
        let iter = scratch
            .tiles
            .iter_mut()
            .zip(scratch.borders.iter_mut())
            .zip(st.h.chunks_mut(tile_cells))
            .zip(st.e.chunks_mut(tile_cells))
            .zip(cap_n.chunks_mut(tile_cells))
            .zip(cap_s.chunks_mut(tile_cells))
            .zip(cap_w.chunks_mut(tile_cells))
            .zip(cap_e.chunks_mut(tile_cells))
            .zip(st.cap_sink.chunks_mut(tile_cells))
            .zip(st.cap_src.chunks_mut(tile_cells))
            .zip(scratch.on_list.chunks_mut(tile_cells))
            .zip(scratch.decisions.chunks_mut(tile_cells))
            .enumerate();
        let mut per_worker: Vec<Vec<TileJob<'_>>> = (0..threads).map(|_| Vec::new()).collect();
        for (t, (((((((((((tile, border), h), e), cap_n), cap_s), cap_w), cap_e), cap_sink), cap_src), on_list), decisions)) in
            iter
        {
            per_worker[t % threads].push(TileJob {
                tile,
                border,
                h,
                e,
                cap_n,
                cap_s,
                cap_w,
                cap_e,
                cap_sink,
                cap_src,
                on_list,
                decisions,
            });
        }
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
        for work in per_worker {
            jobs.push(Box::new(move || {
                for job in work {
                    apply_tile(job, ww);
                }
            }));
        }
        let panicked = run_workers(pool, jobs);
        anyhow::ensure!(panicked == 0, "{panicked} apply job(s) panicked");
    }

    // --- Phase 3: parity-coloured border reconciliation -----------------
    // Cross-tile receive sides, applied by the *owning* tile: every op
    // from tile `p` lands in stripe `p ± 1`, so each owner drains its
    // two neighbours' outboxes (upper first, matching the old serial
    // tile order).  Even-index tiles run first, then odd — "even tiles
    // then odd tiles own their borders" — the same two-pass shape as
    // the frontier substrate's commit (`crate::parallel::frontier`).
    // Bit-exact with the retired serial loop: the increments are
    // additive, and per owner the activation append order (upper
    // neighbour's ops, then lower's) is exactly the serial order.
    let any_border = scratch.borders.iter().any(|b| !b.is_empty());
    // Per-wave border timing is `obs-fine` only: a Timer read plus a
    // registry lookup per wave is noise at service load but real in the
    // micro-benches, so by default this block compiles to the plain
    // reconcile.
    #[cfg(feature = "obs-fine")]
    let border_timer = crate::util::Timer::start();
    if any_border {
        struct ReconcileJob<'a> {
            t: usize,
            tile: &'a mut Tile,
            e: &'a mut [i32],
            cap_n: &'a mut [i32],
            cap_s: &'a mut [i32],
            on_list: &'a mut [bool],
        }
        let borders: &[Vec<CrossOp>] = &scratch.borders;
        let (cap_n, rest) = st.cap.split_at_mut(cells);
        let (cap_s, _) = rest.split_at_mut(cells);
        let mut tasks = Vec::with_capacity(n_tiles);
        let iter = scratch
            .tiles
            .iter_mut()
            .zip(st.e.chunks_mut(tile_cells))
            .zip(cap_n.chunks_mut(tile_cells))
            .zip(cap_s.chunks_mut(tile_cells))
            .zip(scratch.on_list.chunks_mut(tile_cells))
            .enumerate();
        for (t, ((((tile, e), cap_n), cap_s), on_list)) in iter {
            tasks.push(ReconcileJob {
                t,
                tile,
                e,
                cap_n,
                cap_s,
                on_list,
            });
        }
        // `TwoPass` is the parity-coloured oracle protocol; `Merged`
        // runs every owner in one batch (halving the per-wave barrier
        // count).  Identical results: owners write disjoint tile
        // slices, the outboxes are read-only for the whole phase, and
        // each owner's apply order (upper neighbour's ops, then
        // lower's) is the same in both shapes.
        let passes: Vec<Vec<ReconcileJob<'_>>> = match scratch.commit {
            CommitMode::Merged => vec![tasks],
            CommitMode::TwoPass => {
                let (even, odd): (Vec<_>, Vec<_>) =
                    tasks.into_iter().partition(|j| j.t % 2 == 0);
                vec![even, odd]
            }
        };
        for pass in passes {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for group in crate::parallel::deal(pass, threads) {
                jobs.push(Box::new(move || {
                    for job in group {
                        let base = job.tile.cells.start;
                        let end = job.tile.cells.end;
                        for p in [job.t.wrapping_sub(1), job.t + 1] {
                            if p >= n_tiles {
                                continue;
                            }
                            for op in &borders[p] {
                                let nc = op.cell as usize;
                                if nc < base || nc >= end {
                                    continue;
                                }
                                let ln = nc - base;
                                debug_assert!(op.arc < 2, "cross-tile ops are N/S only");
                                if op.arc == 0 {
                                    job.cap_n[ln] += op.delta;
                                } else {
                                    job.cap_s[ln] += op.delta;
                                }
                                job.e[ln] += op.delta;
                                if !job.on_list[ln] {
                                    job.on_list[ln] = true;
                                    job.tile.active.push(op.cell);
                                }
                            }
                        }
                    }
                }));
            }
            // Border ops are O(width) worst case: a pooled batch is two
            // cheap condvar wakeups, but spawning scoped threads for
            // them would cost more than applying them — unpooled lanes
            // run the owner jobs inline (owner-disjoint, so execution
            // order is irrelevant).
            match pool {
                Some(p) => {
                    let panicked = p.try_run_batch(jobs);
                    anyhow::ensure!(panicked == 0, "{panicked} reconcile job(s) panicked");
                }
                None => {
                    // Inline on the caller's thread: a panic here
                    // unwinds into the per-attempt catch in the service
                    // router, not into a shared worker.
                    for job in jobs {
                        job();
                    }
                }
            }
        }
    }

    #[cfg(feature = "obs-fine")]
    if any_border {
        crate::obs::record_phase_secs(
            "grid",
            crate::obs::Phase::BorderReconcile,
            border_timer.elapsed(),
        );
    }

    // --- Phase 4: compaction + stats reduction --------------------------
    // Runs after reconciliation so the surviving set is exactly {e > 0},
    // matching the sequential engine wave for wave.
    let mut stats = WaveStats::default();
    for tile in &mut scratch.tiles {
        stats.sink_flow += tile.stats.sink_flow;
        stats.src_flow += tile.stats.src_flow;
        stats.pushes += tile.stats.pushes;
        stats.relabels += tile.stats.relabels;
        let mut w = 0;
        for r in 0..tile.active.len() {
            let c = tile.active[r] as usize;
            if st.e[c] > 0 {
                tile.active[w] = tile.active[r];
                w += 1;
            } else {
                scratch.on_list[c] = false;
            }
        }
        tile.active.truncate(w);
    }
    Ok(stats)
}

/// Multi-threaded tiled executor: a drop-in [`GridExecutor`] whose
/// trajectory is bit-exact with [`super::NativeGridExecutor`] — the
/// sequential engine is the differential oracle for this one.
pub struct NativeParGridExecutor {
    pub k_inner: usize,
    pub threads: usize,
    pub tile_rows: usize,
    /// Striped-pass tuning.  The wave itself honours `commit` (border
    /// reconcile batching); `balance` is carried for the solver's host
    /// rounds — tile boundaries are bound to the scratch geometry and
    /// are never re-cut mid-solve.
    pub tuning: ParTuning,
    scratch: ParWaveScratch,
    needs_rebuild: bool,
    pool: Option<Arc<WorkerPool>>,
}

impl NativeParGridExecutor {
    pub fn new(threads: usize, tile_rows: usize) -> Self {
        let tile_rows = tile_rows.max(1);
        Self {
            k_inner: 16,
            threads: threads.max(1),
            tile_rows,
            tuning: ParTuning::default(),
            scratch: ParWaveScratch::new(tile_rows),
            needs_rebuild: true,
            pool: None,
        }
    }

    pub fn with_k_inner(mut self, k_inner: usize) -> Self {
        self.k_inner = k_inner.max(1);
        self
    }

    pub fn with_tuning(mut self, tuning: ParTuning) -> Self {
        self.tuning = tuning;
        self
    }

    /// Borrow a persistent worker pool for the wave phases instead of
    /// spawning scoped threads per wave.  The pool's width becomes the
    /// effective worker count.  This is the ROADMAP "persistent worker
    /// pool for par_wave" item: on small grids the per-wave spawn/join
    /// overhead dominated, so pooled execution is what lets `native-par`
    /// serve sub-128² instances from the solver service.
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }
}

impl Default for NativeParGridExecutor {
    fn default() -> Self {
        Self::new(4, 16)
    }
}

impl GridExecutor for NativeParGridExecutor {
    fn k_inner(&self) -> usize {
        self.k_inner
    }

    fn name(&self) -> &'static str {
        if self.pool.is_some() {
            "native-par-pooled"
        } else {
            "native-par"
        }
    }

    fn invalidate(&mut self) {
        self.needs_rebuild = true;
    }

    fn host_pool(&self) -> Option<Arc<WorkerPool>> {
        // Striped host rounds ride the same pool as the wave phases:
        // between super-steps the pool is idle, so lending it out is
        // free.  Unpooled executors keep host rounds sequential (the
        // per-level spawn cost of scoped threads would exceed the BFS).
        self.pool.clone()
    }

    fn superstep(&mut self, st: &mut GridWireState, outer: i32) -> Result<GridStepStats> {
        let mut stats = GridStepStats::default();
        let budget = outer as i64 * self.k_inner as i64;
        // Honour post-construction changes to the public tile_rows
        // field (the scratch owns the authoritative copy).
        if self.scratch.tile_rows() != self.tile_rows.max(1) {
            self.scratch = ParWaveScratch::new(self.tile_rows);
            self.needs_rebuild = true;
        }
        if self.needs_rebuild || self.scratch.built_for != Some((st.height, st.width)) {
            self.scratch.rebuild(st);
            self.needs_rebuild = false;
        }
        self.scratch.set_commit(self.tuning.commit);
        for _ in 0..budget {
            if self.scratch.active_count() == 0 {
                break;
            }
            let w = match &self.pool {
                Some(pool) => par_wave_pooled(st, &mut self.scratch, pool),
                None => par_wave_with(st, &mut self.scratch, self.threads),
            }
            .map_err(|e| {
                // A torn wave leaves the scratch unusable; make sure the
                // next solve on this cached executor rebuilds.
                self.needs_rebuild = true;
                e
            })?;
            stats.sink_flow += w.sink_flow;
            stats.src_flow += w.src_flow;
            stats.pushes += w.pushes;
            stats.relabels += w.relabels;
            stats.waves += 1;
        }
        #[cfg(feature = "paranoid")]
        debug_assert_eq!(
            self.scratch.active_count(),
            super::wave::active_cells(st)
        );
        stats.active = self.scratch.active_count() as i64;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::super::wave::{active_cells, native_wave_with, WaveScratch};
    use super::*;

    fn tiny() -> GridWireState {
        // 1x3: src arcs at cell 0, sink at cell 2, chain capacity 2
        // (mirrors wave.rs::tests::tiny).
        let mut st = GridWireState::zeros(1, 3);
        st.e[0] = 4;
        st.cap_src[0] = 4;
        st.cap_sink[2] = 3;
        st.cap[3 * 3] = 2;
        st.cap[3 * 3 + 1] = 2;
        st
    }

    #[test]
    fn tiny_chain_matches_sequential_wave_by_wave() {
        let mut seq = tiny();
        let mut par = tiny();
        let mut ss = WaveScratch::default();
        let mut ps = ParWaveScratch::new(1);
        for _ in 0..200 {
            if active_cells(&seq) == 0 {
                break;
            }
            let a = native_wave_with(&mut seq, &mut ss);
            let b = par_wave_with(&mut par, &mut ps, 2).unwrap();
            assert_eq!(a, b);
            assert_eq!(seq.h, par.h);
            assert_eq!(seq.e, par.e);
            assert_eq!(seq.cap, par.cap);
            assert_eq!(seq.cap_sink, par.cap_sink);
            assert_eq!(seq.cap_src, par.cap_src);
            assert_eq!(ss.active_count(), ps.active_count());
        }
        assert_eq!(active_cells(&par), 0);
    }

    #[test]
    fn vertical_chain_crosses_tile_borders() {
        // 4x1 column with tile_rows=1: every S push is a border op.
        let mut seq = GridWireState::zeros(4, 1);
        seq.e[0] = 5;
        seq.cap_src[0] = 5;
        seq.cap_sink[3] = 4;
        // S plane (arc 1) starts at cells=4: S arcs from cells 0, 1, 2.
        seq.cap[4] = 3;
        seq.cap[5] = 3;
        seq.cap[6] = 3;
        let mut par = seq.clone();
        let mut ss = WaveScratch::default();
        let mut ps = ParWaveScratch::new(1);
        let mut sink_total = 0i64;
        for _ in 0..400 {
            if active_cells(&seq) == 0 {
                break;
            }
            let a = native_wave_with(&mut seq, &mut ss);
            let b = par_wave_with(&mut par, &mut ps, 3).unwrap();
            assert_eq!(a, b);
            assert_eq!(seq.e, par.e);
            assert_eq!(seq.h, par.h);
            sink_total += b.sink_flow;
        }
        assert_eq!(active_cells(&par), 0);
        assert_eq!(sink_total, 3); // bottleneck: chain capacity
    }

    #[test]
    fn executor_reports_match_sequential_executor() {
        use crate::gridflow::{HybridGridSolver, NativeGridExecutor};
        use crate::graph::grid::{E, S};
        use crate::graph::GridNetwork;

        let mut net = GridNetwork::zeros(4, 4);
        for j in 0..4 {
            let top = net.cell(0, j);
            let bot = net.cell(3, j);
            net.cap_source[top] = 4;
            net.cap_sink[bot] = 3;
        }
        for i in 0..4 {
            for j in 0..4 {
                if i + 1 < 4 {
                    net.set_neighbour_cap(i, j, S, 2);
                }
                if j + 1 < 4 {
                    net.set_neighbour_cap(i, j, E, 1);
                }
            }
        }
        let solver = HybridGridSolver::with_cycle(32);
        let mut seq_exec = NativeGridExecutor::default();
        let want = solver.solve(&net, &mut seq_exec).unwrap();
        for (threads, tile_rows) in [(1, 1), (2, 2), (4, 3), (3, 16)] {
            let mut exec = NativeParGridExecutor::new(threads, tile_rows);
            let got = solver.solve(&net, &mut exec).unwrap();
            assert_eq!(got.flow, want.flow, "t={threads} tr={tile_rows}");
            assert_eq!(got.waves, want.waves, "t={threads} tr={tile_rows}");
            assert_eq!(got.pushes, want.pushes, "t={threads} tr={tile_rows}");
            assert_eq!(got.relabels, want.relabels, "t={threads} tr={tile_rows}");
            assert_eq!(got.host_rounds, want.host_rounds, "t={threads} tr={tile_rows}");
        }
    }

    #[test]
    fn merged_commit_bit_exact_with_two_pass_wave_by_wave() {
        // 6x1 column with tile_rows=1: every S push is a border op, so
        // the reconcile protocols are exercised on every wave.  The
        // merged commit must reproduce the two-pass (and sequential)
        // trajectory state-for-state.
        let mut seq = GridWireState::zeros(6, 1);
        seq.e[0] = 7;
        seq.cap_src[0] = 7;
        seq.cap_sink[5] = 5;
        for c in 0..5 {
            seq.cap[6 + c] = 4; // S plane (arc 1) starts at cells=6
        }
        let mut two = seq.clone();
        let mut merged = seq.clone();
        let mut ss = WaveScratch::default();
        let mut ts = ParWaveScratch::new(1);
        let mut ms = ParWaveScratch::new(1);
        ms.set_commit(crate::parallel::CommitMode::Merged);
        for _ in 0..400 {
            if active_cells(&seq) == 0 {
                break;
            }
            let a = native_wave_with(&mut seq, &mut ss);
            let b = par_wave_with(&mut two, &mut ts, 3).unwrap();
            let c = par_wave_with(&mut merged, &mut ms, 3).unwrap();
            assert_eq!(a, b);
            assert_eq!(a, c);
            assert_eq!(seq.e, merged.e);
            assert_eq!(seq.h, merged.h);
            assert_eq!(seq.cap, merged.cap);
            assert_eq!(two.e, merged.e);
        }
        assert_eq!(active_cells(&merged), 0);
    }

    #[test]
    fn tuned_executor_matches_sequential_executor() {
        use crate::gridflow::{HybridGridSolver, NativeGridExecutor};
        use crate::parallel::{CommitMode, ParTuning, StripeBalance};
        use crate::util::Rng;
        use crate::workloads::grid_gen::random_grid;

        let mut rng = Rng::seeded(37);
        let net = random_grid(&mut rng, 8, 6, 9, 0.3, 0.3);
        let solver = HybridGridSolver::with_cycle(48);
        let mut seq_exec = NativeGridExecutor::default();
        let want = solver.solve(&net, &mut seq_exec).unwrap();
        for balance in [StripeBalance::Fixed, StripeBalance::Weighted] {
            for commit in [CommitMode::TwoPass, CommitMode::Merged] {
                let tuning = ParTuning { balance, commit };
                let mut exec =
                    NativeParGridExecutor::new(3, 2).with_tuning(tuning);
                let got = solver.solve(&net, &mut exec).unwrap();
                assert_eq!(got.flow, want.flow, "{tuning:?}");
                assert_eq!(got.waves, want.waves, "{tuning:?}");
                assert_eq!(got.pushes, want.pushes, "{tuning:?}");
                assert_eq!(got.relabels, want.relabels, "{tuning:?}");
            }
        }
    }

    #[test]
    fn pooled_executor_bit_exact_with_sequential() {
        use crate::gridflow::{HybridGridSolver, NativeGridExecutor};
        use crate::util::Rng;
        use crate::workloads::grid_gen::random_grid;

        let mut rng = Rng::seeded(91);
        let net = random_grid(&mut rng, 9, 7, 11, 0.3, 0.3);
        let solver = HybridGridSolver::with_cycle(48);
        let mut seq_exec = NativeGridExecutor::default();
        let want = solver.solve(&net, &mut seq_exec).unwrap();
        let pool = Arc::new(WorkerPool::new(3));
        for tile_rows in [1usize, 2, 4, 16] {
            let mut exec =
                NativeParGridExecutor::new(2, tile_rows).with_pool(Arc::clone(&pool));
            assert!(exec.is_pooled());
            // Two back-to-back solves on the same executor: the pool
            // and scratch are reused across requests, as in the
            // service workers.
            for round in 0..2 {
                let got = solver.solve(&net, &mut exec).unwrap();
                assert_eq!(got.flow, want.flow, "tr={tile_rows} round={round}");
                assert_eq!(got.waves, want.waves, "tr={tile_rows} round={round}");
                assert_eq!(got.pushes, want.pushes, "tr={tile_rows} round={round}");
                assert_eq!(got.relabels, want.relabels, "tr={tile_rows} round={round}");
            }
        }
    }
}
