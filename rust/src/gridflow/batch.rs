//! The batched hybrid loop: K instances of one padded size class advance
//! through Algorithm 4.6 *together* — every superstep is one
//! [`BatchedGridDriver`] dispatch over the whole batch, while the host
//! rounds (violation cancel + global/gap relabel) run per slot between
//! dispatches, exactly as [`HybridGridSolver::resume`] runs them for a
//! single instance.
//!
//! Bit-exactness: slots never interact inside a dispatch (each has its
//! own planes in the packed literal), and the per-slot wave/host-round
//! sequence below mirrors `resume` line for line, so every slot's
//! trajectory — flow, heights, waves, pushes, relabels, host rounds, gap
//! cells, cancelled arcs — is identical to a solo solve of the same
//! instance.  The differential suites (`tests/integration_batch.rs`) pin
//! this against the native sequential oracle.
//!
//! A slot retires from the batch when it terminates, errors, or its
//! cancel token fires; retired slots stay in the literal as dead (zero)
//! planes but cost no compute.  An expired batchmate therefore never
//! delays — or is delayed by — the rest of the batch.

use std::sync::Arc;

use anyhow::Result;

use crate::graph::GridNetwork;
use crate::obs::{self, Phase};
use crate::parallel::{Lanes, ParTuning};
use crate::runtime::batch::BatchedGridDriver;
use crate::runtime::device::{GridStepStats, GridWireState};
use crate::runtime::SimGridDevice;
use crate::service::pool::WorkerPool;
use crate::util::CancelToken;

use super::host;
use super::solver::{GridExecutor, GridSolveReport, HostRounds};
use super::state::init_state;

/// The host-simulated device as a per-instance executor: batch-of-one
/// dispatches through the same packed wire format, so the explicit
/// `GridEngine::Pjrt` path exercises pack/unpack + transfer accounting
/// even in device-free containers.
impl GridExecutor for SimGridDevice {
    fn k_inner(&self) -> usize {
        self.driver.k_inner()
    }

    fn name(&self) -> &'static str {
        "pjrt-sim"
    }

    fn superstep(&mut self, st: &mut GridWireState, outer: i32) -> Result<GridStepStats> {
        self.step(st, outer)
    }

    // No `invalidate` override: the driver re-packs from the caller's
    // state on every dispatch, so there is no cached activity to drop.
}

/// Smallest padded class `(Hmax, Wmax)` that admits every instance.
pub fn padded_class(nets: &[&GridNetwork]) -> (usize, usize) {
    nets.iter()
        .fold((1, 1), |(h, w), n| (h.max(n.height), w.max(n.width)))
}

/// Per-slot solve bookkeeping (the locals of `resume`, one set per
/// batch member).
struct Slot {
    excess_total: i64,
    sink_total: i64,
    src_total: i64,
    hscratch: host::HostScratch,
    report: GridSolveReport,
}

/// The batched twin of [`HybridGridSolver`]: same knobs, joint loop.
pub struct BatchGridSolver {
    pub cycle_waves: usize,
    pub heuristics: bool,
    pub max_rounds: u64,
    pub host_rounds: HostRounds,
    pub tuning: ParTuning,
    /// Pool for striped host rounds (sequential lanes otherwise — same
    /// results).  The batched driver has no worker threads of its own.
    pub host_pool: Option<Arc<WorkerPool>>,
}

impl Default for BatchGridSolver {
    fn default() -> Self {
        Self {
            cycle_waves: 512,
            heuristics: true,
            max_rounds: 100_000,
            host_rounds: HostRounds::Seq,
            tuning: ParTuning::default(),
            host_pool: None,
        }
    }
}

impl BatchGridSolver {
    pub fn with_cycle(cycle_waves: usize) -> Self {
        Self {
            cycle_waves: cycle_waves.max(1),
            ..Self::default()
        }
    }

    pub fn with_host_rounds(mut self, host_rounds: HostRounds) -> Self {
        self.host_rounds = host_rounds;
        self
    }

    pub fn with_tuning(mut self, tuning: ParTuning) -> Self {
        self.tuning = tuning;
        self
    }

    pub fn with_host_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.host_pool = Some(pool);
        self
    }

    /// Solve `nets[k]` under `cancels[k]` (per-job deadlines: a token
    /// that fires retires only its own slot).  Returns one result per
    /// slot, in order.  A `Err` from the driver itself (shape refused,
    /// artifact died) fails the whole batch — the caller falls back to
    /// per-instance solves.
    pub fn solve_batch(
        &self,
        nets: &[&GridNetwork],
        cancels: &[Option<CancelToken>],
        driver: &mut BatchedGridDriver,
    ) -> Result<Vec<Result<GridSolveReport>>> {
        anyhow::ensure!(!nets.is_empty(), "solve_batch: empty batch");
        anyhow::ensure!(
            nets.len() == cancels.len(),
            "solve_batch: {} nets vs {} cancel tokens",
            nets.len(),
            cancels.len()
        );
        let n = nets.len();
        let mut states: Vec<GridWireState> = Vec::with_capacity(n);
        let mut slots: Vec<Slot> = Vec::with_capacity(n);
        for net in nets {
            let (st, excess_total) = init_state(net);
            let mut hscratch = host::HostScratch::for_state(&st);
            hscratch.set_tuning(self.tuning);
            states.push(st);
            slots.push(Slot {
                excess_total,
                sink_total: 0,
                src_total: 0,
                hscratch,
                report: GridSolveReport {
                    excess_total,
                    ..Default::default()
                },
            });
        }
        let mut live = vec![true; n];
        let mut results: Vec<Option<Result<GridSolveReport>>> = (0..n).map(|_| None).collect();

        let striped = self.host_rounds == HostRounds::Striped;
        let host_pool = if striped { self.host_pool.clone() } else { None };
        let lanes = match &host_pool {
            Some(p) => Lanes::Pool(p.as_ref()),
            None => Lanes::Seq,
        };

        // Initial global relabel per slot (exact heights before the
        // first dispatch), with per-slot cancel checks first.
        for k in 0..n {
            if let Some(c) = &cancels[k] {
                if let Err(e) = c.check() {
                    results[k] = Some(Err(e.into()));
                    live[k] = false;
                    continue;
                }
            }
            if self.heuristics {
                let t = crate::util::Timer::start();
                let out = if striped {
                    host::global_relabel_par(&mut states[k], &mut slots[k].hscratch, &lanes)
                } else {
                    host::global_relabel_with(&mut states[k], &mut slots[k].hscratch)
                };
                let report = &mut slots[k].report;
                report.gap_cells += out.gap_cells;
                if out.gap_cells > 0 {
                    report.phases.gap_relabels += 1;
                }
                let secs = t.elapsed();
                report.host_seconds += secs;
                report.phases.add(Phase::GlobalRelabel, secs);
                report.phases.global_relabels += 1;
            }
        }

        let outer =
            (self.cycle_waves as i64 + driver.k_inner() as i64 - 1) / driver.k_inner() as i64;

        while live.iter().any(|&l| l) {
            // Host-round boundary: per-slot cancel checks — an expired
            // slot retires with the typed error, its batchmates go on.
            for k in 0..n {
                if !live[k] {
                    continue;
                }
                if let Some(c) = &cancels[k] {
                    if let Err(e) = c.check() {
                        results[k] = Some(Err(e.into()));
                        live[k] = false;
                    }
                }
            }
            let live_count = live.iter().filter(|&&l| l).count();
            if live_count == 0 {
                break;
            }

            // One padded dispatch advances every live slot.  The joint
            // wall-clock is attributed evenly — it *was* one device
            // call; per-slot shares keep the phase totals additive.
            let t = crate::util::Timer::start();
            let stats = driver.superstep_batch(&mut states, &live, outer as i32)?;
            let share = t.elapsed() / live_count as f64;

            for k in 0..n {
                if !live[k] {
                    continue;
                }
                let slot = &mut slots[k];
                slot.report.device_seconds += share;
                slot.report.phases.add(Phase::WaveCompute, share);
                slot.sink_total += stats[k].sink_flow;
                slot.src_total += stats[k].src_flow;
                slot.report.waves += stats[k].waves;
                slot.report.pushes += stats[k].pushes;
                slot.report.relabels += stats[k].relabels;
                slot.report.host_rounds += 1;

                if slot.sink_total + slot.src_total >= slot.excess_total
                    && stats[k].active == 0
                {
                    results[k] = Some(finish(slot));
                    live[k] = false;
                    continue;
                }
                if slot.report.host_rounds >= self.max_rounds {
                    results[k] = Some(Err(anyhow::anyhow!(
                        "hybrid grid solve exceeded {} rounds (sink={} src={} total={})",
                        self.max_rounds,
                        slot.sink_total,
                        slot.src_total,
                        slot.excess_total
                    )));
                    live[k] = false;
                    continue;
                }

                if self.heuristics {
                    let t = crate::util::Timer::start();
                    let (c0, r0) = (slot.hscratch.cancel_seconds, slot.hscratch.relabel_seconds);
                    let out = if striped {
                        host::host_round_par(&mut states[k], &mut slot.hscratch, &lanes)
                    } else {
                        host::host_round_with(&mut states[k], &mut slot.hscratch)
                    };
                    slot.src_total += out.src_returned;
                    slot.report.gap_cells += out.gap_cells;
                    if out.gap_cells > 0 {
                        slot.report.phases.gap_relabels += 1;
                    }
                    slot.report.cancelled_arcs += out.cancelled_arcs;
                    slot.report.host_seconds += t.elapsed();
                    slot.report
                        .phases
                        .add(Phase::Cancel, slot.hscratch.cancel_seconds - c0);
                    slot.report
                        .phases
                        .add(Phase::GlobalRelabel, slot.hscratch.relabel_seconds - r0);
                    slot.report.phases.global_relabels += 1;
                    // No executor cache to invalidate: the next dispatch
                    // re-packs this state from scratch.
                }
            }
        }

        Ok(results
            .into_iter()
            .map(|r| r.expect("every slot retired with a result"))
            .collect())
    }
}

/// Terminal bookkeeping for one slot — the tail of `resume`, verbatim.
fn finish(slot: &mut Slot) -> Result<GridSolveReport> {
    anyhow::ensure!(
        slot.sink_total + slot.src_total == slot.excess_total,
        "mass accounting broken: sink {} + src {} != total {}",
        slot.sink_total,
        slot.src_total,
        slot.excess_total
    );
    let mut report = std::mem::take(&mut slot.report);
    report.flow = slot.sink_total;
    report.phases.pushes = report.pushes.max(0) as u64;
    report.phases.relabels = report.relabels.max(0) as u64;
    report.phases.waves = report.waves.max(0) as u64;
    report.phases.rebalances = slot.hscratch.take_rebalances();
    obs::record_phases("grid", &report.phases);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gridflow::{HybridGridSolver, NativeGridExecutor};
    use crate::util::Rng;
    use crate::workloads::grid_gen::random_grid;

    fn nets(seeds: &[(u64, usize, usize)]) -> Vec<GridNetwork> {
        seeds
            .iter()
            .map(|&(seed, h, w)| {
                let mut rng = Rng::seeded(seed);
                random_grid(&mut rng, h, w, 9, 0.3, 0.3)
            })
            .collect()
    }

    fn solo(net: &GridNetwork, cycle: usize) -> GridSolveReport {
        let mut exec = NativeGridExecutor::default();
        HybridGridSolver::with_cycle(cycle).solve(net, &mut exec).unwrap()
    }

    /// The headline invariant: a ragged batch reproduces every solo
    /// trajectory counter-for-counter.
    #[test]
    fn batched_solve_matches_solo_trajectories() {
        let owned = nets(&[(21, 5, 7), (22, 7, 5), (23, 7, 7), (24, 3, 4)]);
        let refs: Vec<&GridNetwork> = owned.iter().collect();
        let (hmax, wmax) = padded_class(&refs);
        assert_eq!((hmax, wmax), (7, 7));
        let mut driver = BatchedGridDriver::for_class(hmax, wmax);
        let cancels = vec![None; refs.len()];
        let got = BatchGridSolver::with_cycle(64)
            .solve_batch(&refs, &cancels, &mut driver)
            .unwrap();
        for (k, (net, report)) in owned.iter().zip(got).enumerate() {
            let report = report.unwrap();
            let want = solo(net, 64);
            assert_eq!(report.flow, want.flow, "slot {k}");
            assert_eq!(report.waves, want.waves, "slot {k}");
            assert_eq!(report.pushes, want.pushes, "slot {k}");
            assert_eq!(report.relabels, want.relabels, "slot {k}");
            assert_eq!(report.host_rounds, want.host_rounds, "slot {k}");
            assert_eq!(report.gap_cells, want.gap_cells, "slot {k}");
            assert_eq!(report.cancelled_arcs, want.cancelled_arcs, "slot {k}");
        }
    }

    /// A batch of one is the degenerate case (batch_max = 1).
    #[test]
    fn batch_of_one_matches_solo() {
        let owned = nets(&[(31, 6, 6)]);
        let refs: Vec<&GridNetwork> = owned.iter().collect();
        let mut driver = BatchedGridDriver::for_class(6, 6);
        let got = BatchGridSolver::with_cycle(128)
            .solve_batch(&refs, &[None], &mut driver)
            .unwrap();
        let report = got.into_iter().next().unwrap().unwrap();
        let want = solo(&owned[0], 128);
        assert_eq!(report.flow, want.flow);
        assert_eq!(report.waves, want.waves);
    }

    /// A pre-cancelled slot retires with the typed error while its
    /// batchmates solve to the exact solo answers.
    #[test]
    fn cancelled_slot_retires_batchmates_solve() {
        use crate::util::{CancelToken, Cancelled};
        let owned = nets(&[(41, 5, 5), (42, 5, 5), (43, 4, 5)]);
        let refs: Vec<&GridNetwork> = owned.iter().collect();
        let dead = CancelToken::new();
        dead.cancel();
        let cancels = vec![None, Some(dead), None];
        let mut driver = BatchedGridDriver::for_class(5, 5);
        let got = BatchGridSolver::with_cycle(64)
            .solve_batch(&refs, &cancels, &mut driver)
            .unwrap();
        assert!(got[1].as_ref().is_err(), "cancelled slot errors");
        assert!(
            Cancelled::caused(got[1].as_ref().err().unwrap()),
            "typed cancel error"
        );
        for k in [0, 2] {
            let want = solo(&owned[k], 64);
            let r = got[k].as_ref().unwrap();
            assert_eq!(r.flow, want.flow, "slot {k}");
            assert_eq!(r.waves, want.waves, "slot {k}");
        }
    }

    /// Heuristics-off batches terminate too and still agree on flow.
    #[test]
    fn no_heuristics_batch_matches() {
        let owned = nets(&[(51, 4, 4), (52, 4, 3)]);
        let refs: Vec<&GridNetwork> = owned.iter().collect();
        let mut driver = BatchedGridDriver::for_class(4, 4);
        let solver = BatchGridSolver {
            heuristics: false,
            cycle_waves: 64,
            ..Default::default()
        };
        let got = solver.solve_batch(&refs, &[None, None], &mut driver).unwrap();
        for (k, (net, r)) in owned.iter().zip(got).enumerate() {
            let r = r.unwrap();
            let mut exec = NativeGridExecutor::default();
            let want = HybridGridSolver::no_heuristics(64).solve(net, &mut exec).unwrap();
            assert_eq!(r.flow, want.flow, "slot {k}");
            assert_eq!(r.waves, want.waves, "slot {k}");
        }
    }
}
