//! Minimal argument parser (no `clap` in the offline image): subcommands
//! with `--flag`, `--key value` and `--key=value` options, typed getters
//! and generated usage text.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed invocation: subcommand + options + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Option names that never take a value (needed to disambiguate
/// `--verbose file` from `--key value`).
pub const BOOLEAN_FLAGS: &[&str] = &[
    "native",
    "verbose",
    "fast",
    "no-heuristics",
    "baseline",
    "gap-relabel",
    "scaling",
];

impl Args {
    /// Parse from an iterator (first element = argv[0], skipped).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        Self::parse_with_flags(argv, BOOLEAN_FLAGS)
    }

    /// Parse with an explicit boolean-flag vocabulary.
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(
        argv: I,
        boolean_flags: &[&str],
    ) -> Result<Self> {
        let mut it = argv.into_iter().skip(1).peekable();
        let mut out = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = Some(it.next().expect("peeked"));
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if boolean_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options
                        .insert(body.to_string(), it.next().expect("peeked"));
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v:?}")),
        }
    }

    pub fn get_i64(&self, name: &str, default: i64) -> Result<i64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v:?}")),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Reject unknown options (catches typos early).
    pub fn expect_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (expected one of: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        let mut v = vec!["prog".to_string()];
        v.extend(tokens.iter().map(|s| s.to_string()));
        Args::parse(v).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["assign", "--n", "30", "--alpha=10", "--verbose", "file.asn"]);
        assert_eq!(a.command.as_deref(), Some("assign"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 30);
        assert_eq!(a.get_i64("alpha", 0).unwrap(), 10);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["file.asn"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_usize("n", 1).is_err());
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_str("mode", "fast"), "fast");
    }

    #[test]
    fn unknown_rejected() {
        let a = parse(&["x", "--typo", "1"]);
        assert!(a.expect_known(&["n"]).is_err());
        assert!(a.expect_known(&["typo"]).is_ok());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--fast", "--n", "5"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
    }
}
