//! # flowmatch
//!
//! Reproduction of *"Parallel implementation of flow and matching
//! algorithms"* (Łupińska, 2011) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): synchronous
//!   push-relabel waves for grid max-flow and cost-scaling refine waves for
//!   the assignment problem (AOT-compiled to HLO text).
//! * **L2** — JAX super-steps (`python/compile/model.py`): dynamic wave
//!   loops with device-side quiescence detection.
//! * **L3** — this crate: every runtime component, from the graph
//!   substrates and sequential baselines through the lock-free atomic
//!   engines up to the hybrid CPU/device coordinator and the sharded
//!   solver-pool service (`service`) that serves both problem families
//!   under load.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod assignment;
pub mod benchkit;
pub mod gridflow;
pub mod maxflow;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod graph;
pub mod obs;
pub mod opticalflow;
pub mod parallel;
pub mod reductions;
pub mod service;
pub mod workloads;
pub mod prop;
pub mod runtime;
pub mod util;
