//! Optical flow via the assignment problem — the §1 motivation ("a new
//! and most interesting for us idea consists in computing optical flow by
//! reducing it to the assignment problem").
//!
//! Pipeline: two frames -> corner-like feature extraction -> patch
//! descriptors -> similarity weight matrix -> max-weight assignment ->
//! displacement field + endpoint-error metrics against the known
//! synthetic ground truth.

pub mod features;
pub mod flow;

pub use features::{extract_features, Feature};
pub use flow::{compute_flow, FlowField};
