//! Flow-from-assignment: match features between frames by solving the
//! max-weight assignment over descriptor similarities, yielding a sparse
//! displacement field.

use anyhow::Result;

use crate::assignment::{AssignmentResult, AssignmentSolver};
use crate::graph::AssignmentInstance;

use super::features::{descriptor_distance, extract_features, Feature};

/// A matched displacement vector.
#[derive(Debug, Clone, Copy)]
pub struct FlowVector {
    pub from: (usize, usize),
    pub to: (usize, usize),
}

/// Sparse optical-flow field.
#[derive(Debug, Clone)]
pub struct FlowField {
    pub vectors: Vec<FlowVector>,
    pub matching_weight: i64,
    pub solver_result: AssignmentResult,
}

impl FlowField {
    /// Mean endpoint error against a known constant translation.
    pub fn mean_endpoint_error(&self, dy: f64, dx: f64) -> f64 {
        if self.vectors.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .vectors
            .iter()
            .map(|v| {
                let vy = v.to.0 as f64 - v.from.0 as f64;
                let vx = v.to.1 as f64 - v.from.1 as f64;
                ((vy - dy).powi(2) + (vx - dx).powi(2)).sqrt()
            })
            .sum();
        sum / self.vectors.len() as f64
    }
}

/// Build the weight matrix between two feature sets: similarity = scaled
/// inverse descriptor distance, damped by spatial displacement (flows are
/// small between consecutive frames).
pub fn match_weights(fa: &[Feature], fb: &[Feature]) -> AssignmentInstance {
    let n = fa.len().min(fb.len());
    let fa = &fa[..n];
    let fb = &fb[..n];
    let mut w = vec![0i64; n * n];
    for (i, a) in fa.iter().enumerate() {
        for (j, b) in fb.iter().enumerate() {
            let d = descriptor_distance(a, b);
            let spatial =
                ((a.i.abs_diff(b.i)).pow(2) + (a.j.abs_diff(b.j)).pow(2)) as f64;
            let sim = 1000.0 * (-(d as f64) / 2000.0).exp() * (-spatial / 200.0).exp();
            w[i * n + j] = sim.round() as i64;
        }
    }
    AssignmentInstance::new(n, w)
}

/// Full pipeline: frames -> features -> assignment -> flow field.
pub fn compute_flow(
    frame_a: &[u8],
    frame_b: &[u8],
    height: usize,
    width: usize,
    feature_count: usize,
    solver: &dyn AssignmentSolver,
) -> Result<FlowField> {
    let fa = extract_features(frame_a, height, width, feature_count);
    let fb = extract_features(frame_b, height, width, feature_count);
    anyhow::ensure!(!fa.is_empty() && !fb.is_empty(), "no features detected");
    let inst = match_weights(&fa, &fb);
    let result = solver.solve(&inst)?;
    let n = inst.n;
    let vectors = (0..n)
        .map(|i| FlowVector {
            from: (fa[i].i, fa[i].j),
            to: (fb[result.assignment[i]].i, fb[result.assignment[i]].j),
        })
        .collect();
    Ok(FlowField {
        vectors,
        matching_weight: result.weight,
        solver_result: result,
    })
}

/// Translate an image by (dy, dx) with border clamping (synthetic frames).
pub fn translate_image(img: &[u8], h: usize, w: usize, dy: i64, dx: i64) -> Vec<u8> {
    let mut out = vec![0u8; h * w];
    for i in 0..h {
        for j in 0..w {
            let si = (i as i64 - dy).clamp(0, h as i64 - 1) as usize;
            let sj = (j as i64 - dx).clamp(0, w as i64 - 1) as usize;
            out[i * w + j] = img[si * w + sj];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::csa::SequentialCsa;
    use crate::workloads::grid_gen::synthetic_image;

    #[test]
    fn recovers_constant_translation() {
        let mut rng = crate::util::Rng::seeded(71);
        let (h, w) = (24, 24);
        let a = synthetic_image(&mut rng, h, w);
        let b = translate_image(&a, h, w, 2, 1);
        let field = compute_flow(&a, &b, h, w, 10, &SequentialCsa::default()).unwrap();
        let err = field.mean_endpoint_error(2.0, 1.0);
        // Features near the border clamp, so allow a loose bound.
        assert!(err < 3.0, "mean endpoint error too high: {err}");
        assert!(field.vectors.len() >= 6);
    }

    #[test]
    fn zero_motion_gives_identity_matches() {
        let mut rng = crate::util::Rng::seeded(73);
        let (h, w) = (20, 20);
        let a = synthetic_image(&mut rng, h, w);
        let field = compute_flow(&a, &a, h, w, 8, &SequentialCsa::default()).unwrap();
        let err = field.mean_endpoint_error(0.0, 0.0);
        assert!(err < 0.5, "identity flow should be near-zero: {err}");
    }
}
