//! Feature extraction on intensity images: gradient-energy corner scores
//! (a poor man's Harris detector) + raw 5x5 patch descriptors.  Enough
//! structure to make descriptor matching meaningful without any imaging
//! dependency.

/// One detected feature.
#[derive(Debug, Clone)]
pub struct Feature {
    pub i: usize,
    pub j: usize,
    pub score: i64,
    /// Flattened 5x5 patch around (i, j), border-clamped.
    pub descriptor: Vec<i16>,
}

fn pixel(img: &[u8], h: usize, w: usize, i: i64, j: i64) -> i64 {
    let ii = i.clamp(0, h as i64 - 1) as usize;
    let jj = j.clamp(0, w as i64 - 1) as usize;
    img[ii * w + jj] as i64
}

/// Gradient-product corner score at (i, j).
fn corner_score(img: &[u8], h: usize, w: usize, i: usize, j: usize) -> i64 {
    let (i, j) = (i as i64, j as i64);
    let mut gxx = 0i64;
    let mut gyy = 0i64;
    let mut gxy = 0i64;
    for di in -1..=1i64 {
        for dj in -1..=1i64 {
            let gx = pixel(img, h, w, i + di, j + dj + 1) - pixel(img, h, w, i + di, j + dj - 1);
            let gy = pixel(img, h, w, i + di + 1, j + dj) - pixel(img, h, w, i + di - 1, j + dj);
            gxx += gx * gx;
            gyy += gy * gy;
            gxy += gx * gy;
        }
    }
    // det - trace^2/4 (scaled Harris-like response).
    let det = gxx * gyy - gxy * gxy;
    let tr = gxx + gyy;
    det / 256 - tr * tr / 4096
}

fn descriptor(img: &[u8], h: usize, w: usize, i: usize, j: usize) -> Vec<i16> {
    let mut d = Vec::with_capacity(25);
    for di in -2..=2i64 {
        for dj in -2..=2i64 {
            d.push(pixel(img, h, w, i as i64 + di, j as i64 + dj) as i16);
        }
    }
    d
}

/// Extract the top `count` features by corner score with non-maximum
/// suppression radius 2.
pub fn extract_features(img: &[u8], h: usize, w: usize, count: usize) -> Vec<Feature> {
    assert_eq!(img.len(), h * w);
    let mut scored: Vec<(i64, usize, usize)> = Vec::new();
    for i in 1..h.saturating_sub(1) {
        for j in 1..w.saturating_sub(1) {
            scored.push((corner_score(img, h, w, i, j), i, j));
        }
    }
    scored.sort_by_key(|&(s, _, _)| std::cmp::Reverse(s));
    let mut picked: Vec<Feature> = Vec::new();
    for (score, i, j) in scored {
        if picked.len() >= count {
            break;
        }
        let clash = picked
            .iter()
            .any(|f| f.i.abs_diff(i) <= 2 && f.j.abs_diff(j) <= 2);
        if !clash {
            picked.push(Feature {
                i,
                j,
                score,
                descriptor: descriptor(img, h, w, i, j),
            });
        }
    }
    picked
}

/// Sum of absolute descriptor differences.
pub fn descriptor_distance(a: &Feature, b: &Feature) -> i64 {
    a.descriptor
        .iter()
        .zip(&b.descriptor)
        .map(|(&x, &y)| (x as i64 - y as i64).abs())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn checkerboard(h: usize, w: usize) -> Vec<u8> {
        (0..h * w)
            .map(|p| {
                let (i, j) = (p / w, p % w);
                if ((i / 4) + (j / 4)) % 2 == 0 {
                    220
                } else {
                    40
                }
            })
            .collect()
    }

    #[test]
    fn corners_found_on_checkerboard() {
        let img = checkerboard(16, 16);
        let feats = extract_features(&img, 16, 16, 8);
        assert_eq!(feats.len(), 8);
        // Top features should sit near block boundaries (gradient energy).
        for f in &feats {
            let near_boundary = (f.i % 4 <= 1 || f.i % 4 >= 3) || (f.j % 4 <= 1 || f.j % 4 >= 3);
            assert!(near_boundary, "feature at ({}, {}) not near an edge", f.i, f.j);
        }
    }

    #[test]
    fn nms_enforces_spacing() {
        let img = checkerboard(20, 20);
        let feats = extract_features(&img, 20, 20, 12);
        for (a_idx, a) in feats.iter().enumerate() {
            for b in feats.iter().skip(a_idx + 1) {
                assert!(a.i.abs_diff(b.i) > 2 || a.j.abs_diff(b.j) > 2);
            }
        }
    }

    #[test]
    fn identical_patches_have_zero_distance() {
        let img = checkerboard(12, 12);
        let f = extract_features(&img, 12, 12, 2);
        assert_eq!(descriptor_distance(&f[0], &f[0]), 0);
    }
}
