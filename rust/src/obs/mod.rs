//! One observability spine for engines, substrate, and service.
//!
//! * [`registry`] — the lock-free metrics registry (sharded counters,
//!   gauges, fixed-bucket histograms) with Prometheus-style text
//!   exposition and a benchkit-compatible JSON snapshot.
//! * [`phase`] — per-solve phase tracing ([`PhaseBreakdown`], [`Span`],
//!   [`PhaseTimer`]) and the solve-boundary flush into the registry.
//!
//! Conventions: metric families are `flowmatch_*`; service series carry
//! a `pool="pN"` label (one per [`crate::service::SolverPool`] start,
//! so concurrent pools and tests never share a series); seconds-valued
//! counters are micro-unit fixed point (`*_micros_total`).  The full
//! name catalogue lives in README "Observability".
//!
//! Cost model: hot paths touch one `Relaxed` atomic on a padded shard;
//! registration is a mutex and happens at setup or solve boundaries;
//! anything per-wave or per-stripe is behind the `obs-fine` feature and
//! compiles out by default.

pub mod phase;
pub mod registry;

pub use phase::{record_phase_secs, record_phases, Phase, PhaseBreakdown, PhaseTimer, Span};
pub use registry::{global, Counter, Gauge, Histogram, Registry, LATENCY_BUCKETS};

/// Flush a max-flow engine's end-of-solve counters into the global
/// registry (one call per solve; never in the discharge loop).
pub fn record_flow_stats(engine: &str, stats: &crate::maxflow::FlowStats) {
    let reg = global();
    if stats.pushes > 0 {
        reg.counter(&format!("flowmatch_engine_pushes_total{{engine=\"{engine}\"}}"))
            .add(stats.pushes);
    }
    if stats.relabels > 0 {
        reg.counter(&format!("flowmatch_engine_relabels_total{{engine=\"{engine}\"}}"))
            .add(stats.relabels);
    }
    if stats.global_relabels > 0 {
        reg.counter(&format!(
            "flowmatch_engine_global_relabels_total{{engine=\"{engine}\"}}"
        ))
        .add(stats.global_relabels);
    }
    if stats.gap_nodes > 0 {
        reg.counter(&format!("flowmatch_engine_gap_nodes_total{{engine=\"{engine}\"}}"))
            .add(stats.gap_nodes);
    }
    if stats.gap_relabels > 0 {
        reg.counter(&format!(
            "flowmatch_engine_gap_relabels_total{{engine=\"{engine}\"}}"
        ))
        .add(stats.gap_relabels);
    }
    reg.counter(&format!("flowmatch_engine_solves_total{{engine=\"{engine}\"}}"))
        .inc();
}

/// Flush an assignment engine's end-of-solve counters.
pub fn record_assignment_stats(engine: &str, stats: &crate::assignment::AssignStats) {
    let reg = global();
    if stats.pushes > 0 {
        reg.counter(&format!("flowmatch_engine_pushes_total{{engine=\"{engine}\"}}"))
            .add(stats.pushes);
    }
    if stats.relabels > 0 {
        reg.counter(&format!("flowmatch_engine_relabels_total{{engine=\"{engine}\"}}"))
            .add(stats.relabels);
    }
    if stats.price_updates > 0 {
        reg.counter(&format!(
            "flowmatch_engine_price_updates_total{{engine=\"{engine}\"}}"
        ))
        .add(stats.price_updates);
    }
    if stats.waves > 0 {
        reg.counter(&format!("flowmatch_engine_waves_total{{engine=\"{engine}\"}}"))
            .add(stats.waves);
    }
    reg.counter(&format!("flowmatch_engine_solves_total{{engine=\"{engine}\"}}"))
        .inc();
}
