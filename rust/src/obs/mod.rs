//! One observability spine for engines, substrate, and service.
//!
//! * [`registry`] — the lock-free metrics registry (sharded counters,
//!   gauges, fixed-bucket histograms) with Prometheus-style text
//!   exposition and a benchkit-compatible JSON snapshot.
//! * [`phase`] — per-solve phase tracing ([`PhaseBreakdown`], [`Span`],
//!   [`PhaseTimer`]) and the solve-boundary flush into the registry.
//!
//! Conventions: metric families are `flowmatch_*`; service series carry
//! a `pool="pN"` label (one per [`crate::service::SolverPool`] start,
//! so concurrent pools and tests never share a series); seconds-valued
//! counters are micro-unit fixed point (`*_micros_total`).  The full
//! name catalogue lives in README "Observability".
//!
//! Cost model: hot paths touch one `Relaxed` atomic on a padded shard;
//! registration is a mutex and happens at setup or solve boundaries;
//! anything per-wave or per-stripe is behind the `obs-fine` feature and
//! compiles out by default.

pub mod phase;
pub mod registry;

pub use phase::{record_phase_secs, record_phases, Phase, PhaseBreakdown, PhaseTimer, Span};
pub use registry::{global, Counter, Gauge, Histogram, Registry, LATENCY_BUCKETS};

/// Flush a max-flow engine's end-of-solve counters into the global
/// registry (one call per solve; never in the discharge loop).
pub fn record_flow_stats(engine: &str, stats: &crate::maxflow::FlowStats) {
    let reg = global();
    if stats.pushes > 0 {
        reg.counter(&format!("flowmatch_engine_pushes_total{{engine=\"{engine}\"}}"))
            .add(stats.pushes);
    }
    if stats.relabels > 0 {
        reg.counter(&format!("flowmatch_engine_relabels_total{{engine=\"{engine}\"}}"))
            .add(stats.relabels);
    }
    if stats.global_relabels > 0 {
        reg.counter(&format!(
            "flowmatch_engine_global_relabels_total{{engine=\"{engine}\"}}"
        ))
        .add(stats.global_relabels);
    }
    if stats.gap_nodes > 0 {
        reg.counter(&format!("flowmatch_engine_gap_nodes_total{{engine=\"{engine}\"}}"))
            .add(stats.gap_nodes);
    }
    if stats.gap_relabels > 0 {
        reg.counter(&format!(
            "flowmatch_engine_gap_relabels_total{{engine=\"{engine}\"}}"
        ))
        .add(stats.gap_relabels);
    }
    reg.counter(&format!("flowmatch_engine_solves_total{{engine=\"{engine}\"}}"))
        .inc();
}

/// Jobs per cut batch (upper bounds; `batch_max` caps the real value).
const BATCH_SIZE_BUCKETS: &[f64] = &[1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0];
/// Ratio-valued histograms (padding waste, transfer/compute overlap).
const RATIO_BUCKETS: &[f64] = &[0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

/// Flush the delta of a [`crate::runtime::BatchedGridDriver`]'s
/// dispatch stats after a batched solve: dispatch/instance/cell
/// counters, the transfer vs compute clocks (micro-unit fixed point),
/// and per-solve padding-waste and overlap-ratio histograms.  Called
/// once per batch solve with the driver's stats snapshot from before
/// and after — never inside the dispatch loop.
pub fn record_batch_dispatches(
    before: &crate::runtime::BatchDispatchStats,
    after: &crate::runtime::BatchDispatchStats,
) {
    let dispatches = after.dispatches.saturating_sub(before.dispatches);
    if dispatches == 0 {
        return;
    }
    let reg = global();
    reg.counter("flowmatch_batch_dispatches_total").add(dispatches);
    reg.counter("flowmatch_batch_dispatch_instances_total")
        .add(after.instances.saturating_sub(before.instances));
    let padded = after.padded_cells.saturating_sub(before.padded_cells);
    let logical = after.logical_cells.saturating_sub(before.logical_cells);
    reg.counter("flowmatch_batch_padded_cells_total").add(padded);
    reg.counter("flowmatch_batch_logical_cells_total").add(logical);
    let transfer = after.transfer_seconds - before.transfer_seconds;
    let overlap = after.overlap_seconds - before.overlap_seconds;
    reg.counter("flowmatch_batch_transfer_micros_total").add_secs(transfer);
    reg.counter("flowmatch_batch_compute_micros_total")
        .add_secs(after.compute_seconds - before.compute_seconds);
    reg.counter("flowmatch_batch_overlap_micros_total").add_secs(overlap);
    if transfer > 0.0 {
        reg.histogram("flowmatch_batch_overlap_ratio", RATIO_BUCKETS)
            .observe((overlap / transfer).clamp(0.0, 1.0));
    }
    if padded > 0 {
        reg.histogram("flowmatch_batch_padding_waste_ratio", RATIO_BUCKETS)
            .observe(1.0 - logical as f64 / padded as f64);
    }
}

/// Record one batch cut from the shard queues: jobs carried, padding
/// the cut will waste on the padded slab, and how long the cut lingered
/// for late arrivals (the batching tax on the seed job's latency).
pub fn record_batch_cut(jobs: usize, padded_cells: u64, logical_cells: u64, linger_secs: f64) {
    let reg = global();
    reg.histogram("flowmatch_batch_cut_jobs", BATCH_SIZE_BUCKETS)
        .observe(jobs as f64);
    reg.counter("flowmatch_batch_cut_padding_cells_total")
        .add(padded_cells.saturating_sub(logical_cells));
    reg.histogram("flowmatch_batch_linger_seconds", LATENCY_BUCKETS)
        .observe(linger_secs);
}

/// Flush an assignment engine's end-of-solve counters.
pub fn record_assignment_stats(engine: &str, stats: &crate::assignment::AssignStats) {
    let reg = global();
    if stats.pushes > 0 {
        reg.counter(&format!("flowmatch_engine_pushes_total{{engine=\"{engine}\"}}"))
            .add(stats.pushes);
    }
    if stats.relabels > 0 {
        reg.counter(&format!("flowmatch_engine_relabels_total{{engine=\"{engine}\"}}"))
            .add(stats.relabels);
    }
    if stats.price_updates > 0 {
        reg.counter(&format!(
            "flowmatch_engine_price_updates_total{{engine=\"{engine}\"}}"
        ))
        .add(stats.price_updates);
    }
    if stats.waves > 0 {
        reg.counter(&format!("flowmatch_engine_waves_total{{engine=\"{engine}\"}}"))
            .add(stats.waves);
    }
    reg.counter(&format!("flowmatch_engine_solves_total{{engine=\"{engine}\"}}"))
        .inc();
}
