//! Lock-free metrics registry: monotonic counters, gauges, and
//! fixed-bucket histograms, with a stable Prometheus-style text
//! exposition and a benchkit-compatible JSON snapshot.
//!
//! Hot paths touch only a `Relaxed` atomic: counters and histograms are
//! sharded across cache-line-padded slots (threads are assigned a shard
//! round-robin on first use), so concurrent workers never contend on
//! one line.  Aggregation happens at snapshot time, which is the slow
//! path by construction.  Registration (`Registry::counter` & co.) goes
//! through a mutex + name map and is meant for setup or solve
//! boundaries, never inner loops — call sites that care cache the
//! returned `Arc` handle.
//!
//! Metric names follow the Prometheus convention and may carry an
//! inline label block: `flowmatch_pool_replies_total{pool="p1"}`.  The
//! registry keys metrics by the full string; the exposition groups
//! `# TYPE` lines by the family (the part before `{`).  Seconds-valued
//! counters use micro-unit fixed point (see [`Counter::add_secs`]) so
//! the hot-path add stays a single integer `fetch_add`.

use std::collections::{BTreeMap, HashSet};
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::benchkit::{Cell, Table};

/// Number of per-worker shards.  A power of two at least as wide as
/// the service's worker counts; threads beyond it wrap and share.
pub const SHARDS: usize = 16;

static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

/// The calling thread's shard slot, assigned round-robin on first use.
#[inline]
fn shard_index() -> usize {
    thread_local! {
        static SHARD: usize = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    SHARD.with(|s| *s)
}

/// One cache line per shard so neighbouring slots never false-share.
#[repr(align(64))]
#[derive(Debug)]
struct PaddedU64(AtomicU64);

/// Monotonic counter, sharded per worker thread.
#[derive(Debug)]
pub struct Counter {
    shards: [PaddedU64; SHARDS],
}

impl Counter {
    fn new() -> Self {
        Self {
            shards: std::array::from_fn(|_| PaddedU64(AtomicU64::new(0))),
        }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.shards[shard_index()].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Accumulate a duration in micro-unit fixed point (1 count = 1 µs),
    /// so seconds-valued series stay integer counters.
    #[inline]
    pub fn add_secs(&self, secs: f64) {
        if secs > 0.0 {
            self.add((secs * 1e6) as u64);
        }
    }

    /// Aggregate across shards.  A snapshot taken while writers are hot
    /// is a valid value between the pre- and post-snapshot totals
    /// (every shard is read exactly once, each monotonic).
    pub fn value(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Point-in-time gauge.  Set semantics don't shard, so a gauge is one
/// atomic — gauges are updated at round boundaries, not inner loops.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn value(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

#[derive(Debug)]
struct HistShard {
    /// One slot per bound plus the overflow (+Inf) bucket.
    counts: Vec<AtomicU64>,
    sum_micro: AtomicU64,
    total: AtomicU64,
}

/// Fixed-bucket histogram, sharded like [`Counter`].  Bounds are upper
/// bounds (`v <= bound`), ascending; values above the last bound land
/// in the implicit +Inf bucket.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    shards: Vec<HistShard>,
}

/// Aggregated histogram state: cumulative counts per bound (Prometheus
/// `le` semantics), plus total count and sum.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnapshot {
    pub bounds: Vec<f64>,
    /// `cumulative[i]` = observations `<= bounds[i]`; one extra entry
    /// for +Inf (== `count`).
    pub cumulative: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        let mut b: Vec<f64> = bounds.to_vec();
        b.sort_by(|x, y| x.partial_cmp(y).expect("histogram bounds must not be NaN"));
        b.dedup();
        let shards = (0..SHARDS)
            .map(|_| HistShard {
                counts: (0..=b.len()).map(|_| AtomicU64::new(0)).collect(),
                sum_micro: AtomicU64::new(0),
                total: AtomicU64::new(0),
            })
            .collect();
        Self { bounds: b, shards }
    }

    #[inline]
    pub fn observe(&self, v: f64) {
        let shard = &self.shards[shard_index()];
        let mut i = self.bounds.len(); // +Inf bucket by default
        for (k, &ub) in self.bounds.iter().enumerate() {
            if v <= ub {
                i = k;
                break;
            }
        }
        shard.counts[i].fetch_add(1, Ordering::Relaxed);
        if v > 0.0 {
            shard.sum_micro.fetch_add((v * 1e6) as u64, Ordering::Relaxed);
        }
        shard.total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let n = self.bounds.len();
        let mut per_bucket = vec![0u64; n + 1];
        let mut sum_micro = 0u64;
        let mut count = 0u64;
        for shard in &self.shards {
            for (acc, c) in per_bucket.iter_mut().zip(shard.counts.iter()) {
                *acc += c.load(Ordering::Relaxed);
            }
            sum_micro += shard.sum_micro.load(Ordering::Relaxed);
            count += shard.total.load(Ordering::Relaxed);
        }
        let mut cumulative = per_bucket;
        for i in 1..cumulative.len() {
            cumulative[i] += cumulative[i - 1];
        }
        HistSnapshot {
            bounds: self.bounds.clone(),
            cumulative,
            count,
            sum: sum_micro as f64 / 1e6,
        }
    }
}

/// Default latency buckets (seconds) shared by the service histograms.
pub const LATENCY_BUCKETS: &[f64] = &[
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
];

#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// The metric name map.  `get-or-create` by full name; the returned
/// `Arc` handle is the hot-path object and never goes back through the
/// registry.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())));
        match entry {
            Metric::Counter(c) => Arc::clone(c),
            other => panic!("metric {name:?} already registered as a {}", other.kind()),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())));
        match entry {
            Metric::Gauge(g) => Arc::clone(g),
            other => panic!("metric {name:?} already registered as a {}", other.kind()),
        }
    }

    /// `bounds` is used only when the histogram is first created.
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        let entry = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new(bounds))));
        match entry {
            Metric::Histogram(h) => Arc::clone(h),
            other => panic!("metric {name:?} already registered as a {}", other.kind()),
        }
    }

    /// Current value of a counter, if one with this exact name exists.
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        match self.metrics.lock().unwrap().get(name) {
            Some(Metric::Counter(c)) => Some(c.value()),
            _ => None,
        }
    }

    /// Current value of a gauge, if one with this exact name exists.
    pub fn gauge_value(&self, name: &str) -> Option<i64> {
        match self.metrics.lock().unwrap().get(name) {
            Some(Metric::Gauge(g)) => Some(g.value()),
            _ => None,
        }
    }

    /// All registered names, sorted (the registry key order).
    pub fn names(&self) -> Vec<String> {
        self.metrics.lock().unwrap().keys().cloned().collect()
    }

    /// Sum of every counter whose name starts with `prefix` — scrape
    /// helper for labelled families (`flowmatch_route_total{...}`).
    pub fn counter_sum(&self, prefix: &str) -> u64 {
        self.metrics
            .lock()
            .unwrap()
            .iter()
            .filter(|(name, _)| name.starts_with(prefix))
            .filter_map(|(_, m)| match m {
                Metric::Counter(c) => Some(c.value()),
                _ => None,
            })
            .sum()
    }

    /// Stable Prometheus-style text exposition: one `# TYPE` line per
    /// family (first occurrence), then `name value` lines in sorted
    /// name order.  Histograms expand to `_bucket{le=...}`, `_sum`,
    /// `_count` series.
    pub fn render_text(&self) -> String {
        let m = self.metrics.lock().unwrap();
        let mut out = String::new();
        let mut typed: HashSet<String> = HashSet::new();
        for (name, metric) in m.iter() {
            let family = name.split('{').next().unwrap_or(name);
            if typed.insert(family.to_string()) {
                out.push_str(&format!("# TYPE {family} {}\n", metric.kind()));
            }
            match metric {
                Metric::Counter(c) => out.push_str(&format!("{name} {}\n", c.value())),
                Metric::Gauge(g) => out.push_str(&format!("{name} {}\n", g.value())),
                Metric::Histogram(h) => {
                    let snap = h.snapshot();
                    let labels = match name.find('{') {
                        // "family{a=\"b\"}" -> "a=\"b\","
                        Some(i) => format!("{},", &name[i + 1..name.len() - 1]),
                        None => String::new(),
                    };
                    for (bound, cum) in snap.bounds.iter().zip(snap.cumulative.iter()) {
                        out.push_str(&format!(
                            "{family}_bucket{{{labels}le=\"{bound}\"}} {cum}\n"
                        ));
                    }
                    out.push_str(&format!(
                        "{family}_bucket{{{labels}le=\"+Inf\"}} {}\n",
                        snap.count
                    ));
                    let plain = match name.find('{') {
                        Some(i) => format!("{{{}}}", &name[i + 1..name.len() - 1]),
                        None => String::new(),
                    };
                    out.push_str(&format!("{family}_sum{plain} {}\n", snap.sum));
                    out.push_str(&format!("{family}_count{plain} {}\n", snap.count));
                }
            }
        }
        out
    }

    /// Benchkit-compatible snapshot: one row per scalar series
    /// (histograms contribute `_count` and `_sum` rows), renderable as
    /// markdown and serialisable with [`crate::benchkit::write_json`].
    pub fn to_table(&self, title: &str) -> Table {
        let m = self.metrics.lock().unwrap();
        let mut table = Table::new(title, &["metric", "type", "value"]);
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => table.row(vec![
                    name.clone().into(),
                    "counter".into(),
                    Cell::Int(c.value() as i64),
                ]),
                Metric::Gauge(g) => table.row(vec![
                    name.clone().into(),
                    "gauge".into(),
                    Cell::Int(g.value()),
                ]),
                Metric::Histogram(h) => {
                    let snap = h.snapshot();
                    table.row(vec![
                        format!("{name}_count").into(),
                        "histogram".into(),
                        Cell::Int(snap.count as i64),
                    ]);
                    table.row(vec![
                        format!("{name}_sum").into(),
                        "histogram".into(),
                        Cell::Float(snap.sum),
                    ]);
                }
            }
        }
        table
    }
}

/// The process-wide registry every layer shares.  Per-pool series are
/// disambiguated by a `pool="pN"` label, so concurrent pools (and
/// concurrent tests) never collide on a series.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrent_increments_are_exact() {
        // N threads x M increments == N*M: no lost updates across shards.
        let reg = Registry::new();
        let c = reg.counter("t_concurrent_total");
        const N: usize = 8;
        const M: u64 = 10_000;
        std::thread::scope(|s| {
            for _ in 0..N {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..M {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.value(), N as u64 * M);
        assert_eq!(reg.counter_value("t_concurrent_total"), Some(N as u64 * M));
    }

    #[test]
    fn snapshot_while_hot_is_monotonic_and_bounded() {
        // Snapshots taken while writers run must land between the
        // pre-read floor and the final total, and never decrease.
        let reg = Registry::new();
        let c = reg.counter("t_hot_total");
        let stop = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = Arc::clone(&c);
                let stop = &stop;
                s.spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        c.add(3);
                    }
                });
            }
            let mut last = 0u64;
            for _ in 0..200 {
                let v = c.value();
                assert!(v >= last, "snapshot went backwards: {v} < {last}");
                assert_eq!(v % 3, 0, "torn aggregate: {v} not a multiple of 3");
                last = v;
            }
            stop.store(true, Ordering::Relaxed);
        });
        assert!(c.value() >= 3);
    }

    #[test]
    fn histogram_bucket_boundaries() {
        let h = Histogram::new(&[0.01, 0.1, 1.0]);
        // Exactly on a bound counts into that bucket (le semantics).
        h.observe(0.01);
        h.observe(0.05);
        h.observe(0.1);
        h.observe(0.5);
        h.observe(1.0);
        h.observe(7.0); // +Inf
        let snap = h.snapshot();
        assert_eq!(snap.bounds, vec![0.01, 0.1, 1.0]);
        assert_eq!(snap.cumulative, vec![1, 3, 5, 6]);
        assert_eq!(snap.count, 6);
        assert!((snap.sum - 7.66).abs() < 1e-3, "sum={}", snap.sum);
    }

    #[test]
    fn gauge_set_and_add() {
        let g = Gauge::default();
        g.set(5);
        g.add(-2);
        assert_eq!(g.value(), 3);
    }

    #[test]
    fn exposition_is_stable_and_grouped() {
        let reg = Registry::new();
        reg.counter("t_a_total{pool=\"p1\"}").add(2);
        reg.counter("t_a_total{pool=\"p2\"}").add(3);
        reg.gauge("t_depth").set(7);
        reg.histogram("t_lat_seconds", &[0.5]).observe(0.25);
        let text = reg.render_text();
        let again = reg.render_text();
        assert_eq!(text, again, "exposition must be deterministic");
        assert!(text.contains("# TYPE t_a_total counter"));
        assert_eq!(text.matches("# TYPE t_a_total").count(), 1);
        assert!(text.contains("t_a_total{pool=\"p1\"} 2"));
        assert!(text.contains("t_a_total{pool=\"p2\"} 3"));
        assert!(text.contains("# TYPE t_depth gauge"));
        assert!(text.contains("t_depth 7"));
        assert!(text.contains("t_lat_seconds_bucket{le=\"0.5\"} 1"));
        assert!(text.contains("t_lat_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("t_lat_seconds_count 1"));
        assert_eq!(reg.counter_sum("t_a_total"), 5);
    }

    #[test]
    fn table_snapshot_has_scalar_rows() {
        let reg = Registry::new();
        reg.counter("t_rows_total").add(4);
        reg.histogram("t_rows_seconds", &[1.0]).observe(0.5);
        let table = reg.to_table("snapshot");
        let json = table.to_json();
        assert!(json.contains("t_rows_total"));
        assert!(json.contains("t_rows_seconds_count"));
        assert!(json.contains("t_rows_seconds_sum"));
    }
}
