//! Solve-phase tracing: a lightweight per-solve breakdown of where
//! time goes — wave compute, border reconcile, violation cancel,
//! global relabel, queue wait, session repair — plus the engine op
//! counters the paper's complexity claims are stated in.
//!
//! A [`PhaseBreakdown`] is a plain value: engines accumulate into it
//! with [`PhaseBreakdown::time`] / [`PhaseTimer`] / [`Span`] (no
//! atomics, no allocation), it rides the solve reports up to the
//! service reply, and [`record_phases`] flushes it into the global
//! registry at the solve boundary.  Fine-grained per-wave/per-stripe
//! instrumentation is gated behind the `obs-fine` cargo feature so the
//! inner loops compile to the uninstrumented code by default.

use crate::util::Timer;

/// The traced solve phases, in display order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Device/wave super-steps (push-relabel waves, refine waves).
    WaveCompute,
    /// Cross-tile border reconciliation inside the tiled wave engine
    /// (recorded only with the `obs-fine` feature).
    BorderReconcile,
    /// Host-round violation cancelling.
    Cancel,
    /// Host-round global relabel (BFS + gap).
    GlobalRelabel,
    /// Time a job sat in the shard queue before a worker picked it up.
    QueueWait,
    /// Warm-session delta apply + state repair before the resumed solve.
    SessionRepair,
}

pub const N_PHASES: usize = 6;

impl Phase {
    pub const ALL: [Phase; N_PHASES] = [
        Phase::WaveCompute,
        Phase::BorderReconcile,
        Phase::Cancel,
        Phase::GlobalRelabel,
        Phase::QueueWait,
        Phase::SessionRepair,
    ];

    #[inline]
    pub fn index(self) -> usize {
        match self {
            Phase::WaveCompute => 0,
            Phase::BorderReconcile => 1,
            Phase::Cancel => 2,
            Phase::GlobalRelabel => 3,
            Phase::QueueWait => 4,
            Phase::SessionRepair => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::WaveCompute => "wave_compute",
            Phase::BorderReconcile => "border_reconcile",
            Phase::Cancel => "cancel",
            Phase::GlobalRelabel => "global_relabel",
            Phase::QueueWait => "queue_wait",
            Phase::SessionRepair => "session_repair",
        }
    }
}

/// Per-solve phase breakdown plus engine op counters.  A plain value —
/// cheap to copy, merge, and compare; `Default` is the zero breakdown.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseBreakdown {
    seconds: [f64; N_PHASES],
    pub pushes: u64,
    pub relabels: u64,
    pub global_relabels: u64,
    /// Gap-relabel events: a height bucket emptied and the stranded
    /// nodes above it were lifted in one batch.
    pub gap_relabels: u64,
    /// Weighted stripe-boundary re-cuts (frontier levels / host rounds).
    pub rebalances: u64,
    pub waves: u64,
}

impl PhaseBreakdown {
    #[inline]
    pub fn add(&mut self, phase: Phase, secs: f64) {
        self.seconds[phase.index()] += secs;
    }

    #[inline]
    pub fn get(&self, phase: Phase) -> f64 {
        self.seconds[phase.index()]
    }

    /// Time a closure into `phase`.
    #[inline]
    pub fn time<T, F: FnOnce() -> T>(&mut self, phase: Phase, f: F) -> T {
        let t = Timer::start();
        let out = f();
        self.add(phase, t.elapsed());
        out
    }

    pub fn merge(&mut self, other: &PhaseBreakdown) {
        for i in 0..N_PHASES {
            self.seconds[i] += other.seconds[i];
        }
        self.pushes += other.pushes;
        self.relabels += other.relabels;
        self.global_relabels += other.global_relabels;
        self.gap_relabels += other.gap_relabels;
        self.rebalances += other.rebalances;
        self.waves += other.waves;
    }

    /// Sum of all phase times (seconds).
    pub fn total_seconds(&self) -> f64 {
        self.seconds.iter().sum()
    }

    pub fn is_zero(&self) -> bool {
        self.total_seconds() == 0.0
            && self.pushes == 0
            && self.relabels == 0
            && self.global_relabels == 0
            && self.gap_relabels == 0
            && self.rebalances == 0
            && self.waves == 0
    }

    /// `(phase name, seconds)` pairs in display order, zeros included.
    pub fn entries(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        Phase::ALL.iter().map(move |&p| (p.name(), self.get(p)))
    }

    /// Compact one-line rendering of the nonzero phases, e.g.
    /// `wave_compute=1.2ms global_relabel=340µs`.
    pub fn fmt_compact(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for (name, secs) in self.entries() {
            if secs > 0.0 {
                parts.push(format!("{name}={}", crate::util::stats::fmt_duration(secs)));
            }
        }
        if parts.is_empty() {
            "(no phases)".to_string()
        } else {
            parts.join(" ")
        }
    }
}

/// Free-standing phase stopwatch for code paths where the breakdown
/// isn't borrowable across the timed region.
#[derive(Debug)]
pub struct PhaseTimer {
    phase: Phase,
    timer: Timer,
}

impl PhaseTimer {
    pub fn start(phase: Phase) -> Self {
        Self {
            phase,
            timer: Timer::start(),
        }
    }

    /// Stop and accumulate into `into`; returns the elapsed seconds.
    pub fn stop(self, into: &mut PhaseBreakdown) -> f64 {
        let secs = self.timer.elapsed();
        into.add(self.phase, secs);
        secs
    }
}

/// RAII span: accumulates into the borrowed breakdown on drop.
#[derive(Debug)]
pub struct Span<'a> {
    breakdown: &'a mut PhaseBreakdown,
    phase: Phase,
    timer: Timer,
}

impl<'a> Span<'a> {
    pub fn enter(breakdown: &'a mut PhaseBreakdown, phase: Phase) -> Self {
        Self {
            breakdown,
            phase,
            timer: Timer::start(),
        }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        self.breakdown.add(self.phase, self.timer.elapsed());
    }
}

/// Record one already-measured phase duration straight into the global
/// registry — for call sites (periodic global relabels inside the CSR
/// engines) that have no breakdown in scope.
pub fn record_phase_secs(family: &str, phase: Phase, secs: f64) {
    if secs > 0.0 {
        super::global()
            .counter(&format!(
                "flowmatch_phase_micros_total{{family=\"{family}\",phase=\"{}\"}}",
                phase.name()
            ))
            .add_secs(secs);
    }
}

/// Flush a solve's breakdown into the global registry under
/// `family` (`"grid"`, `"assignment"`, ...): per-phase micro-second
/// counters plus the op counters.  Called once per solve — a handful
/// of relaxed adds plus one registry lookup per nonzero series.
pub fn record_phases(family: &str, b: &PhaseBreakdown) {
    let reg = super::global();
    for (name, secs) in b.entries() {
        if secs > 0.0 {
            reg.counter(&format!(
                "flowmatch_phase_micros_total{{family=\"{family}\",phase=\"{name}\"}}"
            ))
            .add_secs(secs);
        }
    }
    if b.pushes > 0 {
        reg.counter(&format!("flowmatch_engine_pushes_total{{family=\"{family}\"}}"))
            .add(b.pushes);
    }
    if b.relabels > 0 {
        reg.counter(&format!(
            "flowmatch_engine_relabels_total{{family=\"{family}\"}}"
        ))
        .add(b.relabels);
    }
    if b.global_relabels > 0 {
        reg.counter(&format!(
            "flowmatch_engine_global_relabels_total{{family=\"{family}\"}}"
        ))
        .add(b.global_relabels);
    }
    if b.gap_relabels > 0 {
        reg.counter(&format!(
            "flowmatch_engine_gap_relabels_total{{family=\"{family}\"}}"
        ))
        .add(b.gap_relabels);
    }
    if b.rebalances > 0 {
        reg.counter(&format!(
            "flowmatch_engine_rebalances_total{{family=\"{family}\"}}"
        ))
        .add(b.rebalances);
    }
    if b.waves > 0 {
        reg.counter(&format!("flowmatch_engine_waves_total{{family=\"{family}\"}}"))
            .add(b.waves);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_merge_total() {
        let mut a = PhaseBreakdown::default();
        a.add(Phase::WaveCompute, 0.5);
        a.add(Phase::Cancel, 0.25);
        a.pushes = 10;
        let mut b = PhaseBreakdown::default();
        b.add(Phase::WaveCompute, 0.5);
        b.relabels = 3;
        a.merge(&b);
        assert_eq!(a.get(Phase::WaveCompute), 1.0);
        assert_eq!(a.get(Phase::Cancel), 0.25);
        assert_eq!(a.total_seconds(), 1.25);
        assert_eq!(a.pushes, 10);
        assert_eq!(a.relabels, 3);
        assert!(!a.is_zero());
        assert!(PhaseBreakdown::default().is_zero());
    }

    #[test]
    fn timers_accumulate_into_the_right_phase() {
        let mut b = PhaseBreakdown::default();
        b.time(Phase::GlobalRelabel, || std::thread::sleep(
            std::time::Duration::from_millis(2),
        ));
        let t = PhaseTimer::start(Phase::QueueWait);
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.stop(&mut b);
        {
            let _span = Span::enter(&mut b, Phase::Cancel);
        }
        assert!(b.get(Phase::GlobalRelabel) >= 0.002);
        assert!(b.get(Phase::QueueWait) >= 0.001);
        assert!(b.get(Phase::Cancel) >= 0.0);
        assert_eq!(b.get(Phase::WaveCompute), 0.0);
        assert!(b.fmt_compact().contains("global_relabel="));
    }

    #[test]
    fn record_phases_lands_in_global_registry() {
        let mut b = PhaseBreakdown::default();
        b.add(Phase::WaveCompute, 0.125);
        b.pushes = 7;
        b.gap_relabels = 3;
        b.rebalances = 2;
        let reg = crate::obs::global();
        let phase_name =
            "flowmatch_phase_micros_total{family=\"test_phase\",phase=\"wave_compute\"}";
        let push_name = "flowmatch_engine_pushes_total{family=\"test_phase\"}";
        let gap_name = "flowmatch_engine_gap_relabels_total{family=\"test_phase\"}";
        let reb_name = "flowmatch_engine_rebalances_total{family=\"test_phase\"}";
        let before_phase = reg.counter_value(phase_name).unwrap_or(0);
        let before_push = reg.counter_value(push_name).unwrap_or(0);
        let before_gap = reg.counter_value(gap_name).unwrap_or(0);
        let before_reb = reg.counter_value(reb_name).unwrap_or(0);
        record_phases("test_phase", &b);
        assert_eq!(reg.counter_value(phase_name), Some(before_phase + 125_000));
        assert_eq!(reg.counter_value(push_name), Some(before_push + 7));
        assert_eq!(reg.counter_value(gap_name), Some(before_gap + 3));
        assert_eq!(reg.counter_value(reb_name), Some(before_reb + 2));
    }
}
