//! In-tree benchmark harness (no `criterion` in the offline image).
//!
//! Each `cargo bench` target is a plain binary (`harness = false`) that
//! builds a [`Table`] of rows, where every cell is either a measured
//! [`Summary`] or a derived count.  Output is a markdown table — the exact
//! rows that EXPERIMENTS.md records for each paper table/figure.
//!
//! Measurement protocol: `warmup` untimed runs, then `samples` timed runs
//! of the closure; the closure returns an opaque value that is black-boxed
//! to keep the optimizer honest.

use std::hint::black_box;
use std::time::Instant;

use crate::util::stats::{fmt_duration, Summary};

/// Measurement configuration.
#[derive(Debug, Clone, Copy)]
pub struct Measure {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Measure {
    fn default() -> Self {
        Self {
            warmup: 2,
            samples: 7,
        }
    }
}

impl Measure {
    pub fn quick() -> Self {
        Self {
            warmup: 1,
            samples: 3,
        }
    }

    /// Honour `FLOWMATCH_BENCH_FAST=1` (CI smoke mode).
    pub fn from_env(self) -> Self {
        if std::env::var("FLOWMATCH_BENCH_FAST").as_deref() == Ok("1") {
            Self::quick()
        } else {
            self
        }
    }

    /// Time `f`, returning per-run seconds.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Vec<f64> {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut out = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            black_box(f());
            out.push(t.elapsed().as_secs_f64());
        }
        out
    }
}

/// One table cell.
#[derive(Debug, Clone)]
pub enum Cell {
    Text(String),
    Int(i64),
    Float(f64),
    /// Time summary rendered as "mean ± stddev".
    Time(Summary),
    Missing,
}

impl Cell {
    fn render(&self) -> String {
        match self {
            Cell::Text(s) => s.clone(),
            Cell::Int(v) => crate::util::stats::fmt_count(*v),
            Cell::Float(v) => format!("{v:.3}"),
            Cell::Time(s) => format!("{} ± {}", fmt_duration(s.mean), fmt_duration(s.stddev)),
            Cell::Missing => "—".to_string(),
        }
    }
}

impl From<&str> for Cell {
    fn from(s: &str) -> Self {
        Cell::Text(s.to_string())
    }
}

impl From<String> for Cell {
    fn from(s: String) -> Self {
        Cell::Text(s)
    }
}

impl From<i64> for Cell {
    fn from(v: i64) -> Self {
        Cell::Int(v)
    }
}

impl From<f64> for Cell {
    fn from(v: f64) -> Self {
        Cell::Float(v)
    }
}

impl From<Summary> for Cell {
    fn from(s: Summary) -> Self {
        Cell::Time(s)
    }
}

/// A bench-result table, rendered as markdown on `print`.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<Cell>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<Cell>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut cols: Vec<Vec<String>> = vec![self.headers.clone()];
        for row in &self.rows {
            cols.push(row.iter().map(Cell::render).collect());
        }
        let widths: Vec<usize> = (0..self.headers.len())
            .map(|c| cols.iter().map(|r| r[c].chars().count()).max().unwrap_or(1))
            .collect();
        let line = |cells: &[String]| {
            let body: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |", body.join(" | "))
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&line(&cols[0]));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&sep));
        out.push('\n');
        for r in &cols[1..] {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render the table as a JSON object (no `serde` in the image):
    /// `{"title": ..., "headers": [...], "rows": [[cell, ...], ...]}`.
    /// Time cells become objects carrying the full summary.
    pub fn to_json(&self) -> String {
        let headers: Vec<String> = self.headers.iter().map(|h| json_string(h)).collect();
        let rows: Vec<String> = self
            .rows
            .iter()
            .map(|r| {
                let cells: Vec<String> = r.iter().map(Cell::to_json).collect();
                format!("[{}]", cells.join(","))
            })
            .collect();
        format!(
            "{{\"title\":{},\"headers\":[{}],\"rows\":[{}]}}",
            json_string(&self.title),
            headers.join(","),
            rows.join(",")
        )
    }
}

impl Cell {
    fn to_json(&self) -> String {
        match self {
            Cell::Text(s) => json_string(s),
            Cell::Int(v) => v.to_string(),
            Cell::Float(v) => json_f64(*v),
            Cell::Time(s) => format!(
                "{{\"mean\":{},\"stddev\":{},\"min\":{},\"p50\":{},\"p90\":{},\"p95\":{},\"p99\":{},\"max\":{},\"count\":{}}}",
                json_f64(s.mean),
                json_f64(s.stddev),
                json_f64(s.min),
                json_f64(s.p50),
                json_f64(s.p90),
                json_f64(s.p95),
                json_f64(s.p99),
                json_f64(s.max),
                s.count
            ),
            Cell::Missing => "null".to_string(),
        }
    }
}

fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// f64 to JSON (JSON has no NaN/Inf; map them to null).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Write tables as a JSON array to `path`, creating parent directories.
/// Every bench that sweeps a tunable emits one of these so later PRs
/// have a machine-readable perf trajectory to diff against.
pub fn write_json(path: &std::path::Path, tables: &[&Table]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let body: Vec<String> = tables.iter().map(|t| t.to_json()).collect();
    std::fs::write(path, format!("[{}]\n", body.join(",\n")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_requested_samples() {
        let m = Measure {
            warmup: 1,
            samples: 5,
        };
        let times = m.run(|| 1 + 1);
        assert_eq!(times.len(), 5);
        assert!(times.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("demo", &["name", "n", "time"]);
        t.row(vec![
            "fifo".into(),
            Cell::Int(1234),
            Cell::Time(Summary::of(&[0.001, 0.002]).unwrap()),
        ]);
        let s = t.render();
        assert!(s.contains("### demo"));
        assert!(s.contains("| fifo"));
        assert!(s.contains("1_234"));
        assert!(s.contains("ms"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn json_escapes_and_shapes() {
        let mut t = Table::new("q\"t\\n", &["name", "n", "time"]);
        t.row(vec![
            "se\tq".into(),
            Cell::Int(-3),
            Cell::Time(Summary::of(&[0.5, 1.5]).unwrap()),
        ]);
        t.row(vec![Cell::Missing, Cell::Float(0.25), "x".into()]);
        let s = t.to_json();
        assert!(s.starts_with("{\"title\":\"q\\\"t\\\\n\""), "{s}");
        assert!(s.contains("\"headers\":[\"name\",\"n\",\"time\"]"), "{s}");
        assert!(s.contains("\"se\\tq\",-3,{\"mean\":1"), "{s}");
        assert!(s.contains("null,0.25,\"x\""), "{s}");
    }

    #[test]
    fn json_written_to_disk() {
        let mut t = Table::new("disk", &["a"]);
        t.row(vec![Cell::Int(7)]);
        let dir = std::env::temp_dir().join("flowmatch_benchkit_test");
        let path = dir.join("nested").join("out.json");
        write_json(&path, &[&t]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with('['));
        assert!(text.contains("\"title\":\"disk\""));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
