//! Request traces for the batched assignment service (E7): a stream of
//! assignment instances with arrival offsets, modelling the real-time
//! optical-flow use the paper's §6 targets (one matching problem per
//! frame pair at a fixed frame rate).

use crate::graph::AssignmentInstance;
use crate::util::Rng;

use super::bipartite_gen::{geometric_costs, uniform_costs};

/// Trace parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub requests: usize,
    /// Instance size (paper: n <= 30).
    pub n: usize,
    /// Max weight (paper: 100).
    pub max_weight: i64,
    /// Inter-arrival gap in seconds (1/fps); 0 = closed-loop.
    pub arrival_gap: f64,
    /// Fraction of geometric (optical-flow-like) instances.
    pub geometric_frac: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            requests: 50,
            n: 30,
            max_weight: 100,
            arrival_gap: 0.05, // 20 fps, the paper's real-time bar
            geometric_frac: 0.5,
        }
    }
}

/// One request of the trace.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    /// Arrival time offset from trace start, seconds.
    pub arrival: f64,
    pub instance: AssignmentInstance,
}

/// A generated trace.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub requests: Vec<Request>,
}

impl RequestTrace {
    pub fn generate(rng: &mut Rng, cfg: &TraceConfig) -> Self {
        let requests = (0..cfg.requests)
            .map(|id| {
                let instance = if rng.chance(cfg.geometric_frac) {
                    geometric_costs(rng, cfg.n, 3.0, cfg.max_weight)
                } else {
                    uniform_costs(rng, cfg.n, cfg.max_weight)
                };
                Request {
                    id,
                    arrival: id as f64 * cfg.arrival_gap,
                    instance,
                }
            })
            .collect();
        Self { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shape_and_arrivals() {
        let mut rng = Rng::seeded(21);
        let cfg = TraceConfig {
            requests: 10,
            n: 8,
            ..Default::default()
        };
        let trace = RequestTrace::generate(&mut rng, &cfg);
        assert_eq!(trace.len(), 10);
        assert!(trace
            .requests
            .windows(2)
            .all(|w| w[1].arrival >= w[0].arrival));
        assert!(trace.requests.iter().all(|r| r.instance.n == 8));
    }
}
