//! Request traces for the solver services (E7/E9): a stream of
//! assignment instances with arrival offsets, modelling the real-time
//! optical-flow use the paper's §6 targets (one matching problem per
//! frame pair at a fixed frame rate), plus the mixed grid+assignment
//! traces the sharded solver pool is sized against (small real-time
//! matchings interleaved with heavyweight grid max-flow solves).

use crate::graph::{AssignmentInstance, GridNetwork};
use crate::util::Rng;

use super::bipartite_gen::{geometric_costs, uniform_costs};
use super::grid_gen::random_grid;

/// Trace parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub requests: usize,
    /// Instance size (paper: n <= 30).
    pub n: usize,
    /// Max weight (paper: 100).
    pub max_weight: i64,
    /// Inter-arrival gap in seconds (1/fps); 0 = closed-loop.
    pub arrival_gap: f64,
    /// Fraction of geometric (optical-flow-like) instances.
    pub geometric_frac: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            requests: 50,
            n: 30,
            max_weight: 100,
            arrival_gap: 0.05, // 20 fps, the paper's real-time bar
            geometric_frac: 0.5,
        }
    }
}

/// One request of the trace.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    /// Arrival time offset from trace start, seconds.
    pub arrival: f64,
    pub instance: AssignmentInstance,
}

/// A generated trace.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub requests: Vec<Request>,
}

impl RequestTrace {
    pub fn generate(rng: &mut Rng, cfg: &TraceConfig) -> Self {
        let requests = (0..cfg.requests)
            .map(|id| {
                let instance = if rng.chance(cfg.geometric_frac) {
                    geometric_costs(rng, cfg.n, 3.0, cfg.max_weight)
                } else {
                    uniform_costs(rng, cfg.n, cfg.max_weight)
                };
                Request {
                    id,
                    arrival: id as f64 * cfg.arrival_gap,
                    instance,
                }
            })
            .collect();
        Self { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// One request payload for the sharded solver pool: either of the
/// paper's two problem families behind a single submit API.
#[derive(Debug, Clone)]
pub enum ProblemInstance {
    Assignment(AssignmentInstance),
    Grid(GridNetwork),
}

impl ProblemInstance {
    /// Work units used by the pool's size-class sharding: cost-matrix
    /// cells for assignment (`n²`), grid cells for max-flow.
    pub fn work_units(&self) -> usize {
        match self {
            ProblemInstance::Assignment(a) => a.n * a.n,
            ProblemInstance::Grid(g) => g.cells(),
        }
    }

    pub fn family(&self) -> &'static str {
        match self {
            ProblemInstance::Assignment(_) => "assignment",
            ProblemInstance::Grid(_) => "grid",
        }
    }
}

/// Mixed-trace parameters: an assignment stream (the §6 real-time
/// workload) interleaved with a grid max-flow stream, including a
/// periodic oversized grid so the shard scheduler has something to keep
/// out of the real-time lane.
#[derive(Debug, Clone)]
pub struct MixedTraceConfig {
    /// The assignment sub-stream (requests, n, fps, ...).
    pub assign: TraceConfig,
    /// Number of grid max-flow requests.
    pub grid_requests: usize,
    /// Grid side length (height = width).
    pub grid_size: usize,
    /// Max arc capacity of generated grids.
    pub grid_max_cap: i64,
    /// Inter-arrival gap of the grid sub-stream, seconds; 0 = closed-loop.
    pub grid_arrival_gap: f64,
    /// Every `large_every`-th grid request uses `large_size` instead of
    /// `grid_size` (0 disables the oversized requests).
    pub large_every: usize,
    pub large_size: usize,
    /// Per-request deadline budget in seconds, stamped on every request
    /// of both sub-streams; 0 = no deadlines.
    pub deadline: f64,
}

impl Default for MixedTraceConfig {
    fn default() -> Self {
        Self {
            assign: TraceConfig::default(),
            grid_requests: 8,
            grid_size: 24,
            grid_max_cap: 16,
            grid_arrival_gap: 0.3,
            large_every: 4,
            large_size: 48,
            deadline: 0.0,
        }
    }
}

/// One request of a mixed trace.  `id` indexes into
/// [`MixedTrace::requests`] (assigned after the arrival-order merge).
#[derive(Debug, Clone)]
pub struct MixedRequest {
    pub id: usize,
    /// Arrival time offset from trace start, seconds.
    pub arrival: f64,
    /// Deadline budget in seconds from submission, if any.
    pub deadline: Option<f64>,
    pub instance: ProblemInstance,
}

/// A generated mixed trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct MixedTrace {
    pub requests: Vec<MixedRequest>,
}

impl MixedTrace {
    pub fn generate(rng: &mut Rng, cfg: &MixedTraceConfig) -> Self {
        let deadline = (cfg.deadline > 0.0).then_some(cfg.deadline);
        let assign = RequestTrace::generate(rng, &cfg.assign);
        let mut requests: Vec<MixedRequest> = assign
            .requests
            .into_iter()
            .map(|r| MixedRequest {
                id: 0,
                arrival: r.arrival,
                deadline,
                instance: ProblemInstance::Assignment(r.instance),
            })
            .collect();
        for k in 0..cfg.grid_requests {
            let size = if cfg.large_every > 0 && (k + 1) % cfg.large_every == 0 {
                cfg.large_size
            } else {
                cfg.grid_size
            };
            let net = random_grid(rng, size, size, cfg.grid_max_cap, 0.25, 0.25);
            requests.push(MixedRequest {
                id: 0,
                arrival: k as f64 * cfg.grid_arrival_gap,
                deadline,
                instance: ProblemInstance::Grid(net),
            });
        }
        // Stable sort: at equal arrival the assignment request keeps its
        // place ahead of the grid request, so traces are reproducible.
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).expect("NaN arrival"));
        for (id, req) in requests.iter_mut().enumerate() {
            req.id = id;
        }
        Self { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn assignment_count(&self) -> usize {
        self.requests
            .iter()
            .filter(|r| matches!(r.instance, ProblemInstance::Assignment(_)))
            .count()
    }

    pub fn grid_count(&self) -> usize {
        self.len() - self.assignment_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shape_and_arrivals() {
        let mut rng = Rng::seeded(21);
        let cfg = TraceConfig {
            requests: 10,
            n: 8,
            ..Default::default()
        };
        let trace = RequestTrace::generate(&mut rng, &cfg);
        assert_eq!(trace.len(), 10);
        assert!(trace
            .requests
            .windows(2)
            .all(|w| w[1].arrival >= w[0].arrival));
        assert!(trace.requests.iter().all(|r| r.instance.n == 8));
    }

    #[test]
    fn mixed_trace_interleaves_and_sorts() {
        let mut rng = Rng::seeded(33);
        let cfg = MixedTraceConfig {
            assign: TraceConfig {
                requests: 6,
                n: 8,
                arrival_gap: 0.1,
                ..Default::default()
            },
            grid_requests: 4,
            grid_size: 6,
            grid_arrival_gap: 0.15,
            large_every: 2,
            large_size: 10,
            ..Default::default()
        };
        let trace = MixedTrace::generate(&mut rng, &cfg);
        assert_eq!(trace.len(), 10);
        assert_eq!(trace.assignment_count(), 6);
        assert_eq!(trace.grid_count(), 4);
        assert!(trace
            .requests
            .windows(2)
            .all(|w| w[1].arrival >= w[0].arrival));
        assert!(trace.requests.iter().enumerate().all(|(i, r)| r.id == i));
        // Every second grid is the oversized one.
        let sizes: Vec<usize> = trace
            .requests
            .iter()
            .filter_map(|r| match &r.instance {
                ProblemInstance::Grid(g) => Some(g.height),
                _ => None,
            })
            .collect();
        assert!(sizes.contains(&6) && sizes.contains(&10));
    }

    #[test]
    fn work_units_by_family() {
        let a = ProblemInstance::Assignment(AssignmentInstance::new(4, vec![0; 16]));
        assert_eq!(a.work_units(), 16);
        assert_eq!(a.family(), "assignment");
        let g = ProblemInstance::Grid(GridNetwork::zeros(3, 5));
        assert_eq!(g.work_units(), 15);
        assert_eq!(g.family(), "grid");
    }
}
