//! Request traces for the solver services (E7/E9): a stream of
//! assignment instances with arrival offsets, modelling the real-time
//! optical-flow use the paper's §6 targets (one matching problem per
//! frame pair at a fixed frame rate), plus the mixed grid+assignment
//! traces the sharded solver pool is sized against (small real-time
//! matchings interleaved with heavyweight grid max-flow solves).

use crate::graph::{AssignmentInstance, GridNetwork};
use crate::gridflow::CapacityDelta;
use crate::util::Rng;

use super::bipartite_gen::{geometric_costs, uniform_costs};
use super::grid_gen::random_grid;

/// Trace parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub requests: usize,
    /// Instance size (paper: n <= 30).
    pub n: usize,
    /// Max weight (paper: 100).
    pub max_weight: i64,
    /// Inter-arrival gap in seconds (1/fps); 0 = closed-loop.
    pub arrival_gap: f64,
    /// Fraction of geometric (optical-flow-like) instances.
    pub geometric_frac: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            requests: 50,
            n: 30,
            max_weight: 100,
            arrival_gap: 0.05, // 20 fps, the paper's real-time bar
            geometric_frac: 0.5,
        }
    }
}

/// One request of the trace.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    /// Arrival time offset from trace start, seconds.
    pub arrival: f64,
    pub instance: AssignmentInstance,
}

/// A generated trace.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub requests: Vec<Request>,
}

impl RequestTrace {
    pub fn generate(rng: &mut Rng, cfg: &TraceConfig) -> Self {
        let requests = (0..cfg.requests)
            .map(|id| {
                let instance = if rng.chance(cfg.geometric_frac) {
                    geometric_costs(rng, cfg.n, 3.0, cfg.max_weight)
                } else {
                    uniform_costs(rng, cfg.n, cfg.max_weight)
                };
                Request {
                    id,
                    arrival: id as f64 * cfg.arrival_gap,
                    instance,
                }
            })
            .collect();
        Self { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// One request payload for the sharded solver pool: either of the
/// paper's two problem families behind a single submit API.
#[derive(Debug, Clone)]
pub enum ProblemInstance {
    Assignment(AssignmentInstance),
    Grid(GridNetwork),
}

impl ProblemInstance {
    /// Work units used by the pool's size-class sharding: cost-matrix
    /// cells for assignment (`n²`), grid cells for max-flow.
    pub fn work_units(&self) -> usize {
        match self {
            ProblemInstance::Assignment(a) => a.n * a.n,
            ProblemInstance::Grid(g) => g.cells(),
        }
    }

    pub fn family(&self) -> &'static str {
        match self {
            ProblemInstance::Assignment(_) => "assignment",
            ProblemInstance::Grid(_) => "grid",
        }
    }
}

/// Mixed-trace parameters: an assignment stream (the §6 real-time
/// workload) interleaved with a grid max-flow stream, including a
/// periodic oversized grid so the shard scheduler has something to keep
/// out of the real-time lane.
#[derive(Debug, Clone)]
pub struct MixedTraceConfig {
    /// The assignment sub-stream (requests, n, fps, ...).
    pub assign: TraceConfig,
    /// Number of grid max-flow requests.
    pub grid_requests: usize,
    /// Grid side length (height = width).
    pub grid_size: usize,
    /// Max arc capacity of generated grids.
    pub grid_max_cap: i64,
    /// Inter-arrival gap of the grid sub-stream, seconds; 0 = closed-loop.
    pub grid_arrival_gap: f64,
    /// Every `large_every`-th grid request uses `large_size` instead of
    /// `grid_size` (0 disables the oversized requests).
    pub large_every: usize,
    pub large_size: usize,
    /// Per-request deadline budget in seconds, stamped on every request
    /// of both sub-streams; 0 = no deadlines.
    pub deadline: f64,
}

impl Default for MixedTraceConfig {
    fn default() -> Self {
        Self {
            assign: TraceConfig::default(),
            grid_requests: 8,
            grid_size: 24,
            grid_max_cap: 16,
            grid_arrival_gap: 0.3,
            large_every: 4,
            large_size: 48,
            deadline: 0.0,
        }
    }
}

/// One request of a mixed trace.  `id` indexes into
/// [`MixedTrace::requests`] (assigned after the arrival-order merge).
#[derive(Debug, Clone)]
pub struct MixedRequest {
    pub id: usize,
    /// Arrival time offset from trace start, seconds.
    pub arrival: f64,
    /// Deadline budget in seconds from submission, if any.
    pub deadline: Option<f64>,
    pub instance: ProblemInstance,
}

/// A generated mixed trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct MixedTrace {
    pub requests: Vec<MixedRequest>,
}

impl MixedTrace {
    pub fn generate(rng: &mut Rng, cfg: &MixedTraceConfig) -> Self {
        let deadline = (cfg.deadline > 0.0).then_some(cfg.deadline);
        let assign = RequestTrace::generate(rng, &cfg.assign);
        let mut requests: Vec<MixedRequest> = assign
            .requests
            .into_iter()
            .map(|r| MixedRequest {
                id: 0,
                arrival: r.arrival,
                deadline,
                instance: ProblemInstance::Assignment(r.instance),
            })
            .collect();
        for k in 0..cfg.grid_requests {
            let size = if cfg.large_every > 0 && (k + 1) % cfg.large_every == 0 {
                cfg.large_size
            } else {
                cfg.grid_size
            };
            let net = random_grid(rng, size, size, cfg.grid_max_cap, 0.25, 0.25);
            requests.push(MixedRequest {
                id: 0,
                arrival: k as f64 * cfg.grid_arrival_gap,
                deadline,
                instance: ProblemInstance::Grid(net),
            });
        }
        // Stable sort: at equal arrival the assignment request keeps its
        // place ahead of the grid request, so traces are reproducible.
        requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).expect("NaN arrival"));
        for (id, req) in requests.iter_mut().enumerate() {
            req.id = id;
        }
        Self { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn assignment_count(&self) -> usize {
        self.requests
            .iter()
            .filter(|r| matches!(r.instance, ProblemInstance::Assignment(_)))
            .count()
    }

    pub fn grid_count(&self) -> usize {
        self.len() - self.assignment_count()
    }
}

/// Delta-trace parameters for warm-start sessions (E13): each session
/// opens a grid instance, then streams small capacity-edit updates
/// against it.
#[derive(Debug, Clone)]
pub struct DeltaTraceConfig {
    /// Concurrently open sessions (interleaved round-robin, so sticky
    /// routing and the LRU store see several at once).
    pub sessions: usize,
    /// Updates per session after the open.
    pub updates_per_session: usize,
    /// Capacity edits bundled into each update.
    pub edits_per_update: usize,
    /// Grid side length (height = width).
    pub grid_size: usize,
    /// Max arc capacity, for both the base grids and the edits.
    pub grid_max_cap: i64,
    /// Inter-arrival gap in seconds; 0 = closed-loop.
    pub arrival_gap: f64,
    /// Per-request deadline budget in seconds; 0 = no deadlines.
    pub deadline: f64,
}

impl Default for DeltaTraceConfig {
    fn default() -> Self {
        Self {
            sessions: 4,
            updates_per_session: 8,
            edits_per_update: 4,
            grid_size: 24,
            grid_max_cap: 16,
            arrival_gap: 0.0,
            deadline: 0.0,
        }
    }
}

/// What one delta-trace request asks of the service.
#[derive(Debug, Clone)]
pub enum DeltaKind {
    /// Cold-solve this instance and open a warm-start session.
    Open(GridNetwork),
    /// Apply these edits to the session's graph and re-solve.
    Update(Vec<CapacityDelta>),
}

/// One request of a delta trace.  `session` indexes the trace's logical
/// sessions (the service assigns its own session ids at open time).
#[derive(Debug, Clone)]
pub struct DeltaRequest {
    pub id: usize,
    /// Arrival time offset from trace start, seconds.
    pub arrival: f64,
    /// Deadline budget in seconds from submission, if any.
    pub deadline: Option<f64>,
    pub session: usize,
    pub kind: DeltaKind,
}

/// A generated delta trace, with the fully-materialised edited instance
/// after every request — the cold-solve oracle the warm replies must
/// match bit-for-bit, and the fallback instance a client resubmits when
/// its session was evicted.
#[derive(Debug, Clone)]
pub struct DeltaTrace {
    pub requests: Vec<DeltaRequest>,
    /// `edited[k]` is the instance as of request `k` (for an open, the
    /// opened instance itself).
    pub edited: Vec<GridNetwork>,
}

impl DeltaTrace {
    pub fn generate(rng: &mut Rng, cfg: &DeltaTraceConfig) -> Self {
        assert!(cfg.sessions > 0 && cfg.grid_size > 0);
        let deadline = (cfg.deadline > 0.0).then_some(cfg.deadline);
        // `cur[s]` tracks session s's graph as the edits accumulate;
        // CapacityDelta::apply_to *defines* the edit semantics, so the
        // materialised oracle and the service's warm repair agree.
        let mut cur: Vec<GridNetwork> = (0..cfg.sessions)
            .map(|_| {
                random_grid(
                    rng,
                    cfg.grid_size,
                    cfg.grid_size,
                    cfg.grid_max_cap,
                    0.25,
                    0.25,
                )
            })
            .collect();
        let mut requests = Vec::new();
        let mut edited = Vec::new();
        for (s, net) in cur.iter().enumerate() {
            requests.push(DeltaRequest {
                id: 0,
                arrival: 0.0,
                deadline,
                session: s,
                kind: DeltaKind::Open(net.clone()),
            });
            edited.push(net.clone());
        }
        for _ in 0..cfg.updates_per_session {
            for (s, net) in cur.iter_mut().enumerate() {
                let deltas: Vec<CapacityDelta> = (0..cfg.edits_per_update)
                    .map(|_| random_delta(rng, net, cfg.grid_max_cap))
                    .collect();
                for d in &deltas {
                    d.apply_to(net).expect("generated deltas are in-grid");
                }
                requests.push(DeltaRequest {
                    id: 0,
                    arrival: 0.0,
                    deadline,
                    session: s,
                    kind: DeltaKind::Update(deltas),
                });
                edited.push(net.clone());
            }
        }
        for (id, req) in requests.iter_mut().enumerate() {
            req.id = id;
            req.arrival = id as f64 * cfg.arrival_gap;
        }
        Self { requests, edited }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn update_count(&self) -> usize {
        self.requests
            .iter()
            .filter(|r| matches!(r.kind, DeltaKind::Update(_)))
            .count()
    }
}

/// A uniformly random in-grid capacity edit.
fn random_delta(rng: &mut Rng, net: &GridNetwork, max_cap: i64) -> CapacityDelta {
    let span = max_cap.max(0) as u64 + 1;
    loop {
        let i = (rng.next_u64() as usize) % net.height;
        let j = (rng.next_u64() as usize) % net.width;
        let cap = (rng.next_u64() % span) as i64;
        match rng.next_u64() % 4 {
            0 => return CapacityDelta::Source { i, j, cap },
            1 => return CapacityDelta::Sink { i, j, cap },
            _ => {
                let dir = (rng.next_u64() as usize) % 4;
                if net.neighbour(i, j, dir).is_some() {
                    return CapacityDelta::Arc { i, j, dir, cap };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shape_and_arrivals() {
        let mut rng = Rng::seeded(21);
        let cfg = TraceConfig {
            requests: 10,
            n: 8,
            ..Default::default()
        };
        let trace = RequestTrace::generate(&mut rng, &cfg);
        assert_eq!(trace.len(), 10);
        assert!(trace
            .requests
            .windows(2)
            .all(|w| w[1].arrival >= w[0].arrival));
        assert!(trace.requests.iter().all(|r| r.instance.n == 8));
    }

    #[test]
    fn mixed_trace_interleaves_and_sorts() {
        let mut rng = Rng::seeded(33);
        let cfg = MixedTraceConfig {
            assign: TraceConfig {
                requests: 6,
                n: 8,
                arrival_gap: 0.1,
                ..Default::default()
            },
            grid_requests: 4,
            grid_size: 6,
            grid_arrival_gap: 0.15,
            large_every: 2,
            large_size: 10,
            ..Default::default()
        };
        let trace = MixedTrace::generate(&mut rng, &cfg);
        assert_eq!(trace.len(), 10);
        assert_eq!(trace.assignment_count(), 6);
        assert_eq!(trace.grid_count(), 4);
        assert!(trace
            .requests
            .windows(2)
            .all(|w| w[1].arrival >= w[0].arrival));
        assert!(trace.requests.iter().enumerate().all(|(i, r)| r.id == i));
        // Every second grid is the oversized one.
        let sizes: Vec<usize> = trace
            .requests
            .iter()
            .filter_map(|r| match &r.instance {
                ProblemInstance::Grid(g) => Some(g.height),
                _ => None,
            })
            .collect();
        assert!(sizes.contains(&6) && sizes.contains(&10));
    }

    #[test]
    fn delta_trace_materialises_cumulative_edits() {
        let mut rng = Rng::seeded(44);
        let cfg = DeltaTraceConfig {
            sessions: 2,
            updates_per_session: 3,
            edits_per_update: 2,
            grid_size: 5,
            grid_max_cap: 9,
            arrival_gap: 0.01,
            ..Default::default()
        };
        let trace = DeltaTrace::generate(&mut rng, &cfg);
        assert_eq!(trace.len(), 2 + 2 * 3);
        assert_eq!(trace.edited.len(), trace.len());
        assert_eq!(trace.update_count(), 6);
        assert!(trace.requests.iter().enumerate().all(|(i, r)| r.id == i));
        assert!(matches!(trace.requests[0].kind, DeltaKind::Open(_)));
        assert!(matches!(trace.requests[1].kind, DeltaKind::Open(_)));
        // Re-applying each update's deltas to the session's previous
        // materialised instance reproduces the stored one: `edited` is
        // cumulative per session, in request order.
        for (k, req) in trace.requests.iter().enumerate() {
            let DeltaKind::Update(deltas) = &req.kind else {
                continue;
            };
            let prev = trace.requests[..k]
                .iter()
                .rposition(|r| r.session == req.session)
                .expect("every update follows its session's open");
            let mut net = trace.edited[prev].clone();
            for d in deltas {
                d.apply_to(&mut net).unwrap();
            }
            assert_eq!(net.cap, trace.edited[k].cap);
            assert_eq!(net.cap_source, trace.edited[k].cap_source);
            assert_eq!(net.cap_sink, trace.edited[k].cap_sink);
        }
    }

    #[test]
    fn work_units_by_family() {
        let a = ProblemInstance::Assignment(AssignmentInstance::new(4, vec![0; 16]));
        assert_eq!(a.work_units(), 16);
        assert_eq!(a.family(), "assignment");
        let g = ProblemInstance::Grid(GridNetwork::zeros(3, 5));
        assert_eq!(g.work_units(), 15);
        assert_eq!(g.family(), "grid");
    }
}
