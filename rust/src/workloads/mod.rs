//! Workload generators for every experiment row: grid instances
//! (random + segmentation-like, standing in for the CVIT grid-graph
//! datasets of Vineet & Narayanan), RMF-style layered CSR networks, random
//! bipartite cost matrices, and request traces for the service bench.

pub mod bipartite_gen;
pub mod grid_gen;
pub mod rmf;
pub mod traces;

pub use bipartite_gen::{geometric_costs, uniform_costs};
pub use grid_gen::{random_grid, segmentation_grid};
pub use rmf::rmf_network;
pub use traces::{
    DeltaKind, DeltaRequest, DeltaTrace, DeltaTraceConfig, MixedRequest, MixedTrace,
    MixedTraceConfig, ProblemInstance, RequestTrace, TraceConfig,
};
