//! Assignment-instance generators: the §6 workload (uniform costs ≤ C)
//! and a geometric family (points in the plane, weight = max_dist - dist)
//! that models the optical-flow feature-matching application.

use crate::graph::AssignmentInstance;
use crate::util::Rng;

/// Uniform weights in `[0, max_weight]` — the paper's §6 setting with
/// `max_weight = 100`.
pub fn uniform_costs(rng: &mut Rng, n: usize, max_weight: i64) -> AssignmentInstance {
    let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, max_weight)).collect();
    AssignmentInstance::new(n, w)
}

/// Geometric weights: two point clouds where Y is a jittered copy of X —
/// high weight for matching a point to its displaced twin (the optical
/// flow structure).  Weight = `scale * exp(-dist / bandwidth)`.
pub fn geometric_costs(rng: &mut Rng, n: usize, jitter: f64, scale: i64) -> AssignmentInstance {
    let xs: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64() * 100.0, rng.f64() * 100.0)).collect();
    let ys: Vec<(f64, f64)> = xs
        .iter()
        .map(|&(x, y)| {
            (
                x + (rng.f64() - 0.5) * 2.0 * jitter,
                y + (rng.f64() - 0.5) * 2.0 * jitter,
            )
        })
        .collect();
    let bandwidth = 25.0;
    let mut w = vec![0i64; n * n];
    for (i, &(ax, ay)) in xs.iter().enumerate() {
        for (j, &(bx, by)) in ys.iter().enumerate() {
            let d = ((ax - bx).powi(2) + (ay - by).powi(2)).sqrt();
            w[i * n + j] = ((scale as f64) * (-d / bandwidth).exp()).round() as i64;
        }
    }
    AssignmentInstance::new(n, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::{hungarian::Hungarian, AssignmentSolver};

    #[test]
    fn uniform_bounds_respected() {
        let mut rng = Rng::seeded(11);
        let inst = uniform_costs(&mut rng, 12, 100);
        assert!(inst.weights.iter().all(|&w| (0..=100).contains(&w)));
        assert_eq!(inst.n, 12);
    }

    #[test]
    fn geometric_prefers_identity_for_small_jitter() {
        let mut rng = Rng::seeded(13);
        let inst = geometric_costs(&mut rng, 10, 0.5, 1000);
        let r = Hungarian.solve(&inst).unwrap();
        // With tiny jitter the optimal matching is (almost always) the
        // identity permutation.
        let identity_hits = r
            .assignment
            .iter()
            .enumerate()
            .filter(|&(i, &y)| i == y)
            .count();
        assert!(identity_hits >= 8, "only {identity_hits}/10 identity");
    }
}
