//! Grid max-flow instance generators.
//!
//! `random_grid` draws independent capacities — the stress workload.
//! `segmentation_grid` mimics the §4 application: a smooth synthetic image
//! with two regions produces terminal capacities from unary likelihoods
//! and neighbour capacities from a contrast-sensitive smoothness term —
//! structurally the same instances the CUDA-cuts datasets contain.

use crate::graph::grid::{E, S};
use crate::graph::GridNetwork;
use crate::util::Rng;

/// Uniform random grid: interior caps in [0, max_cap], a `frac_source`
/// fraction of cells carries a source arc, `frac_sink` a sink arc.
pub fn random_grid(
    rng: &mut Rng,
    height: usize,
    width: usize,
    max_cap: i64,
    frac_source: f64,
    frac_sink: f64,
) -> GridNetwork {
    let mut net = GridNetwork::zeros(height, width);
    for i in 0..height {
        for j in 0..width {
            if i + 1 < height {
                net.set_neighbour_cap(i, j, S, rng.range_i64(0, max_cap));
                let cap_up = rng.range_i64(0, max_cap);
                net.set_neighbour_cap(i + 1, j, crate::graph::grid::N, cap_up);
            }
            if j + 1 < width {
                net.set_neighbour_cap(i, j, E, rng.range_i64(0, max_cap));
                let cap_left = rng.range_i64(0, max_cap);
                net.set_neighbour_cap(i, j + 1, crate::graph::grid::W, cap_left);
            }
            let c = net.cell(i, j);
            if rng.chance(frac_source) {
                net.cap_source[c] = rng.range_i64(1, max_cap.max(1));
            }
            if rng.chance(frac_sink) {
                net.cap_sink[c] = rng.range_i64(1, max_cap.max(1));
            }
        }
    }
    net
}

/// A synthetic two-region "image": intensities in [0, 255] with a smooth
/// blob of foreground, plus noise.  Returned row-major.
pub fn synthetic_image(rng: &mut Rng, height: usize, width: usize) -> Vec<u8> {
    let cy = height as f64 * (0.35 + 0.3 * rng.f64());
    let cx = width as f64 * (0.35 + 0.3 * rng.f64());
    let r = (height.min(width) as f64) * (0.2 + 0.15 * rng.f64());
    let mut img = vec![0u8; height * width];
    for i in 0..height {
        for j in 0..width {
            let d = ((i as f64 - cy).powi(2) + (j as f64 - cx).powi(2)).sqrt();
            let base = if d < r { 200.0 } else { 60.0 };
            let noise = rng.range_i64(-25, 25) as f64;
            img[i * width + j] = (base + noise).clamp(0.0, 255.0) as u8;
        }
    }
    img
}

/// Build the graph-cut instance for a two-label MRF over `img`
/// (Kolmogorov–Zabih / Boykov-Jolly construction):
///
/// * unary terms: likelihood of foreground (bright) vs background (dark)
///   become source/sink terminal capacities;
/// * pairwise terms: contrast-sensitive Potts `lambda * exp(-|dI|/sigma)`
///   become symmetric neighbour capacities.
pub fn segmentation_grid(img: &[u8], height: usize, width: usize, lambda: i64) -> GridNetwork {
    assert_eq!(img.len(), height * width);
    let mut net = GridNetwork::zeros(height, width);
    let sigma = 30.0f64;
    let pairwise = |a: u8, b: u8| -> i64 {
        let d = (a as f64 - b as f64).abs();
        ((lambda as f64) * (-d / sigma).exp()).round() as i64 + 1
    };
    for i in 0..height {
        for j in 0..width {
            let c = net.cell(i, j);
            let v = img[c] as i64;
            // Unary: distance to the two class means (fg=200, bg=60),
            // scaled to the capacity range.
            let fg_cost = (v - 200).abs() / 4;
            let bg_cost = (v - 60).abs() / 4;
            // Cheap-to-be-foreground pixels attach to the source.
            net.cap_source[c] = bg_cost; // cutting to bg costs this
            net.cap_sink[c] = fg_cost;
            if i + 1 < height {
                let w = pairwise(img[c], img[(i + 1) * width + j]);
                net.set_neighbour_cap(i, j, S, w);
                net.set_neighbour_cap(i + 1, j, crate::graph::grid::N, w);
            }
            if j + 1 < width {
                let w = pairwise(img[c], img[i * width + j + 1]);
                net.set_neighbour_cap(i, j, E, w);
                net.set_neighbour_cap(i, j + 1, crate::graph::grid::W, w);
            }
        }
    }
    net
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_grid_is_well_formed() {
        let mut rng = Rng::seeded(1);
        let net = random_grid(&mut rng, 6, 7, 10, 0.3, 0.3);
        assert_eq!(net.cells(), 42);
        // Border arcs zero.
        for j in 0..7 {
            assert_eq!(net.cap[net.arc(crate::graph::grid::N, 0, j)], 0);
        }
        assert!(net.excess_total() > 0);
        // Convertible and solvable.
        let g = net.to_flow_network();
        assert_eq!(g.node_count(), 44);
    }

    #[test]
    fn random_grid_deterministic_by_seed() {
        let a = random_grid(&mut Rng::seeded(7), 5, 5, 9, 0.4, 0.4);
        let b = random_grid(&mut Rng::seeded(7), 5, 5, 9, 0.4, 0.4);
        assert_eq!(a.cap, b.cap);
        assert_eq!(a.cap_source, b.cap_source);
    }

    #[test]
    fn synthetic_image_has_two_modes() {
        let mut rng = Rng::seeded(3);
        let img = synthetic_image(&mut rng, 16, 16);
        let bright = img.iter().filter(|&&v| v > 130).count();
        let dark = img.iter().filter(|&&v| v <= 130).count();
        assert!(bright > 8, "blob missing: {bright}");
        assert!(dark > 8, "background missing: {dark}");
    }

    #[test]
    fn segmentation_instance_attaches_terminals_by_intensity() {
        let mut rng = Rng::seeded(4);
        let img = synthetic_image(&mut rng, 12, 12);
        let net = segmentation_grid(&img, 12, 12, 20);
        // A bright pixel should have higher source capacity than sink.
        let bright = img.iter().position(|&v| v > 180).unwrap();
        assert!(net.cap_source[bright] > net.cap_sink[bright]);
        let dark = img.iter().position(|&v| v < 80).unwrap();
        assert!(net.cap_sink[dark] > net.cap_source[dark]);
    }
}
