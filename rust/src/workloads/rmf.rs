//! RMF-style layered networks (Goldberg–Rao "washington RMF" family):
//! `frames` square grids of side `a`, dense random arcs between
//! consecutive frames, source in the first frame, sink in the last.
//! The classic hard family for augmenting-path codes — the E2 stress
//! workload for the CSR engines.

use crate::graph::csr::{FlowNetwork, NetworkBuilder};
use crate::util::Rng;

/// Build an RMF-like network with `frames` frames of `a x a` nodes.
pub fn rmf_network(rng: &mut Rng, a: usize, frames: usize, max_cap: i64) -> FlowNetwork {
    assert!(a >= 2 && frames >= 2);
    let per = a * a;
    let n = per * frames + 2;
    let s = n - 2;
    let t = n - 1;
    let node = |f: usize, i: usize, j: usize| f * per + i * a + j;
    let mut b = NetworkBuilder::new(n, s, t);

    // In-frame grid arcs with large capacity (cheap lateral movement).
    for f in 0..frames {
        for i in 0..a {
            for j in 0..a {
                if i + 1 < a {
                    b.add_edge(node(f, i, j), node(f, i + 1, j), max_cap * 4, max_cap * 4);
                }
                if j + 1 < a {
                    b.add_edge(node(f, i, j), node(f, i, j + 1), max_cap * 4, max_cap * 4);
                }
            }
        }
    }
    // Between frames: a random permutation of a*a arcs with random caps —
    // the bottleneck structure.
    for f in 0..frames - 1 {
        let mut perm: Vec<usize> = (0..per).collect();
        rng.shuffle(&mut perm);
        for (k, &p) in perm.iter().enumerate() {
            let u = f * per + k;
            let v = (f + 1) * per + p;
            b.add_edge(u, v, rng.range_i64(1, max_cap), 0);
        }
    }
    // Source feeds frame 0, sink drains the last frame.
    for k in 0..per {
        b.add_edge(s, k, max_cap * 8, 0);
        b.add_edge((frames - 1) * per + k, t, max_cap * 8, 0);
    }
    b.build().expect("rmf well-formed")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maxflow;

    #[test]
    fn rmf_shape() {
        let mut rng = Rng::seeded(2);
        let g = rmf_network(&mut rng, 3, 4, 10);
        assert_eq!(g.node_count(), 9 * 4 + 2);
        // Every inter-frame layer has exactly a*a arcs: bottleneck exists.
        assert!(g.edge_pair_count() > 0);
    }

    #[test]
    fn engines_agree_on_rmf() -> anyhow::Result<()> {
        use anyhow::Context;
        let mut rng = Rng::seeded(3);
        let base = rmf_network(&mut rng, 3, 3, 8);
        let mut value = None;
        for engine in maxflow::all_engines() {
            let mut g = base.clone();
            let stats = engine
                .solve(&mut g)
                .with_context(|| format!("{} solve", engine.name()))?;
            crate::graph::validate::assert_max_flow(&g, stats.value)
                .with_context(|| format!("{} certificate", engine.name()))?;
            match value {
                None => value = Some(stats.value),
                Some(v) => assert_eq!(stats.value, v, "{}", engine.name()),
            }
        }
        assert!(value.unwrap() > 0);
        Ok(())
    }
}
