//! Bertsekas ε-scaling auction algorithm — the second baseline for the
//! E5/E8 tables.  Bidders (X) raise prices on their best object (Y) by the
//! bid increment `best - second_best + ε`; ε-scaling keeps the total work
//! near O(n² log(nC)).

use anyhow::Result;

use crate::graph::AssignmentInstance;

use super::{AssignStats, AssignmentResult, AssignmentSolver};

#[derive(Debug, Clone)]
pub struct Auction {
    /// ε divisor per scaling phase.
    pub alpha: i64,
}

impl Default for Auction {
    fn default() -> Self {
        Self { alpha: 4 }
    }
}

impl AssignmentSolver for Auction {
    fn name(&self) -> &'static str {
        "auction"
    }

    fn solve(&self, inst: &AssignmentInstance) -> Result<AssignmentResult> {
        let n = inst.n;
        if n == 0 {
            return Ok(AssignmentResult {
                assignment: vec![],
                weight: 0,
                stats: AssignStats::default(),
            });
        }
        let mut stats = AssignStats::default();
        // Scale weights by (n+1) so ε = 1 certifies optimality.
        let k = (n + 1) as i64;
        let values: Vec<i64> = inst.weights.iter().map(|&w| w * k).collect();
        let vmax = values.iter().copied().max().unwrap_or(0);

        let mut prices = vec![0i64; n];
        let mut owner: Vec<Option<usize>> = vec![None; n]; // y -> x
        let mut assigned: Vec<Option<usize>> = vec![None; n]; // x -> y

        let mut eps = (vmax / 2).max(1);
        loop {
            stats.refines += 1;
            // Dissolve the matching at each phase start (ε-scaling restart).
            owner.iter_mut().for_each(|o| *o = None);
            assigned.iter_mut().for_each(|a| *a = None);
            let mut free: Vec<usize> = (0..n).collect();

            while let Some(x) = free.pop() {
                // Find best and second-best net value for bidder x.
                let mut best_y = 0usize;
                let mut best = i64::MIN;
                let mut second = i64::MIN;
                for y in 0..n {
                    let net = values[x * n + y] - prices[y];
                    if net > best {
                        second = best;
                        best = net;
                        best_y = y;
                    } else if net > second {
                        second = net;
                    }
                }
                if second == i64::MIN {
                    second = best; // n = 1
                }
                // Bid: raise the price so x is indifferent to second best.
                prices[best_y] += best - second + eps;
                stats.pushes += 1;
                if let Some(prev) = owner[best_y].replace(x) {
                    assigned[prev] = None;
                    free.push(prev);
                }
                assigned[x] = Some(best_y);
            }

            if eps == 1 {
                break;
            }
            eps = (eps / self.alpha).max(1);
        }

        let assignment: Vec<usize> = assigned.into_iter().map(|y| y.expect("complete")).collect();
        Ok(AssignmentResult {
            weight: inst.assignment_weight(&assignment),
            assignment,
            stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;

    #[test]
    fn matches_hungarian_on_random() {
        let mut rng = crate::util::Rng::seeded(5);
        for n in [1usize, 2, 4, 6, 10, 16] {
            let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
            let inst = AssignmentInstance::new(n, w);
            let a = Auction::default().solve(&inst).unwrap();
            let h = Hungarian.solve(&inst).unwrap();
            assert_eq!(a.weight, h.weight, "n={n}");
        }
    }

    #[test]
    fn single_item() {
        let inst = AssignmentInstance::new(1, vec![42]);
        let r = Auction::default().solve(&inst).unwrap();
        assert_eq!(r.assignment, vec![0]);
        assert_eq!(r.weight, 42);
    }
}
