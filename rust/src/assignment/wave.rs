//! Dense synchronous-wave refine: the bit-exact native twin of the L1
//! Pallas CSA kernel (python/compile/kernels/csa_wave.py).  Forward
//! half-wave (active X push/relabel), then backward half-wave (active Y
//! push back/relabel), snapshot-then-apply.

use anyhow::Result;

use crate::graph::AssignmentInstance;

use super::scaling::{solve_scaling, CsaState, RefineEngine};
use super::{AssignStats, AssignmentResult, AssignmentSolver};

const INF: i64 = 1 << 60;

/// Per-wave counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CsaWaveStats {
    pub pushes: u64,
    pub relabels: u64,
}

/// Forward half-wave: every active x scans its residual row for the
/// minimum partially-reduced cost, pushes one unit if admissible
/// (`min < -p(x)`), else relabels `p(x) = -(min + eps)`.
pub fn forward_half_wave(st: &mut CsaState, eps: i64) -> CsaWaveStats {
    let n = st.n;
    let mut stats = CsaWaveStats::default();
    // Snapshot decisions (px/py/f are read before any mutation).
    let mut pushes: Vec<(usize, usize)> = Vec::new();
    let mut relabels: Vec<(usize, i64)> = Vec::new();
    for x in 0..n {
        if st.ex[x] <= 0 {
            continue;
        }
        let mut best = INF;
        let mut best_y = usize::MAX;
        for y in 0..n {
            if st.f[x * n + y] == 0 {
                let c = st.cp_forward(x, y);
                if c < best {
                    best = c;
                    best_y = y;
                }
            }
        }
        if best_y == usize::MAX {
            continue;
        }
        if best < -st.px[x] {
            pushes.push((x, best_y));
        } else {
            relabels.push((x, -(best + eps)));
        }
    }
    for (x, y) in pushes {
        st.f[x * n + y] = 1;
        st.ex[x] -= 1;
        st.ey[y] += 1;
        stats.pushes += 1;
    }
    for (x, p) in relabels {
        st.px[x] = p;
        stats.relabels += 1;
    }
    stats
}

/// Backward half-wave: active y scans matched arcs (f = 1) for the
/// minimum `c'_p(y,x)` and pushes one unit back or relabels.
pub fn backward_half_wave(st: &mut CsaState, eps: i64) -> CsaWaveStats {
    let n = st.n;
    let mut stats = CsaWaveStats::default();
    let mut pushes: Vec<(usize, usize)> = Vec::new();
    let mut relabels: Vec<(usize, i64)> = Vec::new();
    for y in 0..n {
        if st.ey[y] <= 0 {
            continue;
        }
        let mut best = INF;
        let mut best_x = usize::MAX;
        for x in 0..n {
            if st.f[x * n + y] == 1 {
                let c = st.cp_backward(x, y);
                if c < best {
                    best = c;
                    best_x = x;
                }
            }
        }
        if best_x == usize::MAX {
            continue;
        }
        if best < -st.py[y] {
            pushes.push((y, best_x));
        } else {
            relabels.push((y, -(best + eps)));
        }
    }
    for (y, x) in pushes {
        st.f[x * n + y] = 0;
        st.ey[y] -= 1;
        st.ex[x] += 1;
        stats.pushes += 1;
    }
    for (y, p) in relabels {
        st.py[y] = p;
        stats.relabels += 1;
    }
    stats
}

/// One full wave.
pub fn native_wave(st: &mut CsaState, eps: i64) -> CsaWaveStats {
    let a = forward_half_wave(st, eps);
    let b = backward_half_wave(st, eps);
    CsaWaveStats {
        pushes: a.pushes + b.pushes,
        relabels: a.relabels + b.relabels,
    }
}

/// Wave-based refine engine (native; the PJRT twin lives in
/// `coordinator::assignment_driver`).
#[derive(Debug, Clone)]
pub struct WaveRefine {
    pub max_waves: u64,
}

impl Default for WaveRefine {
    fn default() -> Self {
        Self {
            max_waves: 100_000_000,
        }
    }
}

impl RefineEngine for WaveRefine {
    fn name(&self) -> &'static str {
        "wave-native"
    }

    fn refine(&mut self, st: &mut CsaState, eps: i64, stats: &mut AssignStats) -> Result<()> {
        let mut waves = 0u64;
        while st.active_count() > 0 {
            let w = native_wave(st, eps);
            stats.pushes += w.pushes;
            stats.relabels += w.relabels;
            stats.waves += 1;
            waves += 1;
            anyhow::ensure!(
                waves < self.max_waves,
                "wave refine exceeded {} waves at eps={eps}",
                self.max_waves
            );
        }
        Ok(())
    }
}

/// Full solver: scaling loop over the wave refine.
#[derive(Debug, Clone, Default)]
pub struct WaveCsa {
    pub alpha: Option<i64>,
}

impl AssignmentSolver for WaveCsa {
    fn name(&self) -> &'static str {
        "csa-wave"
    }

    fn solve(&self, inst: &AssignmentInstance) -> Result<AssignmentResult> {
        let mut engine = WaveRefine::default();
        solve_scaling(inst, self.alpha.unwrap_or(10), &mut engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;

    #[test]
    fn wave_refine_preserves_eps_optimality() {
        let inst = AssignmentInstance::new(4, vec![3, 9, 1, 0, 4, 4, 7, 2, 0, 5, 8, 6, 1, 2, 3, 4]);
        let (mut st, eps0) = CsaState::new(&inst);
        st.reset_refine(eps0);
        let mut guard = 0;
        while st.active_count() > 0 {
            native_wave(&mut st, eps0);
            st.check_eps_optimal(eps0).unwrap();
            guard += 1;
            assert!(guard < 10_000);
        }
        assert!(st.is_flow());
    }

    #[test]
    fn matches_hungarian() {
        let mut rng = crate::util::Rng::seeded(17);
        for n in [2usize, 3, 5, 9, 14] {
            let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
            let inst = AssignmentInstance::new(n, w);
            let got = WaveCsa::default().solve(&inst).unwrap();
            let want = Hungarian.solve(&inst).unwrap();
            assert_eq!(got.weight, want.weight, "n={n}");
        }
    }
}
