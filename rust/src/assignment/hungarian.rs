//! Exact Hungarian algorithm (Jonker–Volgenant shortest-augmenting-path
//! formulation), O(n³): the ground-truth baseline for every other engine
//! and the source of exact dual certificates.

use anyhow::Result;

use crate::graph::validate::assert_optimal_assignment;
use crate::graph::AssignmentInstance;

use super::{AssignStats, AssignmentResult, AssignmentSolver};

pub struct Hungarian;

/// Solve min-cost assignment for a row-major `cost` matrix, returning
/// (assign, px, py) with exact complementary-slackness duals:
/// `cost[x][y] + px[x] - py[y] >= 0`, equality on matched arcs.
pub fn solve_min_cost(n: usize, cost: &[i64]) -> (Vec<usize>, Vec<i64>, Vec<i64>) {
    assert_eq!(cost.len(), n * n);
    const INF: i64 = i64::MAX / 4;
    // 1-based helpers from the classic JV formulation.
    let mut p = vec![0i64; n + 1]; // potentials for rows (assigned via way)
    let mut v = vec![0i64; n + 1]; // potentials for columns
    let mut way = vec![0usize; n + 1];
    let mut matched_row = vec![0usize; n + 1]; // column -> row (1-based, 0 = free)

    for x in 1..=n {
        matched_row[0] = x;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = matched_row[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[(i0 - 1) * n + (j - 1)] - p[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    p[matched_row[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if matched_row[j0] == 0 {
                break;
            }
        }
        // Augment along the alternating path.
        loop {
            let j1 = way[j0];
            matched_row[j0] = matched_row[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assign = vec![0usize; n];
    for j in 1..=n {
        if matched_row[j] > 0 {
            assign[matched_row[j] - 1] = j - 1;
        }
    }
    // Duals: rc(x,y) = cost - p[x+1] - v[y+1] >= 0 with equality on match.
    // Map to the (px, py) convention of validate::assert_optimal_assignment
    // (cost + px - py >= 0): px = -p, py = v.
    let px: Vec<i64> = (1..=n).map(|x| -p[x]).collect();
    let py: Vec<i64> = (1..=n).map(|j| v[j]).collect();
    (assign, px, py)
}

impl AssignmentSolver for Hungarian {
    fn name(&self) -> &'static str {
        "hungarian"
    }

    fn solve(&self, inst: &AssignmentInstance) -> Result<AssignmentResult> {
        let n = inst.n;
        if n == 0 {
            return Ok(AssignmentResult {
                assignment: vec![],
                weight: 0,
                stats: AssignStats::default(),
            });
        }
        // Max-weight -> min-cost.
        let cost: Vec<i64> = inst.weights.iter().map(|&w| -w).collect();
        let (assign, px, py) = solve_min_cost(n, &cost);
        // Self-certify.
        assert_optimal_assignment(n, &cost, &assign, &px, &py)?;
        Ok(AssignmentResult {
            weight: inst.assignment_weight(&assign),
            assignment: assign,
            stats: AssignStats::default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_checked_3x3() {
        // w = [[5,1,0],[2,8,1],[0,3,9]] -> diagonal, weight 22.
        let inst = AssignmentInstance::new(3, vec![5, 1, 0, 2, 8, 1, 0, 3, 9]);
        let r = Hungarian.solve(&inst).unwrap();
        assert_eq!(r.assignment, vec![0, 1, 2]);
        assert_eq!(r.weight, 22);
    }

    #[test]
    fn anti_diagonal_instance() {
        let inst = AssignmentInstance::new(2, vec![0, 9, 9, 0]);
        let r = Hungarian.solve(&inst).unwrap();
        assert_eq!(r.assignment, vec![1, 0]);
        assert_eq!(r.weight, 18);
    }

    #[test]
    fn matches_brute_force_up_to_7() {
        let mut rng = crate::util::Rng::seeded(99);
        for n in 1..=7usize {
            for _ in 0..4 {
                let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 50)).collect();
                let inst = AssignmentInstance::new(n, w);
                let r = Hungarian.solve(&inst).unwrap();
                let best = brute_force(&inst);
                assert_eq!(r.weight, best, "n={n}");
            }
        }
    }

    fn brute_force(inst: &AssignmentInstance) -> i64 {
        fn rec(inst: &AssignmentInstance, x: usize, used: &mut [bool]) -> i64 {
            if x == inst.n {
                return 0;
            }
            let mut best = i64::MIN;
            for y in 0..inst.n {
                if !used[y] {
                    used[y] = true;
                    best = best.max(inst.weight(x, y) + rec(inst, x + 1, used));
                    used[y] = false;
                }
            }
            best
        }
        rec(inst, 0, &mut vec![false; inst.n])
    }
}
