//! Algorithm 5.1 — Goldberg & Kennedy's *second* cost-scaling variant
//! (§5.1 "version 2"), which the paper contrasts with its own Algorithm
//! 5.2 before combining the two.  The differences from Algorithm 5.2:
//!
//! * **asymmetric admissibility**: a forward arc (x,y) is admissible when
//!   `c_p(x,y) < ε/2`, a reverse arc (y,x) when `c_p(y,x) < -ε/2`
//!   (the paper's two-sided definition after §5.1);
//! * **refine preamble** sets `p(x) = -min_y c'_p(x,y)` (without the +ε);
//! * **relabel** on X restores `min c_p = 0` (`p(x) = max{p(y) - c(x,y)}`)
//!   while Y keeps the ε-shifted rule.
//!
//! ε-optimality here is the two-sided form: `c_p >= 0` on residual X→Y
//! arcs and `c_p >= -ε` on residual Y→X arcs — which implies the
//! symmetric ε-optimality the validators check.
//!
//! Comparing this engine against Algorithm 5.2 realises the paper's
//! "differences ... have impact on the efficiency" observation (E5/E6).

use anyhow::Result;

use crate::graph::AssignmentInstance;

use super::scaling::{epsilon_schedule, CsaState};
use super::{AssignStats, AssignmentResult, AssignmentSolver};

const INF: i64 = 1 << 60;

/// Sequential engine implementing Algorithm 5.1.
#[derive(Debug, Clone)]
pub struct GkCsa {
    pub alpha: i64,
}

impl Default for GkCsa {
    fn default() -> Self {
        Self { alpha: 10 }
    }
}

impl GkCsa {
    /// Refine preamble (Algorithm 5.1 lines 3-6): de-saturate and set
    /// `p(x) = -min c'_p(x,y)` — note: no ε shift, unlike Algorithm 5.2.
    fn reset_refine(st: &mut CsaState) {
        let n = st.n;
        st.f.iter_mut().for_each(|v| *v = 0);
        st.ex.iter_mut().for_each(|v| *v = 1);
        st.ey.iter_mut().for_each(|v| *v = -1);
        for x in 0..n {
            let row_min = (0..n)
                .map(|y| st.cost[x * n + y] - st.py[y])
                .min()
                .expect("n > 0");
            st.px[x] = -row_min;
        }
    }

    /// Run refine at `eps` with the Algorithm 5.1 rules.
    fn refine(st: &mut CsaState, eps: i64, stats: &mut AssignStats) -> Result<()> {
        let n = st.n;
        let mut stack: Vec<u32> = (0..n as u32).collect(); // all X active
        let mut on_stack = vec![false; 2 * n];
        on_stack[..n].iter_mut().for_each(|b| *b = true);

        let mut guard = 0u64;
        while let Some(v) = stack.pop() {
            let v = v as usize;
            on_stack[v] = false;
            loop {
                guard += 1;
                anyhow::ensure!(guard < 1_000_000_000, "GK refine wedged at eps={eps}");
                let (is_x, idx) = if v < n { (true, v) } else { (false, v - n) };
                let excess = if is_x { st.ex[idx] } else { st.ey[idx] };
                if excess <= 0 {
                    break;
                }
                let mut best = INF;
                let mut other = usize::MAX;
                if is_x {
                    for y in 0..n {
                        if st.f[idx * n + y] == 0 {
                            let c = st.cp_forward(idx, y);
                            if c < best {
                                best = c;
                                other = y;
                            }
                        }
                    }
                } else {
                    for x in 0..n {
                        if st.f[x * n + idx] == 1 {
                            let c = st.cp_backward(x, idx);
                            if c < best {
                                best = c;
                                other = x;
                            }
                        }
                    }
                }
                anyhow::ensure!(other != usize::MAX, "active node with no residual arc");
                if is_x {
                    // Admissible iff c_p(x,y) < eps/2, i.e. 2(c'_p + px) < eps.
                    if 2 * (best + st.px[idx]) < eps {
                        st.f[idx * n + other] = 1;
                        st.ex[idx] -= 1;
                        st.ey[other] += 1;
                        stats.pushes += 1;
                        if st.ey[other] > 0 && !on_stack[n + other] {
                            stack.push((n + other) as u32);
                            on_stack[n + other] = true;
                        }
                    } else {
                        // Relabel: p(x) = max{p(y) - c(x,y)} = -min c'_p.
                        st.px[idx] = -best;
                        stats.relabels += 1;
                    }
                } else {
                    // Admissible iff c_p(y,x) < -eps/2, i.e. 2(c'_p + py) < -eps.
                    if 2 * (best + st.py[idx]) < -eps {
                        st.f[other * n + idx] = 0;
                        st.ey[idx] -= 1;
                        st.ex[other] += 1;
                        stats.pushes += 1;
                        if st.ex[other] > 0 && !on_stack[other] {
                            stack.push(other as u32);
                            on_stack[other] = true;
                        }
                    } else {
                        // Relabel: p(y) = max{p(z) + c(z,y) - eps}.
                        st.py[idx] = -(best + eps);
                        stats.relabels += 1;
                    }
                }
            }
        }
        Ok(())
    }
}

impl AssignmentSolver for GkCsa {
    fn name(&self) -> &'static str {
        "csa-gk(5.1)"
    }

    fn solve(&self, inst: &AssignmentInstance) -> Result<AssignmentResult> {
        if inst.n == 0 {
            return Ok(AssignmentResult {
                assignment: vec![],
                weight: 0,
                stats: AssignStats::default(),
            });
        }
        let (mut st, eps0) = CsaState::new(inst);
        let mut stats = AssignStats::default();
        for eps in epsilon_schedule(eps0, self.alpha) {
            Self::reset_refine(&mut st);
            Self::refine(&mut st, eps, &mut stats)?;
            stats.refines += 1;
            anyhow::ensure!(st.is_flow(), "GK refine at eps={eps} not a flow");
            // Two-sided eps-optimality implies the symmetric form.
            st.check_eps_optimal(eps)?;
        }
        let assignment = st.assignment();
        Ok(AssignmentResult {
            weight: inst.assignment_weight(&assignment),
            assignment,
            stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;

    #[test]
    fn matches_hungarian_on_random() {
        let mut rng = crate::util::Rng::seeded(91);
        for n in [1usize, 2, 4, 7, 12, 20, 30] {
            let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
            let inst = AssignmentInstance::new(n, w);
            let got = GkCsa::default().solve(&inst).unwrap();
            let want = Hungarian.solve(&inst).unwrap();
            assert_eq!(got.weight, want.weight, "n={n}");
        }
    }

    #[test]
    fn alpha_sweep_optimal() {
        let mut rng = crate::util::Rng::seeded(93);
        let n = 14;
        let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
        let inst = AssignmentInstance::new(n, w);
        let want = Hungarian.solve(&inst).unwrap().weight;
        for alpha in [2i64, 4, 10, 32] {
            assert_eq!(GkCsa { alpha }.solve(&inst).unwrap().weight, want);
        }
    }

    #[test]
    fn half_eps_admissibility_differs_from_52_in_ops() {
        // Not a strict theorem, but on a fixed instance the two variants
        // should generally take different op counts — the paper's point
        // that the definitional differences "have impact on the
        // efficiency".
        let mut rng = crate::util::Rng::seeded(95);
        let n = 16;
        let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
        let inst = AssignmentInstance::new(n, w);
        let gk = GkCsa::default().solve(&inst).unwrap();
        let plain = crate::assignment::csa::SequentialCsa::plain(10)
            .solve(&inst)
            .unwrap();
        assert_eq!(gk.weight, plain.weight);
        assert!(gk.stats.pushes > 0 && plain.stats.pushes > 0);
    }
}
