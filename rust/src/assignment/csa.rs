//! Sequential cost-scaling refine (Algorithm 5.2 + the §5.2 heuristics):
//! an active-node stack, per-node minimum-reduced-cost scans (the paper's
//! combined push/relabel rule), the price-update heuristic every ~n
//! relabels, and per-refine arc fixing.

use anyhow::Result;

use crate::graph::AssignmentInstance;

use super::arc_fixing::{compute_fixed, FixedArcs};
use super::price_update::price_update;
use super::scaling::{solve_scaling, CsaState, RefineEngine};
use super::{AssignStats, AssignmentResult, AssignmentSolver};

const INF: i64 = 1 << 60;

/// Sequential refine engine.
#[derive(Debug, Clone)]
pub struct SequentialRefine {
    /// Run price updates every `price_update_freq * n` relabels
    /// (`None` disables — the ablation rows of E5/E6).
    pub price_update_freq: Option<f64>,
    /// Enable per-refine arc fixing.
    pub arc_fixing: bool,
}

impl Default for SequentialRefine {
    fn default() -> Self {
        Self {
            price_update_freq: Some(1.0),
            arc_fixing: true,
        }
    }
}

impl SequentialRefine {
    pub fn plain() -> Self {
        Self {
            price_update_freq: None,
            arc_fixing: false,
        }
    }
}

impl RefineEngine for SequentialRefine {
    fn name(&self) -> &'static str {
        "csa-seq"
    }

    fn refine(&mut self, st: &mut CsaState, eps: i64, stats: &mut AssignStats) -> Result<()> {
        let n = st.n;
        let mut fixed: Option<FixedArcs> = if self.arc_fixing {
            let fx = compute_fixed(st, eps);
            stats.arcs_fixed += fx.count;
            Some(fx)
        } else {
            None
        };

        // Active stack holds node ids: X = 0..n, Y = n..2n.
        let mut stack: Vec<u32> = Vec::with_capacity(2 * n);
        let mut on_stack = vec![false; 2 * n];
        for x in 0..n {
            if st.ex[x] > 0 {
                stack.push(x as u32);
                on_stack[x] = true;
            }
        }

        let mut relabels_since_update = 0u64;
        let budget = self
            .price_update_freq
            .map(|f| ((f * n as f64) as u64).max(1));

        let mut guard: u64 = 0;
        let guard_max = 1_000_000_000;

        while let Some(v) = stack.pop() {
            let v = v as usize;
            on_stack[v] = false;
            loop {
                guard += 1;
                anyhow::ensure!(guard < guard_max, "sequential refine wedged at eps={eps}");
                let (is_x, idx) = if v < n { (true, v) } else { (false, v - n) };
                let excess = if is_x { st.ex[idx] } else { st.ey[idx] };
                if excess <= 0 {
                    break;
                }
                // Min partially-reduced cost over residual, non-fixed arcs.
                let mut best = INF;
                let mut best_other = usize::MAX;
                if is_x {
                    for y in 0..n {
                        if st.f[idx * n + y] == 0
                            && !fixed.as_ref().is_some_and(|fx| fx.mask[idx * n + y])
                        {
                            let c = st.cp_forward(idx, y);
                            if c < best {
                                best = c;
                                best_other = y;
                            }
                        }
                    }
                } else {
                    for x in 0..n {
                        if st.f[x * n + idx] == 1
                            && !fixed.as_ref().is_some_and(|fx| fx.mask[x * n + idx])
                        {
                            let c = st.cp_backward(x, idx);
                            if c < best {
                                best = c;
                                best_other = x;
                            }
                        }
                    }
                }
                if best_other == usize::MAX {
                    // All candidate arcs fixed: theory says this cannot
                    // happen for an active node; fall back to a full scan.
                    fixed = None;
                    continue;
                }
                let price = if is_x { st.px[idx] } else { st.py[idx] };
                if best < -price {
                    // PUSH one unit along the argmin arc.
                    let (x, y) = if is_x {
                        (idx, best_other)
                    } else {
                        (best_other, idx)
                    };
                    if is_x {
                        st.f[x * n + y] = 1;
                        st.ex[x] -= 1;
                        st.ey[y] += 1;
                        if st.ey[y] > 0 && !on_stack[n + y] {
                            stack.push((n + y) as u32);
                            on_stack[n + y] = true;
                        }
                    } else {
                        st.f[x * n + y] = 0;
                        st.ey[y] -= 1;
                        st.ex[x] += 1;
                        if st.ex[x] > 0 && !on_stack[x] {
                            stack.push(x as u32);
                            on_stack[x] = true;
                        }
                    }
                    stats.pushes += 1;
                } else {
                    // RELABEL.
                    if is_x {
                        st.px[idx] = -(best + eps);
                    } else {
                        st.py[idx] = -(best + eps);
                    }
                    stats.relabels += 1;
                    relabels_since_update += 1;
                    if let Some(b) = budget {
                        if relabels_since_update >= b {
                            price_update(st, eps);
                            stats.price_updates += 1;
                            relabels_since_update = 0;
                            if self.arc_fixing {
                                let fx = compute_fixed(st, eps);
                                stats.arcs_fixed += fx.count;
                                fixed = Some(fx);
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Full sequential CSA solver (Algorithm 5.2 inside Algorithm 5.0).
#[derive(Debug, Clone)]
pub struct SequentialCsa {
    pub alpha: i64,
    pub refine: SequentialRefine,
}

impl Default for SequentialCsa {
    fn default() -> Self {
        Self {
            alpha: 10,
            refine: SequentialRefine::default(),
        }
    }
}

impl SequentialCsa {
    pub fn plain(alpha: i64) -> Self {
        Self {
            alpha,
            refine: SequentialRefine::plain(),
        }
    }

    pub fn with_alpha(alpha: i64) -> Self {
        Self {
            alpha,
            ..Self::default()
        }
    }
}

impl AssignmentSolver for SequentialCsa {
    fn name(&self) -> &'static str {
        "csa-seq"
    }

    fn solve(&self, inst: &AssignmentInstance) -> Result<AssignmentResult> {
        let mut engine = self.refine.clone();
        solve_scaling(inst, self.alpha, &mut engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;

    #[test]
    fn matches_hungarian_with_and_without_heuristics() {
        let mut rng = crate::util::Rng::seeded(23);
        for n in [2usize, 4, 7, 12] {
            let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
            let inst = AssignmentInstance::new(n, w);
            let want = Hungarian.solve(&inst).unwrap().weight;
            for solver in [SequentialCsa::default(), SequentialCsa::plain(10)] {
                let got = solver.solve(&inst).unwrap();
                assert_eq!(got.weight, want, "n={n} heuristics={:?}", solver.refine);
            }
        }
    }

    #[test]
    fn alpha_variants_agree() {
        let mut rng = crate::util::Rng::seeded(29);
        let n = 10;
        let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
        let inst = AssignmentInstance::new(n, w);
        let want = Hungarian.solve(&inst).unwrap().weight;
        for alpha in [2, 4, 8, 10, 16, 32] {
            let got = SequentialCsa::with_alpha(alpha).solve(&inst).unwrap();
            assert_eq!(got.weight, want, "alpha={alpha}");
        }
    }

    #[test]
    fn heuristics_record_activity() {
        let mut rng = crate::util::Rng::seeded(31);
        let n = 16;
        let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
        let inst = AssignmentInstance::new(n, w);
        let got = SequentialCsa::default().solve(&inst).unwrap();
        // On a 16-node instance the schedule runs several refines and the
        // heuristics must have fired at least once.
        assert!(got.stats.refines >= 2);
        assert!(got.stats.pushes > 0);
    }
}
