//! Price-update heuristic (Algorithm 5.3): a Dial-bucket backward
//! Dijkstra from the deficit nodes, assigning each node a label `l(v)` =
//! its ε-distance to a deficit; prices drop by `ε·l(v)` (unscanned nodes
//! by `ε·(last+1)`).  This is the cost-scaling analogue of the max-flow
//! global relabel and preserves ε-optimality.

use super::scaling::CsaState;

/// Arc length in ε units (Goldberg's `max(0, ⌊c_p/ε⌋ + 1)` — the paper's
/// listing omits the clamp/offset, which we restore for correctness).
#[inline]
fn arc_len(cp: i64, eps: i64) -> i64 {
    (cp.div_euclid(eps) + 1).max(0)
}

/// Run the heuristic; returns the number of scanned nodes.
///
/// Node ids: X = 0..n, Y = n..2n.
pub fn price_update(st: &mut CsaState, eps: i64) -> usize {
    let n = st.n;
    if n == 0 {
        return 0;
    }
    let nn = 2 * n;
    const UNSET: i64 = i64::MAX / 2;

    // Active nodes must all get scanned; deficits seed bucket 0.
    let mut label = vec![UNSET; nn];
    let mut scanned = vec![false; nn];
    let mut active_left = 0usize;
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new()];
    for x in 0..n {
        if st.ex[x] > 0 {
            active_left += 1;
        }
        if st.ex[x] < 0 {
            label[x] = 0;
            buckets[0].push(x as u32);
        }
    }
    for y in 0..n {
        if st.ey[y] > 0 {
            active_left += 1;
        }
        if st.ey[y] < 0 {
            label[n + y] = 0;
            buckets[0].push((n + y) as u32);
        }
    }
    if buckets[0].is_empty() {
        return 0; // no deficits: nothing to anchor distances to
    }

    let mut last = 0i64;
    let mut scanned_count = 0usize;
    let mut i = 0usize;
    while active_left > 0 && i < buckets.len() {
        while let Some(v) = buckets[i].pop() {
            let v = v as usize;
            if scanned[v] || label[v] != i as i64 {
                continue; // stale entry from a lazy decrease-key
            }
            scanned[v] = true;
            scanned_count += 1;
            last = i as i64;
            let is_active = if v < n {
                st.ex[v] > 0
            } else {
                st.ey[v - n] > 0
            };
            if is_active {
                // NOTE: even when this was the last active node, finish
                // the current bucket — stopping mid-bucket leaves nodes
                // with tentative labels <= `last` unscanned, and the
                // uniform `last + 1` drop for unscanned nodes would then
                // break eps-optimality on arcs into them.  Stopping at a
                // bucket boundary keeps every unscanned tentative label
                // >= last + 1, which is exactly what the proof needs.
                active_left -= 1;
            }
            // Relax residual arcs *entering* v.
            if v < n {
                // v = x: entering arcs are (y -> x) for matched pairs.
                let x = v;
                for y in 0..n {
                    if st.f[x * n + y] == 1 && !scanned[n + y] {
                        let cp = -st.cost[x * n + y] - st.px[x] + st.py[y];
                        let nl = i as i64 + arc_len(cp, eps);
                        if nl < label[n + y] {
                            label[n + y] = nl;
                            push_bucket(&mut buckets, nl as usize, (n + y) as u32);
                        }
                    }
                }
            } else {
                // v = y: entering arcs are (x -> y) for unmatched pairs.
                let y = v - n;
                for x in 0..n {
                    if st.f[x * n + y] == 0 && !scanned[x] {
                        let cp = st.cost[x * n + y] + st.px[x] - st.py[y];
                        let nl = i as i64 + arc_len(cp, eps);
                        if nl < label[x] {
                            label[x] = nl;
                            push_bucket(&mut buckets, nl as usize, x as u32);
                        }
                    }
                }
            }
        }
        if active_left == 0 {
            break;
        }
        i += 1;
    }

    // Apply price drops.
    for x in 0..n {
        let drop = if scanned[x] { label[x] } else { last + 1 };
        st.px[x] -= eps * drop;
    }
    for y in 0..n {
        let drop = if scanned[n + y] { label[n + y] } else { last + 1 };
        st.py[y] -= eps * drop;
    }
    scanned_count
}

fn push_bucket(buckets: &mut Vec<Vec<u32>>, idx: usize, v: u32) {
    if buckets.len() <= idx {
        buckets.resize_with(idx + 1, Vec::new);
    }
    buckets[idx].push(v);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::wave::native_wave;
    use crate::graph::AssignmentInstance;

    fn mid_refine_state() -> (CsaState, i64) {
        let inst = AssignmentInstance::new(
            5,
            vec![
                3, 9, 1, 0, 4, 4, 7, 2, 0, 5, 8, 6, 1, 2, 3, 4, 9, 9, 0, 1, 2, 5, 5, 5, 5,
            ],
        );
        let (mut st, eps0) = CsaState::new(&inst);
        st.reset_refine(eps0);
        // Advance a few waves to a non-trivial mid-state.
        for _ in 0..2 {
            native_wave(&mut st, eps0);
        }
        (st, eps0)
    }

    #[test]
    fn preserves_eps_optimality() {
        let (mut st, eps) = mid_refine_state();
        st.check_eps_optimal(eps).unwrap();
        price_update(&mut st, eps);
        st.check_eps_optimal(eps).unwrap();
    }

    #[test]
    fn prices_never_increase() {
        let (mut st, eps) = mid_refine_state();
        let px0 = st.px.clone();
        let py0 = st.py.clone();
        price_update(&mut st, eps);
        assert!(st.px.iter().zip(&px0).all(|(a, b)| a <= b));
        assert!(st.py.iter().zip(&py0).all(|(a, b)| a <= b));
    }

    #[test]
    fn noop_when_no_deficits() {
        let inst = AssignmentInstance::new(2, vec![1, 2, 3, 4]);
        let (mut st, eps) = CsaState::new(&inst);
        // Perfect matching, all excesses zero.
        st.f = vec![1, 0, 0, 1];
        st.ex = vec![0, 0];
        st.ey = vec![0, 0];
        let scanned = price_update(&mut st, eps);
        assert_eq!(scanned, 0);
    }

    #[test]
    fn refine_still_converges_after_update() {
        let (mut st, eps) = mid_refine_state();
        price_update(&mut st, eps);
        let mut guard = 0;
        while st.active_count() > 0 {
            native_wave(&mut st, eps);
            guard += 1;
            assert!(guard < 100_000);
        }
        assert!(st.is_flow());
    }
}
