//! Assignment-problem engines (§5 of the paper).
//!
//! * [`hungarian`] — exact O(n³) Jonker–Volgenant/Kuhn–Munkres baseline
//!   with dual certificates;
//! * [`auction`] — Bertsekas ε-scaling auction, second baseline;
//! * [`csa`] — the sequential cost-scaling algorithm (Algorithm 5.2) with
//!   the price-update (Algorithm 5.3) and arc-fixing heuristics;
//! * [`csa_gk`] — Goldberg & Kennedy's version-2 refine (Algorithm 5.1,
//!   asymmetric ε/2 admissibility), the paper's §5.1 comparison point;
//! * [`csa_lockfree`] — the paper's contribution: lock-free refine
//!   (Algorithm 5.4) on threads + atomics;
//! * [`wave`] — the dense synchronous-wave refine, a bit-exact native twin
//!   of the L1 Pallas kernel (the PJRT-backed version lives in
//!   `coordinator::assignment_driver`);
//! * [`scaling`] — the shared ε-schedule driver (Algorithm 5.0 Min-Cost).

pub mod arc_fixing;
pub mod auction;
pub mod csa;
pub mod csa_gk;
pub mod csa_lockfree;
pub mod hungarian;
pub mod price_update;
pub mod scaling;
pub mod wave;

use anyhow::Result;

use crate::graph::AssignmentInstance;

/// Counters for the §6 complexity discussion and the E5-E8 benches.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AssignStats {
    pub pushes: u64,
    pub relabels: u64,
    /// Refine invocations (scaling phases).
    pub refines: u64,
    /// Price-update heuristic runs.
    pub price_updates: u64,
    /// Arcs frozen by arc fixing (cumulative over refines).
    pub arcs_fixed: u64,
    /// Synchronous waves (wave engines only).
    pub waves: u64,
}

/// An engine's answer: the matching, its weight, and the counters.
#[derive(Debug, Clone)]
pub struct AssignmentResult {
    /// `assign[x] = y`.
    pub assignment: Vec<usize>,
    pub weight: i64,
    pub stats: AssignStats,
}

pub trait AssignmentSolver {
    fn name(&self) -> &'static str;
    fn solve(&self, inst: &AssignmentInstance) -> Result<AssignmentResult>;

    /// [`AssignmentSolver::solve`], plus a flush of the op counters into
    /// the global metrics registry under this engine's name
    /// (`flowmatch_engine_*_total{engine="auction"}`, …).  One registry
    /// touch per solve; the solve itself is unchanged.
    fn solve_traced(&self, inst: &AssignmentInstance) -> Result<AssignmentResult> {
        let result = self.solve(inst)?;
        crate::obs::record_assignment_stats(self.name(), &result.stats);
        Ok(result)
    }
}

/// All engines, for parity tests and the E5 bench.
pub fn all_engines() -> Vec<Box<dyn AssignmentSolver>> {
    vec![
        Box::new(hungarian::Hungarian),
        Box::new(auction::Auction::default()),
        Box::new(csa::SequentialCsa::default()),
        Box::new(csa_gk::GkCsa::default()),
        Box::new(csa_lockfree::LockFreeCsa::default()),
        Box::new(wave::WaveCsa::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(n: usize, seed: u64) -> AssignmentInstance {
        let mut rng = crate::util::Rng::seeded(seed);
        let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
        AssignmentInstance::new(n, w)
    }

    #[test]
    fn engines_agree_on_small_instances() {
        for seed in 0..5u64 {
            for n in [1usize, 2, 3, 5, 8] {
                let inst = inst(n, seed * 31 + n as u64);
                let reference = hungarian::Hungarian.solve(&inst).unwrap();
                for engine in all_engines() {
                    let got = engine.solve(&inst).unwrap();
                    assert!(
                        AssignmentInstance::is_permutation(&got.assignment),
                        "{} n={n} seed={seed}: not a permutation",
                        engine.name()
                    );
                    assert_eq!(
                        got.weight,
                        reference.weight,
                        "{} n={n} seed={seed}",
                        engine.name()
                    );
                    assert_eq!(got.weight, inst.assignment_weight(&got.assignment));
                }
            }
        }
    }

    #[test]
    fn degenerate_all_equal_weights() {
        let inst = AssignmentInstance::new(4, vec![7; 16]);
        for engine in all_engines() {
            let got = engine.solve(&inst).unwrap();
            assert_eq!(got.weight, 28, "{}", engine.name());
        }
    }

    #[test]
    fn zero_weights() {
        let inst = AssignmentInstance::new(3, vec![0; 9]);
        for engine in all_engines() {
            let got = engine.solve(&inst).unwrap();
            assert_eq!(got.weight, 0, "{}", engine.name());
        }
    }
}
