//! Lock-free parallel refine (Algorithm 5.4) — the paper's §5 core
//! contribution — on OS threads and atomics:
//!
//! * each thread owns a stripe of X nodes *and* a stripe of Y nodes and is
//!   the only writer of their prices (relabels need no RMW, exactly as
//!   the paper observes for heights);
//! * excesses and the 0/1 arc flows are `AtomicI64`/`AtomicI32` updated
//!   with fetch-add — the write conflicts the paper resolves with
//!   `atomicAdd`/`atomicSub`;
//! * the trace-equivalence argument (Lemmas 5.3–5.5) covers the
//!   interleavings; transient ε-optimality violations (case 5b) are
//!   self-correcting, so the final state is only audited after
//!   quiescence.

use std::sync::atomic::{AtomicBool, AtomicI32, AtomicI64, Ordering};

use anyhow::Result;

use crate::graph::AssignmentInstance;

use super::scaling::{solve_scaling, CsaState, RefineEngine};
use super::{AssignStats, AssignmentResult, AssignmentSolver};

const INF: i64 = 1 << 60;

/// Lock-free refine engine.
#[derive(Debug, Clone)]
pub struct LockFreeRefine {
    pub threads: usize,
}

impl Default for LockFreeRefine {
    fn default() -> Self {
        Self { threads: 2 }
    }
}

struct SharedCsa<'a> {
    n: usize,
    cost: &'a [i64],
    f: Vec<AtomicI32>,
    px: Vec<AtomicI64>,
    py: Vec<AtomicI64>,
    ex: Vec<AtomicI64>,
    ey: Vec<AtomicI64>,
    eps: i64,
    done: AtomicBool,
    pushes: AtomicI64,
    relabels: AtomicI64,
}

impl<'a> SharedCsa<'a> {
    /// One Algorithm 5.4 step for X node `x`; true if an op was applied.
    fn step_x(&self, x: usize) -> bool {
        let n = self.n;
        if self.ex[x].load(Ordering::SeqCst) <= 0 {
            return false;
        }
        // Lines 6-10: min partially-reduced cost over residual row arcs.
        let mut best = INF;
        let mut best_y = usize::MAX;
        for y in 0..n {
            if self.f[x * n + y].load(Ordering::SeqCst) == 0 {
                let c = self.cost[x * n + y] - self.py[y].load(Ordering::SeqCst);
                if c < best {
                    best = c;
                    best_y = y;
                }
            }
        }
        if best_y == usize::MAX {
            return false;
        }
        if best < -self.px[x].load(Ordering::SeqCst) {
            // PUSH (lines 12-16): one unit along the argmin arc.  Only this
            // thread flips f[x, y] 0 -> 1 (x's owner), so fetch_add is safe.
            // ORDER MATTERS: credit the destination before debiting the
            // source so total excess is never transiently understated —
            // otherwise the quiescence detector can fire with a unit
            // "in flight" and refine would terminate on a non-flow.
            self.f[x * n + best_y].fetch_add(1, Ordering::SeqCst);
            self.ey[best_y].fetch_add(1, Ordering::SeqCst);
            self.ex[x].fetch_sub(1, Ordering::SeqCst);
            self.pushes.fetch_add(1, Ordering::Relaxed);
        } else {
            // RELABEL (line 18): only x's owner writes px[x].
            self.px[x].store(-(best + self.eps), Ordering::SeqCst);
            self.relabels.fetch_add(1, Ordering::Relaxed);
        }
        true
    }

    /// Symmetric step for Y node `y` (pushing back along matched arcs).
    fn step_y(&self, y: usize) -> bool {
        let n = self.n;
        if self.ey[y].load(Ordering::SeqCst) <= 0 {
            return false;
        }
        let mut best = INF;
        let mut best_x = usize::MAX;
        for x in 0..n {
            if self.f[x * n + y].load(Ordering::SeqCst) == 1 {
                let c = -self.cost[x * n + y] - self.px[x].load(Ordering::SeqCst);
                if c < best {
                    best = c;
                    best_x = x;
                }
            }
        }
        if best_x == usize::MAX {
            return false;
        }
        if best < -self.py[y].load(Ordering::SeqCst) {
            // Same credit-before-debit ordering as step_x.
            self.f[best_x * n + y].fetch_sub(1, Ordering::SeqCst);
            self.ex[best_x].fetch_add(1, Ordering::SeqCst);
            self.ey[y].fetch_sub(1, Ordering::SeqCst);
            self.pushes.fetch_add(1, Ordering::Relaxed);
        } else {
            self.py[y].store(-(best + self.eps), Ordering::SeqCst);
            self.relabels.fetch_add(1, Ordering::Relaxed);
        }
        true
    }

    fn quiescent(&self) -> bool {
        self.ex.iter().all(|e| e.load(Ordering::SeqCst) <= 0)
            && self.ey.iter().all(|e| e.load(Ordering::SeqCst) <= 0)
    }
}

impl RefineEngine for LockFreeRefine {
    fn name(&self) -> &'static str {
        "csa-lockfree"
    }

    fn refine(&mut self, st: &mut CsaState, eps: i64, stats: &mut AssignStats) -> Result<()> {
        let n = st.n;
        let shared = SharedCsa {
            n,
            cost: &st.cost,
            f: st.f.iter().map(|&v| AtomicI32::new(v)).collect(),
            px: st.px.iter().map(|&v| AtomicI64::new(v)).collect(),
            py: st.py.iter().map(|&v| AtomicI64::new(v)).collect(),
            ex: st.ex.iter().map(|&v| AtomicI64::new(v)).collect(),
            ey: st.ey.iter().map(|&v| AtomicI64::new(v)).collect(),
            eps,
            done: AtomicBool::new(false),
            pushes: AtomicI64::new(0),
            relabels: AtomicI64::new(0),
        };

        let workers = self.threads.max(1);
        std::thread::scope(|scope| {
            for w in 0..workers {
                let shared = &shared;
                scope.spawn(move || {
                    let my_x: Vec<usize> = (0..n).filter(|v| v % workers == w).collect();
                    let my_y: Vec<usize> = (0..n).filter(|v| v % workers == w).collect();
                    loop {
                        if shared.done.load(Ordering::SeqCst) {
                            break;
                        }
                        let mut did = false;
                        for &x in &my_x {
                            // The paper's while e(x) > 0, bounded per sweep.
                            let mut burst = 0;
                            while shared.step_x(x) {
                                did = true;
                                burst += 1;
                                if burst >= 32 {
                                    break;
                                }
                            }
                        }
                        for &y in &my_y {
                            let mut burst = 0;
                            while shared.step_y(y) {
                                did = true;
                                burst += 1;
                                if burst >= 32 {
                                    break;
                                }
                            }
                        }
                        if !did && shared.quiescent() {
                            shared.done.store(true, Ordering::SeqCst);
                            break;
                        }
                        if !did {
                            std::thread::yield_now();
                        }
                    }
                });
            }
        });

        // Copy back.
        for (dst, src) in st.f.iter_mut().zip(&shared.f) {
            *dst = src.load(Ordering::SeqCst);
        }
        for (dst, src) in st.px.iter_mut().zip(&shared.px) {
            *dst = src.load(Ordering::SeqCst);
        }
        for (dst, src) in st.py.iter_mut().zip(&shared.py) {
            *dst = src.load(Ordering::SeqCst);
        }
        for (dst, src) in st.ex.iter_mut().zip(&shared.ex) {
            *dst = src.load(Ordering::SeqCst);
        }
        for (dst, src) in st.ey.iter_mut().zip(&shared.ey) {
            *dst = src.load(Ordering::SeqCst);
        }
        stats.pushes += shared.pushes.load(Ordering::Relaxed) as u64;
        stats.relabels += shared.relabels.load(Ordering::Relaxed) as u64;
        Ok(())
    }
}

/// Full lock-free CSA solver.
#[derive(Debug, Clone)]
pub struct LockFreeCsa {
    pub alpha: i64,
    pub threads: usize,
}

impl Default for LockFreeCsa {
    fn default() -> Self {
        Self {
            alpha: 10,
            threads: 2,
        }
    }
}

impl LockFreeCsa {
    pub fn with_threads(threads: usize) -> Self {
        Self {
            threads,
            ..Self::default()
        }
    }
}

impl AssignmentSolver for LockFreeCsa {
    fn name(&self) -> &'static str {
        "csa-lockfree"
    }

    fn solve(&self, inst: &AssignmentInstance) -> Result<AssignmentResult> {
        let mut engine = LockFreeRefine {
            threads: self.threads,
        };
        solve_scaling(inst, self.alpha, &mut engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;

    #[test]
    fn single_thread_matches_hungarian() {
        let mut rng = crate::util::Rng::seeded(41);
        for n in [2usize, 5, 9] {
            let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
            let inst = AssignmentInstance::new(n, w);
            let got = LockFreeCsa::with_threads(1).solve(&inst).unwrap();
            let want = Hungarian.solve(&inst).unwrap();
            assert_eq!(got.weight, want.weight, "n={n}");
        }
    }

    #[test]
    fn multi_thread_matches_hungarian() {
        let mut rng = crate::util::Rng::seeded(43);
        for threads in [2usize, 4] {
            for n in [3usize, 8, 12] {
                let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
                let inst = AssignmentInstance::new(n, w);
                let got = LockFreeCsa::with_threads(threads).solve(&inst).unwrap();
                let want = Hungarian.solve(&inst).unwrap();
                assert_eq!(got.weight, want.weight, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn final_state_is_one_optimal() {
        let mut rng = crate::util::Rng::seeded(47);
        let n = 6;
        let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 100)).collect();
        let inst = AssignmentInstance::new(n, w);
        let (mut st, eps0) = CsaState::new(&inst);
        let mut stats = AssignStats::default();
        let mut engine = LockFreeRefine { threads: 2 };
        for eps in crate::assignment::scaling::epsilon_schedule(eps0, 10) {
            st.reset_refine(eps);
            engine.refine(&mut st, eps, &mut stats).unwrap();
            // After quiescence the pseudoflow is an eps-optimal flow
            // (paper Lemma 5.6) — transient violations must be gone.
            st.check_eps_optimal(eps).unwrap();
        }
        assert!(st.is_flow());
    }
}
