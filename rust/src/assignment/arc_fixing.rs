//! Arc-fixing heuristic (§5.2 end): at ε-optimality, an arc whose reduced
//! cost satisfies `|c_p(e)| > 2nε` can never carry different flow for the
//! rest of the refine, so scans may skip it.  (Kennedy'95 §4; the paper
//! "deletes" such arcs by marking their flow with a sentinel — here we
//! keep an explicit boolean mask, recomputed per refine.)

use super::scaling::CsaState;

/// Mask of fixed arcs, row-major like `f`.  `true` = frozen.
#[derive(Debug, Clone)]
pub struct FixedArcs {
    pub mask: Vec<bool>,
    pub count: u64,
}

/// Compute the fixing mask for the current prices at `eps`.
pub fn compute_fixed(st: &CsaState, eps: i64) -> FixedArcs {
    let n = st.n;
    let bound = 2 * (n as i64) * eps;
    let mut mask = vec![false; n * n];
    let mut count = 0u64;
    for x in 0..n {
        for y in 0..n {
            let rc = st.cost[x * n + y] + st.px[x] - st.py[y];
            if rc.abs() > bound {
                mask[x * n + y] = true;
                count += 1;
            }
        }
    }
    FixedArcs { mask, count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::scaling::CsaState;
    use crate::graph::AssignmentInstance;

    #[test]
    fn small_eps_fixes_expensive_arcs() {
        let inst = AssignmentInstance::new(2, vec![0, 100, 100, 0]);
        let (mut st, _) = CsaState::new(&inst);
        st.reset_refine(1);
        let fixed = compute_fixed(&st, 1);
        // Bound = 4.  In min-cost form the heavy-weight arcs (w=100) are
        // the attractive ones; the zero-weight diagonal sits ~300 above
        // the row minimum and gets frozen.
        assert_eq!(fixed.count, 2);
        assert!(fixed.mask[0] && fixed.mask[3]);
        assert!(!fixed.mask[1] && !fixed.mask[2]);
    }

    #[test]
    fn huge_eps_fixes_nothing() {
        let inst = AssignmentInstance::new(2, vec![0, 100, 100, 0]);
        let (mut st, eps0) = CsaState::new(&inst);
        st.reset_refine(eps0);
        let fixed = compute_fixed(&st, eps0);
        assert_eq!(fixed.count, 0);
    }
}
