//! The shared cost-scaling driver (Algorithm 5.0 `Min-Cost`): maintain
//! (ε, f, p), call a [`RefineEngine`] per phase, halt once the refine at
//! ε = 1 (scaled integers) completes — 1-optimality at costs scaled by
//! (n+1) certifies an optimal assignment (DESIGN.md §7).

use anyhow::Result;

use crate::graph::AssignmentInstance;

use super::{AssignStats, AssignmentResult};

/// Refine-level state shared by every CSA engine: dense 0/1 flow matrix,
/// prices and excesses, over the scaled min-cost matrix.
#[derive(Debug, Clone)]
pub struct CsaState {
    pub n: usize,
    /// Scaled min-cost matrix `c(x,y) = -w(x,y) * (n+1)`, row-major.
    pub cost: Vec<i64>,
    /// Unit flows: `f[x*n+y] ∈ {0,1}`.
    pub f: Vec<i32>,
    pub px: Vec<i64>,
    pub py: Vec<i64>,
    /// Excess of X nodes (`1 - rowsum`).
    pub ex: Vec<i64>,
    /// Excess of Y nodes (`colsum - 1`).
    pub ey: Vec<i64>,
}

impl CsaState {
    pub fn new(inst: &AssignmentInstance) -> (Self, i64) {
        let n = inst.n;
        let st = Self {
            n,
            cost: inst.scaled_costs_i64(),
            f: vec![0; n * n],
            px: vec![0; n],
            py: vec![0; n],
            ex: vec![1; n],
            ey: vec![-1; n],
        };
        (st, inst.initial_epsilon())
    }

    /// Refine preamble (Algorithm 5.2 lines 3-6): de-saturate every arc
    /// and set `p(x) = -min_y (c'_p(x,y) + ε)`.
    pub fn reset_refine(&mut self, eps: i64) {
        let n = self.n;
        self.f.iter_mut().for_each(|v| *v = 0);
        self.ex.iter_mut().for_each(|v| *v = 1);
        self.ey.iter_mut().for_each(|v| *v = -1);
        for x in 0..n {
            let row_min = (0..n)
                .map(|y| self.cost[x * n + y] - self.py[y])
                .min()
                .expect("n > 0");
            self.px[x] = -(row_min + eps);
        }
    }

    /// Partially-reduced cost `c'_p(x,y) = c(x,y) - p(y)`.
    #[inline]
    pub fn cp_forward(&self, x: usize, y: usize) -> i64 {
        self.cost[x * self.n + y] - self.py[y]
    }

    /// Partially-reduced cost of the reverse arc `c'_p(y,x) = -c(x,y) - p(x)`.
    #[inline]
    pub fn cp_backward(&self, x: usize, y: usize) -> i64 {
        -self.cost[x * self.n + y] - self.px[x]
    }

    pub fn active_count(&self) -> usize {
        self.ex.iter().filter(|&&e| e > 0).count() + self.ey.iter().filter(|&&e| e > 0).count()
    }

    /// f is a flow (perfect matching) when no node holds excess.
    pub fn is_flow(&self) -> bool {
        self.ex.iter().all(|&e| e == 0) && self.ey.iter().all(|&e| e == 0)
    }

    /// Extract `assign[x] = y` (requires `is_flow()`).
    pub fn assignment(&self) -> Vec<usize> {
        let n = self.n;
        (0..n)
            .map(|x| {
                (0..n)
                    .find(|&y| self.f[x * n + y] == 1)
                    .expect("perfect matching")
            })
            .collect()
    }

    /// ε-optimality audit (test hook): every residual arc must satisfy
    /// `c_p >= -eps`.
    pub fn check_eps_optimal(&self, eps: i64) -> Result<()> {
        let n = self.n;
        for x in 0..n {
            for y in 0..n {
                let rc_fwd = self.cost[x * n + y] + self.px[x] - self.py[y];
                if self.f[x * n + y] == 0 {
                    anyhow::ensure!(
                        rc_fwd >= -eps,
                        "residual (x{x},y{y}) violates eps-optimality: {rc_fwd} < -{eps}"
                    );
                } else {
                    anyhow::ensure!(
                        -rc_fwd >= -eps,
                        "residual (y{y},x{x}) violates eps-optimality: {} < -{eps}",
                        -rc_fwd
                    );
                }
            }
        }
        Ok(())
    }
}

/// One refine engine (sequential / lock-free / wave / PJRT).
pub trait RefineEngine {
    fn name(&self) -> &'static str;
    /// Drive `st` (already `reset_refine`-ed by the caller) to a flow at
    /// ε-optimality `eps`.
    fn refine(&mut self, st: &mut CsaState, eps: i64, stats: &mut AssignStats) -> Result<()>;
}

/// ε schedule (matches python kernels/ref.py `csa_solve_ref`): refine at
/// ε₀ = C̄, then ε ← max(1, ⌈ε/α⌉), stopping after the ε = 1 refine.
pub fn epsilon_schedule(eps0: i64, alpha: i64) -> Vec<i64> {
    assert!(alpha >= 2, "alpha must be >= 2");
    let mut eps = eps0.max(1);
    let mut out = vec![eps];
    while eps > 1 {
        eps = ((eps + alpha - 1) / alpha).max(1);
        out.push(eps);
    }
    out
}

/// Full solve: scaling loop around `engine`.
pub fn solve_scaling(
    inst: &AssignmentInstance,
    alpha: i64,
    engine: &mut dyn RefineEngine,
) -> Result<AssignmentResult> {
    if inst.n == 0 {
        return Ok(AssignmentResult {
            assignment: vec![],
            weight: 0,
            stats: AssignStats::default(),
        });
    }
    let (mut st, eps0) = CsaState::new(inst);
    let mut stats = AssignStats::default();
    for eps in epsilon_schedule(eps0, alpha) {
        st.reset_refine(eps);
        engine.refine(&mut st, eps, &mut stats)?;
        stats.refines += 1;
        anyhow::ensure!(st.is_flow(), "refine at eps={eps} did not produce a flow");
    }
    let assignment = st.assignment();
    Ok(AssignmentResult {
        weight: inst.assignment_weight(&assignment),
        assignment,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_schedule_shrinks_to_one() {
        let sched = epsilon_schedule(1000, 10);
        assert_eq!(sched, vec![1000, 100, 10, 1]);
        assert_eq!(epsilon_schedule(1, 10), vec![1]);
        assert_eq!(epsilon_schedule(9, 10), vec![9, 1]);
    }

    #[test]
    fn reset_refine_prices_make_pseudoflow_0_optimal() {
        let inst = AssignmentInstance::new(3, vec![5, 1, 0, 2, 8, 1, 0, 3, 9]);
        let (mut st, eps0) = CsaState::new(&inst);
        st.reset_refine(eps0);
        // After the reset the minimum arc of each row sits at exactly
        // c_p = -eps (Algorithm 5.2 line 6), so f is eps-optimal.
        st.check_eps_optimal(eps0).unwrap();
        assert!(st.check_eps_optimal(0).is_err());
        assert_eq!(st.active_count(), 3); // every x active
    }

    #[test]
    fn state_flow_extraction() {
        let inst = AssignmentInstance::new(2, vec![1, 2, 3, 4]);
        let (mut st, _) = CsaState::new(&inst);
        st.f = vec![0, 1, 1, 0];
        st.ex = vec![0, 0];
        st.ey = vec![0, 0];
        assert!(st.is_flow());
        assert_eq!(st.assignment(), vec![1, 0]);
    }
}
