//! Successive-shortest-paths min-cost max-flow on the explicit §5
//! reduction instance `I'` — the Fig. 1 path "assignment -> max flow min
//! cost", used to certify the reduction itself (E1).
//!
//! SPFA-based Bellman–Ford potentials (costs include negative arcs from
//! the max->min conversion), unit capacities, O(n) augmentations.

use anyhow::Result;

use crate::graph::AssignmentInstance;

/// Solve the assignment instance through the explicit flow reduction;
/// returns (assignment, weight).
pub fn solve_assignment_via_mcmf(inst: &AssignmentInstance) -> Result<(Vec<usize>, i64)> {
    let n = inst.n;
    if n == 0 {
        return Ok((vec![], 0));
    }
    let (g, costs) = inst.to_mincost_network();
    let nn = g.node_count();
    let (s, t) = (g.source(), g.sink());

    // Mutable residual copies: cap per directed edge id, cost per edge id
    // (mate has negated cost).
    let m2 = g.edge_pair_count() * 2;
    let mut cap: Vec<i64> = (0..m2 as u32).map(|e| g.residual(e)).collect();
    let cost: Vec<i64> = (0..m2)
        .map(|e| {
            let pair = e / 2;
            if e % 2 == 0 {
                costs[pair]
            } else {
                -costs[pair]
            }
        })
        .collect();

    let mut total_cost = 0i64;
    let mut flow = 0i64;
    loop {
        // SPFA shortest path s -> t over residual arcs.
        const INF: i64 = i64::MAX / 4;
        let mut dist = vec![INF; nn];
        let mut in_queue = vec![false; nn];
        let mut pre: Vec<Option<u32>> = vec![None; nn];
        dist[s] = 0;
        let mut q = std::collections::VecDeque::new();
        q.push_back(s);
        in_queue[s] = true;
        while let Some(u) = q.pop_front() {
            in_queue[u] = false;
            for &e in g.out_edges(u) {
                if cap[e as usize] > 0 {
                    let v = g.edge_head(e);
                    let nd = dist[u] + cost[e as usize];
                    if nd < dist[v] {
                        dist[v] = nd;
                        pre[v] = Some(e);
                        if !in_queue[v] {
                            in_queue[v] = true;
                            q.push_back(v);
                        }
                    }
                }
            }
        }
        if dist[t] >= INF {
            break;
        }
        // Unit capacities on terminal arcs: bottleneck is 1.
        let mut bottleneck = i64::MAX;
        let mut v = t;
        while v != s {
            let e = pre[v].expect("path");
            bottleneck = bottleneck.min(cap[e as usize]);
            v = g.edge_head((e ^ 1) as u32);
        }
        let mut v = t;
        while v != s {
            let e = pre[v].expect("path");
            cap[e as usize] -= bottleneck;
            cap[(e ^ 1) as usize] += bottleneck;
            total_cost += cost[e as usize] * bottleneck;
            v = g.edge_head((e ^ 1) as u32);
        }
        flow += bottleneck;
    }
    anyhow::ensure!(flow == n as i64, "reduction flow {flow} != n {n}");

    // Extract the matching: X->Y edge pairs with flow (cap 0 on forward).
    // Edge pairs were added X-major: pair k = (x, y) with k = x*n + y for
    // the first n*n pairs.
    let mut assign = vec![usize::MAX; n];
    for x in 0..n {
        for y in 0..n {
            let e = (2 * (x * n + y)) as u32;
            if cap[e as usize] == 0 && g.capacity0(e) == 1 {
                assign[x] = y;
            }
        }
    }
    anyhow::ensure!(
        AssignmentInstance::is_permutation(&assign),
        "reduction produced a non-matching"
    );
    let weight = inst.assignment_weight(&assign);
    anyhow::ensure!(
        weight == -total_cost,
        "cost accounting mismatch: weight {weight} vs -cost {}",
        -total_cost
    );
    Ok((assign, weight))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;
    use crate::assignment::AssignmentSolver;
    use crate::util::Rng;

    #[test]
    fn reduction_matches_hungarian() {
        let mut rng = Rng::seeded(37);
        for n in [1usize, 2, 4, 6, 9] {
            let w: Vec<i64> = (0..n * n).map(|_| rng.range_i64(0, 50)).collect();
            let inst = AssignmentInstance::new(n, w);
            let (assign, weight) = solve_assignment_via_mcmf(&inst).unwrap();
            let want = Hungarian.solve(&inst).unwrap();
            assert_eq!(weight, want.weight, "n={n}");
            assert_eq!(weight, inst.assignment_weight(&assign));
        }
    }
}
