//! The paper's Figure 1: reductions between the analysed problems.
//!
//! * bipartite cardinality matching -> max flow (unit network);
//! * assignment -> max-flow-min-cost on the explicit instance `I'` of §5
//!   (checked against Hungarian via a successive-shortest-path solver).

pub mod matching_to_flow;
pub mod mcmf;

pub use matching_to_flow::max_cardinality_matching;
pub use mcmf::solve_assignment_via_mcmf;
