//! Unweighted bipartite matching via max flow (the classic reduction the
//! paper cites from CLRS §"Maximum bipartite matching"): unit source/sink
//! arcs, unit X->Y arcs, max-flow value = maximum matching cardinality.

use anyhow::Result;

use crate::graph::csr::NetworkBuilder;
use crate::maxflow::MaxFlowSolver;

/// `edges[x]` lists the Y-neighbours of X node `x` (|X| = nx, |Y| = ny).
/// Returns (cardinality, matching pairs), solving with `engine`.
pub fn max_cardinality_matching(
    nx: usize,
    ny: usize,
    edges: &[Vec<usize>],
    engine: &dyn MaxFlowSolver,
) -> Result<(usize, Vec<(usize, usize)>)> {
    assert_eq!(edges.len(), nx);
    let n = nx + ny + 2;
    let (s, t) = (n - 2, n - 1);
    let mut b = NetworkBuilder::new(n, s, t);
    let mut xy_edges = Vec::new();
    for (x, nbrs) in edges.iter().enumerate() {
        for &y in nbrs {
            assert!(y < ny, "edge to out-of-range y {y}");
            let e = b.add_edge(x, nx + y, 1, 0);
            xy_edges.push((e, x, y));
        }
    }
    for x in 0..nx {
        b.add_edge(s, x, 1, 0);
    }
    for y in 0..ny {
        b.add_edge(nx + y, t, 1, 0);
    }
    let mut g = b.build()?;
    let stats = engine.solve(&mut g)?;
    crate::graph::validate::assert_max_flow(&g, stats.value)?;

    let matching: Vec<(usize, usize)> = xy_edges
        .iter()
        .filter(|&&(e, _, _)| g.flow(e) == 1)
        .map(|&(_, x, y)| (x, y))
        .collect();
    anyhow::ensure!(
        matching.len() as i64 == stats.value,
        "matching size {} != flow value {}",
        matching.len(),
        stats.value
    );
    Ok((stats.value as usize, matching))
}

/// Independent Hopcroft–Karp-style (augmenting BFS/DFS) matcher used to
/// cross-check the reduction in tests and benches.
pub fn reference_matching(nx: usize, ny: usize, edges: &[Vec<usize>]) -> usize {
    let mut match_x: Vec<Option<usize>> = vec![None; nx];
    let mut match_y: Vec<Option<usize>> = vec![None; ny];

    fn try_augment(
        x: usize,
        edges: &[Vec<usize>],
        match_x: &mut [Option<usize>],
        match_y: &mut [Option<usize>],
        visited: &mut [bool],
    ) -> bool {
        for &y in &edges[x] {
            if visited[y] {
                continue;
            }
            visited[y] = true;
            if match_y[y].is_none()
                || try_augment(match_y[y].unwrap(), edges, match_x, match_y, visited)
            {
                match_x[x] = Some(y);
                match_y[y] = Some(x);
                return true;
            }
        }
        false
    }

    let mut size = 0;
    for x in 0..nx {
        let mut visited = vec![false; ny];
        if try_augment(x, edges, &mut match_x, &mut match_y, &mut visited) {
            size += 1;
        }
    }
    size
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maxflow::dinic::Dinic;
    use crate::util::Rng;

    #[test]
    fn perfect_matching_found() {
        // 3x3 with a unique perfect matching on the diagonal.
        let edges = vec![vec![0], vec![0, 1], vec![1, 2]];
        let (size, matching) = max_cardinality_matching(3, 3, &edges, &Dinic).unwrap();
        assert_eq!(size, 3);
        assert_eq!(matching.len(), 3);
    }

    #[test]
    fn deficient_graph() {
        // Both X nodes only see y0: matching is 1.
        let edges = vec![vec![0], vec![0]];
        let (size, _) = max_cardinality_matching(2, 2, &edges, &Dinic).unwrap();
        assert_eq!(size, 1);
    }

    #[test]
    fn matches_reference_on_random_graphs() {
        let mut rng = Rng::seeded(31);
        for _ in 0..10 {
            let nx = 2 + rng.index(8);
            let ny = 2 + rng.index(8);
            let edges: Vec<Vec<usize>> = (0..nx)
                .map(|_| (0..ny).filter(|_| rng.chance(0.4)).collect())
                .collect();
            let (size, matching) = max_cardinality_matching(nx, ny, &edges, &Dinic).unwrap();
            assert_eq!(size, reference_matching(nx, ny, &edges));
            // Matching is valid: no repeated endpoints.
            let mut used_x = vec![false; nx];
            let mut used_y = vec![false; ny];
            for (x, y) in matching {
                assert!(!used_x[x] && !used_y[y]);
                used_x[x] = true;
                used_y[y] = true;
            }
        }
    }
}
