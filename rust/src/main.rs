//! `flowmatch` — launcher for the paper's two systems.
//!
//! ```text
//! flowmatch info
//! flowmatch maxflow   --height 32 --width 32 [--cycle 512] [--seed 1] [--native] [--dimacs f.max]
//!                     [--engine auto|native|native-par|pjrt] [--threads 4] [--tile-rows 16]
//! flowmatch assign    --n 30 [--max-weight 100] [--alpha 10] [--engine csa-seq|csa-lockfree|csa-wave|hungarian|auction|pjrt] [--seed 1]
//! flowmatch segment   --height 32 --width 32 [--lambda 12] [--seed 1]
//! flowmatch optflow   --height 32 --width 32 [--features 12] [--dy 2 --dx 1]
//! flowmatch serve     --requests 50 --n 30 [--fps 20] [--native]
//! flowmatch solver-pool serve   --workers 4 --requests 40 --grid-requests 8 [--fps 20]
//! flowmatch solver-pool loadgen --workers 4 --requests 200 [--baseline] [--routing adaptive]
//! flowmatch solver-pool loadgen --workers 4 --sessions 4 --session-updates 8 [--session-budget-mb 64]
//! flowmatch artifacts
//! ```

use anyhow::{bail, ensure, Result};

use flowmatch::assignment::{self, AssignmentSolver};
use flowmatch::cli::Args;
use flowmatch::config;
use flowmatch::coordinator::{self, AssignmentService, GridEngine, ServiceConfig};
use flowmatch::graph::dimacs;
use flowmatch::runtime::ArtifactRegistry;
use flowmatch::util::stats::{fmt_count_pairs, fmt_duration};
use flowmatch::util::{Rng, Timer};
use flowmatch::workloads;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: Args) -> Result<()> {
    // Read FLOWMATCH_LOG once, up front, so every thread any command
    // spawns inherits the same level.
    flowmatch::util::logging::ensure_init();
    match args.command.as_deref() {
        Some("info") => cmd_info(),
        Some("maxflow") => cmd_maxflow(&args),
        Some("assign") => cmd_assign(&args),
        Some("segment") => cmd_segment(&args),
        Some("optflow") => cmd_optflow(&args),
        Some("serve") => cmd_serve(&args),
        Some("solver-pool") => cmd_solver_pool(&args),
        Some("artifacts") => cmd_artifacts(),
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "flowmatch <info|maxflow|assign|segment|optflow|serve|solver-pool|artifacts> [options]
  maxflow   --height H --width W [--cycle N] [--seed S] [--native] [--dimacs FILE]
            [--engine auto|native|native-par|pjrt] [--threads T] [--tile-rows R]
            [--host-rounds seq|striped] [--preset paper|smoke]
            [--rmf AxFRAMES (CSR smoke on a Goldberg-Rao RMF instance; with
            --gap-relabel / --scaling, self-asserts the opt-in heuristics
            match the default engine's flow)] [--relabel-min-nodes N]
  assign    --n N [--max-weight C] [--alpha A] [--engine NAME] [--seed S] [--preset paper|smoke]
  segment   --height H --width W [--lambda L] [--seed S]
  optflow   --height H --width W [--features K] [--dy D --dx D]
  serve     --requests R --n N [--fps F] [--native] [--batch B]
  solver-pool <serve|loadgen>
            [--workers W] [--requests R] [--grid-requests G] [--n N] [--grid S]
            [--large-grid S] [--fps F] [--queue-depth D] [--max-units U] [--seed S]
            [--routing static|adaptive] [--probe-every N] [--spill-depth D]
            [--host-rounds seq|striped] [--stripe-balance fixed|weighted]
            [--commit two_pass|merged] [--relabel-min-nodes N]
            [--native] [--preset paper|smoke] [--baseline (loadgen)]
            [--max-retries N] [--deadline-ms MS] [--breaker-threshold N (consecutive failures
            that trip a circuit breaker; 0 disables)]
            [--chaos SEED (loadgen; seeded fault injection,
            asserts zero lost replies)]
            [--batch-max K (cut up to K compatible grid jobs per dispatch;
            1 = batching off, loadgen self-asserts a multi-instance batch)]
            [--batch-linger-us US (max wait for batch-mates; realtime lane
            never lingers)]
            [--sessions K (loadgen; warm-start delta-trace smoke, asserts warm hits + zero lost)]
            [--session-updates U] [--session-edits E] [--session-budget-mb MB]
            [--metrics-interval SECS (dump the metrics exposition every SECS and at shutdown)]
            [--metrics-out FILE (write the exposition to FILE instead of stdout)]";

fn cmd_info() -> Result<()> {
    println!("flowmatch — parallel flow and matching algorithms (Łupińska 2011 reproduction)");
    println!("PJRT: {}", flowmatch::runtime::client::platform_info()?);
    match ArtifactRegistry::discover() {
        Ok(reg) => {
            println!("artifacts:");
            for spec in reg.iter() {
                println!(
                    "  {} ({:?} {}x{}, k_inner={})",
                    spec.name, spec.kind, spec.dim0, spec.dim1, spec.k_inner
                );
            }
        }
        Err(e) => println!("artifacts: none ({e})"),
    }
    Ok(())
}

fn cmd_maxflow(args: &Args) -> Result<()> {
    args.expect_known(&[
        "height", "width", "cycle", "seed", "native", "dimacs", "max-cap", "engine", "threads",
        "tile-rows", "host-rounds", "preset", "rmf", "gap-relabel", "scaling",
        "relabel-min-nodes",
    ])?;
    if let Some(spec) = args.get("rmf") {
        return cmd_maxflow_rmf(args, spec);
    }
    if let Some(path) = args.get("dimacs") {
        // CSR path: solve a DIMACS file with every engine.  With
        // --threads the push-relabel engines borrow one worker pool for
        // their (striped) periodic global relabels.
        let text = std::fs::read_to_string(path)?;
        let parsed = dimacs::MaxFlowFile::parse(&text)?;
        let pool = match args.get_usize("threads", 0)? {
            0 => None,
            t => Some(std::sync::Arc::new(flowmatch::service::WorkerPool::new(t))),
        };
        for engine in flowmatch::maxflow::all_engines_with(pool) {
            let mut g = parsed.to_network()?;
            let t = Timer::start();
            let stats = engine.solve(&mut g)?;
            println!(
                "{:<16} value={} pushes={} relabels={} time={}",
                engine.name(),
                stats.value,
                stats.pushes,
                stats.relabels,
                fmt_duration(t.elapsed())
            );
        }
        return Ok(());
    }
    // Defaults come from the preset only when one is asked for, so the
    // bare CLI behaviour is unchanged.
    let cfg = match args.get("preset") {
        Some(p) => Some(config::preset(p)?),
        None => None,
    };
    let mut d_cycle = 512usize;
    let mut d_threads = 4usize;
    let mut d_tile_rows = 16usize;
    let mut d_engine = "auto";
    let mut d_host_rounds = "seq";
    if let Some(c) = &cfg {
        d_cycle = c.get_usize("maxflow.cycle", d_cycle)?;
        d_threads = c.get_usize("maxflow.threads", d_threads)?;
        d_tile_rows = c.get_usize("maxflow.tile_rows", d_tile_rows)?;
        if let Some(e) = c.get("maxflow.engine") {
            d_engine = e;
        }
        if let Some(hr) = c.get("gridflow.host_rounds") {
            d_host_rounds = hr;
        }
    }
    let height = args.get_usize("height", 32)?;
    let width = args.get_usize("width", 32)?;
    let cycle = args.get_usize("cycle", d_cycle)?;
    let seed = args.get_u64("seed", 1)?;
    let max_cap = args.get_i64("max-cap", 32)?;
    let threads = args.get_usize("threads", d_threads)?;
    let tile_rows = args.get_usize("tile-rows", d_tile_rows)?;
    let engine_name = args.get_str("engine", d_engine);
    let engine = match engine_name {
        "auto" => GridEngine::Auto,
        "native" => GridEngine::Native,
        "native-par" => GridEngine::NativePar { threads, tile_rows },
        // Forced device path: the PJRT artifact when one matches the
        // shape, else the bit-exact host-simulated device.
        "pjrt" => GridEngine::Pjrt,
        other => bail!("unknown grid engine {other:?} (expected auto, native, native-par, pjrt)"),
    };
    let host_rounds =
        flowmatch::gridflow::HostRounds::parse(args.get_str("host-rounds", d_host_rounds))?;
    let mut rng = Rng::seeded(seed);
    let net = workloads::random_grid(&mut rng, height, width, max_cap, 0.25, 0.25);

    // Artifact discovery only matters on the Auto and Pjrt paths;
    // forced native engines never consult the registry.
    let registry = if args.flag("native")
        || !matches!(engine, GridEngine::Auto | GridEngine::Pjrt)
    {
        None
    } else {
        ArtifactRegistry::discover().ok()
    };
    let t = Timer::start();
    let (report, backend) =
        coordinator::solve_grid_opts(&net, cycle, registry.as_ref(), engine, host_rounds, None)?;
    let elapsed = t.elapsed();
    println!(
        "grid {}x{} seed={} backend={:?} host_rounds={}: maxflow={} (ExcessTotal={})",
        height,
        width,
        seed,
        backend,
        host_rounds.name(),
        report.flow,
        report.excess_total
    );
    println!(
        "  rounds={} waves={} pushes={} relabels={} gap_cells={} cancelled={}",
        report.host_rounds,
        report.waves,
        report.pushes,
        report.relabels,
        report.gap_cells,
        report.cancelled_arcs
    );
    println!(
        "  time={} (device={} host={})",
        fmt_duration(elapsed),
        fmt_duration(report.device_seconds),
        fmt_duration(report.host_seconds)
    );
    Ok(())
}

/// `maxflow --rmf AxFRAMES`: the §E15 heuristics smoke.  Solves one
/// Goldberg–Rao RMF instance with the default FIFO engine, then again
/// with whatever opt-in heuristics the flags ask for (`--gap-relabel`,
/// `--scaling`) on the FIFO, highest-label, and hybrid engines — and
/// fails unless every flow agrees with the default.  CI runs this as a
/// one-liner; a silent heuristic regression becomes a hard error here.
fn cmd_maxflow_rmf(args: &Args, spec: &str) -> Result<()> {
    use flowmatch::maxflow::{
        fifo::FifoPushRelabel, highest::HighestLabel, hybrid::Hybrid, MaxFlowSolver, ScalingMode,
    };
    let (a, frames) = match spec.split_once('x') {
        Some((a, f)) => (a.parse::<usize>()?, f.parse::<usize>()?),
        None => bail!("--rmf expects AxFRAMES, e.g. --rmf 4x6"),
    };
    ensure!(a >= 2 && frames >= 2, "--rmf needs a >= 2 and frames >= 2");
    let seed = args.get_u64("seed", 1)?;
    let max_cap = args.get_i64("max-cap", 16)?;
    let gap = args.flag("gap-relabel");
    let scaling = if args.flag("scaling") {
        ScalingMode::Delta
    } else {
        ScalingMode::Off
    };
    let min_nodes = args.get_usize(
        "relabel-min-nodes",
        flowmatch::maxflow::global_relabel::STRIPED_RELABEL_MIN_NODES,
    )?;
    let pool = match args.get_usize("threads", 0)? {
        0 => None,
        t => Some(std::sync::Arc::new(flowmatch::service::WorkerPool::new(t))),
    };

    let mut rng = Rng::seeded(seed);
    let mut g = workloads::rmf_network(&mut rng, a, frames, max_cap);
    let t = Timer::start();
    let want = FifoPushRelabel::default().solve(&mut g)?;
    println!(
        "rmf {a}x{a}x{frames} seed={seed}: {:<16} value={} pushes={} relabels={} time={}",
        "fifo (baseline)",
        want.value,
        want.pushes,
        want.relabels,
        fmt_duration(t.elapsed())
    );

    let mut fifo = FifoPushRelabel::default()
        .with_scaling(scaling)
        .with_striped_min_nodes(min_nodes);
    if gap {
        fifo = fifo.with_gap();
    }
    let mut highest = HighestLabel::default()
        .with_scaling(scaling)
        .with_striped_min_nodes(min_nodes);
    let mut hybrid = Hybrid::default()
        .with_scaling(scaling)
        .with_striped_min_nodes(min_nodes);
    if gap {
        hybrid = hybrid.with_gap();
    }
    if let Some(p) = &pool {
        fifo = fifo.with_relabel_pool(std::sync::Arc::clone(p));
        highest = highest.with_relabel_pool(std::sync::Arc::clone(p));
        hybrid = hybrid.with_relabel_pool(std::sync::Arc::clone(p));
    }
    let engines: [Box<dyn MaxFlowSolver>; 3] = [Box::new(fifo), Box::new(highest), Box::new(hybrid)];
    for engine in engines {
        g.reset();
        let t = Timer::start();
        let stats = engine.solve(&mut g)?;
        println!(
            "  {:<16} value={} pushes={} relabels={} gap_relabels={} gap_nodes={} rounds={} time={}",
            engine.name(),
            stats.value,
            stats.pushes,
            stats.relabels,
            stats.gap_relabels,
            stats.gap_nodes,
            stats.rounds,
            fmt_duration(t.elapsed())
        );
        ensure!(
            stats.value == want.value,
            "{} returned flow {} but the default engine found {}",
            engine.name(),
            stats.value,
            want.value
        );
    }
    println!(
        "rmf: OK — gap-relabel={} scaling={} agree with the default flow {}",
        gap,
        scaling.name(),
        want.value
    );
    Ok(())
}

fn cmd_assign(args: &Args) -> Result<()> {
    args.expect_known(&["n", "max-weight", "alpha", "engine", "seed", "preset"])?;
    let mut cfg = config::preset("paper")?;
    if let Some(p) = args.get("preset") {
        cfg = config::preset(p)?;
    }
    let n = args.get_usize("n", cfg.get_usize("assign.max_n", 30)?)?;
    let max_weight = args.get_i64("max-weight", cfg.get_i64("assign.max_weight", 100)?)?;
    let alpha = args.get_i64("alpha", cfg.get_i64("assign.alpha", 10)?)?;
    let seed = args.get_u64("seed", 1)?;
    let engine_name = args.get_str("engine", "csa-lockfree");

    let mut rng = Rng::seeded(seed);
    let inst = workloads::uniform_costs(&mut rng, n, max_weight);

    let t = Timer::start();
    let result = match engine_name {
        "pjrt" => {
            let reg = ArtifactRegistry::discover()?;
            let mut driver = coordinator::PjrtAssignmentDriver::for_size(&reg, n)?;
            driver.alpha = alpha;
            let (r, tel) = driver.solve(&inst)?;
            println!(
                "  device_rounds={} price_updates={} padded_n={} device={} host={}",
                tel.device_rounds,
                tel.host_price_updates,
                tel.padded_n,
                fmt_duration(tel.device_seconds),
                fmt_duration(tel.host_seconds)
            );
            r
        }
        "hungarian" => assignment::hungarian::Hungarian.solve(&inst)?,
        "auction" => assignment::auction::Auction::default().solve(&inst)?,
        "csa-seq" => assignment::csa::SequentialCsa::with_alpha(alpha).solve(&inst)?,
        "csa-wave" => assignment::wave::WaveCsa { alpha: Some(alpha) }.solve(&inst)?,
        "csa-lockfree" => assignment::csa_lockfree::LockFreeCsa {
            alpha,
            threads: 2,
        }
        .solve(&inst)?,
        other => bail!("unknown engine {other:?}"),
    };
    let elapsed = t.elapsed();

    // Always cross-check against the exact baseline.
    let want = assignment::hungarian::Hungarian.solve(&inst)?;
    anyhow::ensure!(
        result.weight == want.weight,
        "engine {engine_name} returned weight {} but optimum is {}",
        result.weight,
        want.weight
    );
    println!(
        "assign n={n} C={max_weight} alpha={alpha} engine={engine_name}: weight={} (optimal) time={}",
        result.weight,
        fmt_duration(elapsed)
    );
    println!(
        "  pushes={} relabels={} refines={} price_updates={} waves={}",
        result.stats.pushes,
        result.stats.relabels,
        result.stats.refines,
        result.stats.price_updates,
        result.stats.waves
    );
    Ok(())
}

fn cmd_segment(args: &Args) -> Result<()> {
    args.expect_known(&["height", "width", "lambda", "seed"])?;
    let height = args.get_usize("height", 32)?;
    let width = args.get_usize("width", 32)?;
    let lambda = args.get_i64("lambda", 12)?;
    let seed = args.get_u64("seed", 1)?;
    let mut rng = Rng::seeded(seed);
    let img = workloads::grid_gen::synthetic_image(&mut rng, height, width);
    let mut exec = flowmatch::gridflow::NativeGridExecutor::default();
    let t = Timer::start();
    let seg = flowmatch::energy::segment_image(&img, height, width, lambda, &mut exec)?;
    println!(
        "segment {}x{} lambda={}: energy={} flow={} foreground={} time={}",
        height,
        width,
        lambda,
        seg.energy,
        seg.flow,
        seg.foreground,
        fmt_duration(t.elapsed())
    );
    print!(
        "{}",
        flowmatch::energy::segmentation::ascii_render(&seg.labels, height, width)
    );
    Ok(())
}

fn cmd_optflow(args: &Args) -> Result<()> {
    args.expect_known(&["height", "width", "features", "dy", "dx", "seed"])?;
    let height = args.get_usize("height", 32)?;
    let width = args.get_usize("width", 32)?;
    let features = args.get_usize("features", 12)?;
    let dy = args.get_i64("dy", 2)?;
    let dx = args.get_i64("dx", 1)?;
    let seed = args.get_u64("seed", 1)?;
    let mut rng = Rng::seeded(seed);
    let frame_a = workloads::grid_gen::synthetic_image(&mut rng, height, width);
    let frame_b = flowmatch::opticalflow::flow::translate_image(&frame_a, height, width, dy, dx);
    let solver = assignment::csa::SequentialCsa::default();
    let t = Timer::start();
    let field =
        flowmatch::opticalflow::compute_flow(&frame_a, &frame_b, height, width, features, &solver)?;
    println!(
        "optflow {}x{} features={}: matches={} weight={} epe={:.3} time={}",
        height,
        width,
        features,
        field.vectors.len(),
        field.matching_weight,
        field.mean_endpoint_error(dy as f64, dx as f64),
        fmt_duration(t.elapsed())
    );
    for v in field.vectors.iter().take(8) {
        println!(
            "  ({:>2},{:>2}) -> ({:>2},{:>2})",
            v.from.0, v.from.1, v.to.0, v.to.1
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_known(&["requests", "n", "fps", "native", "batch", "seed"])?;
    let requests = args.get_usize("requests", 50)?;
    let n = args.get_usize("n", 30)?;
    let fps = args.get_f64("fps", 20.0)?;
    let seed = args.get_u64("seed", 1)?;
    let batch = args.get_usize("batch", 8)?;

    let cfg = workloads::TraceConfig {
        requests,
        n,
        arrival_gap: if fps > 0.0 { 1.0 / fps } else { 0.0 },
        ..Default::default()
    };
    let mut rng = Rng::seeded(seed);
    let trace = workloads::RequestTrace::generate(&mut rng, &cfg);

    let service = AssignmentService::start(ServiceConfig {
        max_batch: batch,
        use_pjrt: !args.flag("native"),
        max_n: n.max(30),
    });
    let start = Timer::start();
    let mut receivers = Vec::new();
    for req in &trace.requests {
        // Open-loop arrivals at the trace's frame rate.
        let target = req.arrival;
        let now = start.elapsed();
        if target > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        receivers.push(service.submit(req.instance.clone()));
    }
    let mut ok = 0usize;
    for rx in receivers {
        let reply = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("service dropped reply"))?
            .map_err(|e| anyhow::anyhow!(e))?;
        ok += 1;
        let _ = reply;
    }
    let report = service.shutdown()?;
    println!(
        "serve: {} requests, backend={}, p50={} p99={} mean={} throughput={:.1} req/s",
        ok,
        report.backend,
        fmt_duration(report.p50_latency),
        fmt_duration(report.p99_latency),
        fmt_duration(report.mean_latency),
        report.throughput_rps
    );
    if !report.backends.is_empty() {
        println!("  backends: [{}]", fmt_count_pairs(&report.backends));
    }
    println!(
        "  paper §6 bar: 1/20 s per solve -> p50 {} that bar",
        if report.p50_latency <= 0.05 {
            "MEETS"
        } else {
            "misses"
        }
    );
    Ok(())
}

/// Write the global registry's Prometheus-style exposition to `path`
/// (replacing the previous dump) or to stdout.
fn dump_metrics(path: Option<&str>) {
    let text = flowmatch::obs::global().render_text();
    match path {
        Some(p) => {
            if let Err(e) = std::fs::write(p, text.as_bytes()) {
                eprintln!("metrics: failed to write {p}: {e}");
            }
        }
        None => print!("{text}"),
    }
}

fn fmt_lat(tag: &str, s: &Option<flowmatch::util::stats::Summary>) -> String {
    match s {
        Some(s) => format!(
            "{tag}: p50={} p95={} p99={} max={} mean={} ({} reqs)",
            fmt_duration(s.p50),
            fmt_duration(s.p95),
            fmt_duration(s.p99),
            fmt_duration(s.max),
            fmt_duration(s.mean),
            s.count
        ),
        None => format!("{tag}: no samples"),
    }
}

fn cmd_solver_pool(args: &Args) -> Result<()> {
    args.expect_known(&[
        "workers",
        "requests",
        "grid-requests",
        "n",
        "grid",
        "large-grid",
        "fps",
        "queue-depth",
        "max-units",
        "seed",
        "native",
        "preset",
        "baseline",
        "cycle",
        "threads",
        "tile-rows",
        "routing",
        "probe-every",
        "spill-depth",
        "host-rounds",
        "stripe-balance",
        "commit",
        "relabel-min-nodes",
        "max-retries",
        "deadline-ms",
        "breaker-threshold",
        "batch-max",
        "batch-linger-us",
        "chaos",
        "sessions",
        "session-updates",
        "session-edits",
        "session-budget-mb",
        "metrics-interval",
        "metrics-out",
    ])?;
    let action = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("serve");
    if action != "serve" && action != "loadgen" {
        bail!("unknown solver-pool action {action:?} (expected serve or loadgen)");
    }

    let mut pool_cfg = match args.get("preset") {
        Some(p) => flowmatch::service::PoolConfig::from_config(&config::preset(p)?)?,
        None => flowmatch::service::PoolConfig::default(),
    };
    pool_cfg.workers = args.get_usize("workers", pool_cfg.workers)?;
    pool_cfg.shard.queue_depth = args.get_usize("queue-depth", pool_cfg.shard.queue_depth)?;
    pool_cfg.shard.max_units = args.get_usize("max-units", pool_cfg.shard.max_units)?;
    pool_cfg.router.cycle_waves = args.get_usize("cycle", pool_cfg.router.cycle_waves)?;
    pool_cfg.router.par_threads = args.get_usize("threads", pool_cfg.router.par_threads)?;
    pool_cfg.router.tile_rows = args.get_usize("tile-rows", pool_cfg.router.tile_rows)?;
    if let Some(mode) = args.get("routing") {
        pool_cfg.router.routing = flowmatch::service::RoutingMode::parse(mode)?;
    }
    pool_cfg.router.probe_every = args.get_usize("probe-every", pool_cfg.router.probe_every)?;
    pool_cfg.router.spill_depth = args.get_usize("spill-depth", pool_cfg.router.spill_depth)?;
    if let Some(hr) = args.get("host-rounds") {
        pool_cfg.router.host_rounds = flowmatch::service::HostRounds::parse(hr)?;
    }
    if let Some(b) = args.get("stripe-balance") {
        pool_cfg.router.tuning.balance = flowmatch::parallel::StripeBalance::parse(b)?;
    }
    if let Some(c) = args.get("commit") {
        pool_cfg.router.tuning.commit = flowmatch::parallel::CommitMode::parse(c)?;
    }
    pool_cfg.router.striped_relabel_min_nodes = args.get_usize(
        "relabel-min-nodes",
        pool_cfg.router.striped_relabel_min_nodes,
    )?;
    if args.flag("native") {
        pool_cfg.router.use_pjrt = false;
    }
    pool_cfg.router.max_retries = args.get_usize("max-retries", pool_cfg.router.max_retries)?;
    pool_cfg.router.breaker_threshold =
        args.get_usize("breaker-threshold", pool_cfg.router.breaker_threshold)?;
    // Micro-batching: at the default batch_max = 1 the queues and the
    // routing are bit-identical to the pre-batching service.
    pool_cfg.router.batch_max = args.get_usize("batch-max", pool_cfg.router.batch_max)?;
    pool_cfg.router.batch_linger_us =
        args.get_usize("batch-linger-us", pool_cfg.router.batch_linger_us as usize)? as u64;
    // Chaos mode: wrap one backend in a seeded deterministic fault plan
    // (periodic panics + injected failures, never corrupted answers) so
    // the retry/breaker machinery is exercised end to end.
    let chaos = args.get("chaos").is_some();
    if chaos {
        if action != "loadgen" {
            bail!("--chaos is a loadgen option (open-loop serve timing would mask faults)");
        }
        let chaos_seed = args.get_u64("chaos", 0)?;
        let plan = flowmatch::service::FaultPlan::chaos(chaos_seed);
        println!(
            "chaos: seed {chaos_seed} -> {} panics every {} solves, fails every {}",
            plan.target, plan.panic_every, plan.fail_every
        );
        pool_cfg.router.fault = Some(plan);
    }
    let deadline_ms = args.get_f64("deadline-ms", 0.0)?;

    let requests = args.get_usize("requests", 40)?;
    let grid_requests = args.get_usize("grid-requests", 8)?;
    let n = args.get_usize("n", 30)?;
    // Defaults straddle the default shard boundaries: 48² grids are
    // Medium, 96² grids are Large, matchings are Small.
    let grid = args.get_usize("grid", 48)?;
    let large_grid = args.get_usize("large-grid", 96)?;
    let fps = args.get_f64("fps", 20.0)?;
    let seed = args.get_u64("seed", 1)?;
    pool_cfg.router.pjrt_max_n = pool_cfg.router.pjrt_max_n.max(n);

    // Warm-start session smoke: replay a delta trace through the
    // session API instead of the mixed cold trace.  Self-asserting
    // like --chaos, so CI can run it as a one-liner.
    let sessions = args.get_usize("sessions", 0)?;
    pool_cfg.session_budget_mb =
        args.get_usize("session-budget-mb", pool_cfg.session_budget_mb)?;
    if sessions > 0 {
        if action != "loadgen" {
            bail!("--sessions is a loadgen option");
        }
        if chaos {
            bail!("--chaos and --sessions are separate smokes (sessions bypass the fault-injected backend registry)");
        }
        let dcfg = workloads::DeltaTraceConfig {
            sessions,
            updates_per_session: args.get_usize("session-updates", 8)?,
            edits_per_update: args.get_usize("session-edits", 4)?,
            grid_size: grid,
            deadline: deadline_ms / 1000.0,
            ..Default::default()
        };
        let mut rng = Rng::seeded(seed);
        let trace = workloads::DeltaTrace::generate(&mut rng, &dcfg);
        println!(
            "solver-pool sessions: {} requests ({sessions} opens + {} updates) on {grid}² grids, \
             {} workers, session budget {} MiB",
            trace.len(),
            trace.update_count(),
            pool_cfg.workers,
            pool_cfg.session_budget_mb
        );
        let pool = flowmatch::service::SolverPool::start(pool_cfg);
        let out = flowmatch::service::replay_sessions(&pool, &trace);
        let report = pool.shutdown();
        println!(
            "client : opens={} warm={} cold_fallback={} rejected={} failed={} lost={} \
             warm_rate={:.0}% wall={}",
            out.opens,
            out.warm_hits,
            out.cold_fallbacks,
            out.rejected,
            out.failed,
            out.lost,
            100.0 * out.warm_rate(),
            fmt_duration(out.wall_seconds)
        );
        println!("  {}", fmt_lat("sessions  ", &out.overall));
        println!(
            "server : served={} warm_served={} sessions_evicted={} via [{}]",
            report.served,
            report.warm_served,
            report.sessions_evicted,
            fmt_count_pairs(&report.backends)
        );
        ensure!(
            out.lost == 0,
            "session run lost {} repl(ies) — every request must get exactly one reply",
            out.lost
        );
        ensure!(
            out.warm_hits > 0,
            "session run served no update warm — the residual caches never hit"
        );
        println!(
            "sessions: OK — {} of {} updates served warm, 0 lost replies",
            out.warm_hits,
            trace.update_count()
        );
        return Ok(());
    }

    // serve = open-loop at the trace's frame rate (the §6 real-time
    // shape); loadgen = closed-loop (the throughput shape).
    let open_loop = action == "serve" && fps > 0.0;
    let gap = if open_loop { 1.0 / fps } else { 0.0 };
    let trace_cfg = workloads::MixedTraceConfig {
        assign: workloads::TraceConfig {
            requests,
            n,
            arrival_gap: gap,
            ..Default::default()
        },
        grid_requests,
        grid_size: grid,
        large_size: large_grid,
        grid_arrival_gap: if open_loop { 3.0 * gap } else { 0.0 },
        deadline: deadline_ms / 1000.0,
        ..Default::default()
    };
    let mut rng = Rng::seeded(seed);
    let trace = workloads::MixedTrace::generate(&mut rng, &trace_cfg);
    println!(
        "solver-pool {action}: {} requests ({} assignment n={n}, {} grid {grid}²/{large_grid}²), \
         {} workers, routing={}, host_rounds={}, stripe_balance={}, commit={}",
        trace.len(),
        trace.assignment_count(),
        trace.grid_count(),
        pool_cfg.workers,
        pool_cfg.router.routing.name(),
        pool_cfg.router.host_rounds.name(),
        pool_cfg.router.tuning.balance.name(),
        pool_cfg.router.tuning.commit.name()
    );

    let shard_cfg = pool_cfg.shard.clone();
    let router_cfg = pool_cfg.router.clone();
    let metrics_interval = args.get_f64("metrics-interval", 0.0)?;
    let metrics_out = args.get("metrics-out").map(|s| s.to_string());
    let pool = flowmatch::service::SolverPool::start(pool_cfg);
    // Live introspection: a scoped sidecar thread refreshes the gauges
    // and dumps the exposition every --metrics-interval seconds while
    // the replay runs, then stops with it (the scope joins it).
    let stop = std::sync::atomic::AtomicBool::new(false);
    let out = std::thread::scope(|s| {
        if metrics_interval > 0.0 {
            let pool = &pool;
            let stop = &stop;
            let path = metrics_out.clone();
            s.spawn(move || {
                let tick = std::time::Duration::from_millis(25);
                let mut since_dump = 0.0f64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    since_dump += tick.as_secs_f64();
                    if since_dump >= metrics_interval {
                        since_dump = 0.0;
                        pool.publish_gauges();
                        dump_metrics(path.as_deref());
                    }
                }
            });
        }
        let out = flowmatch::service::replay(&pool, &trace, open_loop);
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        out
    });
    let report = pool.shutdown();
    if metrics_interval > 0.0 || metrics_out.is_some() {
        // Final exposition after shutdown: queues drained, gauges in
        // their final state, counters equal to the report printed below.
        dump_metrics(metrics_out.as_deref());
    }

    println!(
        "client : ok={} rejected={} failed={} wall={} throughput={:.1} req/s",
        out.ok,
        out.rejected,
        out.failed,
        fmt_duration(out.wall_seconds),
        out.throughput_rps
    );
    if !out.reject_reasons.is_empty() {
        println!("  rejects: {}", fmt_count_pairs(&out.reject_reasons));
    }
    println!("  {}", fmt_lat("assignment", &out.assign));
    println!("  {}", fmt_lat("grid      ", &out.grid));
    if !out.phases.is_zero() {
        println!("  phases : {}", out.phases.fmt_compact());
    }
    for class in flowmatch::service::SizeClass::ALL {
        println!(
            "  {}",
            fmt_lat(
                &format!("{:<10}", class.name()),
                &report.class_latency[class.index()]
            )
        );
    }
    println!(
        "server : served={} via [{}]",
        report.served,
        fmt_count_pairs(&report.backends)
    );
    if report.spilled > 0 {
        println!(
            "  spill  : {} Large grid solve(s) re-routed to fifo-lockfree (wave pool saturated)",
            report.spilled
        );
    }
    if report.batches > 0 || report.linger_sheds > 0 {
        println!(
            "  batch  : dispatches={} jobs={} padding_waste_cells={} linger_sheds={}",
            report.batches, report.batched_jobs, report.padding_waste_cells, report.linger_sheds
        );
    }
    // Fault-tolerance counters: printed whenever anything non-trivial
    // happened, so a clean run stays a clean report.
    if out.retries > 0
        || out.breaker_skips > 0
        || out.deadline_misses > 0
        || out.lost > 0
        || report.failed > 0
        || report.respawns > 0
    {
        println!(
            "  faults : retries={} breaker_skips={} deadline_miss={} lost={} failed={} respawns={}",
            out.retries, out.breaker_skips, out.deadline_misses, out.lost, report.failed, report.respawns
        );
    }
    for b in report.breakers.iter().filter(|b| b.state != "closed") {
        println!(
            "  breaker: {}/{} {} is {} (streak {}, opened {}x)",
            b.family.name(),
            b.class.name(),
            b.backend,
            b.state,
            b.consecutive_failures,
            b.opened_total
        );
    }
    // Routing telemetry: one line per (family, class) with each
    // backend's route count and latency EWMA.
    for family in flowmatch::service::Family::ALL {
        for class in flowmatch::service::SizeClass::ALL {
            let rows: Vec<String> = report
                .routes
                .iter()
                .filter(|r| r.family == family && r.class == class)
                .map(|r| {
                    let ewma = r
                        .ewma_seconds
                        .map_or_else(|| "—".to_string(), fmt_duration);
                    format!("{}={} (ewma {})", r.backend, r.count, ewma)
                })
                .collect();
            if !rows.is_empty() {
                println!(
                    "  routes : {}/{:<6} {}",
                    family.name(),
                    class.name(),
                    rows.join("  ")
                );
            }
        }
    }
    if let Some(s) = &out.assign {
        println!(
            "paper §6 bar (1/20 s per matching): p50 {} ({} vs 50 ms)",
            if s.p50 <= 0.05 { "MET" } else { "MISSED" },
            fmt_duration(s.p50)
        );
    }

    if action == "loadgen" && args.flag("baseline") {
        println!("\nbaseline: spawn-one-thread-per-request, no worker reuse...");
        let base = flowmatch::service::replay_spawn_baseline(&trace, &shard_cfg, &router_cfg);
        println!(
            "baseline: ok={} wall={} throughput={:.1} req/s",
            base.ok,
            fmt_duration(base.wall_seconds),
            base.throughput_rps
        );
        if base.wall_seconds > 0.0 && out.wall_seconds > 0.0 {
            println!(
                "pooled path speedup over per-request spawn: {:.2}x",
                base.wall_seconds / out.wall_seconds
            );
        }
    }
    if chaos {
        // The whole point of chaos mode: injected faults may slow
        // requests down but must never lose one, and the retry path
        // must actually fire.  CI runs this as a self-asserting smoke.
        ensure!(
            out.lost == 0,
            "chaos run lost {} repl(ies) — every request must get exactly one reply",
            out.lost
        );
        ensure!(
            out.retries >= 1,
            "chaos run never retried — the fault plan failed to inject"
        );
        println!(
            "chaos: OK — {} retries, 0 lost replies across {} requests",
            out.retries, out.sent
        );
    }
    if action == "loadgen" && router_cfg.batch_max > 1 {
        // Batching smoke: micro-batching must never lose a reply (each
        // slot still gets exactly one), and a closed-loop run with deep
        // queues must actually cut at least one multi-instance batch.
        ensure!(
            out.lost == 0,
            "batched run lost {} repl(ies) — every slot in a cut batch must reply",
            out.lost
        );
        ensure!(
            report.batches >= 1 && report.batched_jobs > report.batches,
            "batched run never cut a multi-instance batch \
             (dispatches={}, jobs={}) — micro-batching failed to engage",
            report.batches,
            report.batched_jobs
        );
        println!(
            "batch: OK — {} joint dispatch(es) served {} jobs, 0 lost replies",
            report.batches, report.batched_jobs
        );
    }
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let reg = ArtifactRegistry::discover()?;
    for spec in reg.iter() {
        println!(
            "{} kind={:?} dims={}x{} k_inner={} path={}",
            spec.name,
            spec.kind,
            spec.dim0,
            spec.dim1,
            spec.k_inner,
            spec.path.display()
        );
    }
    Ok(())
}
