//! Host-side parallel substrates shared by the engines.
//!
//! Two pieces live here:
//!
//! * [`Lanes`] — where a batch of jobs runs: inline on the calling
//!   thread, on freshly scoped threads, or on a borrowed persistent
//!   [`WorkerPool`] (the service's wave pool, exactly like
//!   `NativeParGridExecutor::with_pool`).  Every striped algorithm is
//!   written against `Lanes`, so the same code path serves the
//!   sequential fallback and the pooled production shape.
//! * [`frontier`] — the stripe-parallel frontier substrate: a
//!   contiguous-range partition ([`Stripes`]) plus a level-synchronous
//!   BFS engine ([`StripedFrontier`]) with per-stripe local queues and
//!   a parity-coloured two-pass commit for cross-stripe edges.  The
//!   grid host rounds (`gridflow::host`), the tiled wave engine's
//!   border reconciliation (`gridflow::par_wave`), and the
//!   general-graph global relabel (`maxflow::global_relabel`) all
//!   partition over it.
//!
//! Why stripes: in the hybrid scheme the host-side BFS is the dominant
//! serial fraction once the wave itself is parallel (Baumstark et al.,
//! arXiv:1507.01926), and contiguous-range stripes make every write
//! owner-exclusive — workers mutate disjoint `chunks_mut` slices, no
//! atomics, no locks — while cross-stripe effects are deferred to
//! outboxes and committed by the owning stripe.  Results are
//! *bit-exact* with the sequential twins for every consumer in the
//! tree: BFS levels assign unique shortest distances regardless of
//! visit order, and the deferred ops are additive.

pub mod frontier;

pub use frontier::{StripeCuts, Stripes, StripedFrontier};

use crate::service::pool::WorkerPool;

/// How stripe boundaries are chosen for a striped pass.
///
/// `Fixed` is the uniform contiguous partition (the default, bit-exact
/// with every sequential twin).  `Weighted` re-cuts the boundaries
/// between rounds/levels from observed per-stripe occupancy
/// (frontier queue sizes, active-cell counts) so non-uniform frontiers
/// spread evenly across lanes (Hsieh et al., arXiv:2404.00270).  The
/// *results* stay bit-exact either way — only the work partition moves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StripeBalance {
    #[default]
    Fixed,
    Weighted,
}

impl StripeBalance {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "fixed" => Ok(StripeBalance::Fixed),
            "weighted" => Ok(StripeBalance::Weighted),
            other => anyhow::bail!("unknown stripe_balance {other:?} (expected fixed or weighted)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            StripeBalance::Fixed => "fixed",
            StripeBalance::Weighted => "weighted",
        }
    }
}

/// How owner-exclusive commit work is batched.
///
/// `TwoPass` is the parity-coloured even-then-odd protocol (the
/// default, and the oracle twin).  `Merged` runs every owner task in
/// one batch: all commit-side writes land in owner-exclusive chunks
/// and read only outboxes that are immutable for the whole phase, so
/// the parity split is purely structural — merging halves the barrier
/// count per level/wave.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CommitMode {
    #[default]
    TwoPass,
    Merged,
}

impl CommitMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "two_pass" | "two-pass" => Ok(CommitMode::TwoPass),
            "merged" => Ok(CommitMode::Merged),
            other => anyhow::bail!("unknown commit mode {other:?} (expected two_pass or merged)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CommitMode::TwoPass => "two_pass",
            CommitMode::Merged => "merged",
        }
    }
}

/// The striped-pass tuning knobs, threaded together through the grid
/// solver, the tiled wave engine, and the frontier substrate.  The
/// default is the prior behaviour exactly: fixed uniform stripes,
/// parity two-pass commits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ParTuning {
    pub balance: StripeBalance,
    pub commit: CommitMode,
}

/// Receive side of one cross-stripe operation, deferred to the owning
/// stripe's parity commit: `cap[arc * cells + cell] += delta` and
/// `e[cell] += delta`.  Shared by the wave engine's border pushes and
/// the host round's violation-cancel receive sides — one type, one
/// protocol.
#[derive(Debug, Clone, Copy)]
pub struct CrossOp {
    pub cell: u32,
    /// Arc plane of the *reverse* arc at the receiving cell.
    pub arc: u8,
    pub delta: i32,
}

/// Execution lanes for one batch of independent jobs.
///
/// `Seq` is the fallback when no pool is supplied: jobs run inline, in
/// order, on the calling thread — same results (the striped algorithms
/// are execution-order independent), no threads.  `Scoped` spawns a
/// fresh `std::thread::scope` per batch (the pre-pool engine shape).
/// `Pool` borrows the persistent service pool: a batch costs one
/// condvar wakeup round instead of a spawn/join round.
pub enum Lanes<'p> {
    Seq,
    Scoped { threads: usize },
    Pool(&'p WorkerPool),
}

/// Round-robin task grouping: stripe tasks dealt across `width`
/// workers, exactly like the wave engine deals tiles.  Empty groups
/// are dropped so `Lanes::run` never schedules a no-op job.
pub fn deal<T>(tasks: Vec<T>, width: usize) -> Vec<Vec<T>> {
    let width = width.max(1);
    let mut groups: Vec<Vec<T>> = (0..width).map(|_| Vec::new()).collect();
    for (i, t) in tasks.into_iter().enumerate() {
        groups[i % width].push(t);
    }
    groups.retain(|g| !g.is_empty());
    groups
}

impl Lanes<'_> {
    /// How many jobs can usefully run at once — the partitioning width
    /// striped algorithms size their batches for.
    pub fn width(&self) -> usize {
        match self {
            Lanes::Seq => 1,
            Lanes::Scoped { threads } => (*threads).max(1),
            Lanes::Pool(p) => p.threads().max(1),
        }
    }

    /// Run every job to completion (the batch barrier all striped
    /// passes rely on).  A job must never re-enter the same pool.
    pub fn run<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        match self {
            Lanes::Seq => {
                for job in jobs {
                    job();
                }
            }
            Lanes::Scoped { .. } => {
                std::thread::scope(|s| {
                    for job in jobs {
                        s.spawn(job);
                    }
                });
            }
            Lanes::Pool(p) => p.scope_run(jobs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn fill_via(lanes: &Lanes<'_>) -> Vec<u64> {
        let mut data = vec![0u64; 48];
        let width = lanes.width().max(1);
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, chunk) in data.chunks_mut(48 / width.min(48)).enumerate() {
                jobs.push(Box::new(move || {
                    for (j, x) in chunk.iter_mut().enumerate() {
                        *x = (i * 1000 + j) as u64;
                    }
                }));
            }
            lanes.run(jobs);
        }
        data
    }

    #[test]
    fn all_lane_kinds_run_every_job() {
        let pool = Arc::new(WorkerPool::new(3));
        let seq = fill_via(&Lanes::Seq);
        assert_eq!(seq, fill_via(&Lanes::Scoped { threads: 3 }));
        assert_eq!(seq, fill_via(&Lanes::Pool(&pool)));
    }

    #[test]
    fn widths() {
        assert_eq!(Lanes::Seq.width(), 1);
        assert_eq!(Lanes::Scoped { threads: 4 }.width(), 4);
        assert_eq!(Lanes::Scoped { threads: 0 }.width(), 1);
        let pool = WorkerPool::new(2);
        assert_eq!(Lanes::Pool(&pool).width(), 2);
    }
}
