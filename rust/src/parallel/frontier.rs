//! The stripe-parallel frontier substrate.
//!
//! [`Stripes`] partitions `0..len` item indices (grid cells in row-major
//! order, CSR node ids) into contiguous equal-length ranges, so every
//! per-item array can be lent to workers as disjoint `chunks_mut`
//! slices — the same trick the tiled wave engine uses for its state
//! planes.  [`StripeCuts`] is the runtime form of that partition:
//! explicit boundary positions, so the stripes can also be *re-cut*
//! from observed workload ([`Stripes::rebalance`]) without changing
//! their count.
//!
//! [`StripedFrontier`] runs a level-synchronous multi-source BFS over
//! that partition.  Each level is two (logically; three with the parity
//! split) barriers:
//!
//! 1. **Expand** — every stripe drains its local queue, calling the
//!    caller's neighbour closure per item.  Targets inside the owning
//!    stripe are committed immediately (distance set, queued for the
//!    next level); targets in a foreign stripe go to a per-(producer ×
//!    owner) outbox — no shared writes anywhere.
//! 2. **Commit** — owners drain the outbox columns addressed to them.
//!    Under [`CommitMode::TwoPass`] this is the parity-coloured
//!    even-then-odd protocol mirroring `gridflow::par_wave`'s border
//!    reconciliation; under [`CommitMode::Merged`] all owners run in
//!    one batch (every write is owner-exclusive and the outboxes are
//!    immutable for the whole phase, so the split is structural only).
//!
//! With [`StripeBalance::Weighted`], the boundaries are re-cut between
//! levels from the previous level's per-stripe queue sizes (prefix-sum
//! interpolation), so a frontier concentrated in one region still
//! spreads across all lanes.
//!
//! Bit-exactness with a sequential queue BFS is structural: BFS
//! distances are the unique shortest-path distances from the seed set,
//! independent of visit order, and duplicate candidates are deduped by
//! the owner's distance check.  The differential tests in
//! `gridflow::host`, `maxflow::global_relabel`, and
//! `tests/prop_par_wave.rs` pin this for every consumer — across both
//! balance and commit modes.

use super::{deal, CommitMode, Lanes, ParTuning, StripeBalance};

/// A contiguous partition of `0..len` into equal-length stripes (the
/// last stripe may be ragged).  `stripe_len` is the chunk size every
/// parallel pass feeds to `chunks_mut`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stripes {
    len: usize,
    stripe_len: usize,
}

impl Stripes {
    /// Partition `len` items into (about) `target_stripes` stripes.
    pub fn new(len: usize, target_stripes: usize) -> Self {
        let stripe_len = len.div_ceil(target_stripes.max(1)).max(1);
        Self { len, stripe_len }
    }

    /// Partition a `rows x width` row-major grid on row boundaries:
    /// about `target_stripes` stripes of whole rows — the same shape as
    /// the wave engine's row-stripe tiles.
    pub fn rows(rows: usize, width: usize, target_stripes: usize) -> Self {
        let stripe_rows = rows.div_ceil(target_stripes.max(1)).max(1);
        Self {
            len: rows * width,
            stripe_len: (stripe_rows * width).max(1),
        }
    }

    /// An explicit stripe length (e.g. the wave engine's
    /// `tile_rows * width`).
    pub fn with_stripe_len(len: usize, stripe_len: usize) -> Self {
        Self {
            len,
            stripe_len: stripe_len.max(1),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn stripe_len(&self) -> usize {
        self.stripe_len
    }

    pub fn n_stripes(&self) -> usize {
        self.len.div_ceil(self.stripe_len)
    }

    /// Which stripe owns item `idx`.
    #[inline]
    pub fn owner(&self, idx: usize) -> usize {
        idx / self.stripe_len
    }

    /// The runtime cut positions of the uniform partition.
    pub fn cuts(&self) -> StripeCuts {
        StripeCuts::uniform(*self)
    }

    /// Re-cut the partition so each stripe carries about the same
    /// weight, where `weights[s]` is the observed occupancy of stripe
    /// `s` of the *uniform* partition (e.g. its frontier queue size).
    /// The stripe count is preserved; see [`StripeCuts::rebalance`].
    pub fn rebalance(&self, weights: &[u64]) -> StripeCuts {
        self.cuts().rebalance(weights, 1)
    }
}

/// Explicit stripe boundaries: `cuts[s]..cuts[s+1]` is stripe `s`.
/// `cuts[0] == 0`, `cuts[n_stripes] == len`, non-decreasing (stripes
/// may be empty after an aggressive rebalance; empty stripes simply
/// own nothing).  The uniform cuts of a [`Stripes`] reproduce its
/// `chunks_mut(stripe_len)` boundaries exactly, so `Fixed` mode is
/// bit-identical to the historical partition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StripeCuts {
    len: usize,
    cuts: Vec<usize>,
}

impl Default for StripeCuts {
    fn default() -> Self {
        Self { len: 0, cuts: vec![0] }
    }
}

impl StripeCuts {
    /// The uniform partition of `stripes`, boundary-identical to
    /// `chunks_mut(stripes.stripe_len())`.
    pub fn uniform(stripes: Stripes) -> Self {
        let ns = stripes.n_stripes();
        let mut cuts = Vec::with_capacity(ns + 1);
        cuts.push(0);
        for s in 1..=ns {
            cuts.push((s * stripes.stripe_len()).min(stripes.len()));
        }
        Self {
            len: stripes.len(),
            cuts,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_stripes(&self) -> usize {
        self.cuts.len() - 1
    }

    /// Boundary positions (`n_stripes + 1` entries).
    pub fn bounds(&self) -> &[usize] {
        &self.cuts
    }

    pub fn start(&self, s: usize) -> usize {
        self.cuts[s]
    }

    pub fn range(&self, s: usize) -> std::ops::Range<usize> {
        self.cuts[s]..self.cuts[s + 1]
    }

    /// Which stripe owns item `idx`.  With possibly-empty stripes the
    /// owner is the unique stripe whose half-open range contains `idx`.
    #[inline]
    pub fn owner(&self, idx: usize) -> usize {
        debug_assert!(idx < self.len);
        let inner = &self.cuts[1..self.cuts.len() - 1];
        inner.partition_point(|&b| b <= idx)
    }

    /// Lend `slice` out as one disjoint `&mut` chunk per stripe
    /// (the cut-aware generalisation of `chunks_mut(stripe_len)`).
    pub fn split_mut<'a, T>(&self, mut slice: &'a mut [T]) -> Vec<&'a mut [T]> {
        debug_assert_eq!(slice.len(), self.len);
        let mut out = Vec::with_capacity(self.n_stripes());
        let mut prev = 0usize;
        for &c in &self.cuts[1..] {
            let (head, tail) = slice.split_at_mut(c - prev);
            out.push(head);
            slice = tail;
            prev = c;
        }
        out
    }

    /// Re-cut so each stripe carries about `total_weight / n_stripes`,
    /// where `weights[s]` is the observed occupancy of *this*
    /// partition's stripe `s`.  Weight is interpolated uniformly inside
    /// each current stripe (the prefix-sum-over-queue-sizes scheme of
    /// Hsieh et al., arXiv:2404.00270), and every new boundary is
    /// rounded down to a multiple of `align` (pass the row width to
    /// keep grid stripes row-aligned; 1 for item granularity).  The
    /// stripe count never changes; zero total weight returns the
    /// partition unchanged.
    pub fn rebalance(&self, weights: &[u64], align: usize) -> StripeCuts {
        let ns = self.n_stripes();
        let align = align.max(1);
        debug_assert_eq!(weights.len(), ns);
        let total: u64 = weights.iter().sum();
        if ns <= 1 || total == 0 {
            return self.clone();
        }
        let mut cuts = Vec::with_capacity(ns + 1);
        cuts.push(0usize);
        let mut acc = 0u64; // cumulative weight strictly before stripe `i`
        let mut i = 0usize;
        for j in 1..ns {
            let target = (total * j as u64).div_ceil(ns as u64);
            while i < ns && acc + weights[i] < target {
                acc += weights[i];
                i += 1;
            }
            let x = if i >= ns {
                self.len
            } else {
                let span = (self.cuts[i + 1] - self.cuts[i]) as u128;
                let need = (target - acc) as u128;
                self.cuts[i] + ((need * span) / weights[i].max(1) as u128) as usize
            };
            let x = (x / align) * align;
            let prev = *cuts.last().unwrap();
            cuts.push(x.clamp(prev, self.len));
        }
        cuts.push(self.len);
        StripeCuts {
            len: self.len,
            cuts,
        }
    }
}

struct ExpandTask<'a> {
    base: usize,
    cuts: &'a StripeCuts,
    cur: &'a mut Vec<u32>,
    nxt: &'a mut Vec<u32>,
    /// This producer's outbox row: one box per owner stripe.
    row: &'a mut [Vec<u32>],
    dist: &'a mut [i32],
    count: &'a mut u64,
}

struct CommitTask<'a> {
    owner: usize,
    base: usize,
    nxt: &'a mut Vec<u32>,
    dist: &'a mut [i32],
    count: &'a mut u64,
}

/// Reusable level-synchronous BFS state: per-stripe current/next
/// queues, the (producer × owner) outboxes, and per-stripe assignment
/// counters.  Allocations survive across `reset` calls, so a solve
/// pays for the queues once.
#[derive(Debug, Default)]
pub struct StripedFrontier {
    stripes: Stripes,
    cuts: StripeCuts,
    tuning: ParTuning,
    rebalances: u64,
    current: Vec<Vec<u32>>,
    next: Vec<Vec<u32>>,
    /// Producer-major: `outbox[p * n_stripes + o]` holds targets stripe
    /// `p` discovered that stripe `o` owns.
    outbox: Vec<Vec<u32>>,
    counts: Vec<u64>,
    weights: Vec<u64>,
    redeal: Vec<u32>,
}

impl Default for Stripes {
    fn default() -> Self {
        Self { len: 0, stripe_len: 1 }
    }
}

/// Frontier-width buckets (items per BFS level) for the `obs-fine`
/// histogram: how much parallelism each level actually exposes.
#[cfg(feature = "obs-fine")]
const FRONTIER_LEVEL_BUCKETS: &[f64] = &[
    1.0, 4.0, 16.0, 64.0, 256.0, 1024.0, 4096.0, 16384.0, 65536.0,
];

impl StripedFrontier {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stripes(&self) -> Stripes {
        self.stripes
    }

    /// The balance/commit tuning for subsequent runs.  Sticky across
    /// `reset`; defaults to fixed stripes + the parity two-pass.
    pub fn set_tuning(&mut self, tuning: ParTuning) {
        self.tuning = tuning;
    }

    pub fn tuning(&self) -> ParTuning {
        self.tuning
    }

    /// Number of weighted boundary re-cuts performed since the last
    /// `take_rebalances` (0 in `Fixed` mode), drained for telemetry.
    pub fn take_rebalances(&mut self) -> u64 {
        std::mem::take(&mut self.rebalances)
    }

    /// Rebind to a partition and clear every queue/outbox (buffers are
    /// kept when the stripe count is unchanged).  Boundaries start
    /// uniform; `Weighted` runs re-cut them level by level.
    pub fn reset(&mut self, stripes: Stripes) {
        self.stripes = stripes;
        self.cuts = StripeCuts::uniform(stripes);
        let ns = stripes.n_stripes();
        self.current.iter_mut().for_each(Vec::clear);
        self.next.iter_mut().for_each(Vec::clear);
        self.outbox.iter_mut().for_each(Vec::clear);
        self.current.resize_with(ns, Vec::new);
        self.next.resize_with(ns, Vec::new);
        self.outbox.resize_with(ns * ns, Vec::new);
        self.counts.clear();
        self.counts.resize(ns, 0);
    }

    /// Enqueue a seed item for level 0 of the run.  The caller must
    /// have already assigned its distance (all seeds share one level).
    pub fn seed(&mut self, idx: usize) {
        let o = self.cuts.owner(idx);
        self.current[o].push(idx as u32);
    }

    /// Run the BFS to exhaustion.  `dist` is the distance plane
    /// (`-1` = unassigned); seeds carry `seed_level` and every item
    /// discovered `r` rounds later gets `seed_level + r`.  `neighbours`
    /// receives an item and an emit callback and must emit every raw
    /// candidate (the substrate dedupes against `dist`).  `skip` names
    /// an item that is assigned a distance but never expanded (the
    /// source node in the reverse-residual BFS).  Returns the number of
    /// distance assignments made (seeds not included).
    pub fn run<F>(
        &mut self,
        dist: &mut [i32],
        seed_level: i32,
        skip: Option<usize>,
        neighbours: &F,
        lanes: &Lanes<'_>,
    ) -> u64
    where
        F: Fn(usize, &mut dyn FnMut(usize)) + Sync,
    {
        let ns = self.cuts.n_stripes();
        debug_assert_eq!(dist.len(), self.stripes.len());
        let width = lanes.width();
        let mut level = seed_level;
        loop {
            if self.current.iter().all(|q| q.is_empty()) {
                break;
            }
            // `obs-fine` only: one histogram observation per BFS level
            // (a registry lookup per level would be visible in the
            // striped-relabel micro-benches, so it is off by default).
            #[cfg(feature = "obs-fine")]
            crate::obs::global()
                .histogram("flowmatch_frontier_level_items", FRONTIER_LEVEL_BUCKETS)
                .observe(self.current.iter().map(Vec::len).sum::<usize>() as f64);
            let next_level = level + 1;

            // --- Expand: parallel over producer stripes ------------------
            {
                let cuts = &self.cuts;
                let mut tasks = Vec::with_capacity(ns);
                let iter = self
                    .current
                    .iter_mut()
                    .zip(self.next.iter_mut())
                    .zip(self.outbox.chunks_mut(ns))
                    .zip(cuts.split_mut(dist))
                    .zip(self.counts.iter_mut())
                    .enumerate();
                for (s, ((((cur, nxt), row), dist), count)) in iter {
                    tasks.push(ExpandTask {
                        base: cuts.start(s),
                        cuts,
                        cur,
                        nxt,
                        row,
                        dist,
                        count,
                    });
                }
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                for group in deal(tasks, width) {
                    jobs.push(Box::new(move || {
                        for task in group {
                            let ExpandTask {
                                base,
                                cuts,
                                cur,
                                nxt,
                                row,
                                dist,
                                count,
                            } = task;
                            let end = base + dist.len();
                            let mut emit = |v: usize| {
                                if v >= base && v < end {
                                    let lv = v - base;
                                    if dist[lv] < 0 {
                                        dist[lv] = next_level;
                                        *count += 1;
                                        if skip != Some(v) {
                                            nxt.push(v as u32);
                                        }
                                    }
                                } else {
                                    row[cuts.owner(v)].push(v as u32);
                                }
                            };
                            for &u in cur.iter() {
                                neighbours(u as usize, &mut emit);
                            }
                            cur.clear();
                        }
                    }));
                }
                lanes.run(jobs);
            }

            // --- Commit: owners drain their outbox columns ---------------
            // Writes stay owner-exclusive in either mode; `TwoPass` is
            // the parity-coloured even-then-odd oracle protocol,
            // `Merged` runs every owner in one batch (one barrier).
            {
                let outbox = &self.outbox;
                let cuts = &self.cuts;
                let mut tasks = Vec::with_capacity(ns);
                let iter = self
                    .next
                    .iter_mut()
                    .zip(cuts.split_mut(dist))
                    .zip(self.counts.iter_mut())
                    .enumerate();
                for (o, ((nxt, dist), count)) in iter {
                    tasks.push(CommitTask {
                        owner: o,
                        base: cuts.start(o),
                        nxt,
                        dist,
                        count,
                    });
                }
                let passes: Vec<Vec<CommitTask<'_>>> = match self.tuning.commit {
                    CommitMode::Merged => vec![tasks],
                    CommitMode::TwoPass => {
                        let (even, odd): (Vec<_>, Vec<_>) =
                            tasks.into_iter().partition(|t| t.owner % 2 == 0);
                        vec![even, odd]
                    }
                };
                for pass in passes {
                    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                    for group in deal(pass, width) {
                        jobs.push(Box::new(move || {
                            for task in group {
                                for p in 0..ns {
                                    for &v in &outbox[p * ns + task.owner] {
                                        let lv = v as usize - task.base;
                                        if task.dist[lv] < 0 {
                                            task.dist[lv] = next_level;
                                            *task.count += 1;
                                            if skip != Some(v as usize) {
                                                task.nxt.push(v);
                                            }
                                        }
                                    }
                                }
                            }
                        }));
                    }
                    lanes.run(jobs);
                }
            }

            for b in &mut self.outbox {
                b.clear();
            }
            std::mem::swap(&mut self.current, &mut self.next);
            if self.tuning.balance == StripeBalance::Weighted && ns > 1 {
                self.rebalance_level();
            }
            level = next_level;
        }
        let total = self.counts.iter().sum();
        self.counts.iter_mut().for_each(|c| *c = 0);
        total
    }

    /// Weighted mode, between levels: re-cut the boundaries from the
    /// next level's per-stripe queue sizes and re-deal queued items to
    /// their new owners.  Distances are untouched, so the BFS output is
    /// identical — only the partition of the coming level's work moves.
    fn rebalance_level(&mut self) {
        self.weights.clear();
        self.weights
            .extend(self.current.iter().map(|q| q.len() as u64));
        let new_cuts = self.cuts.rebalance(&self.weights, 1);
        if new_cuts == self.cuts {
            return;
        }
        self.redeal.clear();
        for q in &mut self.current {
            self.redeal.extend_from_slice(q);
            q.clear();
        }
        self.cuts = new_cuts;
        self.rebalances += 1;
        let redeal = std::mem::take(&mut self.redeal);
        for &v in &redeal {
            self.current[self.cuts.owner(v as usize)].push(v);
        }
        self.redeal = redeal;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::pool::WorkerPool;
    use std::collections::VecDeque;

    /// Sequential oracle: queue BFS over an adjacency list.
    fn bfs_oracle(adj: &[Vec<usize>], seeds: &[usize], skip: Option<usize>) -> Vec<i32> {
        let mut dist = vec![-1i32; adj.len()];
        let mut q = VecDeque::new();
        for &s in seeds {
            dist[s] = 0;
            q.push_back(s);
        }
        while let Some(u) = q.pop_front() {
            for &v in &adj[u] {
                if dist[v] < 0 {
                    dist[v] = dist[u] + 1;
                    if skip != Some(v) {
                        q.push_back(v);
                    }
                }
            }
        }
        dist
    }

    fn ring_with_chords(n: usize) -> Vec<Vec<usize>> {
        let mut adj = vec![Vec::new(); n];
        for v in 0..n {
            adj[v].push((v + 1) % n);
            adj[(v + 1) % n].push(v);
            if v % 7 == 0 {
                adj[v].push((v + n / 2) % n);
            }
        }
        adj
    }

    fn run_striped(
        adj: &[Vec<usize>],
        seeds: &[usize],
        skip: Option<usize>,
        stripes: Stripes,
        tuning: ParTuning,
        lanes: &Lanes<'_>,
    ) -> (Vec<i32>, u64) {
        let mut dist = vec![-1i32; adj.len()];
        let mut fr = StripedFrontier::new();
        fr.set_tuning(tuning);
        fr.reset(stripes);
        for &s in seeds {
            dist[s] = 0;
            fr.seed(s);
        }
        let neigh = |u: usize, emit: &mut dyn FnMut(usize)| {
            for &v in &adj[u] {
                emit(v);
            }
        };
        let assigned = fr.run(&mut dist, 0, skip, &neigh, lanes);
        (dist, assigned)
    }

    fn all_tunings() -> Vec<ParTuning> {
        let mut out = Vec::new();
        for balance in [StripeBalance::Fixed, StripeBalance::Weighted] {
            for commit in [CommitMode::TwoPass, CommitMode::Merged] {
                out.push(ParTuning { balance, commit });
            }
        }
        out
    }

    #[test]
    fn matches_queue_bfs_across_stripe_counts_and_lanes() {
        let adj = ring_with_chords(97);
        let want = bfs_oracle(&adj, &[0, 40], None);
        let pool = WorkerPool::new(3);
        for n_stripes in [1, 2, 3, 5, 16, 97] {
            for lanes in [Lanes::Seq, Lanes::Scoped { threads: 3 }, Lanes::Pool(&pool)] {
                for tuning in all_tunings() {
                    let (dist, assigned) = run_striped(
                        &adj,
                        &[0, 40],
                        None,
                        Stripes::new(97, n_stripes),
                        tuning,
                        &lanes,
                    );
                    assert_eq!(dist, want, "stripes={n_stripes} tuning={tuning:?}");
                    let reach = want.iter().filter(|&&d| d >= 0).count() as u64;
                    assert_eq!(assigned + 2, reach, "stripes={n_stripes} tuning={tuning:?}");
                }
            }
        }
    }

    #[test]
    fn skip_is_assigned_but_not_expanded() {
        // 0 - 1 - 2 - 3 chain; skipping 1 cuts 2 and 3 off.
        let adj = vec![vec![1], vec![0, 2], vec![1, 3], vec![2]];
        let want = bfs_oracle(&adj, &[0], Some(1));
        assert_eq!(want, vec![0, 1, -1, -1]);
        for n_stripes in [1, 2, 4] {
            let (dist, _) = run_striped(
                &adj,
                &[0],
                Some(1),
                Stripes::new(4, n_stripes),
                ParTuning::default(),
                &Lanes::Seq,
            );
            assert_eq!(dist, want, "stripes={n_stripes}");
        }
    }

    #[test]
    fn cross_stripe_duplicates_dedupe_to_one_assignment() {
        // Two nodes in stripe 0 both point at the same node in stripe 1.
        let adj = vec![vec![2], vec![2], vec![]];
        for tuning in all_tunings() {
            let (dist, assigned) = run_striped(
                &adj,
                &[0, 1],
                None,
                Stripes::with_stripe_len(3, 2),
                tuning,
                &Lanes::Seq,
            );
            assert_eq!(dist, vec![0, 0, 1], "tuning={tuning:?}");
            assert_eq!(assigned, 1, "tuning={tuning:?}");
        }
    }

    #[test]
    fn weighted_runs_rebalance_on_skewed_frontiers() {
        // A long path starting in stripe 0 keeps the whole frontier in
        // one uniform stripe; weighted mode must re-cut at least once
        // and still match the oracle.
        let n = 64;
        let mut adj = vec![Vec::new(); n];
        for v in 0..n - 1 {
            adj[v].push(v + 1);
            adj[v + 1].push(v);
        }
        let want = bfs_oracle(&adj, &[0], None);
        let mut dist = vec![-1i32; n];
        let mut fr = StripedFrontier::new();
        fr.set_tuning(ParTuning {
            balance: StripeBalance::Weighted,
            commit: CommitMode::Merged,
        });
        fr.reset(Stripes::new(n, 4));
        dist[0] = 0;
        fr.seed(0);
        let neigh = |u: usize, emit: &mut dyn FnMut(usize)| {
            for &v in &adj[u] {
                emit(v);
            }
        };
        fr.run(&mut dist, 0, None, &neigh, &Lanes::Scoped { threads: 3 });
        assert_eq!(dist, want);
        assert!(fr.take_rebalances() > 0, "skewed frontier never re-cut");
        assert_eq!(fr.take_rebalances(), 0, "take must drain");
    }

    #[test]
    fn stripes_geometry() {
        let s = Stripes::rows(10, 4, 3);
        assert_eq!(s.len(), 40);
        assert_eq!(s.stripe_len(), 16); // 4 rows per stripe
        assert_eq!(s.n_stripes(), 3);
        assert_eq!(s.owner(0), 0);
        assert_eq!(s.owner(16), 1);
        assert_eq!(s.owner(39), 2);
        let s = Stripes::new(7, 16);
        assert_eq!(s.stripe_len(), 1);
        assert_eq!(s.n_stripes(), 7);
    }

    #[test]
    fn uniform_cuts_match_chunks_mut_boundaries() {
        for (len, ts) in [(40, 3), (7, 16), (97, 5), (1, 1)] {
            let s = Stripes::new(len, ts);
            let cuts = s.cuts();
            assert_eq!(cuts.n_stripes(), s.n_stripes());
            let mut data = vec![0u8; len];
            let chunk_lens: Vec<usize> =
                data.chunks_mut(s.stripe_len()).map(|c| c.len()).collect();
            let cut_lens: Vec<usize> = (0..cuts.n_stripes()).map(|i| cuts.range(i).len()).collect();
            assert_eq!(cut_lens, chunk_lens, "len={len} ts={ts}");
            for idx in 0..len {
                assert_eq!(cuts.owner(idx), s.owner(idx), "len={len} ts={ts} idx={idx}");
            }
        }
    }

    #[test]
    fn rebalance_equalises_weight_and_respects_alignment() {
        // All weight in the first of four stripes of 4 rows x 8 cols.
        let s = Stripes::rows(16, 8, 4);
        let cuts = s.cuts();
        let balanced = cuts.rebalance(&[80, 0, 0, 0], 8);
        assert_eq!(balanced.n_stripes(), 4);
        assert_eq!(balanced.bounds()[0], 0);
        assert_eq!(*balanced.bounds().last().unwrap(), 128);
        for w in balanced.bounds() {
            assert_eq!(w % 8, 0, "cut {w} not row-aligned");
        }
        // The loaded first uniform stripe (items 0..32) is split across
        // the new stripes: every interior cut lands inside it.
        for &b in &balanced.bounds()[1..3] {
            assert!(b <= 32, "cut {b} outside the loaded region");
        }
        // Weight spread evenly: interior cuts at 8, 16, 24.
        assert_eq!(balanced.bounds(), &[0, 8, 16, 24, 128]);
        // Ownership stays a partition.
        for idx in 0..128 {
            let o = balanced.owner(idx);
            assert!(balanced.range(o).contains(&idx));
        }
        // Zero weight: unchanged.
        assert_eq!(cuts.rebalance(&[0, 0, 0, 0], 8), cuts);
    }

    #[test]
    fn split_mut_follows_cuts() {
        let s = Stripes::new(10, 3);
        let cuts = s.cuts().rebalance(&[6, 2, 2], 1);
        let mut data: Vec<u32> = (0..10).collect();
        let total: usize = cuts.split_mut(&mut data).iter().map(|c| c.len()).sum();
        assert_eq!(total, 10);
        let chunks = cuts.split_mut(&mut data);
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(c.len(), cuts.range(i).len());
            if !c.is_empty() {
                assert_eq!(c[0] as usize, cuts.start(i));
            }
        }
    }
}
