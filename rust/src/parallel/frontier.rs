//! The stripe-parallel frontier substrate.
//!
//! [`Stripes`] partitions `0..len` item indices (grid cells in row-major
//! order, CSR node ids) into contiguous equal-length ranges, so every
//! per-item array can be lent to workers as disjoint `chunks_mut`
//! slices — the same trick the tiled wave engine uses for its state
//! planes.
//!
//! [`StripedFrontier`] runs a level-synchronous multi-source BFS over
//! that partition.  Each level is two (logically; three with the parity
//! split) barriers:
//!
//! 1. **Expand** — every stripe drains its local queue, calling the
//!    caller's neighbour closure per item.  Targets inside the owning
//!    stripe are committed immediately (distance set, queued for the
//!    next level); targets in a foreign stripe go to a per-(producer ×
//!    owner) outbox — no shared writes anywhere.
//! 2. **Commit** — the parity-coloured two-pass: stripes of even index
//!    drain the outbox columns addressed to them, then the odd stripes.
//!    Only the owner ever writes its distance chunk or queue, so both
//!    passes are race-free; the parity split mirrors the border
//!    reconciliation protocol of `gridflow::par_wave` (even tiles then
//!    odd tiles own their borders) so the two layers share one shape.
//!
//! Bit-exactness with a sequential queue BFS is structural: BFS
//! distances are the unique shortest-path distances from the seed set,
//! independent of visit order, and duplicate candidates are deduped by
//! the owner's distance check.  The differential tests in
//! `gridflow::host`, `maxflow::global_relabel`, and
//! `tests/prop_par_wave.rs` pin this for every consumer.

use super::{deal, Lanes};

/// A contiguous partition of `0..len` into equal-length stripes (the
/// last stripe may be ragged).  `stripe_len` is the chunk size every
/// parallel pass feeds to `chunks_mut`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stripes {
    len: usize,
    stripe_len: usize,
}

impl Stripes {
    /// Partition `len` items into (about) `target_stripes` stripes.
    pub fn new(len: usize, target_stripes: usize) -> Self {
        let stripe_len = len.div_ceil(target_stripes.max(1)).max(1);
        Self { len, stripe_len }
    }

    /// Partition a `rows x width` row-major grid on row boundaries:
    /// about `target_stripes` stripes of whole rows — the same shape as
    /// the wave engine's row-stripe tiles.
    pub fn rows(rows: usize, width: usize, target_stripes: usize) -> Self {
        let stripe_rows = rows.div_ceil(target_stripes.max(1)).max(1);
        Self {
            len: rows * width,
            stripe_len: (stripe_rows * width).max(1),
        }
    }

    /// An explicit stripe length (e.g. the wave engine's
    /// `tile_rows * width`).
    pub fn with_stripe_len(len: usize, stripe_len: usize) -> Self {
        Self {
            len,
            stripe_len: stripe_len.max(1),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn stripe_len(&self) -> usize {
        self.stripe_len
    }

    pub fn n_stripes(&self) -> usize {
        self.len.div_ceil(self.stripe_len)
    }

    /// Which stripe owns item `idx`.
    #[inline]
    pub fn owner(&self, idx: usize) -> usize {
        idx / self.stripe_len
    }
}

struct ExpandTask<'a> {
    base: usize,
    cur: &'a mut Vec<u32>,
    nxt: &'a mut Vec<u32>,
    /// This producer's outbox row: one box per owner stripe.
    row: &'a mut [Vec<u32>],
    dist: &'a mut [i32],
    count: &'a mut u64,
}

struct CommitTask<'a> {
    owner: usize,
    base: usize,
    nxt: &'a mut Vec<u32>,
    dist: &'a mut [i32],
    count: &'a mut u64,
}

/// Reusable level-synchronous BFS state: per-stripe current/next
/// queues, the (producer × owner) outboxes, and per-stripe assignment
/// counters.  Allocations survive across `reset` calls, so a solve
/// pays for the queues once.
#[derive(Debug, Default)]
pub struct StripedFrontier {
    stripes: Stripes,
    current: Vec<Vec<u32>>,
    next: Vec<Vec<u32>>,
    /// Producer-major: `outbox[p * n_stripes + o]` holds targets stripe
    /// `p` discovered that stripe `o` owns.
    outbox: Vec<Vec<u32>>,
    counts: Vec<u64>,
}

impl Default for Stripes {
    fn default() -> Self {
        Self { len: 0, stripe_len: 1 }
    }
}

/// Frontier-width buckets (items per BFS level) for the `obs-fine`
/// histogram: how much parallelism each level actually exposes.
#[cfg(feature = "obs-fine")]
const FRONTIER_LEVEL_BUCKETS: &[f64] = &[
    1.0, 4.0, 16.0, 64.0, 256.0, 1024.0, 4096.0, 16384.0, 65536.0,
];

impl StripedFrontier {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stripes(&self) -> Stripes {
        self.stripes
    }

    /// Rebind to a partition and clear every queue/outbox (buffers are
    /// kept when the stripe count is unchanged).
    pub fn reset(&mut self, stripes: Stripes) {
        self.stripes = stripes;
        let ns = stripes.n_stripes();
        self.current.iter_mut().for_each(Vec::clear);
        self.next.iter_mut().for_each(Vec::clear);
        self.outbox.iter_mut().for_each(Vec::clear);
        self.current.resize_with(ns, Vec::new);
        self.next.resize_with(ns, Vec::new);
        self.outbox.resize_with(ns * ns, Vec::new);
        self.counts.clear();
        self.counts.resize(ns, 0);
    }

    /// Enqueue a seed item for level 0 of the run.  The caller must
    /// have already assigned its distance (all seeds share one level).
    pub fn seed(&mut self, idx: usize) {
        let o = self.stripes.owner(idx);
        self.current[o].push(idx as u32);
    }

    /// Run the BFS to exhaustion.  `dist` is the distance plane
    /// (`-1` = unassigned); seeds carry `seed_level` and every item
    /// discovered `r` rounds later gets `seed_level + r`.  `neighbours`
    /// receives an item and an emit callback and must emit every raw
    /// candidate (the substrate dedupes against `dist`).  `skip` names
    /// an item that is assigned a distance but never expanded (the
    /// source node in the reverse-residual BFS).  Returns the number of
    /// distance assignments made (seeds not included).
    pub fn run<F>(
        &mut self,
        dist: &mut [i32],
        seed_level: i32,
        skip: Option<usize>,
        neighbours: &F,
        lanes: &Lanes<'_>,
    ) -> u64
    where
        F: Fn(usize, &mut dyn FnMut(usize)) + Sync,
    {
        let ns = self.stripes.n_stripes();
        let sl = self.stripes.stripe_len();
        debug_assert_eq!(dist.len(), self.stripes.len());
        let width = lanes.width();
        let mut level = seed_level;
        loop {
            if self.current.iter().all(|q| q.is_empty()) {
                break;
            }
            // `obs-fine` only: one histogram observation per BFS level
            // (a registry lookup per level would be visible in the
            // striped-relabel micro-benches, so it is off by default).
            #[cfg(feature = "obs-fine")]
            crate::obs::global()
                .histogram("flowmatch_frontier_level_items", FRONTIER_LEVEL_BUCKETS)
                .observe(self.current.iter().map(Vec::len).sum::<usize>() as f64);
            let next_level = level + 1;

            // --- Expand: parallel over producer stripes ------------------
            {
                let mut tasks = Vec::with_capacity(ns);
                let iter = self
                    .current
                    .iter_mut()
                    .zip(self.next.iter_mut())
                    .zip(self.outbox.chunks_mut(ns))
                    .zip(dist.chunks_mut(sl))
                    .zip(self.counts.iter_mut())
                    .enumerate();
                for (s, ((((cur, nxt), row), dist), count)) in iter {
                    tasks.push(ExpandTask {
                        base: s * sl,
                        cur,
                        nxt,
                        row,
                        dist,
                        count,
                    });
                }
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                for group in deal(tasks, width) {
                    jobs.push(Box::new(move || {
                        for task in group {
                            let ExpandTask {
                                base,
                                cur,
                                nxt,
                                row,
                                dist,
                                count,
                            } = task;
                            let end = base + dist.len();
                            let mut emit = |v: usize| {
                                if v >= base && v < end {
                                    let lv = v - base;
                                    if dist[lv] < 0 {
                                        dist[lv] = next_level;
                                        *count += 1;
                                        if skip != Some(v) {
                                            nxt.push(v as u32);
                                        }
                                    }
                                } else {
                                    row[v / sl].push(v as u32);
                                }
                            };
                            for &u in cur.iter() {
                                neighbours(u as usize, &mut emit);
                            }
                            cur.clear();
                        }
                    }));
                }
                lanes.run(jobs);
            }

            // --- Commit: the parity-coloured two-pass --------------------
            // Owners drain the outbox columns addressed to them — even
            // stripes first, then odd.  Writes stay owner-exclusive.
            {
                let outbox = &self.outbox;
                let mut even = Vec::new();
                let mut odd = Vec::new();
                let iter = self
                    .next
                    .iter_mut()
                    .zip(dist.chunks_mut(sl))
                    .zip(self.counts.iter_mut())
                    .enumerate();
                for (o, ((nxt, dist), count)) in iter {
                    let task = CommitTask {
                        owner: o,
                        base: o * sl,
                        nxt,
                        dist,
                        count,
                    };
                    if o % 2 == 0 {
                        even.push(task);
                    } else {
                        odd.push(task);
                    }
                }
                for pass in [even, odd] {
                    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                    for group in deal(pass, width) {
                        jobs.push(Box::new(move || {
                            for task in group {
                                for p in 0..ns {
                                    for &v in &outbox[p * ns + task.owner] {
                                        let lv = v as usize - task.base;
                                        if task.dist[lv] < 0 {
                                            task.dist[lv] = next_level;
                                            *task.count += 1;
                                            if skip != Some(v as usize) {
                                                task.nxt.push(v);
                                            }
                                        }
                                    }
                                }
                            }
                        }));
                    }
                    lanes.run(jobs);
                }
            }

            for b in &mut self.outbox {
                b.clear();
            }
            std::mem::swap(&mut self.current, &mut self.next);
            level = next_level;
        }
        let total = self.counts.iter().sum();
        self.counts.iter_mut().for_each(|c| *c = 0);
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::pool::WorkerPool;
    use std::collections::VecDeque;

    /// Sequential oracle: queue BFS over an adjacency list.
    fn bfs_oracle(adj: &[Vec<usize>], seeds: &[usize], skip: Option<usize>) -> Vec<i32> {
        let mut dist = vec![-1i32; adj.len()];
        let mut q = VecDeque::new();
        for &s in seeds {
            dist[s] = 0;
            q.push_back(s);
        }
        while let Some(u) = q.pop_front() {
            for &v in &adj[u] {
                if dist[v] < 0 {
                    dist[v] = dist[u] + 1;
                    if skip != Some(v) {
                        q.push_back(v);
                    }
                }
            }
        }
        dist
    }

    fn ring_with_chords(n: usize) -> Vec<Vec<usize>> {
        let mut adj = vec![Vec::new(); n];
        for v in 0..n {
            adj[v].push((v + 1) % n);
            adj[(v + 1) % n].push(v);
            if v % 7 == 0 {
                adj[v].push((v + n / 2) % n);
            }
        }
        adj
    }

    fn run_striped(
        adj: &[Vec<usize>],
        seeds: &[usize],
        skip: Option<usize>,
        stripes: Stripes,
        lanes: &Lanes<'_>,
    ) -> (Vec<i32>, u64) {
        let mut dist = vec![-1i32; adj.len()];
        let mut fr = StripedFrontier::new();
        fr.reset(stripes);
        for &s in seeds {
            dist[s] = 0;
            fr.seed(s);
        }
        let neigh = |u: usize, emit: &mut dyn FnMut(usize)| {
            for &v in &adj[u] {
                emit(v);
            }
        };
        let assigned = fr.run(&mut dist, 0, skip, &neigh, lanes);
        (dist, assigned)
    }

    #[test]
    fn matches_queue_bfs_across_stripe_counts_and_lanes() {
        let adj = ring_with_chords(97);
        let want = bfs_oracle(&adj, &[0, 40], None);
        let pool = WorkerPool::new(3);
        for n_stripes in [1, 2, 3, 5, 16, 97] {
            for lanes in [Lanes::Seq, Lanes::Scoped { threads: 3 }, Lanes::Pool(&pool)] {
                let (dist, assigned) =
                    run_striped(&adj, &[0, 40], None, Stripes::new(97, n_stripes), &lanes);
                assert_eq!(dist, want, "stripes={n_stripes}");
                let reach = want.iter().filter(|&&d| d >= 0).count() as u64;
                assert_eq!(assigned + 2, reach, "stripes={n_stripes}");
            }
        }
    }

    #[test]
    fn skip_is_assigned_but_not_expanded() {
        // 0 - 1 - 2 - 3 chain; skipping 1 cuts 2 and 3 off.
        let adj = vec![vec![1], vec![0, 2], vec![1, 3], vec![2]];
        let want = bfs_oracle(&adj, &[0], Some(1));
        assert_eq!(want, vec![0, 1, -1, -1]);
        for n_stripes in [1, 2, 4] {
            let (dist, _) = run_striped(&adj, &[0], Some(1), Stripes::new(4, n_stripes), &Lanes::Seq);
            assert_eq!(dist, want, "stripes={n_stripes}");
        }
    }

    #[test]
    fn cross_stripe_duplicates_dedupe_to_one_assignment() {
        // Two nodes in stripe 0 both point at the same node in stripe 1.
        let adj = vec![vec![2], vec![2], vec![]];
        let (dist, assigned) =
            run_striped(&adj, &[0, 1], None, Stripes::with_stripe_len(3, 2), &Lanes::Seq);
        assert_eq!(dist, vec![0, 0, 1]);
        assert_eq!(assigned, 1);
    }

    #[test]
    fn stripes_geometry() {
        let s = Stripes::rows(10, 4, 3);
        assert_eq!(s.len(), 40);
        assert_eq!(s.stripe_len(), 16); // 4 rows per stripe
        assert_eq!(s.n_stripes(), 3);
        assert_eq!(s.owner(0), 0);
        assert_eq!(s.owner(16), 1);
        assert_eq!(s.owner(39), 2);
        let s = Stripes::new(7, 16);
        assert_eq!(s.stripe_len(), 1);
        assert_eq!(s.n_stripes(), 7);
    }
}
