//! Size-class sharded queues with admission control and backpressure.
//!
//! Requests are classified by *work units* (cost-matrix cells for
//! assignment, grid cells for max-flow) into three shards so a 512²
//! grid solve never sits in front of an n=30 real-time matching.  Each
//! shard is a bounded FIFO: when a shard is at depth the submit is
//! rejected synchronously with a [`RejectReason`] instead of queueing
//! unboundedly — the caller sheds load rather than timing out.
//!
//! Scheduling is by per-worker scan order (see [`scan_order`]): with two
//! or more workers, worker 0 is the reserved real-time lane (it never
//! picks up a Large job) and worker 1 prefers Large, so both tails of
//! the size distribution always have a worker whose first look is them.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::workloads::ProblemInstance;

use super::{ReplyError, SolveReply};

/// The three shard classes, by work units.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SizeClass {
    Small,
    Medium,
    Large,
}

impl SizeClass {
    pub const ALL: [SizeClass; 3] = [SizeClass::Small, SizeClass::Medium, SizeClass::Large];

    #[inline]
    pub fn index(self) -> usize {
        match self {
            SizeClass::Small => 0,
            SizeClass::Medium => 1,
            SizeClass::Large => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SizeClass::Small => "small",
            SizeClass::Medium => "medium",
            SizeClass::Large => "large",
        }
    }
}

/// Sharding + admission parameters.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Work-unit ceiling of the Small class — the real-time lane
    /// (default 2048: matchings up to n = 45, grids up to 45²; the
    /// paper's §6 workload of n ≤ 30 lands here with room to spare,
    /// while any grid a solver would take visible time on does not).
    pub small_max_units: usize,
    /// Work-unit ceiling of the Medium class (default 8192: ≤ 90²
    /// grids); anything above is Large.
    pub medium_max_units: usize,
    /// Bounded per-shard queue depth; a full shard rejects.  Clamped
    /// to ≥ 1 by the queues (a 0-depth shard could never admit, which
    /// would turn closed-loop pacing into a spin).
    pub queue_depth: usize,
    /// Admission cap: instances above this many work units are rejected
    /// outright (default 1 << 20: 1024² grids).
    pub max_units: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self {
            small_max_units: 2048,
            medium_max_units: 8192,
            queue_depth: 64,
            max_units: 1 << 20,
        }
    }
}

impl ShardConfig {
    pub fn classify(&self, units: usize) -> SizeClass {
        if units <= self.small_max_units {
            SizeClass::Small
        } else if units <= self.medium_max_units {
            SizeClass::Medium
        } else {
            SizeClass::Large
        }
    }
}

/// Why a submit was refused.  Every rejection is synchronous and
/// carries enough context for the client to adapt (shrink, retry
/// later, or route elsewhere).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    /// The shard for this size class is at its bounded depth.
    QueueFull { class: SizeClass, depth: usize },
    /// The instance exceeds the admission cap.
    TooLarge { units: usize, max_units: usize },
    /// The request's deadline passed before a worker picked it up, so
    /// the solve was shed instead of burning a worker on a result the
    /// client has already given up on.
    DeadlineExceeded,
    /// The pool is shutting down.
    ShuttingDown,
}

impl RejectReason {
    /// Short stable tag for breakdown tables ("queue-full=3 too-large=1"
    /// in the loadgen summary); the `Display` impl carries the detail.
    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::QueueFull { .. } => "queue-full",
            RejectReason::TooLarge { .. } => "too-large",
            RejectReason::DeadlineExceeded => "deadline",
            RejectReason::ShuttingDown => "shutting-down",
        }
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::QueueFull { class, depth } => write!(
                f,
                "queue full: {} shard at bounded depth {depth} (backpressure)",
                class.name()
            ),
            RejectReason::TooLarge { units, max_units } => write!(
                f,
                "instance too large: {units} work units exceed the admission cap {max_units}"
            ),
            RejectReason::DeadlineExceeded => {
                write!(f, "deadline exceeded before dispatch (request shed)")
            }
            RejectReason::ShuttingDown => write!(f, "solver pool is shutting down"),
        }
    }
}

/// A queued request, owned by a shard until a worker pops it.
pub(crate) struct QueuedJob {
    pub id: u64,
    pub class: SizeClass,
    pub instance: ProblemInstance,
    pub submitted: Instant,
    /// Absolute deadline; a worker that pops the job after this instant
    /// sheds it with [`RejectReason::DeadlineExceeded`], and a solve in
    /// flight past it is cancelled at the next poll point.
    pub deadline: Option<Instant>,
    pub reply: std::sync::mpsc::Sender<Result<SolveReply, ReplyError>>,
}

struct State {
    queues: [VecDeque<QueuedJob>; 3],
    shutdown: bool,
}

/// The three bounded shard queues plus the worker wakeup condvar.
pub(crate) struct ShardedQueues {
    cfg: ShardConfig,
    state: Mutex<State>,
    cv: Condvar,
}

/// Which shards worker `worker` scans, in preference order.
///
/// * 1 worker: everything, small first.
/// * ≥ 2 workers: worker 0 is the reserved real-time lane — it never
///   takes a Large job, so a small matching is at worst one Medium
///   solve away from service.  Worker 1 is the heavy lane (Large
///   first), so Large jobs cannot starve either.  Remaining workers
///   alternate small-first / medium-first for load balance.
pub(crate) fn scan_order(worker: usize, workers: usize) -> &'static [SizeClass] {
    use SizeClass::*;
    if workers <= 1 {
        return &[Small, Medium, Large];
    }
    match worker {
        0 => &[Small, Medium],
        1 => &[Large, Medium, Small],
        w if w % 2 == 0 => &[Small, Medium, Large],
        _ => &[Medium, Small, Large],
    }
}

impl ShardedQueues {
    pub fn new(mut cfg: ShardConfig) -> Self {
        cfg.queue_depth = cfg.queue_depth.max(1);
        Self {
            cfg,
            state: Mutex::new(State {
                queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn config(&self) -> &ShardConfig {
        &self.cfg
    }

    /// Admit `job` into its shard, or hand it back with the reason.
    pub fn push(&self, job: QueuedJob) -> Result<(), (QueuedJob, RejectReason)> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err((job, RejectReason::ShuttingDown));
        }
        let q = &mut st.queues[job.class.index()];
        if q.len() >= self.cfg.queue_depth {
            let reason = RejectReason::QueueFull {
                class: job.class,
                depth: self.cfg.queue_depth,
            };
            return Err((job, reason));
        }
        q.push_back(job);
        drop(st);
        // notify_all: the woken worker must be one whose scan order
        // includes this shard (worker 0 never serves Large).
        self.cv.notify_all();
        Ok(())
    }

    /// Block until a job this worker may take is available; `None` once
    /// the pool is shutting down and this worker's shards are drained.
    pub fn pop(&self, worker: usize, workers: usize) -> Option<QueuedJob> {
        let order = scan_order(worker, workers);
        let mut st = self.state.lock().unwrap();
        loop {
            for &class in order {
                if let Some(job) = st.queues[class.index()].pop_front() {
                    return Some(job);
                }
            }
            if st.shutdown {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Begin shutdown: no new admissions; workers drain then exit.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    #[cfg(test)]
    pub fn depth(&self, class: SizeClass) -> usize {
        self.state.lock().unwrap().queues[class.index()].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::AssignmentInstance;

    fn job(class: SizeClass) -> QueuedJob {
        let (tx, _rx) = std::sync::mpsc::channel();
        QueuedJob {
            id: 0,
            class,
            instance: ProblemInstance::Assignment(AssignmentInstance::new(2, vec![0; 4])),
            submitted: Instant::now(),
            deadline: None,
            reply: tx,
        }
    }

    #[test]
    fn classification_boundaries() {
        let cfg = ShardConfig {
            small_max_units: 100,
            medium_max_units: 1000,
            ..Default::default()
        };
        assert_eq!(cfg.classify(1), SizeClass::Small);
        assert_eq!(cfg.classify(100), SizeClass::Small);
        assert_eq!(cfg.classify(101), SizeClass::Medium);
        assert_eq!(cfg.classify(1000), SizeClass::Medium);
        assert_eq!(cfg.classify(1001), SizeClass::Large);
    }

    #[test]
    fn bounded_depth_rejects() {
        let q = ShardedQueues::new(ShardConfig {
            queue_depth: 2,
            ..Default::default()
        });
        assert!(q.push(job(SizeClass::Small)).is_ok());
        assert!(q.push(job(SizeClass::Small)).is_ok());
        let (_, reason) = q.push(job(SizeClass::Small)).unwrap_err();
        assert_eq!(
            reason,
            RejectReason::QueueFull {
                class: SizeClass::Small,
                depth: 2
            }
        );
        // Other shards are independent.
        assert!(q.push(job(SizeClass::Large)).is_ok());
        assert_eq!(q.depth(SizeClass::Small), 2);
        assert_eq!(q.depth(SizeClass::Large), 1);
    }

    #[test]
    fn shutdown_rejects_new_and_drains_old() {
        let q = ShardedQueues::new(ShardConfig::default());
        assert!(q.push(job(SizeClass::Medium)).is_ok());
        q.shutdown();
        let (_, reason) = q.push(job(SizeClass::Small)).unwrap_err();
        assert_eq!(reason, RejectReason::ShuttingDown);
        // The queued job is still drained...
        assert!(q.pop(0, 1).is_some());
        // ...then workers see the shutdown.
        assert!(q.pop(0, 1).is_none());
    }

    #[test]
    fn reserved_lane_never_scans_large() {
        assert!(!scan_order(0, 4).contains(&SizeClass::Large));
        assert_eq!(scan_order(1, 4)[0], SizeClass::Large);
        assert_eq!(scan_order(0, 1), &SizeClass::ALL[..]);
        for w in 0..8 {
            assert!(scan_order(w, 8).contains(&SizeClass::Small));
        }
    }

    #[test]
    fn pop_prefers_small_on_lane_zero() {
        let q = ShardedQueues::new(ShardConfig::default());
        q.push(job(SizeClass::Medium)).unwrap();
        q.push(job(SizeClass::Small)).unwrap();
        let got = q.pop(0, 2).unwrap();
        assert_eq!(got.class, SizeClass::Small);
        let got = q.pop(0, 2).unwrap();
        assert_eq!(got.class, SizeClass::Medium);
    }

    #[test]
    fn zero_depth_clamped_to_one() {
        let q = ShardedQueues::new(ShardConfig {
            queue_depth: 0,
            ..Default::default()
        });
        assert!(q.push(job(SizeClass::Small)).is_ok());
        assert!(q.push(job(SizeClass::Small)).is_err());
    }

    #[test]
    fn default_boundaries_separate_the_demo_workloads() {
        let cfg = ShardConfig::default();
        assert_eq!(cfg.classify(30 * 30), SizeClass::Small); // §6 matchings
        assert_eq!(cfg.classify(48 * 48), SizeClass::Medium); // demo grids
        assert_eq!(cfg.classify(96 * 96), SizeClass::Large); // oversized grids
    }

    #[test]
    fn reject_reasons_render() {
        let full = RejectReason::QueueFull {
            class: SizeClass::Small,
            depth: 4,
        };
        assert!(full.to_string().contains("queue full"));
        assert_eq!(full.label(), "queue-full");
        let large = RejectReason::TooLarge {
            units: 9,
            max_units: 4,
        };
        assert!(large.to_string().contains("too large"));
        assert_eq!(large.label(), "too-large");
        assert_eq!(RejectReason::ShuttingDown.label(), "shutting-down");
        assert_eq!(RejectReason::DeadlineExceeded.label(), "deadline");
        assert!(RejectReason::DeadlineExceeded
            .to_string()
            .contains("deadline exceeded"));
    }
}
